"""Multi-device tests. The shard_map executor needs >1 device, and jax locks
the host device count at first init — so these run in subprocesses with
XLA_FLAGS set (tests/_mesh.py; the same isolation dryrun.py uses)."""
from _mesh import run_in_mesh_subprocess


def _run(code: str, devices: int = 8, timeout: int = 600):
    return run_in_mesh_subprocess(code, devices=devices, timeout=timeout)


def test_shard_map_executor_matches_scipy():
    print(_run("""
        import numpy as np, jax
        from repro.core import apply_reordering, compile_plan, grow_local
        from repro.solver import solve_lower_scipy
        from repro.solver.distributed import run_distributed_solve
        from repro.sparse import dag_from_lower_csr, erdos_renyi_lower

        L = erdos_renyi_lower(800, 2e-3, seed=9)
        dag = dag_from_lower_csr(L)
        s = grow_local(dag, 4)
        L2, s2, _, _ = apply_reordering(L, s)
        plan = compile_plan(L2, s2)
        b = np.random.default_rng(1).standard_normal((2, 800))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        x = run_distributed_solve(plan, b, mesh)
        for i in range(2):
            ref = solve_lower_scipy(L2, b[i])
            err = np.abs(x[i] - ref).max() / np.abs(ref).max()
            assert err < 2e-3, err
        print("dist-ok", s2.n_supersteps)
    """))


def test_distributed_lowering_counts_barriers():
    """The lowered graph must contain exactly n_supersteps all-gather groups
    per tensor exchanged — GrowLocal's barrier reduction is visible in HLO."""
    print(_run("""
        import numpy as np, jax
        from repro.core import apply_reordering, compile_plan, grow_local
        from repro.solver.distributed import dist_plan_spec, lower_distributed_solve
        from repro.sparse import dag_from_lower_csr, narrow_band_lower

        L = narrow_band_lower(600, 0.14, 8, seed=2)
        dag = dag_from_lower_csr(L)
        s = grow_local(dag, 4)
        L2, s2, _, _ = apply_reordering(L, s)
        plan = compile_plan(L2, s2)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = dist_plan_spec(plan, batch=2)
        lowered = lower_distributed_solve(spec, mesh)
        txt = lowered.as_text()
        n_ag = txt.count("all_gather") + txt.count("all-gather")
        # 3 tensors exchanged per superstep (rows, values, accum flags)
        assert n_ag >= s2.n_supersteps, (n_ag, s2.n_supersteps)
        assert n_ag <= 4 * s2.n_supersteps, (n_ag, s2.n_supersteps)
        print("barriers-ok", s2.n_supersteps, n_ag)
    """))


def test_train_step_lowers_on_multidevice_mesh():
    """Reduced-config train step lowers + compiles on a (2, 2) mesh with the
    production sharding rules (miniature of the 512-chip dry-run)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.distributed.meshes import resolve_spec
        from repro.models import abstract_params, logical_specs, param_specs
        from repro.train import AdamWConfig, make_train_step
        from repro.train.train_loop import TrainState

        cfg = get_reduced("deepseek_moe_16b")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        specs = param_specs(cfg)
        logical = logical_specs(specs)
        abst = abstract_params(specs, dtype=jnp.float32)
        is_leaf = lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) or e is None for e in x)
        sds = jax.tree_util.tree_map(
            lambda log, a: jax.ShapeDtypeStruct(
                a.shape, a.dtype,
                sharding=jax.sharding.NamedSharding(
                    mesh, resolve_spec(mesh, log, a.shape))),
            logical, abst, is_leaf=is_leaf)
        f32 = lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32,
                                             sharding=a.sharding)
        state = TrainState(params=sds, opt_state={
            "mu": jax.tree_util.tree_map(f32, sds),
            "nu": jax.tree_util.tree_map(f32, sds),
            "step": jax.ShapeDtypeStruct((), jnp.int32)})
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }
        step = make_train_step(cfg, AdamWConfig(), microbatches=2)
        with mesh:
            compiled = jax.jit(step).lower(state, batch).compile()
        assert compiled.cost_analysis() is not None
        print("lower-ok")
    """))


def test_elastic_mesh_restore_multidevice(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh (elastic)."""
    print(_run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import restore_checkpoint, save_checkpoint

        mesh8 = jax.make_mesh((8,), ("data",))
        x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                           NamedSharding(mesh8, P("data")))
        tree = {{"w": x}}
        save_checkpoint(r"{tmp_path}/ck", tree, step=5)

        devs = np.array(jax.devices()[:4]).reshape(4)
        mesh4 = jax.sharding.Mesh(devs, ("data",))
        sh = {{"w": NamedSharding(mesh4, P("data"))}}
        restored, meta = restore_checkpoint(r"{tmp_path}/ck",
                                            template=tree, shardings=sh)
        assert meta["step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(x))
        assert restored["w"].sharding.mesh.shape["data"] == 4
        print("elastic-ok")
    """))
