"""Elastic execution mode (``mode="elastic"``, ``core.elastic``).

Three layers of guarantees:

* **Certificate invariants** — ``elastic_transform`` emits a staleness
  certificate (per-step readiness, wave ids inside each slack window,
  fused superstep bounds). The invariants checked here are exactly what
  the executors rely on: steps sharing a wave are mutually independent,
  a step's dependencies are all written in earlier macro-steps or
  earlier waves of the same macro-step, and partial-sum (accum) chains
  never share a wave with their consumer.
* **Bitwise conformance** — an elastic solve must equal the
  bulk-synchronous solve of the SAME backend bit for bit (the macro-step
  bodies replay the identical op sequence; waves only reorder provably
  independent steps). Fast subset in-process; the corpus x orientation x
  RHS x backend grid is ``slow``-marked.
* **Selection** — ``strategy="auto"`` turns elastic on exactly where the
  step-granular cost rule says it pays: deep-DAG regimes ("serial",
  "banded") on elastic-capable backends, never when ``mode="bsp"`` or
  on the distributed backend.
"""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.autotune import clear_selection_memo, corpus_entry, corpus_names
from repro.autotune.corpus import chain_lower
from repro.core import DEFAULT_SLACK, elastic_transform, step_dependencies
from repro.core.plan import compile_plan
from repro.pipeline import PlanCache, TriangularSolver, schedule
from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    narrow_band_lower,
    transpose_csr,
)

K = 8

# one cache for the module: bulk and elastic plans of a (matrix,
# orientation, backend) cell are shared across the RHS parametrization
_CACHE = PlanCache()


def _plan_for(L, slack):
    s = schedule(dag_from_lower_csr(L), K, strategy="growlocal")
    return compile_plan(L, s)


def _check_certificate(plan, ep):
    """The independence/staleness invariants the executors rely on."""
    T, slack = plan.n_steps, ep.slack
    assert ep.n_macro_steps == -(-T // slack)
    assert ep.n_steps == T
    # fused superstep bounds are a monotone cover of the superstep range
    fb = ep.fused_bounds
    assert fb[0] == 0 and fb[-1] == ep.n_supersteps
    assert np.all(np.diff(fb) >= 1)
    writer_step, _, _ = step_dependencies(plan)
    wave = ep.wave_id
    for t in range(T):
        m, j = divmod(t, slack)
        w = wave[m, j]
        assert 0 <= w < ep.n_waves[m]
        # readiness: every dependency is written strictly before this
        # step's wave opens — earlier macro-step, or earlier wave here
        assert ep.ready_step[t] <= t
        cols = plan.col_idx[t][~plan.accum[t]][:, :]
        for c in np.unique(cols):
            if c >= plan.n:  # scratch/padding gather
                continue
            ws = int(writer_step[c])
            if ws < 0:
                continue
            wm, wj = divmod(ws, slack)
            assert wm < m or (wm == m and wave[wm, wj] < w), (
                f"step {t} (wave {w}) reads row {c} written at step {ws}"
            )
        # accum chains: the carried partial sum is consumed by the NEXT
        # step, which must sit in a strictly later wave (or macro-step)
        if t + 1 < T and plan.accum[t].any():
            m2, j2 = divmod(t + 1, slack)
            assert m2 > m or wave[m2, j2] > w


@pytest.mark.parametrize(
    "make",
    [
        lambda: chain_lower(200, seed=1),
        lambda: narrow_band_lower(300, 0.14, 8, seed=2),
        lambda: erdos_renyi_lower(300, 0.03, seed=3),
    ],
    ids=["chain", "band", "er"],
)
@pytest.mark.parametrize("slack", [1, 3, 8])
def test_certificate_invariants(make, slack):
    plan = _plan_for(make(), slack)
    ep = elastic_transform(plan, slack)
    _check_certificate(plan, ep)
    st_ = ep.stats()
    assert st_["slack"] == slack
    assert st_["n_macro_steps"] == -(-plan.n_steps // slack)
    assert st_["step_fusion"] == pytest.approx(
        plan.n_steps / st_["n_macro_steps"]
    )


def test_slack_validation():
    plan = _plan_for(chain_lower(50, seed=4), 1)
    with pytest.raises(ValueError):
        elastic_transform(plan, 0)
    with pytest.raises(ValueError):
        TriangularSolver.plan(chain_lower(50, seed=4), mode="nope")
    with pytest.raises(ValueError):
        TriangularSolver.plan(chain_lower(50, seed=4), mode="bsp", slack=4)
    # distributed supports elastic now (fused exchange rounds) but still
    # requires a mesh at bind time
    with pytest.raises(ValueError):
        TriangularSolver.plan(
            chain_lower(50, seed=4), backend="distributed", mode="elastic"
        )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), slack=st.integers(1, 16))
def test_certificate_invariants_property(seed, slack):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 200))
    plan = _plan_for(erdos_renyi_lower(n, 0.05, seed=seed % 1000), slack)
    _check_certificate(plan, elastic_transform(plan, slack))


def test_certificate_invariants_seeded():
    """Deterministic stand-in for the property test (hypothesis is
    optional on this container — _hyp skips @given without it)."""
    rng = np.random.default_rng(20260808)
    for seed in rng.integers(0, 1000, size=5):
        slack = int(rng.integers(1, 17))
        plan = _plan_for(erdos_renyi_lower(150, 0.05, seed=int(seed)), slack)
        _check_certificate(plan, elastic_transform(plan, slack))


# ----------------------------------------------------------- bitwise fast
def _bitwise_cell(a, backend, lower, n_rhs, *, slack=None, cache=None):
    kw = {"interpret": True} if backend == "pallas" else {}
    bulk = TriangularSolver.plan(
        a, strategy="growlocal", k=K, lower=lower, backend=backend,
        cache=cache, **kw,
    )
    el = TriangularSolver.plan(
        a, strategy="growlocal", k=K, lower=lower, backend=backend,
        cache=cache, mode="elastic",
        **({} if slack is None else {"slack": slack}), **kw,
    )
    assert el.info()["mode"] == "elastic"
    rng = np.random.default_rng(7)
    n = a.n_rows
    b = rng.standard_normal((n, n_rhs)) if n_rhs > 1 else rng.standard_normal(n)
    xb = np.asarray(bulk.solve(b))
    xe = np.asarray(el.solve(b))
    assert xb.shape == xe.shape == b.shape
    assert np.array_equal(xb, xe), (
        f"elastic solve diverged from bulk on backend={backend} "
        f"lower={lower} n_rhs={n_rhs}"
    )


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize(
    "make",
    [
        lambda: chain_lower(200, seed=5),
        lambda: narrow_band_lower(400, 0.14, 8, seed=6),
        lambda: erdos_renyi_lower(300, 0.03, seed=7),
    ],
    ids=["chain", "band", "er"],
)
def test_elastic_bitwise_fast(make, backend):
    a = make()
    _bitwise_cell(a, backend, True, 1)
    _bitwise_cell(a, backend, True, 3)


@pytest.mark.parametrize("slack", [1, 2, 5, 16])
def test_elastic_bitwise_across_slack(slack):
    """The bound holds for ANY window size, not just the calibrated
    default — slack=1 degenerates to one step per macro-step."""
    a = narrow_band_lower(300, 0.14, 8, seed=8)
    _bitwise_cell(a, "scan", True, 1, slack=slack)


def test_elastic_update_values_bitwise():
    """Refactorization on the elastic binding: same gather contract, same
    bitwise guarantee as the bulk path."""
    import dataclasses

    a = narrow_band_lower(300, 0.14, 8, seed=9)
    rng = np.random.default_rng(10)
    a2 = dataclasses.replace(a, data=a.data * rng.uniform(0.5, 2.0, a.nnz))
    b = rng.standard_normal(a.n_rows)
    for backend in ("scan", "pallas"):
        kw = {"interpret": True} if backend == "pallas" else {}
        el = TriangularSolver.plan(
            a, strategy="growlocal", k=K, backend=backend, mode="elastic",
            **kw,
        )
        fresh = TriangularSolver.plan(
            a2, strategy="growlocal", k=K, backend=backend, mode="elastic",
            **kw,
        )
        el.numeric_update(a2.data)
        assert np.array_equal(np.asarray(el.solve(b)),
                              np.asarray(fresh.solve(b)))


# ------------------------------------------------------ stats / selection
@pytest.mark.parametrize(
    "make",
    [lambda: chain_lower(2_000, seed=11),
     lambda: narrow_band_lower(2_000, 0.14, 10, seed=12)],
    ids=["chain", "band"],
)
def test_stats_report_step_fusion(make):
    """ExecPlan.stats() reports barrier counts before/after fusion, and
    deep-DAG plans fuse their scan steps at least 2x (ISSUE acceptance:
    n_macro_steps * 2 <= n_steps)."""
    solver = TriangularSolver.plan(make(), strategy="growlocal", k=K,
                                   mode="elastic")
    stats = solver.exec_plan.stats()
    es = stats["elastic"]
    assert es["slack"] == DEFAULT_SLACK
    assert es["n_steps"] == stats["n_steps"]
    assert es["n_macro_steps"] * 2 <= es["n_steps"]
    assert es["step_fusion"] >= 2.0
    assert es["n_supersteps"] == stats["n_supersteps"]
    assert 1 <= es["n_fused_supersteps"] <= es["n_supersteps"]
    assert es["barrier_fusion"] >= 1.0


def test_auto_selects_elastic_on_deep_regimes():
    """strategy="auto" regression: the selector turns elastic on for
    chain/banded patterns on an elastic-capable backend, leaves it off
    for wide patterns, and never enables it under mode="bsp"."""
    clear_selection_memo()
    cache = PlanCache()
    for a in (chain_lower(2_000, seed=13),
              narrow_band_lower(2_000, 0.14, 10, seed=14)):
        solver = TriangularSolver.plan(
            a, strategy="auto", backend="scan", cache=cache
        )
        sel = solver.selection
        assert sel.regime in ("serial", "banded")
        assert sel.options.slack == DEFAULT_SLACK, sel.as_dict()
        assert all(c.options.slack == DEFAULT_SLACK for c in sel.candidates)
        assert solver.info()["mode"] == "elastic"
        # cost bookkeeping is untouched: the winner's cost is still the
        # §2.2 bsp_cost minimum over the scored shortlist
        assert sel.cost == min(c.cost for c in sel.candidates)
        # and the solve stays correct (bitwise vs the same fixed strategy)
        b = np.random.default_rng(15).standard_normal(a.n_rows)
        ref = TriangularSolver.plan(a, strategy=sel.strategy, backend="scan",
                                    options=sel.options.replace(slack=0))
        assert np.array_equal(np.asarray(solver.solve(b)),
                              np.asarray(ref.solve(b)))
    # shallow/wide: the rule must NOT fire
    wide = erdos_renyi_lower(800, 0.002, seed=16)
    s_wide = TriangularSolver.plan(wide, strategy="auto", backend="scan",
                                   cache=cache)
    assert s_wide.selection.options.slack == 0
    assert s_wide.info()["mode"] == "bsp"
    # mode="bsp" gates the rule off even on a chain
    s_bsp = TriangularSolver.plan(chain_lower(2_000, seed=13),
                                  strategy="auto", backend="scan",
                                  mode="bsp", cache=cache)
    assert s_bsp.selection.options.slack == 0
    assert s_bsp.info()["mode"] == "bsp"


def test_backend_capabilities_advertise_elastic():
    from repro.backends import get_backend

    assert "elastic" in get_backend("scan").capabilities()
    assert "elastic" in get_backend("pallas").capabilities()
    # distributed executes elastic as fused exchange rounds (the fused-
    # barrier certificate, run for real) and also row-sharding
    assert "elastic" in get_backend("distributed").capabilities()
    assert "shard-rows" in get_backend("distributed").capabilities()


# --------------------------------------------------- slow: full corpus grid
@pytest.mark.slow
@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("n_rhs", [1, 3], ids=["rhs1", "mrhs"])
@pytest.mark.parametrize("lower", [True, False], ids=["lower", "upper"])
@pytest.mark.parametrize("name", corpus_names())
def test_elastic_conformance_grid(name, lower, n_rhs, backend):
    """Corpus-wide bitwise conformance: every scenario matrix, both
    orientations, single and batched RHS, scan AND pallas (interpret)
    backends — elastic vs bulk of the same backend, bit for bit."""
    L = corpus_entry(name).matrix()
    a = L if lower else transpose_csr(L)
    _bitwise_cell(a, backend, lower, n_rhs, cache=_CACHE)
