"""``repro.analysis`` — the independent static verifier.

Three claims, each tested directly:

  * **soundness on pristine artifacts** — the verifier reports nothing
    on anything the real pipeline produces, including the degenerate
    shapes (single row, serial k=1, single shard) and, property-based,
    on randomly generated matrices across strategies;
  * **sensitivity** — every operator in the mutation harness
    (``analysis.mutate``) is caught at ``level="full"`` on an artifact
    set where it applies (the harness's own acceptance bar);
  * **determinism** — two runs over the same artifacts produce the
    identical findings representation (the verifier is itself part of
    the reproducibility story).

Plus the wiring: ``TriangularSolver.plan(validate=...)`` /
``REPRO_VALIDATE`` gate builds with ``VerificationError``, and the
fast/full level split behaves as documented (fast is a subset that
still catches structural corruption).

Property tests ride the optional-``hypothesis`` shim (``tests/_hyp.py``)
so collection survives without the package installed.
"""
import dataclasses

import numpy as np
import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, strategies as st

from repro.analysis import (
    Artifacts,
    VerificationError,
    resolve_level,
    verify_artifacts,
)
from repro.analysis.mutate import MUTATIONS, build_artifacts, run_harness
from repro.autotune import corpus_entry
from repro.pipeline import TriangularSolver
from repro.sparse import csr_from_dense, erdos_renyi_lower, narrow_band_lower

# one representative artifact set per family-coverage niche (mirrors
# launch.check's harness grid): elastic+4shard, narrow width (accum
# chains), multi-round wavefront exchanges, 2-shard chain
_GRID = [
    ("er_dense", "growlocal", dict(slack=4, n_shards=4)),
    ("band_narrow", "growlocal", dict(slack=4, n_shards=4, width=2)),
    ("er_dense", "wavefront", dict(slack=0, n_shards=4)),
    ("chain", "growlocal", dict(slack=2, n_shards=2)),
]


@pytest.fixture(scope="module")
def artifact_sets():
    return [
        (f"{name}/{strategy}", build_artifacts(
            corpus_entry(name).matrix(), strategy=strategy, k=8, **kw
        ))
        for name, strategy, kw in _GRID
    ]


# ------------------------------------------------------------- soundness

@pytest.mark.parametrize("level", ["fast", "full"])
def test_pristine_artifacts_verify_clean(artifact_sets, level):
    for label, art in artifact_sets:
        rep = verify_artifacts(art, level=level)
        assert rep.ok, (label, level, rep.table())
        # coverage, not just silence: every applicable pass really ran
        expect = {"schedule", "reorder", "plan", "elastic", "rowshard"}
        if art.elastic is None:
            expect.discard("elastic")
        assert expect <= set(rep.checks_run), (label, rep.checks_run)


def test_degenerate_single_row():
    a = csr_from_dense(np.array([[2.0]]))
    art = build_artifacts(a, strategy="serial", k=8)
    for level in ("fast", "full"):
        rep = verify_artifacts(art, level=level)
        assert rep.ok, rep.table()


def test_degenerate_serial_k1():
    a = narrow_band_lower(60, 0.2, 3, seed=5)
    art = build_artifacts(a, strategy="serial", k=1)
    rep = verify_artifacts(art, level="full")
    assert rep.ok, rep.table()


def test_degenerate_single_shard():
    a = erdos_renyi_lower(80, 0.05, seed=7)
    art = build_artifacts(a, strategy="growlocal", k=8, n_shards=1)
    assert art.rowshard is None  # 1 shard -> no partition to audit
    rep = verify_artifacts(art, level="full")
    assert rep.ok, rep.table()


def test_level_off_is_inert():
    rep = verify_artifacts(
        Artifacts(L=None, sched=None, plan=None), level="off"
    )
    assert rep.ok and not rep.checks_run


# ----------------------------------------------------------- sensitivity

def test_every_mutation_caught(artifact_sets):
    """The harness acceptance bar: each operator applies somewhere and
    is caught everywhere it applies; pristine sets stay clean."""
    rows = run_harness(artifact_sets)
    by_op = {}
    for r in rows:
        d = by_op.setdefault(r["mutation"], [])
        if r["caught"] is not None:
            d.append((r["artifacts"], r["caught"], r["codes"]))
    assert set(by_op) == {m.name for m in MUTATIONS}
    assert len(MUTATIONS) >= 8
    assert {m.family for m in MUTATIONS} == {
        "schedule", "plan", "elastic", "rowshard",
    }
    for op, hits in by_op.items():
        assert hits, f"{op}: no applicable artifact set in the grid"
        missed = [(lbl, codes) for lbl, ok, codes in hits if not ok]
        assert not missed, f"{op} escaped verification: {missed}"


def test_fast_level_catches_structural_corruption(artifact_sets):
    """fast is a screen, not a no-op: layout-visible corruption (a row
    finalized in the wrong superstep) is flagged without the O(nnz)
    passes."""
    from repro.analysis.mutate import plan_swap_rows

    _, art = artifact_sets[0]
    bad = plan_swap_rows(art, np.random.default_rng(0))
    assert bad is not None
    rep = verify_artifacts(bad, level="fast")
    assert not rep.ok and rep.codes()


def test_verification_error_carries_report(artifact_sets):
    from repro.analysis.mutate import plan_zero_diag

    _, art = artifact_sets[0]
    bad = plan_zero_diag(art, np.random.default_rng(0))
    rep = verify_artifacts(bad, level="full")
    with pytest.raises(VerificationError) as ei:
        rep.raise_if_failed()
    assert "PLAN_ZERO_DIAG" in str(ei.value)
    assert ei.value.report is rep


# ----------------------------------------------------------- determinism

def test_verifier_is_deterministic(artifact_sets):
    """Same artifacts -> byte-identical findings, clean or corrupt."""
    for label, art in artifact_sets:
        a = verify_artifacts(art, level="full").as_dict()
        b = verify_artifacts(art, level="full").as_dict()
        assert a == b, label
    from repro.analysis.mutate import schedule_swap_steps

    _, art = artifact_sets[2]
    bad = schedule_swap_steps(art, np.random.default_rng(3))
    assert bad is not None
    r1 = verify_artifacts(bad, level="full")
    r2 = verify_artifacts(bad, level="full")
    assert [f.as_dict() for f in r1.findings] == \
        [f.as_dict() for f in r2.findings]


# ---------------------------------------------------------------- wiring

def test_plan_validate_gates_and_env(monkeypatch):
    a = erdos_renyi_lower(120, 0.04, seed=9)
    s = TriangularSolver.plan(a, k=8, validate="full")
    x = np.asarray(s.solve(np.ones(120)))
    assert np.isfinite(x).all()
    with pytest.raises(ValueError, match="validate"):
        TriangularSolver.plan(a, k=8, validate="bogus")
    # env fallback: REPRO_VALIDATE drives the default level
    monkeypatch.setenv("REPRO_VALIDATE", "fast")
    assert resolve_level(None) == "fast"
    assert resolve_level("off") == "off"  # explicit arg wins
    TriangularSolver.plan(a, k=8)  # builds (and verifies) clean
    monkeypatch.setenv("REPRO_VALIDATE", "nope")
    with pytest.raises(ValueError, match="validate"):
        TriangularSolver.plan(a, k=8)


def test_obs_counters_increment():
    from repro import obs

    a = narrow_band_lower(100, 0.15, 4, seed=3)
    art = build_artifacts(a, strategy="growlocal", k=8)
    buf = obs.TraceBuffer("analysis-test")
    with obs.tracing(buf):
        verify_artifacts(art, level="fast")
    assert buf.counters().get("analysis.verifications") == 1
    spans = [s for s in buf.spans() if s.name == "analysis.verify"]
    assert len(spans) == 1
    assert spans[0].args.get("ok") is True


# ------------------------------------------------------- property tests

_DENS = (0.02, 0.05, 0.1)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=220),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dens=st.integers(min_value=0, max_value=len(_DENS) - 1),
    strategy=st.sampled_from(("growlocal", "wavefront", "serial")),
    slack=st.integers(min_value=0, max_value=4),
)
def test_property_pipeline_output_verifies(n, seed, dens, strategy, slack):
    """Whatever the real pipeline builds, the verifier accepts."""
    a = erdos_renyi_lower(n, _DENS[dens], seed=seed)
    art = build_artifacts(
        a, strategy=strategy, k=8, slack=slack,
        n_shards=2 if n >= 8 else 1,
    )
    rep = verify_artifacts(art, level="full")
    assert rep.ok, rep.table()


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    mi=st.integers(min_value=0, max_value=len(MUTATIONS) - 1),
)
def test_property_mutations_never_escape(artifact_sets, seed, mi):
    """Any seeded corruption, on any artifact set where it applies, is
    flagged — and the verdict is stable across a repeat run."""
    m = MUTATIONS[mi]
    rng_seed = seed
    for label, art in artifact_sets:
        bad = m.apply(art, np.random.default_rng(rng_seed))
        if bad is None:
            continue
        r1 = verify_artifacts(bad, level="full")
        assert not r1.ok, (m.name, label)
        r2 = verify_artifacts(bad, level="full")
        assert r1.codes() == r2.codes(), (m.name, label)


def test_hypothesis_shim_consistency():
    """The shim reports its mode honestly (bookkeeping for CI logs)."""
    assert HAVE_HYPOTHESIS in (True, False)


# -------------------------------------------------- slow: corpus depth

@pytest.mark.slow
def test_full_corpus_sweep_clean():
    """launch.check's grid, as a pytest: every corpus matrix x all
    strategies x orientations x modes x shard counts verifies clean at
    level="full"."""
    from repro.launch.check import sweep_cells
    from repro.autotune import corpus_names
    from repro.pipeline.registry import available_strategies

    rows = sweep_cells(
        matrices=corpus_names(),
        strategies=tuple(
            s for s in available_strategies() if s != "auto"
        ),
        level="full",
    )
    bad = [r for r in rows if not r["ok"]]
    assert not bad, bad[:5]
    assert len(rows) == 9 * 7 * 2 * 2 * 2
