"""Continuous-serving soak: sustained concurrent load + live updates.

Eight open-loop client threads hammer a ``mode="continuous"`` service
for ``REPRO_SOAK_SECONDS`` (default 10) wall-clock seconds while a
ninth thread interleaves ``numeric_update`` calls — the adversarial
regime for the slot engine: admissions race lane churn races version
retirement, with no quiet period ever.

Every single served result is bitwise-checked against
``direct_reference`` for the exact ``(solver, width, lane)`` the engine
recorded, and the final books must balance: every submitted ticket
terminates exactly once (no lost tickets, no double fulfillment), the
engines' admitted == completed, and nothing is stranded at shutdown.

``slow``-marked: tier-1 deselects it; CI's serve-soak job runs it with
a short ``REPRO_SOAK_SECONDS``.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.pipeline import TriangularSolver
from repro.serve import QueueFullError, SolveService, direct_reference
from repro.sparse import shifted_coupling_lower
from repro.sparse.generators import erdos_renyi_lower

pytestmark = pytest.mark.slow

SOAK_SECONDS = float(os.environ.get("REPRO_SOAK_SECONDS", "10"))
N_CLIENTS = 8
N = 96


def test_continuous_soak_bitwise_under_updates():
    mats = [shifted_coupling_lower(N, j, seed=20 + j) for j in range(4)]
    mats.append(erdos_renyi_lower(128, 0.04, seed=31))
    svc = SolveService(mode="continuous", strategy="wavefront")
    stop = threading.Event()
    checked = []  # (client, i) per bitwise-verified result
    mismatches = []
    errors = []
    submitted = [0] * N_CLIENTS
    terminated = [0] * N_CLIENTS
    updates = [0]
    try:
        fps = [svc.register(m) for m in mats]
        svc.prewarm()

        def client(cid):
            rng = np.random.default_rng(1000 + cid)
            i = 0
            while not stop.is_set():
                j = int(rng.integers(len(mats)))
                n = mats[j].n_rows
                b = rng.standard_normal(n).astype(np.float32)
                submitted[cid] += 1
                try:
                    t = svc.submit(fps[j], b)
                    x = t.result(timeout=120)
                except QueueFullError:
                    # back-pressure is a valid terminal outcome, not a
                    # lost ticket
                    terminated[cid] += 1
                    continue
                except Exception as exc:  # pragma: no cover - fail info
                    errors.append((cid, i, repr(exc)))
                    terminated[cid] += 1
                    continue
                terminated[cid] += 1
                want = direct_reference(
                    t.served_by, b, t.batch_width, t.batch_position
                )
                if x.tobytes() != want.tobytes():
                    mismatches.append((cid, i, fps[j]))
                else:
                    checked.append((cid, i))
                i += 1
                # open loop: pace, don't wait for capacity
                stop.wait(float(rng.uniform(0.001, 0.004)))

        def updater():
            rng = np.random.default_rng(99)
            while not stop.is_set():
                j = int(rng.integers(len(mats)))
                scale = 1.0 + 0.25 * float(rng.uniform())
                svc.numeric_update(fps[j], mats[j].data * scale)
                updates[0] += 1
                stop.wait(0.05)

        threads = [
            threading.Thread(target=client, args=(c,), name=f"soak-{c}")
            for c in range(N_CLIENTS)
        ]
        threads.append(threading.Thread(target=updater, name="soak-upd"))
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        time.sleep(SOAK_SECONDS)
        stop.set()
        for t in threads:
            t.join(300)
        assert all(not t.is_alive() for t in threads)
        elapsed = time.perf_counter() - t0

        assert errors == []
        assert mismatches == []
        # zero lost / duplicated tickets: every submission terminated
        # exactly once (result, rejection, or error — all counted)
        assert submitted == terminated
        assert len(checked) == len(set(checked))
        stats = svc.stats()
        assert stats["submitted"] == sum(submitted)
        assert stats["failed"] == 0
        # the run actually exercised the engine and the updater
        assert len(checked) >= N_CLIENTS * 10
        assert updates[0] >= 3
        assert stats["slots"]["passes"] >= 1
        for eng in svc._engines.values():
            d = eng.describe()
            assert d["admitted"] == d["completed"]  # nothing stranded
            assert d["occupancy"] == 0
        print(
            f"\nsoak: {len(checked)} bitwise-verified solves, "
            f"{updates[0]} numeric updates, {elapsed:.1f}s, "
            f"{stats['slots']['passes']} slot passes"
        )
    finally:
        stop.set()
        report = svc.close(timeout=120)
    assert report["workers_alive"] == []
    assert report["pins_retained"] == 0
