"""``repro.obs`` — the tracing core's contracts.

What this file pins down:

  * **disabled-path purity** — with tracing off, ``span()`` returns the
    process-wide ``NULL_SPAN`` singleton (identity, not equality: the
    zero-allocation guarantee) and neither spans nor counters reach any
    buffer;
  * **span nesting and threading** — records carry the emitting thread,
    per-thread streams bracket properly, and the Chrome exporter's B/E
    event stream survives a stack-simulation validation after a
    round-trip through JSON on disk;
  * **counter wrap/reset** — counters are exact ints that wrap modulo
    ``COUNTER_WRAP`` and survive ``clear()`` (only ``reset_counters``
    zeroes them);
  * **end-to-end instrumentation** — one ``plan()`` + solve under
    ``obs.tracing()`` produces spans from the inspector, autotune,
    cache, backend, and executor layers; ``timed=True`` solves return
    per-superstep timings and (elastic) a runtime macro-step certificate
    in ``describe()``;
  * **LatencyReservoir thread-safety** (satellite regression): hammering
    ``add`` and ``percentiles_us`` concurrently must not raise — the
    unlocked deque iteration crashed with "deque mutated during
    iteration" under serving load.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.pipeline import PlanCache, TriangularSolver
from repro.serve.metrics import LatencyReservoir
from repro.sparse.generators import erdos_renyi_lower


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with tracing globally off."""
    obs.disable()
    yield
    obs.disable()


def _matrix(n=150, seed=7):
    return erdos_renyi_lower(n, 0.03, seed=seed)


# --------------------------------------------------------- disabled path
def test_disabled_span_is_null_singleton():
    assert not obs.is_enabled()
    s1 = obs.span("a", cat="x", k=1)
    s2 = obs.span("b")
    assert s1 is obs.NULL_SPAN and s2 is obs.NULL_SPAN
    with s1 as inner:
        assert inner is obs.NULL_SPAN
        inner.set(anything=True)  # no-op, returns the singleton
    assert obs.active_buffer() is None


def test_disabled_records_nothing():
    buf = obs.get_buffer("default")
    n0, c0 = len(buf), dict(buf.counters())
    with obs.span("ghost", cat="x"):
        obs.counter_add("ghost.counter", 5)
    assert len(buf) == n0
    assert buf.counters() == c0


def test_disabled_survives_exception():
    with pytest.raises(ValueError):
        with obs.span("ghost"):
            raise ValueError("boom")


# ---------------------------------------------------------- enabled path
def test_span_records_and_nests():
    buf = obs.TraceBuffer("t1")
    with obs.tracing(buf):
        with obs.span("outer", cat="c", a=1) as sp:
            with obs.span("inner", cat="c"):
                pass
            sp.set(b=2)
    assert not obs.is_enabled()  # tracing() restored the off state
    recs = buf.spans()
    assert [r.name for r in recs] == ["inner", "outer"]  # completion order
    outer = recs[1]
    assert outer.args == {"a": 1, "b": 2}
    assert outer.t1_ns >= outer.t0_ns
    inner = recs[0]
    assert outer.t0_ns <= inner.t0_ns and inner.t1_ns <= outer.t1_ns


def test_span_records_exception_and_reraises():
    buf = obs.TraceBuffer("t2")
    with obs.tracing(buf):
        with pytest.raises(RuntimeError):
            with obs.span("fails"):
                raise RuntimeError("boom")
    (rec,) = buf.spans()
    assert rec.args["error"] == "RuntimeError"


def test_default_cat_is_name_prefix():
    buf = obs.TraceBuffer("t3")
    with obs.tracing(buf):
        with obs.span("executor.solve"):
            pass
    assert buf.spans()[0].cat == "executor"


def test_buffer_cap_counts_drops():
    buf = obs.TraceBuffer("t4", cap=2)
    with obs.tracing(buf):
        for i in range(5):
            with obs.span(f"s{i}"):
                pass
    assert len(buf) == 2 and buf.dropped == 3
    buf.clear()
    assert len(buf) == 0 and buf.dropped == 0


def test_threaded_spans_tag_their_thread():
    buf = obs.TraceBuffer("t5")
    n_threads, per = 8, 50
    barrier = threading.Barrier(n_threads)

    def work(i):
        barrier.wait()
        for j in range(per):
            with obs.span("worker", cat="x", i=i, j=j):
                obs.counter_add("work.done")

    with obs.tracing(buf):
        ts = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    assert len(buf) == n_threads * per
    assert buf.counters()["work.done"] == n_threads * per
    assert len({r.tid for r in buf.spans()}) == n_threads


# --------------------------------------------------------------- counters
def test_counter_wrap_and_reset():
    buf = obs.TraceBuffer("t6")
    with obs.tracing(buf):
        obs.counter_add("c", obs.COUNTER_WRAP - 1)
        assert buf.counters()["c"] == obs.COUNTER_WRAP - 1
        obs.counter_add("c", 3)  # wraps
        assert buf.counters()["c"] == 2
        obs.counter_add("neg", -5)
        assert buf.counters()["neg"] == obs.COUNTER_WRAP - 5
    buf.clear()  # spans gone, counters survive
    assert buf.counters()["c"] == 2
    buf.reset_counters()
    assert buf.counters() == {}


# --------------------------------------------------------------- exporter
def test_chrome_trace_roundtrip(tmp_path):
    buf = obs.TraceBuffer("t7")
    with obs.tracing(buf):
        with obs.span("outer", cat="a", n=3):
            with obs.span("inner", cat="b"):
                pass
        with obs.span("sibling", cat="a"):
            pass
        obs.counter_add("hits", 2)
    path = tmp_path / "trace.json"
    payload = obs.export_chrome_trace(str(path), buf)
    assert payload["schema"] == obs.TRACE_SCHEMA
    loaded = obs.load_chrome_trace(str(path))
    assert loaded == json.loads(json.dumps(payload))  # exact round-trip
    report = obs.validate_chrome_trace(loaded)
    assert report["n_pairs"] == 3
    assert set(report["cats"]) == {"a", "b"}
    assert loaded["counters"] == {"hits": 2}
    # ts monotonic + B/E bracketing are what validate_chrome_trace
    # enforces; check the args survived too
    begins = {
        ev["name"]: ev
        for ev in loaded["traceEvents"]
        if ev.get("ph") == "B"
    }
    assert begins["outer"]["args"] == {"n": 3}


def test_validate_rejects_broken_traces():
    ok = {
        "traceEvents": [
            {"ph": "B", "name": "s", "tid": 1, "ts": 1.0},
            {"ph": "E", "name": "s", "tid": 1, "ts": 2.0},
        ]
    }
    assert obs.validate_chrome_trace(ok)["n_pairs"] == 1
    for bad in (
        [{"ph": "E", "name": "s", "tid": 1, "ts": 1.0}],  # E without B
        [{"ph": "B", "name": "s", "tid": 1, "ts": 1.0}],  # unclosed
        [  # not monotonic
            {"ph": "B", "name": "s", "tid": 1, "ts": 2.0},
            {"ph": "E", "name": "s", "tid": 1, "ts": 1.0},
        ],
        [  # mismatched names
            {"ph": "B", "name": "s", "tid": 1, "ts": 1.0},
            {"ph": "E", "name": "t", "tid": 1, "ts": 2.0},
        ],
    ):
        with pytest.raises(ValueError):
            obs.validate_chrome_trace({"traceEvents": bad})


def test_metrics_rows_shape():
    buf = obs.TraceBuffer("t8")
    with obs.tracing(buf):
        with obs.span("executor.solve", cat="executor"):
            pass
        obs.counter_add("cache.hit", 4)
    rows = obs.metrics_rows(buf)
    by_name = {name: (val, derived) for name, val, derived in rows}
    assert "obs.executor.solve" in by_name
    assert by_name["obs.counter.cache.hit"] == (4.0, "counter")


# ----------------------------------------------------------- end to end
def test_plan_solve_spans_all_layers():
    L = _matrix()
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows).astype(np.float32)
    buf = obs.TraceBuffer("e2e")
    with obs.tracing(buf):
        solver = TriangularSolver.plan(
            L, strategy="auto", cache=PlanCache(), timed=True
        )
        x, steps = solver.solve_timed(b)
    cats = {r.cat for r in buf.spans()}
    assert {"inspector", "autotune", "cache", "backend", "executor"} <= cats
    assert buf.counters().get("cache.miss") == 1
    assert steps and all(s["us"] >= 0 for s in steps)
    assert solver.last_step_timings == steps
    # timed path returns the same solution as the untimed one
    solver.timed = False
    np.testing.assert_allclose(
        np.asarray(x), np.asarray(solver.solve(b)), rtol=1e-5, atol=1e-5
    )


def test_cache_hit_counter_and_timed_toggle():
    L = _matrix()
    cache = PlanCache()
    buf = obs.TraceBuffer("hits")
    with obs.tracing(buf):
        s1 = TriangularSolver.plan(L, strategy="growlocal", cache=cache)
        s2 = TriangularSolver.plan(
            L, strategy="growlocal", cache=cache, timed=True
        )
    assert buf.counters()["cache.miss"] == 1
    assert buf.counters()["cache.hit"] == 1
    # timed is a mutable observability toggle, not part of plan identity:
    # the hit returns the SAME cached solver with the toggle flipped
    assert s2 is s1 and s2.timed
    assert s2.info()["timed"]


def test_elastic_runtime_certificate():
    L = _matrix(n=200, seed=9)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(L.n_rows).astype(np.float32)
    solver = TriangularSolver.plan(
        L, strategy="growlocal", mode="elastic", slack=4, timed=True
    )
    before = solver.info()["binding"]["runtime"]
    assert before["timed_solves"] == 0
    x, steps = solver.solve_timed(b)
    np.testing.assert_allclose(
        np.asarray(x),
        np.asarray(
            TriangularSolver.plan(L, strategy="growlocal").solve(b)
        ),
        rtol=1e-4, atol=1e-4,
    )
    rt = solver.info()["binding"]["runtime"]
    assert rt["timed_solves"] == 1
    assert rt["macro_steps_executed"] == len(steps)
    assert rt["macro_steps_per_solve"] == rt["predicted_macro_steps"]
    assert rt["predicted_barrier_fusion"] >= 1.0
    assert all(s["n_steps"] >= 1 and s["us"] >= 0 for s in steps)


def test_obs_summary_merges_into_service_stats():
    from repro.serve import SolveService

    L = _matrix(n=120, seed=3)
    buf = obs.TraceBuffer("svc")
    with obs.tracing(buf):
        with SolveService(max_batch=4, strategy="growlocal") as svc:
            h = svc.register(L)
            rng = np.random.default_rng(0)
            t = svc.submit(h, rng.standard_normal(L.n_rows).astype(np.float32))
            t.result()
            stats = svc.stats()
    assert stats["obs"]["enabled"]
    assert "serve.microbatch" in stats["obs"]["spans"]
    # disabled: the section degrades to a single flag, never raises
    assert obs.summary() == {"enabled": False}


# ----------------------------------------- satellite: reservoir threading
def test_latency_reservoir_threaded():
    """Regression: unlocked deque iteration during concurrent append
    past maxlen raised RuntimeError('deque mutated during iteration')."""
    res = LatencyReservoir(cap=256)
    stop = threading.Event()
    errors = []

    def writer():
        i = 0
        while not stop.is_set():
            res.add(i * 1e-6)
            i += 1

    def reader():
        while not stop.is_set():
            try:
                p = res.percentiles_us()
                assert set(p) == {"p50", "p95", "p99", "p99.9"}
            except Exception as e:  # noqa: BLE001
                errors.append(e)
                return

    threads = [threading.Thread(target=writer) for _ in range(4)] + [
        threading.Thread(target=reader) for _ in range(4)
    ]
    for t in threads:
        t.start()
    import time

    time.sleep(0.5)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, f"reservoir raced: {errors[0]!r}"
    assert res.count > 0 and len(res.samples()) <= 256
