"""Determinism lint (``repro.analysis.lint``) — rule-level fixtures.

Each rule gets positive (flagged) and negative (clean) snippets,
including the pragma escapes. The regression anchor is the PR 9 bug
class itself: the ``einsum``-revert of the executor's fixed-order lane
fold must be flagged, and the *current* solver/kernels tree — where
every library reduction carries a ``blessed-reduction`` justification —
must lint clean.
"""
import textwrap

from repro.analysis.lint import (
    default_lint_roots,
    lint_paths,
    lint_source,
)


def _codes(src):
    return sorted(f.code for f in lint_source(textwrap.dedent(src)))


# ------------------------------------------- LINT_NONDET_REDUCTION

def test_module_reduction_flagged():
    src = """
        import jax.numpy as jnp

        def fold(v, x, cols):
            return jnp.einsum("rw,rw->r", v, x[cols])
    """
    assert _codes(src) == ["LINT_NONDET_REDUCTION"]


def test_einsum_revert_of_lane_fold_flagged():
    """The exact regression the rule exists for: replacing the
    executor's left-to-right lane fold with an einsum dot."""
    src = """
        import jax.numpy as jnp

        def gather_dot(vals, idx, x_block):
            # was: for w in range(W): acc = acc + vals[:, w] * x[idx[:, w]]
            return jnp.einsum("rw,rw->r", vals, x_block[idx])
    """
    found = lint_source(textwrap.dedent(src), filename="revert.py")
    assert [f.code for f in found] == ["LINT_NONDET_REDUCTION"]
    assert "einsum" in found[0].message


def test_fixed_order_fold_clean():
    src = """
        def fold(vals, idx, x, W):
            acc = vals[:, 0] * x[idx[:, 0]]
            for w in range(1, W):
                acc = acc + vals[:, w] * x[idx[:, w]]
            return acc
    """
    assert _codes(src) == []


def test_method_and_lax_forms_flagged():
    src = """
        from jax import lax

        def f(x, v):
            a = x.sum(axis=-1)
            b = lax.psum(v, "model")
            return a, b
    """
    assert _codes(src) == ["LINT_NONDET_REDUCTION"] * 2


def test_unrelated_method_names_clean():
    # `sum`-like names on arbitrary objects outside the numeric set and
    # the method whitelist must not fire
    src = """
        def f(counter, log):
            counter.tensordot("no")  # not a numeric module base
            return log.append(1)
    """
    assert _codes(src) == []


def test_reduction_pragma_same_line_and_block_above():
    src = """
        import jax.numpy as jnp

        def f(v, g):
            a = jnp.sum(v * g, axis=-1)  # repro: blessed-reduction — oracle
            # justification spanning
            # repro: blessed-reduction — outside bitwise contract
            b = jnp.einsum("rw,rw->r", v, g)
            return a, b
    """
    assert _codes(src) == []


def test_pragma_does_not_leak_to_later_lines():
    src = """
        import jax.numpy as jnp

        def f(v, g):
            a = jnp.sum(v, axis=-1)  # repro: blessed-reduction — ok

            b = jnp.sum(g, axis=-1)
            return a, b
    """
    assert _codes(src) == ["LINT_NONDET_REDUCTION"]


# --------------------------------------- LINT_JIT_MUTABLE_CAPTURE

def test_jit_mutable_capture_flagged():
    src = """
        import jax

        _CACHE = {}

        @jax.jit
        def f(x):
            return x * _CACHE.get("scale", 1)
    """
    assert _codes(src) == ["LINT_JIT_MUTABLE_CAPTURE"]


def test_jit_call_form_and_rebound_name_flagged():
    src = """
        import jax

        MODE = "a"
        MODE = "b"  # rebound module binding = mutable state

        def g(x):
            return x if MODE == "a" else -x

        g_fast = jax.jit(g)
    """
    assert _codes(src) == ["LINT_JIT_MUTABLE_CAPTURE"]


def test_jit_over_constants_clean():
    src = """
        import jax
        import jax.numpy as jnp

        SCALE = 2.0  # immutable, bound once

        @jax.jit
        def f(x):
            return jnp.maximum(x, 0) * SCALE
    """
    assert _codes(src) == []


def test_capture_pragma_blesses():
    src = """
        import jax

        _TABLE = {}

        # repro: blessed-capture — table frozen before first trace
        @jax.jit
        def f(x):
            return x + _TABLE["bias"]
    """
    assert _codes(src) == []


def test_global_mutation_flagged():
    src = """
        import jax

        COUNT = 0

        def bump():
            global COUNT
            COUNT += 1

        @jax.jit
        def f(x):
            return x * COUNT
    """
    assert _codes(src) == ["LINT_JIT_MUTABLE_CAPTURE"]


# ------------------------------------------------------ whole tree

def test_syntax_error_reported_not_raised():
    found = lint_source("def broken(:\n", filename="bad.py")
    assert [f.code for f in found] == ["LINT_SYNTAX"]


def test_current_tree_is_clean():
    """The shipped solver + kernels trees lint clean — every library
    reduction carries its blessing pragma."""
    found = lint_paths()
    assert found == [], "\n".join(f.message for f in found)
    roots = default_lint_roots()
    assert len(roots) == 2
    assert roots[0].endswith("solver") and roots[1].endswith("kernels")


def test_cli_exit_codes(tmp_path):
    from repro.analysis.lint import main

    clean = tmp_path / "clean.py"
    clean.write_text("X = 1\n")
    dirty = tmp_path / "dirty.py"
    dirty.write_text(
        "import jax.numpy as jnp\n"
        "def f(v):\n"
        "    return jnp.sum(v)\n"
    )
    assert main([str(clean)]) == 0
    assert main([str(dirty)]) == 1
