"""``repro.serve.slots`` — the continuous-batching engine's gauntlet.

Acceptance bars:

  * ``SlotState`` (the pure lane-allocation state machine) holds its
    invariants under arbitrary admit/release/evict sequences — unit
    cases, a deterministic fuzz walk, and a Hypothesis property drive —
    and every admitted token terminates exactly once;
  * the resident device ops move bits unchanged: ``insert_lane`` /
    ``extract_lane`` round-trip exactly, ``solve_resident`` is bitwise-
    identical to ``solve_bank`` on the same lanes, and writing a
    neighbor lane never perturbs an occupied lane's bits (the
    lane-independence replay the served-equals-direct contract rests
    on);
  * ``mode="continuous"`` serves bitwise-correctly end to end —
    including across interleaved ``numeric_update``s (version pinning),
    slot overflow (backlog > lanes resolves by extra passes, never
    errors), shutdown (``close`` drains; no ticket is ever stranded),
    and back-pressure (``QueueFullError`` beyond ``max_queue``);
  * non-groupable (elastic-bound) patterns fall back to the microbatch
    path gracefully, in continuous mode and under width-class batching.

Matrices stay small (n <= 160) to keep plan+compile in tier-1 budget.
"""
import threading
import time

import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, strategies as st
from repro.pipeline import GroupBank, TriangularSolver
from repro.serve import (
    AdmissionQueue,
    QueueFullError,
    SlotDispatcher,
    SlotEngine,
    SlotState,
    SlotsFull,
    SolveService,
    direct_reference,
)
from repro.serve.service import SolveTicket
from repro.sparse import shifted_coupling_lower
from repro.sparse.generators import erdos_renyi_lower

STRATEGY = "wavefront"  # level scheduler: shift-invariant plan shapes
N = 96


@pytest.fixture(scope="module")
def family():
    return [shifted_coupling_lower(N, j, seed=70 + j) for j in range(3)]


@pytest.fixture(scope="module")
def family_solvers(family):
    return [TriangularSolver.plan(m, strategy=STRATEGY) for m in family]


def rhs(n, seed):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


# ------------------------------------------------------- SlotState: units
def test_slotstate_allocates_lowest_lane_first():
    s = SlotState(4)
    assert [s.admit(f"t{i}") for i in range(4)] == [0, 1, 2, 3]
    s.check()
    assert s.release(1) == "t1"
    assert s.release(2) == "t2"
    # freed lanes are reused before never-used ones (LIFO keeps the
    # occupied prefix tight — the pow2 pass-width bound relies on it)
    assert s.admit("t4") == 2
    assert s.admit("t5") == 1
    s.check()


def test_slotstate_books_and_lookup():
    s = SlotState(2)
    s.admit("a")
    s.admit("b")
    assert s.occupancy == 2 and s.free_count == 0
    assert s.lane_of("b") == 1 and s.lane_of("nope") is None
    assert s.occupants() == {0: "a", 1: "b"}
    s.release(0)
    s.evict(1)
    assert (s.admitted, s.completed, s.evicted) == (2, 1, 1)
    s.check()


def test_slotstate_rejects_double_occupancy():
    s = SlotState(2)
    s.admit("a")
    with pytest.raises(ValueError):
        s.admit("a")  # a token occupies at most one lane
    s.admit("b")
    with pytest.raises(SlotsFull):
        s.admit("c")
    s.check()


def test_slotstate_rejects_freeing_a_free_lane():
    s = SlotState(2)
    s.admit("a")
    with pytest.raises(ValueError):
        s.release(1)
    with pytest.raises(ValueError):
        s.evict(5)
    s.release(0)
    with pytest.raises(ValueError):
        s.release(0)
    s.check()


def test_slotstate_rejects_bad_sizes():
    with pytest.raises(ValueError):
        SlotState(0)


# ------------------------------------ SlotState: property / fuzz coverage
def _walk(state, ops):
    """Drive ``state`` through (op, token) steps, mirroring it against a
    model dict; audits every invariant after every step and returns the
    terminal counts per token."""
    live = {}  # token -> lane (the model)
    done = []  # tokens that terminated (released or evicted)
    for op, token in ops:
        if op == "admit":
            if token in live:
                with pytest.raises(ValueError):
                    state.admit(token)
            elif len(live) == state.n_slots:
                with pytest.raises(SlotsFull):
                    state.admit(token)
            else:
                live[token] = state.admit(token)
        elif live:
            lane = live[sorted(live)[hash(token) % len(live)]]
            got = state.release(lane) if op == "release" else state.evict(lane)
            assert live.pop(got) == lane
            done.append(got)
        state.check()
        assert state.occupancy == len(live)
        assert state.occupants() == {v: k for k, v in live.items()}
    # exactly-once termination: every completion popped a live admission
    # (enforced by ``live.pop`` above), and the books partition every
    # admission into completed/evicted/still-live with nothing counted
    # twice
    assert len(done) == state.completed + state.evicted
    assert state.admitted == state.completed + state.evicted + len(live)


def test_slotstate_fuzz_walk_deterministic():
    rng = np.random.default_rng(7)
    for n_slots in (1, 2, 4, 8):
        ops = [
            (("admit", "release", "evict")[rng.integers(3)],
             f"t{rng.integers(n_slots * 2)}")
            for _ in range(600)
        ]
        _walk(SlotState(n_slots), ops)


@given(
    n_slots=st.sampled_from([1, 2, 4]),
    ops=st.lists(
        st.tuples(
            st.sampled_from(["admit", "release", "evict"]),
            st.integers(min_value=0, max_value=9).map("t{}".format),
        ),
        max_size=200,
    ),
)
@settings(max_examples=150, deadline=None)
def test_slotstate_property_invariants(n_slots, ops):
    _walk(SlotState(n_slots), ops)


# --------------------------------------------------------- AdmissionQueue
def test_admission_queue_fifo_close_and_drain():
    q = AdmissionQueue()
    for i in range(5):
        q.put(i)
    assert q.depth() == 5
    assert q.take(2) == [0, 1]
    assert q.drain() == [2, 3, 4]
    q.put(5)
    q.mark_pending(3)  # consumer-held items still count as backlog
    assert q.depth() == 4
    q.mark_pending(0)
    q.close()
    with pytest.raises(RuntimeError):
        q.put(6)
    assert q.take(10) == [5]  # queued work still drains after close...
    assert q.take(10) == []  # ...then the exit signal


def test_admission_queue_take_blocks_until_put():
    q = AdmissionQueue()
    got = []
    ready = threading.Event()

    def consumer():
        ready.set()
        got.extend(q.take(4))

    t = threading.Thread(target=consumer)
    t.start()
    ready.wait(5)
    q.put("x")
    t.join(5)
    assert got == ["x"]


# -------------------------------------- device ops: bitwise + lane purity
def test_resident_ops_roundtrip_and_purity(family_solvers):
    s = family_solvers[0]
    cls = type(s._bound)
    B0 = cls.blank_rhs(s.n, 4, np.float32)
    b0, b1 = rhs(s.n, 1), rhs(s.n, 2)
    B1 = cls.insert_lane(B0, 0, b0)
    B2 = cls.insert_lane(B1, 2, b1)
    # round-trip moves bits unchanged
    assert np.asarray(cls.extract_lane(B2, 0)).tobytes() == b0.tobytes()
    assert np.asarray(cls.extract_lane(B2, 2)).tobytes() == b1.tobytes()
    # insert is pure: the input bank kept its bits (in-flight passes
    # snapshot the bank; a mutating insert would corrupt them)
    assert np.asarray(cls.extract_lane(B0, 0)).tobytes() == (
        np.zeros(s.n, np.float32).tobytes()
    )
    assert np.asarray(cls.extract_lane(B1, 2)).tobytes() == (
        np.zeros(s.n, np.float32).tobytes()
    )


def test_solve_resident_matches_solve_bank_bitwise(family_solvers):
    bank = GroupBank()
    keys = []
    for i, s in enumerate(family_solvers):
        bank.add(i, s)
        keys.append(i)
    cls = type(family_solvers[0]._bound)
    n = family_solvers[0].n
    cols = [rhs(n, 10 + j) for j in range(4)]
    lane_keys = [keys[0], keys[1], keys[2], keys[0]]
    B = cls.blank_rhs(n, 4, np.float32)
    for j, c in enumerate(cols):
        B = cls.insert_lane(B, j, c)
    X_res = np.asarray(bank.solve_resident(lane_keys, B))
    X_bank = np.asarray(bank.solve(lane_keys, np.stack(cols, axis=1)))
    assert X_res.tobytes() == X_bank.tobytes()


def test_neighbor_insert_never_perturbs_occupied_lane(family_solvers):
    # the lane-independence replay: solve with lane 0 occupied, then
    # churn every OTHER lane and re-solve — lane 0's bits must not move
    bank = GroupBank()
    for i, s in enumerate(family_solvers):
        bank.add(i, s)
    cls = type(family_solvers[0]._bound)
    n = family_solvers[0].n
    b_pinned = rhs(n, 42)
    B = cls.insert_lane(cls.blank_rhs(n, 4, np.float32), 0, b_pinned)
    lane_keys = [0, 1, 2, 1]
    want = np.asarray(
        cls.extract_lane(bank.solve_resident(lane_keys, B), 0)
    ).tobytes()
    for round_ in range(3):
        for j in (1, 2, 3):
            B = cls.insert_lane(B, j, rhs(n, 100 + 10 * round_ + j))
        got = np.asarray(
            cls.extract_lane(bank.solve_resident(lane_keys, B), 0)
        ).tobytes()
        assert got == want


# ----------------------------------------------------- engine-level units
def test_engine_normalizes_slots_to_pow2():
    assert SlotEngine(n_slots=5).n_slots == 8
    assert SlotEngine(n_slots=8).n_slots == 8
    assert SlotEngine(n_slots=1).n_slots == 1
    with pytest.raises(ValueError):
        SlotEngine(n_slots=0)


def test_ticket_double_fulfill_guard():
    t = SolveTicket("ab" * 32, 0)
    t._fulfill(np.zeros(3))
    with pytest.raises(RuntimeError):
        t._fulfill(np.ones(3))


# ------------------------------------------------ continuous service path
@pytest.fixture()
def cont_service():
    svc = SolveService(
        mode="continuous", max_batch=4, strategy=STRATEGY
    )
    yield svc
    svc.close()


def test_continuous_requires_slots_capability():
    with pytest.raises(ValueError, match="slots"):
        SolveService(mode="continuous", backend="pallas")


def test_continuous_rejects_unknown_mode():
    with pytest.raises(ValueError, match="mode"):
        SolveService(mode="batch")


def test_continuous_served_equals_direct_bitwise(cont_service, family):
    svc = cont_service
    fps = [svc.register(m) for m in family]
    tickets = []
    for i in range(24):
        fp = fps[i % len(fps)]
        b = rhs(N, 300 + i)
        tickets.append((svc.submit(fp, b), b))
    for ticket, b in tickets:
        x = ticket.result(timeout=60)
        want = direct_reference(
            ticket.served_by, b, ticket.batch_width, ticket.batch_position
        )
        assert x.tobytes() == want.tobytes()
    st_ = svc.stats()
    assert st_["serving"]["mode"] == "continuous"
    assert st_["slots"]["passes"] >= 1
    # every request went through a lane, none leaked to the worker path
    assert sum(
        occ * cnt for occ, cnt in st_["slots"]["occupancy_hist"].items()
    ) == len(tickets)


def test_continuous_numeric_update_serves_admitted_version(cont_service, family):
    svc = cont_service
    m = family[0]
    fp = svc.register(m)
    b = rhs(N, 50)
    x_v0 = svc.submit(fp, b).result(timeout=60)
    v1 = svc.numeric_update(fp, m.data * 3.0)
    assert v1 == 1
    t1 = svc.submit(fp, b)
    x_v1 = t1.result(timeout=60)
    want = direct_reference(
        t1.served_by, b, t1.batch_width, t1.batch_position
    )
    assert x_v1.tobytes() == want.tobytes()
    assert not np.array_equal(x_v0, x_v1)  # the new values actually landed
    # the superseded version retires once its in-flight work drains
    assert svc.pattern(fp).wait_retired(0, timeout=30)


def test_continuous_overflow_resolves_by_extra_passes(family):
    svc = SolveService(mode="continuous", n_slots=2, strategy=STRATEGY)
    try:
        fp = svc.register(family[0])
        svc.prewarm()
        bs = [rhs(N, 400 + i) for i in range(9)]
        tickets = [svc.submit(fp, b) for b in bs]
        for ticket, b in zip(tickets, bs):
            x = ticket.result(timeout=60)
            want = direct_reference(
                ticket.served_by, b, ticket.batch_width,
                ticket.batch_position,
            )
            assert x.tobytes() == want.tobytes()
            assert ticket.batch_position < 2  # never outside the 2 lanes
        eng = next(iter(svc._engines.values()))
        d = eng.describe()
        assert d["n_slots"] == 2
        assert d["admitted"] == d["completed"] == len(bs)
        assert d["passes"] >= (len(bs) + 1) // 2  # overflow => extra passes
    finally:
        svc.close()


def test_continuous_backpressure_rejects_beyond_max_queue(
    family, monkeypatch
):
    release = threading.Event()
    orig = SlotEngine._run_pass

    def stalled(self, reqs):
        release.wait(30)
        orig(self, reqs)

    monkeypatch.setattr(SlotEngine, "_run_pass", stalled)
    svc = SolveService(
        mode="continuous", max_queue=3, strategy=STRATEGY
    )
    try:
        fp = svc.register(family[0])
        tickets = [svc.submit(fp, rhs(N, 500 + i)) for i in range(8)]
        release.set()
        outcomes = []
        for t in tickets:
            try:
                t.result(timeout=60)
                outcomes.append("ok")
            except QueueFullError:
                outcomes.append("rejected")
        assert "rejected" in outcomes  # the bound actually bounced work
        assert "ok" in outcomes  # ...without starving admitted requests
        assert svc.stats()["rejected"] == outcomes.count("rejected")
    finally:
        release.set()
        svc.close()


def test_continuous_close_drains_without_stranding(family):
    svc = SolveService(mode="continuous", strategy=STRATEGY)
    fp = svc.register(family[0])
    svc.prewarm()
    bs = [rhs(N, 600 + i) for i in range(12)]
    tickets = [svc.submit(fp, b) for b in bs]
    report = svc.close(timeout=60)
    assert report["workers_alive"] == []
    assert report["pins_retained"] == 0
    for ticket, b in zip(tickets, bs):
        x = ticket.result(timeout=1)  # already fulfilled: close() drained
        want = direct_reference(
            ticket.served_by, b, ticket.batch_width, ticket.batch_position
        )
        assert x.tobytes() == want.tobytes()
    with pytest.raises(RuntimeError):
        svc.submit(fp, bs[0])


def test_continuous_concurrent_clients_bitwise(cont_service, family):
    svc = cont_service
    fps = [svc.register(m) for m in family]
    svc.prewarm()
    failures = []

    def client(seed):
        rng = np.random.default_rng(seed)
        for i in range(6):
            fp = fps[int(rng.integers(len(fps)))]
            b = rng.standard_normal(N).astype(np.float32)
            t = svc.submit(fp, b)
            x = t.result(timeout=60)
            want = direct_reference(
                t.served_by, b, t.batch_width, t.batch_position
            )
            if x.tobytes() != want.tobytes():
                failures.append((seed, i))

    threads = [
        threading.Thread(target=client, args=(900 + k,)) for k in range(6)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert failures == []


# ------------------------------------------------- fallback + degradation
def test_continuous_elastic_pattern_falls_back_to_microbatch(family):
    svc = SolveService(mode="continuous", strategy=STRATEGY)
    try:
        m = erdos_renyi_lower(140, 0.03, seed=77)
        # explicit elastic opt-in overrides the continuous-mode bsp
        # default; the bound cannot join a bank (supports_grouped=False)
        fp = svc.register(m, strategy="growlocal", mode="elastic")
        assert not svc.pattern(fp).groupable
        b = rhs(140, 7)
        t = svc.submit(fp, b)
        x = t.result(timeout=60)
        want = direct_reference(
            t.served_by, b, t.batch_width, t.batch_position
        )
        assert x.tobytes() == want.tobytes()
        assert svc._engines == {}  # served by the worker path, no lanes
    finally:
        svc.close()


def test_width_class_batching_elastic_pattern_falls_back_plain(family):
    # regression: width-class routing must skip non-groupable bounds
    # (elastic) and serve them on the plain per-pattern path
    svc = SolveService(width_class_batching=True, strategy=STRATEGY)
    try:
        fp_grp = svc.register(family[0])
        m = erdos_renyi_lower(140, 0.03, seed=78)
        fp_el = svc.register(m, strategy="growlocal", mode="elastic")
        assert svc.pattern(fp_grp).groupable
        assert not svc.pattern(fp_el).groupable
        pairs = []
        for i in range(6):
            fp, n = (fp_grp, N) if i % 2 else (fp_el, 140)
            b = rhs(n, 800 + i)
            pairs.append((svc.submit(fp, b), b))
        for t, b in pairs:
            x = t.result(timeout=60)
            want = direct_reference(
                t.served_by, b, t.batch_width, t.batch_position
            )
            assert x.tobytes() == want.tobytes()
    finally:
        svc.close()


def test_continuous_mode_pins_auto_selection_to_bsp(family):
    # left alone, strategy='auto' may flip deep patterns to elastic —
    # whose bounds silently dodge the slot path; continuous mode must
    # pin auto to bulk-synchronous so registration yields bankable plans
    svc = SolveService(mode="continuous")
    try:
        m = erdos_renyi_lower(150, 0.02, seed=79)
        fp = svc.register(m)
        assert svc.pattern(fp).groupable
    finally:
        svc.close()


# ----------------------------------------------------- dispatcher details
def test_dispatcher_close_is_idempotent_and_rejects_submits(
    family_solvers,
):
    d = SlotDispatcher(name="t")
    eng = SlotEngine(n_slots=2)
    assert d.alive()
    assert d.close(timeout=10)
    assert not d.alive()
    assert d.close(timeout=10)  # second close: still just True
    with pytest.raises(RuntimeError):
        d.submit(eng, SolveTicket("cd" * 32, 0), ("k", 0),
                 family_solvers[0], np.zeros(N, np.float32))


def test_slot_metrics_snapshot_shape(cont_service, family):
    svc = cont_service
    fp = svc.register(family[0])
    svc.submit(fp, rhs(N, 1)).result(timeout=60)
    snap = svc.stats()
    slots = snap["slots"]
    assert set(slots) >= {
        "passes", "n_slots", "occupancy_hist", "mean_occupancy",
        "time_in_slot_us",
    }
    for pct in ("p50", "p95", "p99", "p99.9"):
        assert pct in slots["time_in_slot_us"]
        assert pct in snap["latency_us"]
    assert snap["serving"]["n_slots"] == svc.n_slots
