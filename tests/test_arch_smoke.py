"""Per-architecture smoke tests (reduced configs, CPU, 1 device):
one train step + one prefill + one decode step; asserts shapes and finite
outputs. The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.inputs import make_train_batch, token_split
from repro.models import (
    decode_step,
    init_decode_cache,
    init_params,
    loss_fn,
    param_specs,
    prefill,
)
from repro.train import AdamWConfig, make_train_step
from repro.train.train_loop import init_train_state

B, S = 2, 64


def _params_for(cfg):
    specs = param_specs(cfg)
    return init_params(specs, jax.random.PRNGKey(0), dtype=jnp.float32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # numbers straight from the assignment table
    expected = {
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "phi3_mini_3_8b": (32, 3072, 32, 32, 8192, 32064),
        "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_reduced(arch)
    params = _params_for(cfg)
    batch = make_train_batch(cfg, batch=B, seq_len=S, seed=1)
    loss, parts = loss_fn(cfg, params, batch, train=True)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # one full optimizer step
    state = init_train_state(cfg, params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                            total_steps=10), microbatches=2)
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state.params, state2.params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_reduced(arch)
    params = _params_for(cfg)
    batch = make_train_batch(cfg, batch=B, seq_len=S, seed=2)
    max_len = S + 8
    logits, cache, pos = jax.jit(
        lambda p, b: prefill(cfg, p, b, max_len=max_len)
    )(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    token = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, jnp.asarray(pos, jnp.int32), t)
    )(params, cache, token)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["granite_3_2b", "rwkv6_7b", "recurrentgemma_2b",
                                  "mixtral_8x7b", "seamless_m4t_large_v2"])
def test_decode_cache_structure_matches_prefill(arch):
    """init_decode_cache (used by the dry-run) must produce the same pytree
    structure and shapes as a real prefill."""
    cfg = get_reduced(arch)
    params = _params_for(cfg)
    batch = make_train_batch(cfg, batch=B, seq_len=S, seed=3)
    _, cache, _ = prefill(cfg, params, batch, max_len=S)
    p_fe, _ = token_split(cfg, S)
    blank = init_decode_cache(
        cfg, B, S, enc_len=p_fe if cfg.family == "encdec" else 0,
        dtype=jnp.float32,
    )
    s1 = jax.tree_util.tree_structure(cache)
    s2 = jax.tree_util.tree_structure(blank)
    assert s1 == s2, f"{s1} vs {s2}"
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(blank)):
        assert a.shape == b.shape, f"{arch}: {a.shape} != {b.shape}"
