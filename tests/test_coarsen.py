"""Coarsening tests: Prop. 4.3 (cascades preserve acyclicity), funnel
properties, transitive sparsification correctness."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (
    check_validity,
    coarsen_dag,
    funnel_partition,
    grow_local,
    is_cascade,
    pull_back_schedule,
    transitive_sparsify,
)
from repro.sparse import dag_from_lower_csr, erdos_renyi_lower
from repro.sparse.dag import topological_levels


def test_funnel_parts_are_cascades(any_dag):
    part = funnel_partition(any_dag, max_size=16)
    n_parts = int(part.max()) + 1
    rng = np.random.default_rng(0)
    # checking every part is slow; sample
    sample = rng.choice(n_parts, size=min(40, n_parts), replace=False)
    for c in sample:
        members = np.nonzero(part == c)[0]
        assert is_cascade(any_dag, members), f"part {c} is not a cascade"


def test_coarse_graph_acyclic(any_dag):
    part = funnel_partition(any_dag, max_size=32)
    c = coarsen_dag(any_dag, part)
    # topological_levels raises on cycles
    topological_levels(c.coarse)
    # weights preserved
    assert c.coarse.weights.sum() == any_dag.weights.sum()


def test_pull_back_schedule_validity(any_dag):
    part = funnel_partition(any_dag, max_size=32)
    c = coarsen_dag(any_dag, part)
    cs = grow_local(c.coarse, 8)
    fine = pull_back_schedule(c, cs, any_dag.n)
    check_validity(any_dag, fine)


def test_transitive_sparsify_keeps_levels(any_dag):
    red = transitive_sparsify(any_dag)
    assert red.n_edges <= any_dag.n_edges
    # levels (longest paths) are invariant under transitive reduction
    assert np.array_equal(topological_levels(red), topological_levels(any_dag))


def test_schedule_on_sparsified_valid_on_original(any_dag):
    """The formal argument of core.spmp_like: a valid schedule of the reduced
    DAG is valid for the original."""
    red = transitive_sparsify(any_dag)
    s = grow_local(red, 8)
    check_validity(red, s)
    check_validity(any_dag, s)  # the stronger claim


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 80),
    density=st.floats(0.01, 0.3),
    seed=st.integers(0, 2**31 - 1),
    max_size=st.integers(2, 40),
)
def test_funnel_coarsening_acyclic_property(n, density, seed, max_size):
    """Property (Prop. 4.3): funnel partitions always yield acyclic quotients,
    and the pulled-back GrowLocal schedule is valid on the fine DAG."""
    m = erdos_renyi_lower(n, density, seed=seed)
    dag = dag_from_lower_csr(m)
    part = funnel_partition(dag, max_size=max_size)
    c = coarsen_dag(dag, part)
    topological_levels(c.coarse)  # must not raise
    cs = grow_local(c.coarse, 4)
    fine = pull_back_schedule(c, cs, dag.n)
    check_validity(dag, fine)
