"""Matrix-Market IO: read <-> write round-trips over the full supported
(field, symmetry) grid — real/integer/pattern x general/symmetric — in
plain and gzip-compressed form, plus header validation."""
import gzip

import numpy as np
import pytest

from repro.sparse.csr import CSRMatrix, csr_from_coo, transpose_csr
from repro.sparse.generators import erdos_renyi_lower
from repro.sparse.io import read_matrix_market, write_matrix_market


def _lower(seed=5, n=60):
    return erdos_renyi_lower(n, 0.06, seed=seed)


def _symmetric(seed=6, n=50):
    """L + L^T with a heavy diagonal — numerically symmetric by build."""
    L = erdos_renyi_lower(n, 0.06, seed=seed)
    rows = np.concatenate([L.row_of_entry(), L.indices])
    cols = np.concatenate([L.indices, L.row_of_entry()])
    vals = np.concatenate([L.data, L.data])
    return csr_from_coo(n, n, rows, cols, vals)


def _assert_same(a: CSRMatrix, b: CSRMatrix, values=True):
    assert (a.n_rows, a.n_cols, a.nnz) == (b.n_rows, b.n_cols, b.nnz)
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)
    if values:
        assert np.allclose(a.data, b.data, rtol=0, atol=0)


@pytest.mark.parametrize("gz", [False, True], ids=["plain", "gzip"])
@pytest.mark.parametrize("field", ["real", "integer", "pattern"])
def test_roundtrip_general(tmp_path, field, gz):
    m = _lower()
    if field == "integer":
        import dataclasses

        m = dataclasses.replace(
            m, data=np.round(m.data * 10).astype(np.float64)
        )
    path = tmp_path / ("m.mtx" + (".gz" if gz else ""))
    write_matrix_market(path, m, field=field)
    back = read_matrix_market(path)
    if field == "pattern":
        _assert_same(m, back, values=False)
        assert np.all(back.data == 1.0)
    else:
        _assert_same(m, back)


@pytest.mark.parametrize("gz", [False, True], ids=["plain", "gzip"])
@pytest.mark.parametrize("field", ["real", "integer", "pattern"])
def test_roundtrip_symmetric(tmp_path, field, gz):
    m = _symmetric()
    if field == "integer":
        import dataclasses

        m = dataclasses.replace(
            m, data=np.round(m.data * 10).astype(np.float64)
        )
    path = tmp_path / ("s.mtx" + (".gz" if gz else ""))
    write_matrix_market(path, m, field=field, symmetry="symmetric")
    # symmetric storage really stores only the lower triangle
    opener = gzip.open if gz else open
    with opener(path, "rt") as fh:
        header = fh.readline()
        n, nc, nnz_stored = (int(t) for t in fh.readline().split())
    assert "symmetric" in header and field in header
    assert nnz_stored < m.nnz
    back = read_matrix_market(path)
    if field == "pattern":
        _assert_same(m, back, values=False)
    else:
        _assert_same(m, back)


def test_integer_header_is_accepted(tmp_path):
    """`coordinate integer` files (SuiteSparse has many) parse fine."""
    path = tmp_path / "int.mtx"
    path.write_text(
        "%%MatrixMarket matrix coordinate integer general\n"
        "% a comment line\n"
        "2 2 3\n"
        "1 1 5\n"
        "2 1 -3\n"
        "2 2 7\n"
    )
    m = read_matrix_market(path)
    assert (m.n_rows, m.n_cols, m.nnz) == (2, 2, 3)
    assert np.array_equal(m.data, [5.0, -3.0, 7.0])


def test_symmetric_pattern_read(tmp_path):
    path = tmp_path / "sp.mtx"
    path.write_text(
        "%%MatrixMarket matrix coordinate pattern symmetric\n"
        "3 3 3\n"
        "1 1\n"
        "3 1\n"
        "3 3\n"
    )
    m = read_matrix_market(path)
    assert m.nnz == 4  # (3,1) expands to (1,3)
    assert np.all(m.data == 1.0)
    t = transpose_csr(m)
    assert np.array_equal(m.indptr, t.indptr)
    assert np.array_equal(m.indices, t.indices)


def test_rejects_unsupported_headers(tmp_path):
    cases = [
        ("%%MatrixMarket matrix array real general\n2 2\n1\n0\n0\n1\n",
         "coordinate"),
        ("%%MatrixMarket matrix coordinate complex general\n1 1 1\n"
         "1 1 2.0 0.0\n", "field"),
        ("%%MatrixMarket matrix coordinate real hermitian\n1 1 1\n"
         "1 1 2.0\n", "symmetry"),
        ("garbage first line\n1 1 1\n1 1 2.0\n", "header"),
    ]
    for text, match in cases:
        path = tmp_path / "bad.mtx"
        path.write_text(text)
        with pytest.raises(ValueError, match=match):
            read_matrix_market(path)


def test_rejects_bad_write_args(tmp_path):
    m = _lower()
    with pytest.raises(ValueError, match="field"):
        write_matrix_market(tmp_path / "x.mtx", m, field="complex")
    with pytest.raises(ValueError, match="symmetry"):
        write_matrix_market(tmp_path / "x.mtx", m, symmetry="hermitian")
    with pytest.raises(ValueError, match="symmetric"):
        # a lower-triangular matrix is not symmetric
        write_matrix_market(tmp_path / "x.mtx", m, symmetry="symmetric")
    with pytest.raises(ValueError, match="integral"):
        write_matrix_market(tmp_path / "x.mtx", m, field="integer")


def test_pattern_symmetric_write_needs_only_structural_symmetry(tmp_path):
    """Values are never written for field='pattern', so a structurally
    symmetric matrix with asymmetric values must still round-trip."""
    import dataclasses

    m = _symmetric()
    rng = np.random.default_rng(7)
    m = dataclasses.replace(m, data=rng.standard_normal(m.nnz))
    path = tmp_path / "sp.mtx"
    write_matrix_market(path, m, field="pattern", symmetry="symmetric")
    back = read_matrix_market(path)
    _assert_same(m, back, values=False)
    with pytest.raises(ValueError, match="symmetric"):
        # ... while a value-carrying field still demands numeric symmetry
        write_matrix_market(path, m, field="real", symmetry="symmetric")


def test_entry_count_mismatch_rejected(tmp_path):
    path = tmp_path / "short.mtx"
    path.write_text(
        "%%MatrixMarket matrix coordinate real general\n"
        "2 2 3\n"
        "1 1 1.0\n"
    )
    with pytest.raises(ValueError, match="entry count"):
        read_matrix_market(path)
