"""Checkpointing (incl. corruption + resharding semantics), gradient
compression (error feedback preserves convergence), fault-tolerance
decision logic, data pipeline determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, restore_checkpoint, save_checkpoint
from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLMData
from repro.distributed.compression import (
    dequantize_int8,
    ef_compress_tree,
    init_error_buffers,
    quantize_int8,
)
from repro.distributed.fault_tolerance import (
    FailureSimulator,
    FleetMonitor,
    elastic_mesh_shape,
    recovery_plan,
)
from repro.models import init_params, param_specs
from repro.train import AdamWConfig, make_train_step
from repro.train.train_loop import init_train_state


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def _state():
    cfg = get_reduced("granite_3_2b")
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    return cfg, init_train_state(cfg, params)


def test_checkpoint_roundtrip_exact(tmp_path):
    cfg, state = _state()
    save_checkpoint(tmp_path / "ck", state, step=7, extra={"note": "x"})
    restored, meta = restore_checkpoint(tmp_path / "ck", template=state)
    assert meta["step"] == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    cfg, state = _state()
    save_checkpoint(tmp_path / "ck", state, step=1)
    # flip a byte in one leaf
    victim = sorted((tmp_path / "ck").glob("leaf_*.npy"))[3]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(tmp_path / "ck", template=state)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cfg, state = _state()
    save_checkpoint(tmp_path / "ck", state, step=1)
    bad_cfg = dataclasses.replace(cfg, d_model=128, n_heads=8, d_ff=256)
    bad_params = init_params(param_specs(bad_cfg), jax.random.PRNGKey(1),
                             jnp.float32)
    bad_state = init_train_state(bad_cfg, bad_params)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(tmp_path / "ck", template=bad_state)


def test_async_checkpointer_and_gc(tmp_path):
    cfg, state = _state()
    ck = AsyncCheckpointer(str(tmp_path / "ckpts"), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(state, step=step)
    ck.wait()
    assert ck.latest().name == "step_00000004"
    kept = sorted(p.name for p in (tmp_path / "ckpts").iterdir())
    assert kept == ["step_00000003", "step_00000004"]


def test_checkpoint_restart_training(tmp_path):
    """Kill-and-resume: training continues bit-exact from the checkpoint."""
    cfg, state = _state()
    data = SyntheticLMData(vocab=cfg.vocab_size, batch=2, seq=32, seed=3)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=1))
    for s in range(3):
        state, _ = step_fn(state, data.batch_at(s))
    save_checkpoint(tmp_path / "ck", state, step=3)
    state_a, _ = step_fn(state, data.batch_at(3))

    restored, meta = restore_checkpoint(tmp_path / "ck", template=state)
    state_b, _ = step_fn(restored, data.batch_at(meta["step"]))
    for a, b in zip(jax.tree_util.tree_leaves(state_a.params),
                    jax.tree_util.tree_leaves(state_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 5.0, jnp.float32)
    q, s = quantize_int8(x)
    x2 = dequantize_int8(q, s, x.shape, x.size)
    # per-block max error is scale/2 = max|x|/254
    assert float(jnp.abs(x - x2).max()) <= float(jnp.abs(x).max()) / 127.0


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.full((10,), 0.001, jnp.float32)}
    e = init_error_buffers(g)
    # tiny uniform gradients quantize to zero, but EF must carry them over
    total = jnp.zeros((10,))
    for _ in range(400):
        cg, e = ef_compress_tree(g, e)
        total = total + cg["w"]
    # after many steps the compressed stream delivers ~the true sum
    np.testing.assert_allclose(np.asarray(total), 0.4, rtol=0.05)


def test_training_converges_with_compression():
    cfg, state = _state()
    from repro.distributed.compression import compressed_grad_transform

    err = {"e": init_error_buffers(state.params)}
    step_fn = make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30),
        microbatches=1, grad_transform=compressed_grad_transform(err),
    )
    data = SyntheticLMData(vocab=cfg.vocab_size, batch=4, seq=64, seed=5)
    losses = []
    for s in range(25):
        state, m = step_fn(state, data.batch_at(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_failure_detection_two_strikes():
    mon = FleetMonitor(n_nodes=8, heartbeat_timeout_s=5.0)
    sim = FailureSimulator(mon)
    now = 100.0
    for i in range(8):
        mon.heartbeat(i, 1.0, now=now)
    sim.kill(3, at=now)
    first = mon.sweep(now=now)
    assert first["failed"] == []  # suspect first
    second = mon.sweep(now=now + 1)
    assert second["failed"] == [3]
    assert second["healthy"] == 7


def test_straggler_detection():
    mon = FleetMonitor(n_nodes=4, straggler_factor=2.0)
    sim = FailureSimulator(mon)
    now = 50.0
    for i in range(4):
        for _ in range(10):
            mon.heartbeat(i, 1.0, now=now)
    sim.slow_down(2, factor=3.0)
    out = mon.sweep(now=now)
    assert out["stragglers"] == [2]


def test_elastic_mesh_shrinks_data_axis():
    shape, used = elastic_mesh_shape(256, model=16)
    assert shape == {"data": 16, "model": 16} and used == 256
    # lose 3 nodes of 8 chips: 232 chips -> data 14
    shape, used = elastic_mesh_shape(232, model=16)
    assert shape == {"data": 14, "model": 16} and used == 224
    # multi-pod keeps the pod axis
    shape, used = elastic_mesh_shape(480, model=16, pod=2)
    assert shape == {"pod": 2, "data": 15, "model": 16}


def test_recovery_plan_end_to_end():
    mon = FleetMonitor(n_nodes=32, heartbeat_timeout_s=5.0)
    sim = FailureSimulator(mon)
    now = 10.0
    for i in range(32):
        for _ in range(5):
            mon.heartbeat(i, 1.0, now=now)
    sim.kill(5, at=now)
    mon.sweep(now=now)  # suspect
    sim.slow_down(9, factor=4.0)
    plan = recovery_plan(mon, chips_per_node=8, model=16)
    assert plan["action"] == "restart_from_checkpoint"
    assert 5 in plan["lost_nodes"]
    assert 9 in plan["quarantine"]
    assert plan["mesh_shape"]["data"] == (31 * 8) // 16


def test_elastic_restore_resharding(tmp_path):
    """Restore with different shardings (1-device 'new mesh')."""
    cfg, state = _state()
    save_checkpoint(tmp_path / "ck", state, step=2)
    mesh = jax.make_mesh((1,), ("data",))
    sharding = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree_util.tree_map(lambda _: sharding, state)
    restored, _ = restore_checkpoint(tmp_path / "ck", template=state,
                                     shardings=shardings)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding == sharding


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_pipeline_deterministic_and_sharded():
    a = SyntheticLMData(vocab=100, batch=4, seq=16, seed=1, shard=0, n_shards=2)
    b = SyntheticLMData(vocab=100, batch=4, seq=16, seed=1, shard=1, n_shards=2)
    x1 = a.batch_at(5)
    x2 = a.batch_at(5)
    np.testing.assert_array_equal(x1["tokens"], x2["tokens"])  # deterministic
    assert not np.array_equal(x1["tokens"], b.batch_at(5)["tokens"])  # sharded
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(x1["tokens"][:, 1:]), np.asarray(x1["labels"][:, :-1])
    )
