"""Scheduler unit + property tests: validity (Def. 2.1), barrier reduction,
block concatenation, reordering, and hypothesis-driven random DAGs."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (
    apply_reordering,
    block_parallel_schedule,
    bsp_cost,
    check_validity,
    funnel_grow_local,
    grow_local,
    hdagg_schedule,
    schedule_stats,
    serial_schedule,
    spmp_like_schedule,
    wavefront_schedule,
)
from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    longest_path_length,
    narrow_band_lower,
)
from repro.sparse.dag import is_topological_order

SCHEDULERS = {
    "growlocal": lambda d, k: grow_local(d, k),
    "funnel_gl": lambda d, k: funnel_grow_local(d, k),
    "hdagg": lambda d, k: hdagg_schedule(d, k),
    "spmp_like": lambda d, k: spmp_like_schedule(d, k),
    "wavefront": lambda d, k: wavefront_schedule(d, k),
    "serial": lambda d, k: serial_schedule(d),
}


@pytest.mark.parametrize("name", SCHEDULERS)
def test_schedule_validity(any_dag, name):
    s = SCHEDULERS[name](any_dag, 8)
    check_validity(any_dag, s)
    assert s.n_supersteps >= 1


@pytest.mark.parametrize("k", [2, 4, 16])
def test_growlocal_cores(any_dag, k):
    s = grow_local(any_dag, k)
    check_validity(any_dag, s)
    # every core id in range
    assert s.pi.max() < k


def test_growlocal_beats_wavefront_barriers(nb_matrix):
    """Paper Table 7.2: big superstep reduction on narrow-band matrices."""
    dag = dag_from_lower_csr(nb_matrix)
    gl = grow_local(dag, 8)
    wf_count = longest_path_length(dag)
    assert gl.n_supersteps * 5 < wf_count, (
        f"GrowLocal {gl.n_supersteps} supersteps vs {wf_count} wavefronts"
    )


def test_growlocal_beats_hdagg_cost(nb_matrix):
    """Paper Table 7.1 (narrow bandw.): GrowLocal BSP cost beats HDagg."""
    dag = dag_from_lower_csr(nb_matrix)
    gl = grow_local(dag, 8)
    hd = hdagg_schedule(dag, 8)
    assert bsp_cost(dag, gl) < bsp_cost(dag, hd)


def test_reordering_topological(any_matrix):
    dag = dag_from_lower_csr(any_matrix)
    s = grow_local(dag, 8)
    L2, s2, _, r = apply_reordering(any_matrix, s)
    assert is_topological_order(dag, r.perm)
    assert L2.is_lower_triangular()
    dag2 = dag_from_lower_csr(L2)
    check_validity(dag2, s2)
    # reordering preserves the schedule's shape
    assert s2.n_supersteps == s.n_supersteps
    st_ = schedule_stats(dag2, s2)
    assert st_["n_supersteps"] == s.n_supersteps


@pytest.mark.parametrize("n_blocks", [2, 4])
def test_block_parallel(any_dag, n_blocks):
    s = block_parallel_schedule(any_dag, 8, n_blocks, lambda d, k: grow_local(d, k))
    check_validity(any_dag, s)
    single = grow_local(any_dag, 8)
    # blocks add barriers (Table 7.7: supersteps grow with threads)
    assert s.n_supersteps >= single.n_supersteps


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(10, 120),
    density=st.floats(1e-3, 0.2),
    k=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_growlocal_valid_on_random_dags(n, density, k, seed):
    """Property: GrowLocal emits a valid schedule on any random lower DAG."""
    m = erdos_renyi_lower(n, density, seed=seed)
    dag = dag_from_lower_csr(m)
    s = grow_local(dag, k)
    check_validity(dag, s)
    assert (s.sigma >= 0).all()
    # all vertices scheduled exactly once
    assert s.n == dag.n


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(10, 100),
    band=st.floats(2.0, 20.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_all_schedulers_agree_on_coverage(n, band, seed):
    m = narrow_band_lower(n, 0.2, band, seed=seed)
    dag = dag_from_lower_csr(m)
    for fn in SCHEDULERS.values():
        s = fn(dag, 4)
        check_validity(dag, s)
