"""``repro.backends`` — the registry contract and the ``update_values``
value contract (device-side refresh bitwise-equal to a fresh bind).

The distributed backend needs >1 device, so its cells run in a
subprocess with XLA_FLAGS (tests/_mesh.py — same isolation as
tests/test_distributed.py).
"""
import numpy as np
import pytest
from _mesh import run_in_mesh_subprocess

from repro.backends import (
    Backend,
    available_backends,
    backends_with,
    get_backend,
    register_backend,
    unregister_backend,
)
from repro.core.plan import compile_plan
from repro.pipeline import TriangularSolver, schedule
from repro.sparse import dag_from_lower_csr, erdos_renyi_lower

# in-process backends; distributed is covered by the subprocess test below
LOCAL_BACKENDS = [b for b in available_backends() if b != "distributed"]


def _bind_kwargs(backend: str) -> dict:
    return {"interpret": True, "steps_per_tile": 4} if backend == "pallas" else {}


@pytest.fixture(scope="module")
def planned():
    L = erdos_renyi_lower(150, 0.04, seed=31)
    s = schedule(dag_from_lower_csr(L), 4, strategy="growlocal")
    return L, s


# -------------------------------------------------------------- registry
def test_builtins_registered():
    assert set(available_backends()) == {"scan", "pallas", "distributed"}
    for name in available_backends():
        assert get_backend(name).name == name


def test_grouped_capability_registry():
    """Only the scan backend's compiled graph is shape-only, so only it
    may advertise width-class grouping — the serve layer keys
    cross-pattern batching on this."""
    assert backends_with("grouped") == ("scan",)
    assert backends_with("nonexistent-capability") == ()
    from repro.backends.scan import ScanBoundSolve

    assert ScanBoundSolve.supports_grouped
    for name in ("pallas", "distributed"):
        assert "grouped" not in get_backend(name).capabilities()


def test_unknown_backend_rejected(planned):
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("nope")
    L, _ = planned
    with pytest.raises(ValueError, match="unknown backend"):
        TriangularSolver.plan(L, backend="nope")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):

        @register_backend
        class Shadow(Backend):
            name = "scan"

            def bind(self, exec_plan, **params):
                raise NotImplementedError


def test_custom_backend_reaches_the_pipeline(planned):
    """A registry entry is all a new backend needs: TriangularSolver
    binds it with no pipeline changes (the death of the elif chain)."""
    calls = []

    @register_backend
    class Recording(Backend):
        name = "test-recording"

        def bind(self, exec_plan, **params):
            inner = get_backend("scan").bind(exec_plan, **params)
            calls.append(exec_plan.n)
            return inner

    try:
        L, _ = planned
        solver = TriangularSolver.plan(L, backend="test-recording", k=4)
        assert calls == [L.n_rows]
        b = np.random.default_rng(0).standard_normal(L.n_rows)
        ref = TriangularSolver.plan(L, backend="scan", k=4).solve(b)
        assert np.array_equal(np.asarray(solver.solve(b)), np.asarray(ref))
    finally:
        unregister_backend("test-recording")


def test_describe_is_json_ready(planned):
    import json

    L, s = planned
    plan = compile_plan(L, s)
    for name in LOCAL_BACKENDS:
        d = get_backend(name).bind(plan, **_bind_kwargs(name)).describe()
        assert d["backend"] == name and d["n"] == L.n_rows
        json.dumps(d)  # must serialize for serve/bench telemetry


def test_distributed_requires_mesh(planned):
    L, s = planned
    assert get_backend("distributed").requires() == ("mesh",)
    with pytest.raises(ValueError, match="requires a mesh"):
        get_backend("distributed").bind(compile_plan(L, s))


# ----------------------------------------- update_values value contract
@pytest.mark.parametrize("backend", LOCAL_BACKENDS)
def test_update_values_bitwise_equals_fresh_bind(planned, backend):
    """ISSUE 4 acceptance: ``BoundSolve.update_values`` produces solves
    bitwise-equal to a fresh bind, and never mutates the old bound."""
    import dataclasses

    L, s = planned
    rng = np.random.default_rng(7)
    L2 = dataclasses.replace(L, data=L.data * rng.uniform(0.5, 2.0, L.nnz))
    plan1 = compile_plan(L, s)
    plan2 = compile_plan(L2, s)
    kw = _bind_kwargs(backend)
    bound1 = get_backend(backend).bind(plan1, **kw)
    fresh2 = get_backend(backend).bind(plan2, **kw)

    for shape in ((L.n_rows,), (L.n_rows, 3)):
        b = rng.standard_normal(shape).astype(np.float32)
        x1_before = np.asarray(bound1.solve(b))
        bound2 = bound1.update_values(L2.data)
        assert np.array_equal(
            np.asarray(bound2.solve(b)), np.asarray(fresh2.solve(b))
        ), (backend, shape)
        # immutability: the old bound still solves with the old values
        assert np.array_equal(np.asarray(bound1.solve(b)), x1_before)


@pytest.mark.parametrize("backend", LOCAL_BACKENDS)
def test_update_values_rejects_mis_sized_data(planned, backend):
    """The device gather clamps out-of-range indices, so a wrong-pattern
    data vector must be rejected up front — not silently produce garbage
    values (the same hazard solve() guards for b)."""
    L, s = planned
    bound = get_backend(backend).bind(compile_plan(L, s),
                                      **_bind_kwargs(backend))
    assert bound.n_entries == L.nnz
    for bad in (L.data[:-1], np.concatenate([L.data, [1.0]]),
                L.data.reshape(1, -1)):
        with pytest.raises(ValueError, match="entry data"):
            bound.update_values(bad)


@pytest.mark.parametrize("backend", LOCAL_BACKENDS)
def test_solver_numeric_update_bitwise_equals_fresh_plan(backend):
    """The same contract through TriangularSolver (covers the §5 reorder
    entry-map rebase: val_src is in caller entry order there)."""
    import dataclasses

    L = erdos_renyi_lower(130, 0.05, seed=32)
    rng = np.random.default_rng(8)
    L2 = dataclasses.replace(L, data=L.data * rng.uniform(0.5, 2.0, L.nnz))
    kw = _bind_kwargs(backend)
    solver = TriangularSolver.plan(L, k=4, backend=backend, **kw)
    fresh = TriangularSolver.plan(L2, k=4, backend=backend, **kw)
    solver.numeric_update(L2)
    b = rng.standard_normal((L.n_rows, 2)).astype(np.float32)
    assert np.array_equal(
        np.asarray(solver.solve(b)), np.asarray(fresh.solve(b))
    )


def test_update_values_distributed_subprocess():
    """The distributed cell of the update_values contract (needs a
    multi-device mesh -> subprocess with forced host device count)."""
    out = run_in_mesh_subprocess("""
        import dataclasses
        import numpy as np, jax
        from repro.backends import get_backend
        from repro.core.plan import compile_plan
        from repro.pipeline import schedule
        from repro.sparse import dag_from_lower_csr, erdos_renyi_lower

        L = erdos_renyi_lower(300, 0.02, seed=33)
        s = schedule(dag_from_lower_csr(L), 4, strategy="growlocal")
        rng = np.random.default_rng(9)
        L2 = dataclasses.replace(L, data=L.data * rng.uniform(0.5, 2.0, L.nnz))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        be = get_backend("distributed")
        bound1 = be.bind(compile_plan(L, s), mesh=mesh)
        fresh2 = be.bind(compile_plan(L2, s), mesh=mesh)
        b = rng.standard_normal((L.n_rows, 3)).astype(np.float32)
        x1_before = np.asarray(bound1.solve(b))
        bound2 = bound1.update_values(L2.data)
        assert np.array_equal(np.asarray(bound2.solve(b)),
                              np.asarray(fresh2.solve(b)))
        assert np.array_equal(np.asarray(bound1.solve(b)), x1_before)
        # value refreshes reuse the jitted shape cache (no recompilation)
        assert bound2.describe()["compiled_batch_sizes"] == [4]
        # serial's k=1 pads up to the 4-device model axis...
        s1 = schedule(dag_from_lower_csr(L), 1, strategy="serial")
        b1 = be.bind(compile_plan(L, s1), mesh=mesh)
        x1 = np.asarray(b1.solve(b))
        assert x1.shape == b.shape
        # ...but more schedule cores than devices is a clear error, not a
        # trace-time shape failure
        s8 = schedule(dag_from_lower_csr(L), 8, strategy="growlocal")
        try:
            be.bind(compile_plan(L, s8), mesh=mesh)
            raise SystemExit("k=8 on a 4-device model axis must be rejected")
        except ValueError as e:
            assert "model" in str(e)
        print("dist-update-ok")
    """)
    assert "dist-update-ok" in out
