"""``repro.serve`` — correctness under concurrency.

The acceptance bars this file enforces:

  * with >= 8 client threads over mixed patterns, every served result is
    bitwise-identical to a direct ``TriangularSolver.solve`` call at the
    dispatched batch width (``direct_reference``);
  * interleaved ``numeric_update``s never corrupt or drop queued
    requests — each request is served by the plan version it was
    admitted under (version pinning);
  * the neighbor-independence property the bitwise contract rests on: at
    a fixed (batch width, column position), a column's bits depend only
    on its own right-hand side, never on what the other columns hold.

Matrices here are deliberately small (n ~ 100–200) so plan+compile stays
in tier-1 budget; the corpus-scale serving run is CI's serve smoke
(``benchmarks/serve_load.py --smoke``).
"""
import threading
import time

import numpy as np
import pytest

from repro.pipeline import PlanCache, TriangularSolver
from repro.serve import (
    MicroBatcher,
    QueueFullError,
    SolveService,
    VersionedPlans,
    direct_reference,
    make_sampler,
    mix_weights,
    pad_width,
    run_closed_loop,
    run_open_loop,
)
from repro.sparse.generators import erdos_renyi_lower, narrow_band_lower

STRATEGY = "growlocal"  # fixed: keeps plan() cheap and deterministic


@pytest.fixture(scope="module")
def mats():
    return [
        erdos_renyi_lower(120, 0.03, seed=21),
        narrow_band_lower(160, 0.1, 6, seed=22),
        erdos_renyi_lower(200, 0.02, seed=23),
    ]


@pytest.fixture()
def service():
    svc = SolveService(
        max_batch=8, max_wait_us=3000, strategy=STRATEGY
    )
    yield svc
    svc.close()


# ----------------------------------------------------------- unit: batcher
def test_pad_width_policy():
    assert [pad_width(m, 8) for m in (1, 2, 3, 4, 5, 8)] == [2, 2, 4, 4, 8, 8]
    # a non-pow2 cap quantizes DOWN: dispatching width 12 would break the
    # documented log2(max_batch) compiled-variant bound
    assert pad_width(9, 12) == 8
    assert pad_width(1, 1) == 1  # baseline escape hatch
    assert pad_width(5, 1) == 1


def test_batcher_coalesces_and_splits():
    b = MicroBatcher(max_batch=3, max_wait_us=10_000_000)
    assert b.max_batch == 2  # non-pow2 caps quantize down (pad_width bound)
    for i in range(7):
        b.put("r", i)
    assert b.depth() == 7
    assert b.next_batch() == ("r", [0, 1])  # full group, no wait
    assert b.next_batch() == ("r", [2, 3])
    assert b.next_batch() == ("r", [4, 5])
    b.close()  # flush: the remainder comes out without its deadline
    assert b.next_batch() == ("r", [6])
    assert b.next_batch() is None
    with pytest.raises(RuntimeError):
        b.put("r", 8)


def test_batcher_deadline_dispatches_partial_group():
    b = MicroBatcher(max_batch=64, max_wait_us=20_000)
    t0 = time.perf_counter()
    b.put("r", "x")
    route, items = b.next_batch()
    waited = time.perf_counter() - t0
    assert (route, items) == ("r", ["x"])
    assert waited >= 0.015  # held for ~max_wait, not dispatched eagerly
    b.close()
    assert b.next_batch() is None


def test_batcher_routes_are_isolated():
    b = MicroBatcher(max_batch=2, max_wait_us=10_000_000)
    b.put(("fp1", 0), "a")
    b.put(("fp2", 0), "b")
    b.put(("fp1", 0), "c")
    assert b.next_batch() == (("fp1", 0), ["a", "c"])  # full first
    b.close()
    assert b.next_batch() == (("fp2", 0), ["b"])


# ------------------------------------------- the bitwise contract's bedrock
def test_neighbor_independence_at_fixed_width_and_position(mats):
    """At a fixed (batch width, column position), a column's bits depend
    only on its own b — neighbor contents never matter. This is the
    property that makes coalescing bit-transparent. (Across widths or
    positions XLA may vectorize the batched einsum differently, so the
    contract deliberately fixes both.)"""
    rng = np.random.default_rng(0)
    for L in mats:
        solver = TriangularSolver.plan(L, strategy=STRATEGY)
        n = L.n_rows
        b = rng.standard_normal(n).astype(np.float32)
        for w in (2, 4, 8):
            for pos in (0, w // 2, w - 1):
                ref = direct_reference(solver, b, w, pos)
                for _ in range(2):
                    B = rng.standard_normal((n, w)).astype(np.float32)
                    B[:, pos] = b
                    got = np.asarray(solver.solve(B))[:, pos]
                    assert np.array_equal(got, ref), (n, w, pos)


# --------------------------------------------------------- service basics
def test_submit_by_matrix_then_fingerprint(service, mats):
    L = mats[0]
    rng = np.random.default_rng(1)
    b = rng.standard_normal(L.n_rows)
    t1 = service.submit(L, b)  # auto-registers
    x1 = t1.result(60)
    fp = t1.fingerprint
    x2 = service.solve(fp, b, timeout=60)  # cheap-handle fast path
    solver = service.pattern(fp).solver_for(t1.version)
    assert t1.served_by is solver  # the serving version rides the ticket
    assert np.array_equal(
        x1,
        direct_reference(solver, b, t1.batch_width, t1.batch_position),
    )
    assert np.array_equal(x1, x2)  # lone requests land at (width 2, col 0)


def test_submit_rejects_bad_shapes_and_unknown_fp(service, mats):
    fp = service.register(mats[0])
    n = mats[0].n_rows
    with pytest.raises(ValueError, match="one right-hand side"):
        service.submit(fp, np.ones((n, 2)))
    with pytest.raises(ValueError, match="one right-hand side"):
        service.submit(fp, np.ones(n + 1))
    with pytest.raises(KeyError, match="unknown pattern"):
        service.submit("deadbeef", np.ones(n))


def test_matrix_resubmission_with_new_values_is_implicit_update(
    service, mats
):
    L = mats[0]
    fp = service.register(L)
    assert service.pattern(fp).current == 0
    import dataclasses

    L2 = dataclasses.replace(L, data=L.data * 2.0)
    t = service.submit(L2, np.ones(L.n_rows))
    assert t.version == 1  # pinned to the freshly installed version
    x = t.result(60)
    solver = service.pattern(fp).solver_for(1)
    assert np.array_equal(
        x,
        direct_reference(
            solver, np.ones(L.n_rows), t.batch_width, t.batch_position
        ),
    )
    # resubmitting the same values is NOT another update
    service.solve(L2, np.ones(L.n_rows), timeout=60)
    assert service.pattern(fp).current == 1


def test_register_orientation_mismatch_rejected(service):
    """A diagonal-only matrix passes both orientation checks, so only the
    service's own guard prevents silently re-using a lower=True plan for
    an upper solve."""
    import repro.autotune as at

    d = at.independent_lower(40, seed=9)
    fp = service.register(d, lower=True)
    with pytest.raises(ValueError, match="registered with lower=True"):
        service.register(d, lower=False)
    with pytest.raises(ValueError, match="registered with lower=True"):
        service.submit(d, np.ones(40), lower=False)
    # the fingerprint fast path cross-checks an explicit orientation too
    with pytest.raises(ValueError, match="registered with lower=True"):
        service.submit(fp, np.ones(40), lower=False)
    service.solve(fp, np.ones(40), timeout=60)  # omitted lower: fine
    assert service.pattern(fp).lower is True


def test_close_releases_cache_pins(mats):
    cache = PlanCache(maxsize=2)
    with SolveService(strategy=STRATEGY, cache=cache) as svc:
        for L in mats:
            svc.register(L)
        assert len(cache.pinned) == len(mats)
    assert len(cache.pinned) == 0  # close() released every pin
    assert len(cache) <= 2  # ... and the LRU bound re-applies


def test_closed_service_rejects_submissions(mats):
    svc = SolveService(strategy=STRATEGY)
    fp = svc.register(mats[0])
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(fp, np.ones(mats[0].n_rows))
    with pytest.raises(RuntimeError, match="closed"):
        svc.register(mats[1])  # would pin a key close() can't release


# ----------------------------------------- acceptance: concurrent clients
def test_concurrent_clients_bitwise_identical(service, mats):
    """>= 8 client threads over mixed patterns: every served result is
    bitwise-identical to the direct solve on its pinned version."""
    fps = [service.register(L) for L in mats]
    ns = {fp: L.n_rows for fp, L in zip(fps, mats)}
    n_clients, per_client = 8, 6
    out = [[] for _ in range(n_clients)]
    seed_rngs = [np.random.default_rng(100 + i) for i in range(n_clients)]

    def client(ci):
        rng = seed_rngs[ci]
        for j in range(per_client):
            fp = fps[(ci + j) % len(fps)]
            b = rng.standard_normal(ns[fp]).astype(np.float32)
            t = service.submit(fp, b)
            out[ci].append((t, b, t.result(60)))

    threads = [
        threading.Thread(target=client, args=(i,)) for i in range(n_clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    served = [s for c in out for s in c]
    assert len(served) == n_clients * per_client
    for ticket, b, x in served:
        solver = service.pattern(ticket.fingerprint).solver_for(
            ticket.version
        )
        assert np.array_equal(
            x,
            direct_reference(
                solver, b, ticket.batch_width, ticket.batch_position
            ),
        ), (ticket.fingerprint, ticket.batch_width, ticket.batch_position)
    snap = service.stats()
    assert snap["completed"] == len(served) and snap["failed"] == 0
    assert snap["queue_depth"] == 0


def test_microbatching_actually_coalesces(mats):
    """A burst of same-pattern submissions rides few multi-RHS solves,
    not one solve per request (long max_wait so the test is not timing
    sensitive)."""
    with SolveService(
        max_batch=8, max_wait_us=300_000, strategy=STRATEGY
    ) as svc:
        fp = svc.register(mats[0])
        rng = np.random.default_rng(2)
        n = mats[0].n_rows
        tickets = [
            svc.submit(fp, rng.standard_normal(n)) for _ in range(8)
        ]
        for t in tickets:
            t.result(60)
        snap = svc.stats()
    assert snap["batches"] < len(tickets)
    assert max(int(k) for k in snap["batch_size_hist"]) >= 2
    assert snap["mean_batch_size"] > 1


# ------------------------------------- acceptance: live numeric updates
def test_version_pinning_across_interleaved_updates(mats):
    """Requests admitted before a numeric_update are served with the old
    values; requests admitted after see the new ones — bitwise, and with
    nothing dropped. A long max_wait guarantees the v0 requests are
    still queued when the update lands (real interleaving)."""
    L = mats[1]
    n = L.n_rows
    rng = np.random.default_rng(3)
    with SolveService(
        max_batch=64, max_wait_us=150_000, strategy=STRATEGY
    ) as svc:
        fp = svc.register(L)
        direct = {0: svc.pattern(fp).solver_for(0)}
        admitted = []  # (ticket, b)
        for gen in range(1, 4):  # three value swaps, interleaved
            for _ in range(5):
                b = rng.standard_normal(n).astype(np.float32)
                admitted.append((svc.submit(fp, b), b))
            v = svc.numeric_update(fp, L.data * (1.0 + 0.5 * gen))
            assert v == gen
            direct[v] = svc.pattern(fp).solver_for(v)
        for _ in range(5):  # tail batch on the final version
            b = rng.standard_normal(n).astype(np.float32)
            admitted.append((svc.submit(fp, b), b))
        results = [(t, b, t.result(60)) for t, b in admitted]
    versions = [t.version for t, _, _ in results]
    assert versions == [0] * 5 + [1] * 5 + [2] * 5 + [3] * 5  # pinned
    for t, b, x in results:
        assert np.array_equal(
            x,
            direct_reference(
                direct[t.version], b, t.batch_width, t.batch_position
            ),
        ), f"version {t.version} served with wrong values"


def test_update_unknown_fingerprint_and_missing_data(service, mats):
    fp = service.register(mats[0])
    with pytest.raises(KeyError, match="unknown pattern"):
        service.numeric_update("deadbeef", mats[0].data)
    with pytest.raises(ValueError, match="needs the new values"):
        service.numeric_update(fp)


def test_versions_retire_once_drained(mats):
    with SolveService(
        max_batch=4, max_wait_us=1000, strategy=STRATEGY
    ) as svc:
        fp = svc.register(mats[0])
        n = mats[0].n_rows
        t0 = svc.submit(fp, np.ones(n))
        t0.result(60)
        svc.numeric_update(fp, mats[0].data * 3.0)
        t1 = svc.submit(fp, np.ones(n))
        t1.result(60)
        # v0 has no pins left and was superseded -> retired
        assert svc.pattern(fp).wait_retired(0, timeout=10)
        assert svc.pattern(fp).live_versions() == (1,)
        with pytest.raises(KeyError):
            svc.pattern(fp).solver_for(0)


def test_versioned_plans_unit(mats):
    solver = TriangularSolver.plan(mats[0], strategy=STRATEGY)
    vp = VersionedPlans(solver)
    v, s0 = vp.admit()
    assert (v, s0) == (0, solver)
    v1 = vp.update(mats[0].data * 2.0)
    assert v1 == 1 and vp.live_versions() == (0, 1)  # v0 still pinned
    va, s1 = vp.admit()
    assert va == 1 and s1 is not s0
    assert s0.source_values is not None
    assert np.array_equal(s1.source_values, mats[0].data * 2.0)
    vp.complete(0)
    assert vp.live_versions() == (1,)  # drained + superseded -> gone
    vp.complete(1)


# ------------------------------------------------- cache pins + loadgen
def test_plan_cache_pins_are_eviction_safe(mats):
    cache = PlanCache(maxsize=1)
    s0 = TriangularSolver.plan(mats[0], strategy=STRATEGY, cache=cache)
    cache.pin(s0.plan_key)
    TriangularSolver.plan(mats[1], strategy=STRATEGY, cache=cache)
    TriangularSolver.plan(mats[2], strategy=STRATEGY, cache=cache)
    # the pinned entry survived both insertions; unpinned ones churned
    hits0 = cache.stats.hits
    again = TriangularSolver.plan(mats[0], strategy=STRATEGY, cache=cache)
    assert cache.stats.hits == hits0 + 1 and again is s0
    cache.unpin(s0.plan_key)
    assert len(cache) <= 1  # unpin re-applies the LRU bound


def test_service_pins_registered_plans(mats):
    cache = PlanCache(maxsize=1)
    with SolveService(strategy=STRATEGY, cache=cache) as svc:
        fps = [svc.register(L) for L in mats]
        assert len(set(fps)) == len(mats)
        assert len(cache.pinned) == len(mats)
        misses = cache.stats.misses
        for L in mats:  # all three plans still live despite maxsize=1
            svc.register(L)
        assert cache.stats.misses == misses


def test_loadgen_mixes_and_closed_loop(mats):
    w = mix_weights("hot", 4)
    assert w[0] > w[-1] and abs(w.sum() - 1) < 1e-12
    assert np.allclose(mix_weights("uniform", 4), 0.25)
    with pytest.raises(ValueError, match="unknown mix"):
        mix_weights("nope", 3)
    with SolveService(
        max_batch=8, max_wait_us=2000, strategy=STRATEGY
    ) as svc:
        patterns = [(svc.register(L), L.n_rows) for L in mats]
        sampler = make_sampler(patterns, "hot", seed=5)
        report = run_closed_loop(
            svc, sampler, n_clients=4, requests_per_client=4, validate=True
        )
    assert report["requests"] == 16
    assert report["errors"] == 0
    assert report["bitwise_mismatches"] == 0
    assert report["solves_per_sec"] > 0
    assert set(report["latency_us"]) == {"p50", "p95", "p99", "p99.9"}


def test_loadgen_open_loop(mats):
    with SolveService(
        max_batch=8, max_wait_us=2000, strategy=STRATEGY
    ) as svc:
        patterns = [(svc.register(mats[0]), mats[0].n_rows)]
        sampler = make_sampler(patterns, "uniform", seed=6)
        report = run_open_loop(
            svc, sampler, rate_hz=2000.0, n_requests=12, validate=True
        )
    assert report["requests"] == 12 and report["errors"] == 0
    assert report["bitwise_mismatches"] == 0


# ------------------------------------------------ back-pressure (max_queue)
def test_backpressure_rejects_overflow_keeps_queue_bounded(mats):
    """With a bounded admission queue and a stalled worker (long batch
    deadline, big max_batch), overflow submissions come back rejected
    instead of growing the backlog; the accepted ones still get served
    (close() flushes), bitwise-correct."""
    L = mats[0]
    n = L.n_rows
    rng = np.random.default_rng(11)
    with SolveService(
        max_batch=64, max_wait_us=60_000_000, max_queue=4, strategy=STRATEGY
    ) as svc:
        fp = svc.register(L)
        accepted, rejected = [], []
        for _ in range(10):
            b = rng.standard_normal(n).astype(np.float32)
            t = svc.submit(fp, b)
            (rejected if t.rejected else accepted).append((t, b))
            assert svc._batcher.depth() <= 4  # the bound actually holds
        assert len(accepted) == 4 and len(rejected) == 6
        for t, _ in rejected:
            assert t.done() and t.version == -1
            with pytest.raises(QueueFullError, match="max_queue=4"):
                t.result(1)
        snap = svc.stats()
        assert snap["rejected"] == 6
        assert snap["per_pattern"][fp]["rejected"] == 6
    # close() drained the accepted requests; nothing was dropped
    for t, b in accepted:
        x = t.result(60)
        assert np.array_equal(
            x, direct_reference(t.served_by, b, t.batch_width,
                                t.batch_position)
        )


def test_backpressure_unbounded_by_default_and_validates_bound(mats):
    with pytest.raises(ValueError, match="max_queue"):
        SolveService(max_queue=0)
    with SolveService(
        max_batch=4, max_wait_us=1000, strategy=STRATEGY
    ) as svc:  # no max_queue: nothing rejects
        fp = svc.register(mats[0])
        tickets = [
            svc.submit(fp, np.ones(mats[0].n_rows)) for _ in range(12)
        ]
        for t in tickets:
            assert not t.rejected
            t.result(60)
        assert svc.stats()["rejected"] == 0


def test_open_loop_reports_rejections(mats):
    """Loadgen separates back-pressure rejections from errors: an
    open-loop burst against a tiny bound rejects the overflow and the
    served remainder still validates bitwise."""
    with SolveService(
        max_batch=64, max_wait_us=300_000, max_queue=2, strategy=STRATEGY
    ) as svc:
        patterns = [(svc.register(mats[0]), mats[0].n_rows)]
        sampler = make_sampler(patterns, "uniform", seed=13)
        # rate far above the 0.3s batch deadline: all 10 submissions land
        # while the first batch is still held, so everything past the
        # bound must bounce; the held batch then dispatches and validates.
        report = run_open_loop(
            svc, sampler, rate_hz=100_000.0, n_requests=10, validate=True
        )
    assert report["rejected"] == 8  # 2 admitted, 8 bounced
    assert report["errors"] == 0
    assert report["bitwise_mismatches"] == 0
    assert report["completed"] == 2


def test_worker_failure_propagates_to_tickets(mats):
    """A solve blowing up must fail only that batch's tickets, with the
    original exception, and leave the service serving."""
    with SolveService(
        max_batch=4, max_wait_us=1000, strategy=STRATEGY
    ) as svc:
        fp = svc.register(mats[0])
        vp = svc.pattern(fp)
        n = mats[0].n_rows
        boom = RuntimeError("synthetic backend failure")

        class _Exploding:
            def solve(self, B):  # stand-in for the version's solver
                raise boom

        real = vp._versions[vp.current]
        vp._versions[vp.current] = _Exploding()
        try:
            t = svc.submit(fp, np.ones(n))
            with pytest.raises(RuntimeError, match="synthetic backend"):
                t.result(60)
        finally:
            vp._versions[vp.current] = real
        # service still serves after the failure
        x = svc.solve(fp, np.ones(n), timeout=60)
        assert x.shape == (n,)
        snap = svc.stats()
        assert snap["failed"] == 1 and snap["completed"] >= 1
