"""Executor correctness: scan executor and plan compilation against scipy,
plus the PCG end-to-end driver."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import apply_reordering, compile_plan, grow_local, hdagg_schedule
from repro.solver import (
    cg_solve,
    forward_substitution,
    make_solver,
    pcg_ichol,
    solve_lower_scipy,
)
from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    narrow_band_lower,
    poisson2d_matrix,
)


def _solve_and_check(L, sched_fn, rtol=2e-3, width=None):
    rng = np.random.default_rng(7)
    b = rng.standard_normal(L.n_rows)
    dag = dag_from_lower_csr(L)
    s = sched_fn(dag)
    L2, s2, b2, _ = apply_reordering(L, s, b)
    plan = compile_plan(L2, s2, width=width)
    x = np.asarray(make_solver(plan)(b2))
    x_ref = solve_lower_scipy(L2, b2)
    denom = np.abs(x_ref).max() + 1e-30
    assert np.abs(x - x_ref).max() / denom < rtol


def test_scan_executor_er(er_matrix):
    _solve_and_check(er_matrix, lambda d: grow_local(d, 8))


def test_scan_executor_nb(nb_matrix):
    _solve_and_check(nb_matrix, lambda d: grow_local(d, 8))


def test_scan_executor_ichol(ichol_matrix):
    _solve_and_check(ichol_matrix, lambda d: grow_local(d, 8))


def test_scan_executor_hdagg_schedule(er_matrix):
    """The executor is scheduler-agnostic."""
    _solve_and_check(er_matrix, lambda d: hdagg_schedule(d, 8))


@pytest.mark.parametrize("width", [1, 2, 7, 64])
def test_plan_width_row_splitting(er_matrix, width):
    """Rows wider than W are split into accumulating virtual rows; any W
    must give the same solution."""
    _solve_and_check(er_matrix, lambda d: grow_local(d, 4), width=width)


def test_serial_reference_matches_scipy(er_matrix):
    rng = np.random.default_rng(3)
    b = rng.standard_normal(er_matrix.n_rows)
    x = forward_substitution(er_matrix, b)
    x_ref = solve_lower_scipy(er_matrix, b)
    np.testing.assert_allclose(x, x_ref, rtol=1e-10, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(5, 150),
    density=st.floats(0.005, 0.25),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_solve_property_random(n, density, k, seed):
    """Property: schedule -> reorder -> plan -> scan executor == scipy,
    for arbitrary lower-triangular systems and core counts."""
    L = erdos_renyi_lower(n, density, seed=seed)
    rng = np.random.default_rng(seed)
    b = rng.standard_normal(n)
    dag = dag_from_lower_csr(L)
    s = grow_local(dag, k)
    L2, s2, b2, _ = apply_reordering(L, s, b)
    plan = compile_plan(L2, s2)
    x = np.asarray(make_solver(plan)(b2))
    x_ref = solve_lower_scipy(L2, b2)
    denom = np.abs(x_ref).max() + 1e-30
    assert np.abs(x - x_ref).max() / denom < 5e-3


def test_pcg_end_to_end():
    A = poisson2d_matrix(24)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(A.n_rows)
    x, iters, relres, info = pcg_ichol(A, b, k=4, tol=1e-5, maxiter=600)
    assert relres < 1e-4
    x_plain, iters_plain, _ = cg_solve(A, b, tol=1e-5, maxiter=5000)
    assert iters < iters_plain, "preconditioner must accelerate CG"
    np.testing.assert_allclose(x, x_plain, rtol=5e-3, atol=5e-3)


def test_nb_solver_correctness(nb_matrix):
    rng = np.random.default_rng(9)
    b = rng.standard_normal(nb_matrix.n_rows)
    dag = dag_from_lower_csr(nb_matrix)
    s = grow_local(dag, 8)
    L2, s2, b2, r = apply_reordering(nb_matrix, s, b)
    plan = compile_plan(L2, s2)
    x2 = np.asarray(make_solver(plan)(b2))
    # un-permute and compare against the ORIGINAL system's solution
    x = np.empty_like(x2)
    x[r.perm] = x2
    x_ref = solve_lower_scipy(nb_matrix, b)
    assert np.abs(x - x_ref).max() / (np.abs(x_ref).max() + 1e-30) < 2e-3
