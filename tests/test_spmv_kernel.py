"""Blocked SpMV Pallas kernel vs the jnp oracle and scipy (shape/dtype
sweep, interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.kernels.ref import spmv_block_ref
from repro.kernels.spmv import ell_from_csr, spmv, spmv_pallas
from repro.sparse import erdos_renyi_lower, narrow_band_lower


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("n,density,tile", [(300, 0.02, 64), (512, 0.05, 128)])
def test_spmv_kernel_matches_oracle(n, density, tile, dtype):
    m = erdos_renyi_lower(n, density, seed=n)
    col_idx, vals, row_map = ell_from_csr(m, dtype=np.dtype(dtype))
    R = col_idx.shape[0]
    pad = (-R) % tile
    col_idx = np.concatenate(
        [col_idx, np.full((pad, col_idx.shape[1]), m.n_cols, np.int32)]
    )
    vals = np.concatenate([vals, np.zeros((pad, vals.shape[1]), vals.dtype)])
    rng = np.random.default_rng(1)
    x = rng.standard_normal(n)
    x_pad = jnp.concatenate([jnp.asarray(x, dtype), jnp.zeros(1, dtype)])
    y_kernel = spmv_pallas(
        jnp.asarray(col_idx), jnp.asarray(vals), x_pad,
        rows_per_tile=tile, interpret=True,
    )
    y_oracle = spmv_block_ref(x_pad, jnp.asarray(col_idx), jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 200), seed=st.integers(0, 2**31 - 1))
def test_spmv_matches_scipy_property(n, seed):
    m = narrow_band_lower(n, 0.2, 6.0, seed=seed)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    y = np.asarray(spmv(m, x, rows_per_tile=32, interpret=True))
    y_ref = m.to_scipy() @ x
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
