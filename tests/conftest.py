"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single-device CPU; multi-device tests spawn subprocesses (see
tests/test_distributed.py) and the 512-device dry-run lives in
src/repro/launch/dryrun.py."""
import numpy as np
import pytest

from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    ichol0,
    narrow_band_lower,
    poisson2d_matrix,
)


@pytest.fixture(scope="session")
def er_matrix():
    return erdos_renyi_lower(700, 2e-3, seed=11)


@pytest.fixture(scope="session")
def nb_matrix():
    return narrow_band_lower(700, 0.14, 10, seed=12)


@pytest.fixture(scope="session")
def ichol_matrix():
    return ichol0(poisson2d_matrix(24))


@pytest.fixture(scope="session", params=["er", "nb", "ichol"])
def any_matrix(request, er_matrix, nb_matrix, ichol_matrix):
    return {"er": er_matrix, "nb": nb_matrix, "ichol": ichol_matrix}[request.param]


@pytest.fixture(scope="session")
def any_dag(any_matrix):
    return dag_from_lower_csr(any_matrix)
