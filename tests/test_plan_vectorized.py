"""The vectorized inspector's contract: ``compile_plan`` (O(nnz) array
passes) is bitwise-identical to ``_reference_compile_plan`` (the original
per-row compiler, kept as the oracle) — every tensor, every dtype — across
matrix shapes, strategies, orientations and widths. Plus the
``ExecPlan.stats()`` nnz-accounting regression (explicit stored zeros)."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.plan import (
    _reference_compile_plan,
    compile_plan,
    plans_bitwise_equal,
)
from repro.pipeline import schedule
from repro.sparse import (
    csr_from_coo,
    dag_from_lower_csr,
    erdos_renyi_lower,
    transpose_csr,
)
from repro.sparse.csr import permute_symmetric


def _mirror(a):
    """The lower-triangular mirror plan() feeds the compiler for an
    upper-triangular matrix (reverse-permutation trick)."""
    outer = np.arange(a.n_rows, dtype=np.int64)[::-1].copy()
    return permute_symmetric(a, outer)


def _assert_identical(L, sched, **kw):
    vec = compile_plan(L, sched, **kw)
    ref = _reference_compile_plan(L, sched, **kw)
    for name in (
        "row_ids", "col_idx", "vals", "diag", "accum", "step_bounds",
        "val_src", "diag_src",
    ):
        tv, tr = getattr(vec, name), getattr(ref, name)
        assert tv.dtype == tr.dtype, (name, tv.dtype, tr.dtype)
        np.testing.assert_array_equal(tv, tr, err_msg=name)
    assert (vec.n, vec.k, vec.W) == (ref.n, ref.k, ref.W)
    assert plans_bitwise_equal(vec, ref)


@pytest.mark.parametrize("strategy", ["growlocal", "hdagg", "serial"])
@pytest.mark.parametrize("k", [1, 4])
def test_bitwise_equivalence_basic(any_matrix, strategy, k):
    dag = dag_from_lower_csr(any_matrix)
    s = schedule(dag, k, strategy=strategy)
    _assert_identical(any_matrix, s)


def test_bitwise_equivalence_upper_mirror(ichol_matrix):
    m = _mirror(transpose_csr(ichol_matrix))
    s = schedule(dag_from_lower_csr(m), 4, strategy="growlocal")
    _assert_identical(m, s)


@pytest.mark.parametrize("width", [1, 3, 64])
def test_bitwise_equivalence_forced_widths(er_matrix, width):
    """W=1 maximizes virtual-row splitting; W=64 pads everything."""
    s = schedule(dag_from_lower_csr(er_matrix), 4, strategy="growlocal")
    _assert_identical(er_matrix, s, width=width)


def test_bitwise_equivalence_float64(nb_matrix):
    s = schedule(dag_from_lower_csr(nb_matrix), 4, strategy="growlocal")
    _assert_identical(nb_matrix, s, dtype=np.float64)


def test_empty_matrix():
    m = csr_from_coo(0, 0, np.empty(0, np.int64), np.empty(0, np.int64),
                     np.empty(0))
    s = schedule(dag_from_lower_csr(m), 2, strategy="growlocal")
    _assert_identical(m, s)


def test_diagonal_only_matrix():
    idx = np.arange(5, dtype=np.int64)
    m = csr_from_coo(5, 5, idx, idx, np.arange(1.0, 6.0))
    s = schedule(dag_from_lower_csr(m), 3, strategy="hdagg")
    _assert_identical(m, s)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 120),
    density=st.floats(1e-3, 0.3),
    k=st.integers(1, 9),
    width=st.one_of(st.none(), st.integers(1, 16)),
    strategy=st.sampled_from(["growlocal", "hdagg", "serial", "wavefront"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitwise_equivalence_property(n, density, k, width, strategy, seed):
    """Property: for ANY (matrix, schedule, width) the two compilers
    produce identical plans — the wide-row virtual split, padding, and
    source maps all included."""
    m = erdos_renyi_lower(n, density, seed=seed)
    s = schedule(dag_from_lower_csr(m), k, strategy=strategy)
    _assert_identical(m, s, width=width)


@pytest.mark.slow
def test_bitwise_equivalence_full_corpus_grid():
    """Every scenario-corpus matrix x every registered strategy x both
    orientations (ISSUE 4 acceptance: corpus-wide bitwise equivalence)."""
    from repro.autotune import corpus_entry, corpus_names
    from repro.pipeline import available_strategies

    for name in corpus_names():
        L = corpus_entry(name).matrix()
        for m in (L, _mirror(transpose_csr(L))):
            dag = dag_from_lower_csr(m)
            for strategy in available_strategies():
                _assert_identical(m, schedule(dag, 8, strategy=strategy))


# ------------------------------------------------- stats() nnz accounting
def test_stats_counts_explicit_zero_entries():
    """Regression: a stored-but-zero off-diagonal entry is still a real
    plan slot — stats() must count from ``val_src >= 0``, not from
    ``vals != 0``."""
    rows = np.array([0, 1, 1, 2, 2], dtype=np.int64)
    cols = np.array([0, 0, 1, 0, 2], dtype=np.int64)
    vals = np.array([2.0, 0.0, 3.0, 0.0, 4.0])  # two explicit zeros
    m = csr_from_coo(3, 3, rows, cols, vals)
    s = schedule(dag_from_lower_csr(m), 2, strategy="serial")
    plan = compile_plan(m, s)
    nnz_slots = plan.col_idx.shape[0] * plan.k * plan.W
    got = plan.stats()["nnz_slot_utilization"]
    assert got == 2 / nnz_slots  # the 2 stored off-diagonal entries
    assert got > (plan.vals != 0).sum() / nnz_slots  # old accounting undercounts
    # plans without source maps keep the value-based fallback
    plan.val_src = None
    assert plan.stats()["nnz_slot_utilization"] == 0.0
