"""Optional-``hypothesis`` shim for the property-based tests.

The container image does not always ship ``hypothesis`` (it is listed in
``requirements-dev.txt``). Importing it unguarded used to kill test
*collection* for five whole modules — including all their plain pytest
tests. This shim keeps the modules importable either way:

  * with hypothesis installed: re-exports the real ``given`` / ``settings``
    / ``strategies``;
  * without: ``@given(...)`` marks just that test as skipped, and
    ``settings`` / ``strategies`` become inert stand-ins, so every
    non-property test in the module still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Accepts any strategy-builder call chain and returns itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    strategies = _Strategy()
