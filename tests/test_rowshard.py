"""Host-only properties of the row partitioner (``core.rowshard``):
halo coverage, certificate enforcement, padding invariants, table
consistency and the comm-volume model. Device execution is covered by
the subprocess conformance grid in ``test_rowshard_distributed.py``."""
import numpy as np
import pytest

from _hyp import given, settings, strategies as st
from repro.core import (
    apply_reordering,
    compile_plan,
    elastic_transform,
    partition_plan,
)
from repro.core.elastic import step_dependencies
from repro.pipeline.registry import ScheduleOptions, get_scheduler
from repro.sparse import dag_from_lower_csr
from repro.sparse.generators import erdos_renyi_lower, narrow_band_lower


def _plan_for(L, k=8, strategy="growlocal"):
    dag = dag_from_lower_csr(L)
    s = get_scheduler(strategy)(dag, ScheduleOptions(k=k))
    L2, s2, _, _ = apply_reordering(L, s)
    return compile_plan(L2, s2)


def _cross_edges(plan, owner, n_shards):
    """The ground-truth cross-shard dependency set, computed directly
    from the plan's gathers: every (row, dest shard) pair where a lane
    of a different shard reads the row."""
    n = plan.n
    kp = plan.k
    k_local = kp // n_shards
    lane = np.broadcast_to(
        np.arange(kp, dtype=np.int64)[None, :, None], plan.col_idx.shape
    )
    reader = lane // k_local
    owner_pad = np.concatenate([owner.astype(np.int64), [-1]])
    cross = (plan.col_idx != n) & (owner_pad[plan.col_idx] != reader)
    u = plan.col_idx[cross].astype(np.int64)
    d = reader[cross]
    return set(zip(u.tolist(), d.tolist()))


@pytest.mark.parametrize("n_shards", [2, 4, 8])
@pytest.mark.parametrize(
    "make",
    [
        lambda: erdos_renyi_lower(400, 0.01, seed=7),
        lambda: narrow_band_lower(400, 0.2, 6, seed=3),
    ],
    ids=["er", "band"],
)
def test_halo_covers_exactly_cross_shard_edges(make, n_shards):
    """The halo plan contains exactly the cross-shard dependency edges —
    nothing missing (correctness) and nothing extra (no overshipping)."""
    plan = _plan_for(make())
    rsp = partition_plan(plan, n_shards)

    # recompute the ground truth on the padded plan the partitioner saw
    from repro.core.rowshard import _pad_lanes

    padded = _pad_lanes(plan, rsp.n_shards * rsp.k_local)
    truth = _cross_edges(padded, rsp.owner, n_shards)
    assert rsp.halo_pairs == len(truth)

    # reassemble the (row, dest) pairs from the emitted ring tables:
    # recv slot n_loc + rank identifies the halo row via the g2l order
    shipped = set()
    for rnd in rsp.rounds:
        for h, ss, rt in rnd.hops:
            for src in range(n_shards):
                dst = (src + h) % n_shards
                for p in range(ss.shape[1]):
                    s_slot, r_slot = int(ss[src, p]), int(rt[dst, p])
                    if s_slot == rsp.scratch:
                        assert r_slot == rsp.scratch  # padding -> padding
                        continue
                    # sender slot is the owner's owned slot of a global row
                    owned = np.flatnonzero(
                        (rsp.owner == src) & (rsp.local_slot == s_slot)
                    )
                    assert owned.size == 1
                    shipped.add((int(owned[0]), dst))
                    assert rsp.n_loc <= r_slot < rsp.scratch  # a halo slot
    assert shipped == truth


@pytest.mark.parametrize("n_shards", [2, 4])
def test_halo_rounds_match_writer_rounds(n_shards):
    """Each boundary row is shipped exactly once, in the round that
    writes it — never before (the value would be garbage), never after
    (a consumer round would read a stale halo slot)."""
    plan = _plan_for(erdos_renyi_lower(300, 0.015, seed=11))
    rsp = partition_plan(plan, n_shards)
    from repro.core.rowshard import _pad_lanes

    padded = _pad_lanes(plan, rsp.n_shards * rsp.k_local)
    writer_step, _, _ = step_dependencies(padded)
    sb = np.asarray(padded.step_bounds)
    sup_of_step = np.repeat(
        np.arange(len(sb) - 1, dtype=np.int64), np.diff(sb)
    )
    for r, rnd in enumerate(rsp.rounds):
        for h, ss, rt in rnd.hops:
            for src in range(n_shards):
                for p in range(ss.shape[1]):
                    s_slot = int(ss[src, p])
                    if s_slot == rsp.scratch:
                        continue
                    g = np.flatnonzero(
                        (rsp.owner == src) & (rsp.local_slot == s_slot)
                    )[0]
                    assert sup_of_step[writer_step[g]] == r


def test_certificate_rejects_invalid_fusion():
    """Fusing ALL supersteps into one round removes every exchange — on
    any DAG with cross-shard deps the partitioner must refuse."""
    plan = _plan_for(erdos_renyi_lower(300, 0.02, seed=5))
    rsp = partition_plan(plan, 4)
    if rsp.halo_pairs == 0:
        pytest.skip("no cross-shard deps in this instance")
    S = len(plan.step_bounds) - 1
    with pytest.raises(ValueError, match="certif"):
        partition_plan(plan, 4, exchange_bounds=(0, S))


def test_elastic_fused_bounds_certify():
    """The elastic certificate's fused_bounds always pass the
    partitioner's check, and shrink the exchange count to F-1."""
    plan = _plan_for(narrow_band_lower(500, 0.15, 8, seed=2))
    ep = elastic_transform(plan, 8)
    fb = tuple(int(x) for x in ep.fused_bounds)
    rsp = partition_plan(plan, 4, exchange_bounds=fb)
    assert rsp.n_rounds == len(fb) - 1
    assert len(rsp.rounds) == rsp.n_rounds - 1
    base = partition_plan(plan, 4)
    assert rsp.n_rounds <= base.n_rounds
    # same boundary set, grouped differently
    assert rsp.halo_pairs == base.halo_pairs


def test_exchange_bounds_validation():
    plan = _plan_for(erdos_renyi_lower(100, 0.03, seed=1))
    S = len(plan.step_bounds) - 1
    for bad in [(0,), (1, S), (0, S + 1), (0, 0, S)]:
        with pytest.raises(ValueError):
            partition_plan(plan, 2, exchange_bounds=bad)
    with pytest.raises(ValueError):
        partition_plan(plan, 0)


@pytest.mark.parametrize("n_shards", [2, 3, 4, 8])
def test_partition_invariants(n_shards):
    """Structural invariants: lane padding, ownership partition, local
    plan shapes, slot ranges, b/x index maps."""
    plan = _plan_for(erdos_renyi_lower(350, 0.015, seed=9), k=6)
    rsp = partition_plan(plan, n_shards)
    assert rsp.k_local * n_shards >= plan.k  # lanes padded up
    assert rsp.k_local == -(-plan.k // n_shards)
    # ownership is a partition of [0, n)
    assert rsp.owner.shape == (plan.n,)
    assert rsp.owner.min() >= 0 and rsp.owner.max() < n_shards
    for j in range(n_shards):
        slots = rsp.local_slot[rsp.owner == j]
        assert sorted(slots.tolist()) == list(range(slots.size))
        assert slots.size <= rsp.n_loc
    # shards share shapes and live in the local slot space
    for sp in rsp.shards:
        assert sp.k == rsp.k_local and sp.n == rsp.scratch
        assert sp.row_ids.shape == (rsp.T, rsp.k_local)
        assert sp.row_ids.max() <= rsp.scratch
        assert sp.col_idx.max() <= rsp.scratch
    # the flat maps are injective on their target regions
    assert np.unique(rsp.b_scatter).size == plan.n
    assert np.unique(rsp.x_gather).size == plan.n
    assert rsp.x_gather.max() < n_shards * rsp.n_loc


def test_ring_and_psum_tables_agree():
    """Both lowered forms of each round describe the same value motion:
    same per-round pair count and the same (send slot -> recv slot)
    multiset per (src, dst) shard pair."""
    plan = _plan_for(narrow_band_lower(400, 0.2, 6, seed=8))
    rsp = partition_plan(plan, 4)
    for rnd in rsp.rounds:
        ring_pairs = 0
        for h, ss, rt in rnd.hops:
            real = ss != rsp.scratch
            ring_pairs += int(real.sum())
        assert ring_pairs == rnd.n_values
        # psum: each distinct row appears once in the send tables
        send_real = rnd.send_slot != rsp.scratch
        assert int(send_real.sum()) == rnd.buf_size
        recv_real = rnd.recv_slot != rsp.scratch
        assert int(recv_real.sum()) == rnd.n_values
        assert rnd.recv_pos[recv_real].max(initial=-1) < rnd.buf_size


def test_comm_stats_model():
    plan = _plan_for(narrow_band_lower(600, 0.14, 8, seed=2))
    rsp = partition_plan(plan, 4)
    cs = rsp.comm_stats()
    assert cs["allgather_values"] == 4 * rsp.k_local * rsp.T
    assert cs["halo_bytes_per_solve"] == cs["halo_values_per_solve"] * 4
    assert cs["halo_ratio"] == pytest.approx(
        cs["halo_values_per_solve"] / cs["allgather_values"]
    )
    assert cs["exchange_rounds"] == rsp.n_rounds
    # the paper's locality claim, on the structure the §5 reorder gives
    # a banded instance: halo traffic far under the all-gather baseline
    assert cs["halo_ratio"] <= 0.25


def test_single_shard_degenerate():
    """n_shards=1: no halo, no rounds, the shard IS the plan."""
    plan = _plan_for(erdos_renyi_lower(200, 0.02, seed=4))
    rsp = partition_plan(plan, 1)
    assert rsp.n_halo == 0 and rsp.halo_pairs == 0
    assert all(r.n_values == 0 for r in rsp.rounds)
    assert np.all(rsp.owner == 0)
    cs = rsp.comm_stats()
    assert cs["halo_values_per_solve"] == 0


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_shards=st.sampled_from([2, 4, 8]),
)
def test_halo_coverage_property(seed, n_shards):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(60, 300))
    plan = _plan_for(erdos_renyi_lower(n, 0.03, seed=seed % 997))
    rsp = partition_plan(plan, n_shards)
    from repro.core.rowshard import _pad_lanes

    padded = _pad_lanes(plan, rsp.n_shards * rsp.k_local)
    truth = _cross_edges(padded, rsp.owner, n_shards)
    assert rsp.halo_pairs == len(truth)
    assert sum(r.n_values for r in rsp.rounds) == len(truth)


def test_halo_coverage_seeded():
    """Deterministic stand-in when hypothesis is unavailable."""
    rng = np.random.default_rng(20260809)
    for seed in rng.integers(0, 1000, size=4):
        n_shards = int(rng.choice([2, 4, 8]))
        plan = _plan_for(erdos_renyi_lower(150, 0.03, seed=int(seed)))
        rsp = partition_plan(plan, n_shards)
        from repro.core.rowshard import _pad_lanes

        padded = _pad_lanes(plan, rsp.n_shards * rsp.k_local)
        truth = _cross_edges(padded, rsp.owner, n_shards)
        assert rsp.halo_pairs == len(truth)
        assert sum(r.n_values for r in rsp.rounds) == len(truth)
