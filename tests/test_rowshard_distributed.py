"""Subprocess CPU-mesh conformance for the row-sharded solve
(``shard="rows"``): the partitioned executor must be *bitwise* equal to
the single-chip scan executor — the partitioner only relabels rows into
local slots; every float op runs in the same order on the same values
(``solver/executor.py``'s fixed-order lane reduction makes that hold at
any shard count). The grid covers corpus x orientation x RHS shape on
two mesh shapes, plus the elastic fused-exchange path, the
update_values contract, describe() telemetry and the timed
per-exchange-round path. Host-side partitioner properties live in
``test_rowshard.py``."""
from _mesh import run_in_mesh_subprocess


def _run(code: str, devices: int = 8, timeout: int = 600):
    return run_in_mesh_subprocess(code, devices=devices, timeout=timeout)


def test_rowshard_bitwise_conformance_grid():
    """Corpus x lower/upper x 1/multi-RHS x two mesh shapes: the sharded
    solve matches the scan backend bit for bit, and the repo's canonical
    ``direct_reference`` replay agrees the same way."""
    print(_run("""
        import numpy as np, jax
        from repro.pipeline import PlanCache, TriangularSolver
        from repro.serve.service import direct_reference
        from repro.sparse import transpose_csr
        from repro.sparse.generators import erdos_renyi_lower, narrow_band_lower

        mats = {
            "er": erdos_renyi_lower(700, 2.5e-3, seed=9),
            "band": narrow_band_lower(700, 0.12, 7, seed=2),
        }
        cache = PlanCache()
        for mesh_shape in [(2, 4), (1, 8)]:
            mesh = jax.make_mesh(mesh_shape, ("data", "model"))
            for name, L in mats.items():
                for lower in (True, False):
                    a = L if lower else transpose_csr(L)
                    ref = TriangularSolver.plan(
                        a, k=8, lower=lower, backend="scan", cache=cache)
                    s = TriangularSolver.plan(
                        a, k=8, lower=lower, backend="distributed",
                        mesh=mesh, shard="rows", cache=cache,
                        validate="fast")
                    d = s.bound.describe()
                    assert d["shard"] == "rows", d
                    assert d["n_shards"] == mesh_shape[1], d
                    rng = np.random.default_rng(7)
                    b1 = rng.standard_normal(700).astype(np.float32)
                    B = rng.standard_normal((700, 3)).astype(np.float32)
                    x1 = np.asarray(s.solve(b1))
                    assert np.array_equal(x1, np.asarray(ref.solve(b1))), (
                        mesh_shape, name, lower, "rhs1")
                    assert np.array_equal(
                        np.asarray(s.solve(B)), np.asarray(ref.solve(B))
                    ), (mesh_shape, name, lower, "mrhs")
                    # canonical same-compiled-family replay, bit for bit
                    assert np.array_equal(
                        x1, np.asarray(direct_reference(s, b1))
                    ), (mesh_shape, name, lower, "direct_reference")
        print("rowshard-conformance-ok")
    """))


def test_rowshard_elastic_fused_exchange_bitwise():
    """mode="elastic" on shard="rows" executes the fused-barrier
    certificate as fewer exchange rounds — still bitwise equal to the
    single-chip solve, and describe() reports the fusion."""
    print(_run("""
        import numpy as np, jax
        from repro.pipeline import TriangularSolver
        from repro.sparse.generators import narrow_band_lower

        a = narrow_band_lower(900, 0.1, 6, seed=4)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ref = TriangularSolver.plan(a, k=8, backend="scan")
        s = TriangularSolver.plan(
            a, k=8, backend="distributed", mesh=mesh, shard="rows",
            mode="elastic", slack=8, validate="fast")
        bulk = TriangularSolver.plan(
            a, k=8, backend="distributed", mesh=mesh, shard="rows",
            validate="fast")
        d = s.bound.describe()
        db = bulk.bound.describe()
        ex, exb = d["exchange"], db["exchange"]
        assert ex["rounds"] <= exb["rounds"], (ex, exb)
        assert ex["executed_fusion"] >= 1.0
        b = np.random.default_rng(3).standard_normal(900).astype(np.float32)
        xr = np.asarray(ref.solve(b))
        assert np.array_equal(np.asarray(s.solve(b)), xr)
        assert np.array_equal(np.asarray(bulk.solve(b)), xr)
        print("rowshard-elastic-ok", exb["rounds"], "->", ex["rounds"])
    """))


def test_rowshard_update_values_and_timed():
    """Device-side value refresh equals a fresh bind bitwise; the timed
    path (one dispatch per exchange round) returns the same bits as the
    fused solve and reports per-round halo traffic."""
    print(_run("""
        import numpy as np, jax
        from repro.pipeline import TriangularSolver
        from repro.sparse.generators import erdos_renyi_lower

        a = erdos_renyi_lower(600, 3e-3, seed=11)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        s = TriangularSolver.plan(
            a, k=8, backend="distributed", mesh=mesh, shard="rows",
            validate="fast")
        b = np.random.default_rng(5).standard_normal(600).astype(np.float32)
        x0 = np.asarray(s.solve(b))

        # timed path: same bits, one entry per exchange round
        x_t, steps = s.solve_timed(b)
        assert np.array_equal(np.asarray(x_t), x0)
        ex = s.bound.describe()["exchange"]
        assert len(steps) == ex["rounds"], (len(steps), ex["rounds"])
        assert all("us" in st and "halo_values" in st for st in steps)
        assert sum(st["halo_values"] for st in steps) == \\
            ex["halo_values_per_solve"]

        # numeric refresh == fresh bind, bitwise
        import dataclasses
        rng = np.random.default_rng(12)
        a2 = dataclasses.replace(
            a, data=a.data * rng.uniform(0.5, 2.0, a.nnz))
        s.numeric_update(a2)
        fresh = TriangularSolver.plan(
            a2, k=8, backend="distributed", mesh=mesh, shard="rows")
        x1 = np.asarray(s.solve(b))
        assert np.array_equal(x1, np.asarray(fresh.solve(b)))
        assert not np.array_equal(x1, x0)
        print("rowshard-update-timed-ok")
    """))


def test_rowshard_describe_comm_telemetry():
    """describe() carries the halo comm model next to the all-gather
    baseline; on a banded instance the halo traffic is far below it
    (the acceptance bound: <= 25%)."""
    print(_run("""
        import numpy as np, jax
        from repro.pipeline import TriangularSolver
        from repro.sparse.generators import narrow_band_lower

        a = narrow_band_lower(800, 0.1, 8, seed=6)
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        s = TriangularSolver.plan(
            a, k=8, backend="distributed", mesh=mesh, shard="rows",
            validate="fast")
        d = s.bound.describe()
        assert d["backend"] == "distributed" and d["shard"] == "rows"
        ex = d["exchange"]
        for key in ("mode", "rounds", "halo_pairs",
                    "halo_values_per_solve", "halo_bytes_per_solve",
                    "allgather_values", "allgather_bytes", "halo_ratio",
                    "comm_values_per_solve", "comm_bytes_per_solve"):
            assert key in ex, key
        assert ex["mode"] == "ring"
        assert ex["halo_ratio"] <= 0.25, ex["halo_ratio"]
        assert ex["comm_values_per_solve"] == ex["halo_values_per_solve"]
        assert s.info()["shard"] == "rows"
        print("rowshard-describe-ok", round(ex["halo_ratio"], 4))
    """))
