"""Mesh-sharded serving — the distributed backend behind ``SolveService``.

The distributed backend needs >1 device, so the whole serve conformance
cell runs in a subprocess with XLA_FLAGS forcing the host device count
(tests/_mesh.py — the same isolation as tests/test_backends.py).

What the subprocess asserts (the ISSUE 5 acceptance bar):

  * every served result is bitwise-equal to ``direct_reference`` on the
    pinned plan version at the recorded (width, position) — through the
    mesh-sharded executor;
  * the worker loop aligns dispatch widths to the mesh's ``data`` axis
    (batches shard instead of padding inside the backend), and the
    alignment is surfaced in ``stats()`` along with the mesh shape;
  * live ``numeric_update`` works against the sharded binding (version
    pinning unchanged);
  * ``close()`` joins the workers and releases every plan pin.
"""
from _mesh import run_in_mesh_subprocess


def test_distributed_serve_subprocess():
    out = run_in_mesh_subprocess("""
        import numpy as np, jax, threading
        from repro.serve import SolveService, direct_reference
        from repro.sparse.generators import erdos_renyi_lower

        # data axis 3: pow2 dispatch widths (2, 4) must round UP to the
        # axis multiple (3, 6) — the non-trivial alignment case
        mesh = jax.make_mesh((3, 2), ("data", "model"))
        mats = [erdos_renyi_lower(120, 0.03, seed=101),
                erdos_renyi_lower(160, 0.02, seed=102)]
        svc = SolveService(
            max_batch=4, max_wait_us=50_000, n_workers=2,
            strategy="growlocal", k=2, backend="distributed", mesh=mesh,
        )
        fps = [svc.register(m) for m in mats]
        ns = {fp: m.n_rows for fp, m in zip(fps, mats)}

        snap = svc.stats()
        assert snap["serving"]["batch_align"] == 3, snap["serving"]
        assert snap["serving"]["mesh"] == {"data": 3, "model": 2}
        for fp in fps:
            binding = snap["patterns"][fp]["binding"]
            assert binding["backend"] == "distributed"
            assert binding["mesh"] == {"data": 3, "model": 2}

        # concurrent clients over both routes
        out_lists = [[] for _ in range(4)]
        def client(ci):
            rng = np.random.default_rng(500 + ci)
            for j in range(3):
                fp = fps[(ci + j) % 2]
                b = rng.standard_normal(ns[fp]).astype(np.float32)
                t = svc.submit(fp, b)
                out_lists[ci].append((t, b, t.result(120)))
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(4)]
        for t in threads: t.start()
        for t in threads: t.join()

        served = [s for c in out_lists for s in c]
        assert len(served) == 12
        for ticket, b, x in served:
            # widths aligned to the data axis, never the raw pow2
            assert ticket.batch_width % 3 == 0, ticket.batch_width
            ref = direct_reference(
                ticket.served_by, b, ticket.batch_width,
                ticket.batch_position,
            )
            assert np.array_equal(x, ref), (
                ticket.fingerprint[:8], ticket.batch_width,
                ticket.batch_position,
            )

        # live refactorization against the sharded binding
        v = svc.numeric_update(fps[0], mats[0].data * 2.0)
        assert v == 1
        b = np.ones(ns[fps[0]], np.float32)
        t = svc.submit(fps[0], b)
        x = t.result(120)
        assert t.version == 1
        assert np.array_equal(
            x, direct_reference(t.served_by, b, t.batch_width,
                                t.batch_position))

        snap = svc.stats()
        assert snap["completed"] == 13 and snap["failed"] == 0
        report = svc.close(timeout=120)
        assert report["workers_alive"] == []
        assert report["pins_released"] == 2
        print("dist-serve-ok")
    """, devices=6)
    assert "dist-serve-ok" in out
