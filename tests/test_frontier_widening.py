"""Beyond-paper frontier-widening option (EXPERIMENTS.md §Perf, scheduler
iterations): off by default (paper-faithful), opt-in must stay valid and
must improve the single-source-grid regime."""
import numpy as np

from repro.core import bsp_cost, check_validity, schedule_stats
from repro.core.growlocal import grow_local
from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    ichol0,
    narrow_band_lower,
    poisson2d_matrix,
)


def test_widening_valid_everywhere():
    for L in (
        ichol0(poisson2d_matrix(40)),
        erdos_renyi_lower(1500, 1e-3, seed=3),
        narrow_band_lower(1500, 0.14, 10, seed=4),
    ):
        dag = dag_from_lower_csr(L)
        s = grow_local(dag, 8, frontier_widening=True)
        check_validity(dag, s)


def test_widening_breaks_serial_takeover():
    """Single-source IC0 grid at paper-filter scale: faithful GrowLocal
    emits one serial superstep; widening unlocks the wavefront parallelism."""
    dag = dag_from_lower_csr(ichol0(poisson2d_matrix(120)))
    base = grow_local(dag, 8)
    widened = grow_local(dag, 8, frontier_widening=True)
    assert base.n_supersteps == 1  # the takeover regime
    assert widened.n_supersteps > 1
    assert bsp_cost(dag, widened) < bsp_cost(dag, base)


def test_widening_near_noop_on_wide_dags():
    """Many-source DAGs: the rule must not fire destructively (<5% cost)."""
    dag = dag_from_lower_csr(erdos_renyi_lower(4000, 6e-4, seed=5))
    base = grow_local(dag, 8)
    widened = grow_local(dag, 8, frontier_widening=True)
    assert bsp_cost(dag, widened) <= 1.05 * bsp_cost(dag, base)
