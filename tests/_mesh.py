"""Shared multi-device subprocess harness.

jax locks the host device count at first init, so anything needing a
multi-device CPU mesh (shard_map executors, the distributed backend)
runs in a subprocess with XLA_FLAGS forcing the device count. One
helper, used by tests/test_distributed.py, tests/test_backends.py and
tests/test_conformance.py, so the isolation recipe lives in one place.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_in_mesh_subprocess(
    code: str, *, devices: int = 8, timeout: int = 600
) -> str:
    """Run ``code`` in a fresh interpreter with ``devices`` forced CPU
    devices and PYTHONPATH=src; asserts exit 0 and returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
