"""Serve scale-out: width-class cross-pattern batching, multi-worker
serving, and the lifecycle/metrics hardening that rides with them.

Acceptance bars:

  * structurally-identical patterns (one ``width_class``) coalesce into
    single grouped dispatches, and every grouped result stays bitwise-
    reproducible via its ticket's ``served_by`` replay at the recorded
    (width, position) — including across interleaved ``numeric_update``s
    (versions differ per column inside one batch);
  * the grouped kernel's lane independence: a column's bits depend only
    on its own (plan, rhs), never on neighbor columns' plans or values;
  * ``n_workers > 1`` serves concurrent multi-route traffic bitwise-
    correctly with interleaved updates;
  * ``close(timeout)`` never releases plan-cache pins while a worker is
    still alive (the LRU-eviction-vs-in-flight-batch race);
  * the throughput window survives a batch draining after ``reset()``.
"""
import threading
import time

import numpy as np
import pytest

from repro.pipeline import PlanCache, TriangularSolver, grouped_solve
from repro.serve import (
    GroupReplay,
    ServeMetrics,
    SolveService,
    direct_reference,
    make_sampler,
    normalize_max_batch,
    pad_width,
    run_closed_loop,
    width_class_patterns,
)
from repro.sparse import shifted_coupling_lower
from repro.sparse.generators import erdos_renyi_lower

STRATEGY = "wavefront"  # level scheduler: shift-invariant plan shapes
N = 96


@pytest.fixture(scope="module")
def family():
    return [shifted_coupling_lower(N, j, seed=40 + j) for j in range(4)]


@pytest.fixture(scope="module")
def family_solvers(family):
    return [TriangularSolver.plan(m, strategy=STRATEGY) for m in family]


# ------------------------------------------------------ width-class identity
def test_family_is_distinct_patterns_one_width_class(family, family_solvers):
    from repro.sparse.csr import pattern_fingerprint

    fps = {pattern_fingerprint(m) for m in family}
    assert len(fps) == len(family)  # structurally distinct...
    assert len({s.width_class for s in family_solvers}) == 1  # ...one class
    assert all(s.supports_grouping for s in family_solvers)


def test_width_class_separates_real_structural_differences(family_solvers):
    other = TriangularSolver.plan(
        erdos_renyi_lower(N, 0.05, seed=77), strategy=STRATEGY
    )
    assert other.width_class != family_solvers[0].width_class
    # a different backend binding is a different class even on equal shapes
    s0 = family_solvers[0]
    interp = TriangularSolver.plan(
        shifted_coupling_lower(N, 0, seed=40),
        strategy=STRATEGY,
        backend="pallas",
        interpret=True,
    )
    assert interp.width_class != s0.width_class


def test_plan_cache_width_class_index(family):
    cache = PlanCache()
    solvers = [
        TriangularSolver.plan(m, strategy=STRATEGY, cache=cache)
        for m in family
    ]
    for s in solvers:
        cache.note_width_class(s.width_class, s.plan_key)
    wc = solvers[0].width_class
    assert cache.width_class_members(wc) == frozenset(
        s.plan_key for s in solvers
    )
    assert cache.width_class_sizes()[wc] == len(family)
    cache.clear()
    assert cache.width_class_sizes() == {}


def test_plan_cache_width_class_index_bounded_by_eviction(family):
    """Index entries leave with their evicted plan — a bounded LRU under
    pattern churn must not accumulate width-class keys forever."""
    cache = PlanCache(maxsize=1)
    for m in family:
        s = TriangularSolver.plan(m, strategy=STRATEGY, cache=cache)
        cache.note_width_class(s.width_class, s.plan_key)
    # one live entry -> at most its one index key survives
    assert sum(cache.width_class_sizes().values()) == 1


# ------------------------------------------------- grouped-kernel contracts
def test_grouped_solve_matches_per_solver_solves(family, family_solvers):
    """Each grouped column solves ITS OWN system: checked against the
    scipy-free dense reference of that column's matrix."""
    from repro.sparse.csr import csr_to_dense

    rng = np.random.default_rng(0)
    B = rng.standard_normal((N, len(family_solvers))).astype(np.float32)
    X = np.asarray(grouped_solve(family_solvers, B))
    for j, (m, s) in enumerate(zip(family, family_solvers)):
        dense = csr_to_dense(m).astype(np.float64)
        ref = np.linalg.solve(dense, B[:, j].astype(np.float64))
        np.testing.assert_allclose(X[:, j], ref, rtol=2e-4, atol=2e-5)


def test_grouped_lane_independence_and_replay(family_solvers):
    """The bedrock of the grouped bitwise contract: at a fixed (width,
    position), a lane's bits depend only on its own (plan, b) — vary the
    neighbor lanes' plans AND values, the lane never moves; replaying
    with the lane's own solver replicated everywhere reproduces it."""
    rng = np.random.default_rng(1)
    b = rng.standard_normal(N).astype(np.float32)
    w = len(family_solvers)
    for pos in (0, w - 1):
        fixed = None
        for trial in range(3):
            order = list(rng.permutation(w))
            solvers = [family_solvers[i] for i in order]
            solvers[pos] = family_solvers[0]
            B = rng.standard_normal((N, w)).astype(np.float32)
            B[:, pos] = b
            col = np.asarray(grouped_solve(solvers, B))[:, pos]
            if fixed is None:
                fixed = col
            assert np.array_equal(col, fixed), (pos, trial)
        replay = direct_reference(GroupReplay(family_solvers[0]), b, w, pos)
        assert np.array_equal(replay, fixed)


def test_group_bank_bitwise_matches_grouped_solve(family_solvers):
    """The serving fast path (device bank, lanes indexed inside the jit)
    must be bitwise-identical to the stack-per-call ``grouped_solve`` —
    that identity is what lets ``GroupReplay`` verify bank-served
    results. Checked across compositions and bank sizes (pow2 lane
    padding means P=4 and P=6-padded-to-8 compile different variants)."""
    from repro.pipeline import GroupBank

    rng = np.random.default_rng(4)
    bank = GroupBank()
    for i, s in enumerate(family_solvers):
        bank.add(i, s)
    assert len(bank) == len(family_solvers)
    for comp in ([0, 1, 2, 3], [3, 3, 0, 2], [1, 0, 1, 0]):
        B = rng.standard_normal((N, len(comp))).astype(np.float32)
        got = np.asarray(bank.solve(comp, B))
        ref = np.asarray(
            grouped_solve([family_solvers[i] for i in comp], B)
        )
        assert np.array_equal(got, ref), comp
    # membership churn: drop + prune invalidate and rebuild lazily
    rebuilds = bank.rebuilds
    bank.drop(3)
    bank.prune(lambda k: k != 2)
    assert len(bank) == 2
    B = rng.standard_normal((N, 2)).astype(np.float32)
    got = np.asarray(bank.solve([0, 1], B))
    ref = np.asarray(grouped_solve(family_solvers[:2], B))
    assert np.array_equal(got, ref)
    assert bank.rebuilds == rebuilds + 1
    assert bank.describe() == {"n_lanes": 2, "rebuilds": bank.rebuilds}


def test_group_bank_rejects_wrong_members(family_solvers):
    from repro.pipeline import GroupBank

    bank = GroupBank()
    bank.add("a", family_solvers[0])
    other = TriangularSolver.plan(
        erdos_renyi_lower(N, 0.05, seed=79), strategy=STRATEGY
    )
    with pytest.raises(ValueError, match="one width class"):
        bank.add("b", other)
    dist = TriangularSolver.plan(
        shifted_coupling_lower(N, 0, seed=40),
        strategy=STRATEGY,
        backend="pallas",
        interpret=True,
    )
    with pytest.raises(NotImplementedError, match="grouped"):
        bank.add("c", dist)


def test_grouped_solve_rejects_mixed_classes_and_bad_shapes(family_solvers):
    other = TriangularSolver.plan(
        erdos_renyi_lower(N, 0.05, seed=78), strategy=STRATEGY
    )
    with pytest.raises(ValueError, match="one width class"):
        grouped_solve([family_solvers[0], other], np.zeros((N, 2)))
    with pytest.raises(ValueError, match="one column per solver"):
        grouped_solve(family_solvers[:2], np.zeros((N, 3)))
    with pytest.raises(ValueError, match="at least one"):
        grouped_solve([], np.zeros((N, 0)))


# ------------------------------------------------ service: width-class mode
def test_service_coalesces_across_patterns_bitwise(family):
    with SolveService(
        max_batch=8,
        max_wait_us=300_000,
        width_class_batching=True,
        strategy=STRATEGY,
    ) as svc:
        pats = width_class_patterns(svc, 4, n=N, seed=50)
        rng = np.random.default_rng(2)
        tickets = []
        for i in range(8):
            fp, n = pats[i % len(pats)]
            b = rng.standard_normal(n).astype(np.float32)
            tickets.append((svc.submit(fp, b), b))
        for t, b in tickets:
            x = t.result(60)
            assert isinstance(t.served_by, GroupReplay)
            assert np.array_equal(
                x,
                direct_reference(
                    t.served_by, b, t.batch_width, t.batch_position
                ),
            )
        snap = svc.stats()
    # 8 requests over 4 patterns coalesced into FEW cross-pattern batches
    # (per-fingerprint routing would have needed >= 4 dispatches)
    assert snap["grouped_batches"] >= 1
    assert snap["batches"] < len(tickets)
    assert snap["completed"] == len(tickets) and snap["failed"] == 0
    wcs = snap["width_classes"]
    assert len(wcs) == 1 and next(iter(wcs.values()))["n_patterns"] == 4
    for fp, _ in pats:
        assert snap["patterns"][fp]["width_class"] in wcs


def test_width_class_batching_with_interleaved_updates(family):
    """Versions differ per column inside one grouped batch: requests
    pinned to v0 and v1 of one pattern plus another pattern ride one
    dispatch, each served with exactly its pinned values."""
    m0 = shifted_coupling_lower(N, 0, seed=60)
    m1 = shifted_coupling_lower(N, 1, seed=61)
    rng = np.random.default_rng(3)
    with SolveService(
        max_batch=8,
        max_wait_us=400_000,
        width_class_batching=True,
        strategy=STRATEGY,
    ) as svc:
        fp0, fp1 = svc.register(m0), svc.register(m1)
        admitted = []
        b = rng.standard_normal(N).astype(np.float32)
        admitted.append((svc.submit(fp0, b), b))
        svc.numeric_update(fp0, m0.data * 2.0)  # queued request stays v0
        b2 = rng.standard_normal(N).astype(np.float32)
        admitted.append((svc.submit(fp0, b2), b2))  # pinned v1
        b3 = rng.standard_normal(N).astype(np.float32)
        admitted.append((svc.submit(fp1, b3), b3))
        results = [(t, b, t.result(60)) for t, b in admitted]
    assert [t.version for t, _, _ in results] == [0, 1, 0]
    for t, b, x in results:
        assert np.array_equal(
            x,
            direct_reference(t.served_by, b, t.batch_width, t.batch_position),
        ), f"version {t.version} served with wrong values"
    # all three rode one grouped dispatch (same width class, one flush)
    widths = {t.batch_width for t, _, _ in results}
    positions = [t.batch_position for t, _, _ in results]
    assert widths == {4} and sorted(positions) == [0, 1, 2]


def test_homogeneous_groups_keep_the_plain_path(family):
    """A width-class batch whose columns all share (pattern, version)
    must serve through the classic multi-RHS path — same bits and
    ``served_by`` identity as width_class_batching=False."""
    m = shifted_coupling_lower(N, 2, seed=62)
    with SolveService(
        max_batch=8,
        max_wait_us=200_000,
        width_class_batching=True,
        strategy=STRATEGY,
    ) as svc:
        fp = svc.register(m)
        tickets = [
            svc.submit(fp, np.ones(N, np.float32)) for _ in range(3)
        ]
        for t in tickets:
            t.result(60)
        solver = svc.pattern(fp).solver_for(0)
        for t in tickets:
            assert t.served_by is solver  # plain path, not a GroupReplay
        assert svc.stats()["grouped_batches"] == 0


# --------------------------------------------------- multi-worker serving
def test_multi_worker_multi_route_bitwise_with_updates():
    """n_workers=3 over 3 routes: concurrent clients, interleaved
    numeric updates, every result bitwise vs its pinned version."""
    mats = [
        erdos_renyi_lower(120, 0.03, seed=81),
        erdos_renyi_lower(160, 0.02, seed=82),
        erdos_renyi_lower(200, 0.02, seed=83),
    ]
    with SolveService(
        max_batch=4, max_wait_us=2000, n_workers=3, strategy="growlocal"
    ) as svc:
        assert svc.n_workers == 3
        fps = [svc.register(m) for m in mats]
        ns = {fp: m.n_rows for fp, m in zip(fps, mats)}
        data = {fp: m.data for fp, m in zip(fps, mats)}
        n_clients, per_client = 6, 8
        out = [[] for _ in range(n_clients)]
        stop = threading.Event()

        def client(ci):
            rng = np.random.default_rng(300 + ci)
            for j in range(per_client):
                fp = fps[(ci + j) % len(fps)]
                b = rng.standard_normal(ns[fp]).astype(np.float32)
                t = svc.submit(fp, b)
                out[ci].append((t, b, t.result(60)))

        def updater():
            k = 0
            while not stop.is_set():
                fp = fps[k % len(fps)]
                svc.numeric_update(fp, data[fp] * (1.0 + 0.1 * (k + 1)))
                k += 1
                stop.wait(0.002)  # responsive shutdown, no sleep tail

        threads = [
            threading.Thread(target=client, args=(i,))
            for i in range(n_clients)
        ]
        up = threading.Thread(target=updater, daemon=True)
        for t in threads:
            t.start()
        up.start()
        for t in threads:
            t.join()
        stop.set()
        up.join(5)
        served = [s for c in out for s in c]
        assert len(served) == n_clients * per_client
        for ticket, b, x in served:
            assert np.array_equal(
                x,
                direct_reference(
                    ticket.served_by, b, ticket.batch_width,
                    ticket.batch_position,
                ),
            ), (ticket.fingerprint[:8], ticket.version)
        snap = svc.stats()
    assert snap["serving"]["n_workers"] == 3
    assert snap["completed"] == len(served) and snap["failed"] == 0


def test_multi_worker_width_class_loadgen():
    """Workers + width-class batching + loadgen driver compose: a
    validated closed loop over one width class with 2 workers."""
    with SolveService(
        max_batch=8,
        max_wait_us=2000,
        n_workers=2,
        width_class_batching=True,
        strategy=STRATEGY,
    ) as svc:
        pats = width_class_patterns(svc, 4, n=N, seed=70)
        sampler = make_sampler(pats, "uniform", seed=7)
        report = run_closed_loop(
            svc, sampler, n_clients=6, requests_per_client=5, validate=True
        )
    assert report["errors"] == 0
    assert report["bitwise_mismatches"] == 0
    assert report["requests"] == 30


# ------------------------------------------------------- lifecycle hardening
def test_close_timeout_retains_pins_until_workers_exit():
    """A worker stuck inside a batch past close(timeout) must NOT lose
    its plan's eviction pin — unpinning would let LRU eviction race the
    in-flight solve. The pins release on a later close() once the
    worker has actually exited."""
    m = erdos_renyi_lower(100, 0.03, seed=90)
    cache = PlanCache(maxsize=1)
    svc = SolveService(
        max_batch=2, max_wait_us=1000, cache=cache, strategy="growlocal"
    )
    fp = svc.register(m)
    vp = svc.pattern(fp)
    release = threading.Event()
    picked = threading.Event()
    real = vp.solver_for(0)

    class _Stall:
        def solve(self, B):
            picked.set()
            release.wait(30)
            return real.solve(B)

    vp._versions[0] = _Stall()
    t = svc.submit(fp, np.ones(100, np.float32))
    assert picked.wait(10)  # the worker holds the batch and is stalled
    report = svc.close(timeout=0.2)
    assert report["workers_alive"], "worker should still be stalled"
    assert report["pins_released"] == 0 and report["pins_retained"] == 1
    assert len(cache.pinned) == 1  # the pin survived the timed-out close
    release.set()
    t.result(60)
    report2 = svc.close(timeout=30)
    assert report2["workers_alive"] == []
    assert report2["pins_released"] == 1 and report2["pins_retained"] == 0
    assert len(cache.pinned) == 0


def test_close_clean_reports_released_pins():
    m = erdos_renyi_lower(80, 0.03, seed=91)
    svc = SolveService(strategy="growlocal")
    svc.register(m)
    report = svc.close(timeout=30)
    assert report == {
        "workers_alive": [],
        "pins_released": 1,
        "pins_retained": 0,
    }
    assert svc.close()["pins_released"] == 0  # idempotent


# ----------------------------------------------------- metrics window fix
def test_throughput_window_anchors_on_first_completion():
    """A batch completing after reset() (warm-up drain) used to leave
    ``_t_first`` None while setting ``_t_last`` — every later snapshot
    then divided by a zero-width window and reported 0.0 solves/s."""
    ms = ServeMetrics()
    ms.record_submit("fp")
    ms.record_batch("fp", 2, queue_waits=[0.0], e2e=[0.0], solve_seconds=0.0)
    ms.reset()
    # the warm-up drain: completions with NO post-reset submit
    ms.record_batch("fp", 4, queue_waits=[0.0], e2e=[0.0], solve_seconds=0.0)
    time.sleep(0.01)
    ms.record_batch("fp", 4, queue_waits=[0.0], e2e=[0.0], solve_seconds=0.0)
    snap = ms.snapshot()
    assert snap["completed"] == 8
    assert snap["elapsed_seconds"] > 0
    assert snap["solves_per_sec"] > 0


def test_failures_also_anchor_the_window():
    ms = ServeMetrics()
    ms.record_failure("fp", 1)
    time.sleep(0.01)
    ms.record_batch("fp", 2, queue_waits=[0.0], e2e=[0.0], solve_seconds=0.0)
    snap = ms.snapshot()
    assert snap["elapsed_seconds"] > 0 and snap["solves_per_sec"] > 0


def test_grouped_batch_metrics_attribution():
    ms = ServeMetrics()
    for fp in ("a", "a", "b"):
        ms.record_submit(fp)
    ms.record_grouped_batch(
        ["a", "a", "b"],
        queue_waits=[0.001] * 3,
        e2e=[0.002] * 3,
        solve_seconds=0.001,
    )
    snap = ms.snapshot()
    assert snap["grouped_batches"] == 1 and snap["batches"] == 1
    assert snap["completed"] == 3 and snap["mean_batch_size"] == 3.0
    assert snap["per_pattern"]["a"]["completed"] == 2
    assert snap["per_pattern"]["b"]["completed"] == 1
    # the batch is counted once globally, not once per pattern
    assert snap["per_pattern"]["a"]["batches"] == 0
    assert snap["grouped_batch_size_hist"] == {3: 1}


# ------------------------------------------------- pow2 width quantization
def test_normalize_max_batch():
    assert [normalize_max_batch(x) for x in (1, 2, 3, 15, 16, 24, 33)] == [
        1, 2, 2, 8, 16, 16, 32,
    ]
    with pytest.raises(ValueError, match="max_batch"):
        normalize_max_batch(0)


def test_pad_width_never_dispatches_non_pow2():
    for mb in (1, 2, 3, 8, 12, 24, 64):
        for m in range(1, mb + 1):
            w = pad_width(m, mb)
            assert w & (w - 1) == 0, (m, mb, w)
            assert w <= normalize_max_batch(mb)


def test_service_normalizes_max_batch():
    with SolveService(max_batch=24, strategy="growlocal") as svc:
        assert svc.max_batch == 16
        assert svc._batcher.max_batch == 16
        assert svc.stats()["serving"]["max_batch"] == 16
