"""Beyond-paper: GrowLocal on pipeline DAGs (core/pipeline_schedule.py)."""
import numpy as np
import pytest

from repro.core.pipeline_schedule import (
    PipelineProblem,
    grow_local_pipeline,
    pipeline_dag,
    pipeline_stats,
)
from repro.core.schedule import check_validity
from repro.sparse.dag import topological_levels


@pytest.mark.parametrize("stages,micro", [(2, 4), (4, 8), (4, 16)])
def test_pipeline_schedule_valid(stages, micro):
    p = PipelineProblem(n_stages=stages, n_microbatches=micro)
    dag, stage = pipeline_dag(p)
    topological_levels(dag)  # acyclic
    sched = grow_local_pipeline(p)
    check_validity(dag, sched)
    # placement constraint respected
    np.testing.assert_array_equal(sched.pi, stage.astype(np.int32))


def test_pipeline_bubble_improves_with_microbatches():
    """More microbatches -> smaller bubble fraction (1F1B-like behaviour).
    With cheap barriers (L=1) the schedule approaches fine ticks."""
    fracs = []
    for micro in (2, 8, 32):
        p = PipelineProblem(n_stages=4, n_microbatches=micro)
        sched = grow_local_pipeline(p, L=1.0)
        fracs.append(pipeline_stats(p, sched)["bubble_fraction"])
    assert fracs[-1] < fracs[0]
    assert fracs[-1] < 0.3  # large-microbatch regime is bubble-light


def test_pipeline_supersteps_scale_with_L():
    """Higher barrier cost L -> GrowLocal glues more work per superstep."""
    p = PipelineProblem(n_stages=4, n_microbatches=16)
    cheap = grow_local_pipeline(p, L=0.1)
    pricey = grow_local_pipeline(p, L=100.0)
    assert pricey.n_supersteps <= cheap.n_supersteps
