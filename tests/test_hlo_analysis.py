"""The trip-count-aware HLO analyzer must match XLA exactly on loop-free
programs and hand-counts on (nested) scans — the §Roofline numbers depend
on it."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_matches_xla_on_loop_free_matmul():
    A = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    B = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = _compile(lambda a, b: a @ b, A, B)
    got = analyze_hlo(c.as_text()).flops
    assert got == 2 * 256 * 512 * 128
    assert got == float(xla_cost_analysis(c).get("flops"))


def test_scan_flops_weighted_by_trip_count():
    def g(x, ws):
        def step(h, w):
            return jnp.tanh(h @ w), None

        return jax.lax.scan(step, x, ws)[0]

    X = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    W = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = _compile(g, X, W)
    cost = analyze_hlo(c.as_text())
    expected = 10 * 2 * 64 * 128 * 128
    assert cost.flops == expected
    # XLA undercounts (body counted once) — that is WHY the analyzer exists
    assert float(xla_cost_analysis(c).get("flops")) < expected


def test_nested_scan_flops():
    def h2(x, ws):
        def outer(hh, w):
            def inner(a, _):
                return jnp.tanh(a @ w), None

            return jax.lax.scan(inner, hh, None, length=5)[0], None

        return jax.lax.scan(outer, x, ws)[0]

    X = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    W = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = _compile(h2, X, W)
    assert analyze_hlo(c.as_text()).flops == 10 * 5 * 2 * 64 * 128 * 128


def test_hbm_counts_weight_stream_per_iteration():
    """Scanned weights must be charged per iteration (the dynamic-slice
    effective-read rule), not once and not at full-stack size."""

    def g(x, ws):
        def step(h, w):
            return jnp.tanh(h @ w), None

        return jax.lax.scan(step, x, ws)[0]

    X = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    W = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = _compile(g, X, W)
    cost = analyze_hlo(c.as_text())
    per_iter_weights = 128 * 128 * 4
    # at least one weight-slice read per iteration...
    assert cost.hbm_bytes >= 10 * per_iter_weights
    # ...and nowhere near 10 reads of the FULL stack
    assert cost.hbm_bytes < 10 * 10 * per_iter_weights
