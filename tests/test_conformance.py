"""Cross-strategy conformance: every registered strategy must produce a
valid schedule AND a correct solve on every scenario-corpus matrix, in
both orientations, for single and batched right-hand sides.

This is the safety net under ``strategy="auto"``: the selector may pick
*any* registry strategy for *any* matrix, so every (strategy, scenario)
cell has to work — including ``block`` and ``serial``, which the scheduler
unit tests exercise only lightly. Solves are checked against the serial
reference oracle (``repro.solver.reference`` via scipy's
``spsolve_triangular``).

The solve grid runs on both execution backends: ``scan`` and
``pallas`` in interpret mode (this container has no TPU; interpret
executes the same kernel logic through the Pallas interpreter, so grid
coverage carries to the kernel path). The grid is corpus-wide
(7 strategies x 9 matrices x 2 orientations x 2 RHS shapes x 2
backends) and therefore ``slow``-marked; plans are shared through one
module-level ``PlanCache`` so each (strategy, matrix, orientation,
backend) is scheduled and compiled once across the RHS parametrization.
"""
import numpy as np
import pytest

from repro.autotune import corpus_entry, corpus_names
from repro.core import check_validity
from repro.pipeline import (
    PlanCache,
    TriangularSolver,
    available_strategies,
    schedule,
)
from repro.sparse import dag_from_lower_csr, transpose_csr

pytestmark = pytest.mark.slow

STRATEGIES = available_strategies()  # all 7 registered strategies
K = 8
RTOL = 1e-3  # f32 executor vs f64 reference, relative to max |x|

# one cache for the whole module: the 1-RHS and multi-RHS cells of a
# (strategy, matrix, orientation) triple share a single compiled plan
_CACHE = PlanCache()


def _solver(
    name: str, strategy: str, lower: bool, backend: str = "scan"
) -> TriangularSolver:
    L = corpus_entry(name).matrix()
    a = L if lower else transpose_csr(L)
    kw = {"interpret": True} if backend == "pallas" else {}
    return TriangularSolver.plan(
        a, strategy=strategy, k=K, lower=lower, cache=_CACHE,
        backend=backend, **kw,
    )


def _reference(name: str, lower: bool, b: np.ndarray) -> np.ndarray:
    from scipy.sparse.linalg import spsolve_triangular

    L = corpus_entry(name).matrix()
    a = L if lower else transpose_csr(L)
    return spsolve_triangular(a.to_scipy().tocsr(), b, lower=lower)


def test_grid_is_complete():
    """The suite really covers all 7 registered strategies (a new registry
    entry must extend the corpus grid, not silently skip it)."""
    assert len(STRATEGIES) == 7
    assert set(STRATEGIES) == {
        "block", "funnel-gl", "growlocal", "hdagg", "serial", "spmp",
        "wavefront",
    }


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("name", corpus_names())
def test_schedule_validity(name, strategy):
    """(a) Def. 2.1 validity for every (strategy, scenario) cell."""
    dag = dag_from_lower_csr(corpus_entry(name).matrix())
    s = schedule(dag, K, strategy=strategy)
    check_validity(dag, s)
    assert s.n == dag.n and s.n_supersteps >= 1


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("n_rhs", [1, 3], ids=["rhs1", "mrhs"])
@pytest.mark.parametrize("lower", [True, False], ids=["lower", "upper"])
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("name", corpus_names())
def test_solve_matches_reference(name, strategy, lower, n_rhs, backend):
    """(b) every cell solves to tolerance against the reference oracle,
    on the scan executor and the Pallas kernel (interpret mode)."""
    solver = _solver(name, strategy, lower, backend)
    # str hash is salted per process — derive the seed from the stable
    # corpus order instead so a near-tolerance failure is reproducible
    rng = np.random.default_rng(
        corpus_names().index(name) * 4 + 2 * int(lower) + int(n_rhs > 1)
    )
    n = solver.n
    b = rng.standard_normal((n, n_rhs)) if n_rhs > 1 else rng.standard_normal(n)
    x = np.asarray(solver.solve(b))
    assert x.shape == b.shape
    B = b.reshape(n, -1)
    X = x.reshape(n, -1)
    for j in range(B.shape[1]):
        ref = _reference(name, lower, B[:, j])
        scale = max(np.abs(ref).max(), 1e-30)
        assert np.abs(X[:, j] - ref).max() / scale < RTOL, (
            f"{strategy} on {name} ({'lower' if lower else 'upper'}, "
            f"rhs {j}) exceeded tolerance"
        )
