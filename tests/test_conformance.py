"""Cross-strategy conformance: every registered strategy must produce a
valid schedule AND a correct solve on every scenario-corpus matrix, in
both orientations, for single and batched right-hand sides.

This is the safety net under ``strategy="auto"``: the selector may pick
*any* registry strategy for *any* matrix, so every (strategy, scenario)
cell has to work — including ``block`` and ``serial``, which the scheduler
unit tests exercise only lightly. Solves are checked against the serial
reference oracle (``repro.solver.reference`` via scipy's
``spsolve_triangular``).

The solve grid iterates the ``repro.backends`` registry, NOT a
hard-coded backend list: the in-process cells run every single-device
backend (``scan``, plus ``pallas`` in interpret mode — this container
has no TPU; interpret executes the same kernel logic through the Pallas
interpreter, so grid coverage carries to the kernel path), and the
``distributed`` backend runs its corpus sweep in a subprocess with a
forced multi-device CPU mesh (jax locks the device count at first init —
the same isolation tests/test_distributed.py uses). The grid is
corpus-wide (7 strategies x 9 matrices x 2 orientations x 2 RHS shapes
per in-process backend) and therefore ``slow``-marked; plans are shared
through one module-level ``PlanCache`` so each (strategy, matrix,
orientation, backend) is scheduled and compiled once across the RHS
parametrization.
"""
import numpy as np
import pytest
from _mesh import run_in_mesh_subprocess

from repro.autotune import corpus_entry, corpus_names
from repro.backends import available_backends
from repro.core import check_validity
from repro.pipeline import (
    PlanCache,
    TriangularSolver,
    available_strategies,
    schedule,
)
from repro.sparse import dag_from_lower_csr, transpose_csr

pytestmark = pytest.mark.slow

STRATEGIES = available_strategies()  # all 7 registered strategies
# every registered backend is covered: single-device ones in-process,
# multi-device ones (their own mesh requirement) in the subprocess sweep
IN_PROCESS_BACKENDS = [
    b for b in available_backends() if b != "distributed"
]
K = 8
RTOL = 1e-3  # f32 executor vs f64 reference, relative to max |x|

# one cache for the whole module: the 1-RHS and multi-RHS cells of a
# (strategy, matrix, orientation) triple share a single compiled plan
_CACHE = PlanCache()


def _solver(
    name: str, strategy: str, lower: bool, backend: str = "scan"
) -> TriangularSolver:
    L = corpus_entry(name).matrix()
    a = L if lower else transpose_csr(L)
    kw = {"interpret": True} if backend == "pallas" else {}
    # every freshly built grid cell passes the independent static
    # verifier (repro.analysis) before it solves; cache hits skip it
    return TriangularSolver.plan(
        a, strategy=strategy, k=K, lower=lower, cache=_CACHE,
        backend=backend, validate="fast", **kw,
    )


def _reference(name: str, lower: bool, b: np.ndarray) -> np.ndarray:
    from scipy.sparse.linalg import spsolve_triangular

    L = corpus_entry(name).matrix()
    a = L if lower else transpose_csr(L)
    return spsolve_triangular(a.to_scipy().tocsr(), b, lower=lower)


def test_grid_is_complete():
    """The suite really covers all 7 registered strategies AND all 3
    registered backends (a new registry entry must extend the corpus
    grid, not silently skip it)."""
    assert len(STRATEGIES) == 7
    assert set(STRATEGIES) == {
        "block", "funnel-gl", "growlocal", "hdagg", "serial", "spmp",
        "wavefront",
    }
    assert set(available_backends()) == {"scan", "pallas", "distributed"}
    assert set(IN_PROCESS_BACKENDS) == {"scan", "pallas"}


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("name", corpus_names())
def test_schedule_validity(name, strategy):
    """(a) Def. 2.1 validity for every (strategy, scenario) cell."""
    dag = dag_from_lower_csr(corpus_entry(name).matrix())
    s = schedule(dag, K, strategy=strategy)
    check_validity(dag, s)
    assert s.n == dag.n and s.n_supersteps >= 1


@pytest.mark.parametrize("backend", IN_PROCESS_BACKENDS)
@pytest.mark.parametrize("n_rhs", [1, 3], ids=["rhs1", "mrhs"])
@pytest.mark.parametrize("lower", [True, False], ids=["lower", "upper"])
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("name", corpus_names())
def test_solve_matches_reference(name, strategy, lower, n_rhs, backend):
    """(b) every cell solves to tolerance against the reference oracle,
    on the scan executor and the Pallas kernel (interpret mode)."""
    solver = _solver(name, strategy, lower, backend)
    # str hash is salted per process — derive the seed from the stable
    # corpus order instead so a near-tolerance failure is reproducible
    rng = np.random.default_rng(
        corpus_names().index(name) * 4 + 2 * int(lower) + int(n_rhs > 1)
    )
    n = solver.n
    b = rng.standard_normal((n, n_rhs)) if n_rhs > 1 else rng.standard_normal(n)
    x = np.asarray(solver.solve(b))
    assert x.shape == b.shape
    B = b.reshape(n, -1)
    X = x.reshape(n, -1)
    for j in range(B.shape[1]):
        ref = _reference(name, lower, B[:, j])
        scale = max(np.abs(ref).max(), 1e-30)
        assert np.abs(X[:, j] - ref).max() / scale < RTOL, (
            f"{strategy} on {name} ({'lower' if lower else 'upper'}, "
            f"rhs {j}) exceeded tolerance"
        )


# ------------------------------------------- distributed backend (3rd cell)
def test_distributed_backend_conformance_grid():
    """The distributed executor's corpus sweep — the third registered
    backend joins the conformance grid (ROADMAP open item). Needs a
    multi-device mesh, so the whole sweep runs in ONE subprocess with a
    forced 8-CPU-device count: every corpus matrix x {growlocal, serial}
    x both orientations, single- and multi-RHS, solved through
    ``TriangularSolver.plan(backend="distributed")`` on a (2, 4) mesh and
    checked against the scipy oracle. hdagg rides along on the
    shallow-wide matrices (its distributed-relevant regime; on the deep
    corpus shapes its superstep count makes the per-superstep-unrolled
    graph prohibitively slow to compile, and the scan/pallas grid already
    covers it corpus-wide)."""
    out = run_in_mesh_subprocess("""
        import numpy as np, jax
        from scipy.sparse.linalg import spsolve_triangular
        from repro.autotune import corpus_entry, corpus_names
        from repro.pipeline import PlanCache, TriangularSolver
        from repro.sparse import transpose_csr

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cache = PlanCache()
        cells = [(n, s) for n in corpus_names()
                 for s in ("growlocal", "serial")]
        cells += [(n, "hdagg") for n in ("er_sparse", "star", "independent")]
        ran = 0
        for name, strategy in cells:
            L = corpus_entry(name).matrix()
            for lower in (True, False):
                a = L if lower else transpose_csr(L)
                solver = TriangularSolver.plan(
                    a, strategy=strategy, k=4, lower=lower, cache=cache,
                    backend="distributed", mesh=mesh, validate="fast",
                )
                rng = np.random.default_rng(
                    corpus_names().index(name) * 2 + int(lower)
                )
                n = solver.n
                for n_rhs in (1, 3):
                    b = (rng.standard_normal((n, n_rhs)) if n_rhs > 1
                         else rng.standard_normal(n))
                    x = np.asarray(solver.solve(b))
                    assert x.shape == b.shape
                    B, X = b.reshape(n, -1), x.reshape(n, -1)
                    for j in range(B.shape[1]):
                        ref = spsolve_triangular(
                            a.to_scipy().tocsr(), B[:, j], lower=lower
                        )
                        scale = max(np.abs(ref).max(), 1e-30)
                        err = np.abs(X[:, j] - ref).max() / scale
                        assert err < 1e-3, (name, strategy, lower, j, err)
                    ran += 1
        print("dist-grid-ok", ran)
    """, timeout=1800)
    # (9 corpus x 2 strategies + 3 hdagg cells) x 2 orientations x 2 RHS
    assert "dist-grid-ok 84" in out
