"""Chunked WKV == sequential WKV (the §Perf optimization must be exact)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.configs import get_reduced
from repro.launch.inputs import make_train_batch
from repro.models import init_params, loss_fn, param_specs
from repro.models.rwkv6 import _wkv_chunked, _wkv_sequential


def _random_wkv_inputs(rng, B, S, H, Dh, decay_scale):
    r = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    # dd ~ N(0,1)*scale -> w = exp(-exp(dd)); larger scale = harder numerics
    dd = jnp.asarray(rng.standard_normal((B, S, H, Dh)) * decay_scale,
                     jnp.float32)
    log_w = -jnp.exp(dd)
    w = jnp.exp(log_w)
    u = jnp.asarray(rng.standard_normal((H, Dh)) * 0.3, jnp.float32)
    S0 = jnp.asarray(rng.standard_normal((B, H, Dh, Dh)) * 0.1, jnp.float32)
    return r, k, v, w, log_w, u, S0


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("decay_scale", [0.3, 1.0, 2.0])
def test_chunked_matches_sequential(chunk, decay_scale):
    rng = np.random.default_rng(chunk * 100 + int(decay_scale * 10))
    B, S, H, Dh = 2, 32, 3, 8
    r, k, v, w, log_w, u, S0 = _random_wkv_inputs(rng, B, S, H, Dh, decay_scale)
    S_seq, o_seq = _wkv_sequential(r, k, v, w, u, S0)
    S_chk, o_chk = _wkv_chunked(r, k, v, log_w, u, S0, chunk)
    # The two paths are algebraically identical but accumulate the decay in
    # different f32 orders: the scan multiplies `chunk` individually-rounded
    # exp(log_w_t) factors, the chunked path exponentiates one rounded
    # cumulative sum. The relative divergence is bounded by
    # ~ chunk * max|log_w| * eps_f32, and max|log_w| grows with
    # exp(decay_scale) — so the tolerance scales with chunk * decay_scale.
    # (Verified: the hardest cell, chunk=16 / decay_scale=2.0, peaks at
    # ~3e-4 relative on 1 of 1536 elements; a fixed 2e-4 is below the f32
    # floor of that cell, not evidence of an accumulation bug — rerunning
    # both paths with float64 accumulation collapses the same cell's
    # mismatch to ~3e-13.)
    tol = 2e-4 * max(1.0, chunk * decay_scale / 8.0)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_seq),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(S_chk), np.asarray(S_seq),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    chunk=st.sampled_from([2, 4, 8]),
    s_mult=st.integers(1, 4),
)
def test_chunked_matches_sequential_property(seed, chunk, s_mult):
    rng = np.random.default_rng(seed)
    B, S, H, Dh = 1, chunk * s_mult, 2, 4
    r, k, v, w, log_w, u, S0 = _random_wkv_inputs(rng, B, S, H, Dh, 0.8)
    S_seq, o_seq = _wkv_sequential(r, k, v, w, u, S0)
    S_chk, o_chk = _wkv_chunked(r, k, v, log_w, u, S0, chunk)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_seq),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(S_chk), np.asarray(S_seq),
                               rtol=5e-4, atol=5e-4)


def test_full_model_loss_invariant_under_chunking():
    cfg = get_reduced("rwkv6_7b")
    cfg_chunked = dataclasses.replace(cfg, wkv_chunk=8)
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = make_train_batch(cfg, batch=2, seq_len=64, seed=0)
    l1, _ = loss_fn(cfg, params, batch, train=False)
    l2, _ = loss_fn(cfg_chunked, params, batch, train=False)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
