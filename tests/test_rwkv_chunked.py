"""Chunked WKV == sequential WKV (the §Perf optimization must be exact)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.configs import get_reduced
from repro.launch.inputs import make_train_batch
from repro.models import init_params, loss_fn, param_specs
from repro.models.rwkv6 import _wkv_chunked, _wkv_sequential


def _random_wkv_inputs(rng, B, S, H, Dh, decay_scale):
    r = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    # dd ~ N(0,1)*scale -> w = exp(-exp(dd)); larger scale = harder numerics
    dd = jnp.asarray(rng.standard_normal((B, S, H, Dh)) * decay_scale,
                     jnp.float32)
    log_w = -jnp.exp(dd)
    w = jnp.exp(log_w)
    u = jnp.asarray(rng.standard_normal((H, Dh)) * 0.3, jnp.float32)
    S0 = jnp.asarray(rng.standard_normal((B, H, Dh, Dh)) * 0.1, jnp.float32)
    return r, k, v, w, log_w, u, S0


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("decay_scale", [0.3, 1.0, 2.0])
def test_chunked_matches_sequential(chunk, decay_scale):
    rng = np.random.default_rng(chunk * 100 + int(decay_scale * 10))
    B, S, H, Dh = 2, 32, 3, 8
    r, k, v, w, log_w, u, S0 = _random_wkv_inputs(rng, B, S, H, Dh, decay_scale)
    S_seq, o_seq = _wkv_sequential(r, k, v, w, u, S0)
    S_chk, o_chk = _wkv_chunked(r, k, v, log_w, u, S0, chunk)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_chk), np.asarray(S_seq),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    chunk=st.sampled_from([2, 4, 8]),
    s_mult=st.integers(1, 4),
)
def test_chunked_matches_sequential_property(seed, chunk, s_mult):
    rng = np.random.default_rng(seed)
    B, S, H, Dh = 1, chunk * s_mult, 2, 4
    r, k, v, w, log_w, u, S0 = _random_wkv_inputs(rng, B, S, H, Dh, 0.8)
    S_seq, o_seq = _wkv_sequential(r, k, v, w, u, S0)
    S_chk, o_chk = _wkv_chunked(r, k, v, log_w, u, S0, chunk)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_seq),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(S_chk), np.asarray(S_seq),
                               rtol=5e-4, atol=5e-4)


def test_full_model_loss_invariant_under_chunking():
    cfg = get_reduced("rwkv6_7b")
    cfg_chunked = dataclasses.replace(cfg, wkv_chunk=8)
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = make_train_batch(cfg, batch=2, seq_len=64, seed=0)
    l1, _ = loss_fn(cfg, params, batch, train=False)
    l2, _ = loss_fn(cfg_chunked, params, batch, train=False)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
