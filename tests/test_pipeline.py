"""The ``repro.pipeline`` front door: registry round-trips, plan-cache
hit/miss + numeric refresh, upper-triangular (``lower=False``) solves, and
batched multi-RHS on both executors."""
import numpy as np
import pytest

from repro.core import check_validity, grow_local
from repro.pipeline import (
    PlanCache,
    ScheduleOptions,
    TriangularSolver,
    available_strategies,
    factor_pair,
    get_scheduler,
    register_scheduler,
    schedule,
)
from repro.solver import solve_lower_scipy
from repro.sparse import (
    CSRMatrix,
    dag_from_lower_csr,
    erdos_renyi_lower,
    ichol0,
    narrow_band_lower,
    poisson2d_matrix,
    transpose_csr,
)


def _with_data(m: CSRMatrix, data: np.ndarray) -> CSRMatrix:
    return CSRMatrix(
        n_rows=m.n_rows, n_cols=m.n_cols, indptr=m.indptr,
        indices=m.indices, data=data,
    )


# --------------------------------------------------------------- registry
@pytest.mark.parametrize("strategy", available_strategies())
def test_registry_round_trip_valid_schedule(strategy, er_matrix):
    dag = dag_from_lower_csr(er_matrix)
    s = schedule(dag, 4, strategy=strategy)
    check_validity(dag, s)


def test_registry_unknown_strategy_raises():
    with pytest.raises(KeyError, match="unknown strategy"):
        get_scheduler("nope")


def test_registry_options_flow_through(nb_matrix):
    dag = dag_from_lower_csr(nb_matrix)
    o = ScheduleOptions(k=3, n_blocks=2)
    s = schedule(dag, options=o, strategy="block")
    check_validity(dag, s)
    assert s.k == 3


def test_register_scheduler_and_duplicate_rejection():
    calls = []

    @register_scheduler("test-counting")
    def _counting(dag, o):
        calls.append(dag.n)
        return grow_local(dag, o.k)

    try:
        L = erdos_renyi_lower(80, 0.05, seed=0)
        dag = dag_from_lower_csr(L)
        check_validity(dag, schedule(dag, 2, strategy="test-counting"))
        assert calls == [80]
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("test-counting")(lambda d, o: None)
    finally:
        from repro.pipeline import registry

        registry._REGISTRY.pop("test-counting", None)


# ------------------------------------------------------------- plan cache
def test_cache_hit_skips_scheduling(er_matrix):
    sched_calls = []

    @register_scheduler("test-spy")
    def _spy(dag, o):
        sched_calls.append(1)
        return grow_local(dag, o.k)

    try:
        cache = PlanCache()
        b = np.random.default_rng(0).standard_normal(er_matrix.n_rows)
        s1 = TriangularSolver.plan(er_matrix, strategy="test-spy", k=4,
                                   cache=cache)
        x1 = np.asarray(s1.solve(b))
        s2 = TriangularSolver.plan(er_matrix, strategy="test-spy", k=4,
                                   cache=cache)
        x2 = np.asarray(s2.solve(b))
        # the second plan on the same sparsity pattern never re-scheduled,
        # and identical values mean no numeric refresh either
        assert len(sched_calls) == 1
        assert cache.stats.hits == 1 and cache.stats.misses == 1
        assert cache.stats.numeric_updates == 0
        assert s2 is s1
        np.testing.assert_allclose(x1, x2, rtol=1e-6)
        ref = solve_lower_scipy(er_matrix, b)
        assert np.abs(x1 - ref).max() / np.abs(ref).max() < 1e-4
    finally:
        from repro.pipeline import registry

        registry._REGISTRY.pop("test-spy", None)


def test_cache_key_separates_configs(er_matrix, nb_matrix):
    cache = PlanCache()
    TriangularSolver.plan(er_matrix, k=4, cache=cache)
    TriangularSolver.plan(er_matrix, k=8, cache=cache)  # different k
    TriangularSolver.plan(nb_matrix, k=4, cache=cache)  # different pattern
    TriangularSolver.plan(er_matrix, k=4, strategy="hdagg", cache=cache)
    # scheduling options beyond k/strategy must separate entries too
    TriangularSolver.plan(er_matrix, k=4, strategy="block", n_blocks=2,
                          cache=cache)
    TriangularSolver.plan(er_matrix, k=4, strategy="block", n_blocks=3,
                          cache=cache)
    assert cache.stats.misses == 6 and cache.stats.hits == 0
    TriangularSolver.plan(er_matrix, k=4, cache=cache)
    assert cache.stats.hits == 1


def test_cache_hit_refreshes_values(er_matrix):
    """Same pattern, new values: the hit must solve with the NEW numbers,
    WITHOUT corrupting solvers handed out earlier."""
    cache = PlanCache()
    rng = np.random.default_rng(1)
    b = rng.standard_normal(er_matrix.n_rows)
    s1 = TriangularSolver.plan(er_matrix, k=4, cache=cache)
    scaled = _with_data(
        er_matrix, er_matrix.data * (1.0 + rng.uniform(0.1, 1.0, er_matrix.nnz))
    )
    solver = TriangularSolver.plan(scaled, k=4, cache=cache)
    assert cache.stats.hits == 1 and cache.stats.numeric_updates == 1
    x = np.asarray(solver.solve(b))
    ref = solve_lower_scipy(scaled, b)
    assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-4
    # the earlier solver still solves with the OLD values
    x1 = np.asarray(s1.solve(b))
    ref1 = solve_lower_scipy(er_matrix, b)
    assert np.abs(x1 - ref1).max() / np.abs(ref1).max() < 1e-4
    # the clone became canonical: planning the scaled values again is free
    s3 = TriangularSolver.plan(scaled, k=4, cache=cache)
    assert s3 is solver and cache.stats.numeric_updates == 1


def test_numeric_update_without_cache(nb_matrix):
    rng = np.random.default_rng(2)
    b = rng.standard_normal(nb_matrix.n_rows)
    solver = TriangularSolver.plan(nb_matrix, k=4)
    scaled = _with_data(nb_matrix, nb_matrix.data * 3.0)
    solver.numeric_update(scaled)
    x = np.asarray(solver.solve(b))
    ref = solve_lower_scipy(scaled, b)
    assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-4


def test_numeric_update_rejects_other_pattern(er_matrix, nb_matrix):
    solver = TriangularSolver.plan(er_matrix, k=2)
    with pytest.raises(ValueError, match="fingerprint"):
        solver.numeric_update(nb_matrix)


def test_cache_eviction():
    cache = PlanCache(maxsize=1)
    a = erdos_renyi_lower(60, 0.05, seed=1)
    b = erdos_renyi_lower(60, 0.05, seed=2)
    TriangularSolver.plan(a, k=2, cache=cache)
    TriangularSolver.plan(b, k=2, cache=cache)
    TriangularSolver.plan(a, k=2, cache=cache)  # evicted -> rebuilt
    assert cache.stats.misses == 3 and cache.stats.evictions == 2


# -------------------------------------------------- upper solves / pairs
def test_upper_solve_matches_scipy(ichol_matrix):
    scipy_sparse = pytest.importorskip("scipy.sparse")
    from scipy.sparse.linalg import spsolve_triangular

    U = transpose_csr(ichol_matrix)
    solver = TriangularSolver.plan(U, lower=False, k=4)
    b = np.random.default_rng(3).standard_normal(U.n_rows)
    x = np.asarray(solver.solve(b))
    ref = spsolve_triangular(
        scipy_sparse.csr_matrix(U.to_scipy()), b, lower=False
    )
    assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-4


def test_lower_flag_validates_triangularity(ichol_matrix):
    U = transpose_csr(ichol_matrix)
    with pytest.raises(ValueError, match="lower-triangular"):
        TriangularSolver.plan(U, lower=True)
    with pytest.raises(ValueError, match="upper-triangular"):
        TriangularSolver.plan(ichol_matrix, lower=False)


def test_factor_pair_applies_normal_equations(ichol_matrix):
    fwd, bwd = factor_pair(ichol_matrix, k=4)
    rng = np.random.default_rng(4)
    b = rng.standard_normal(ichol_matrix.n_rows)
    z = np.asarray(bwd(fwd(b)))
    Ls = ichol_matrix.to_scipy()
    ref = np.linalg.solve((Ls @ Ls.T).toarray(), b)
    assert np.abs(z - ref).max() / np.abs(ref).max() < 1e-3


# ------------------------------------------------------- batched multi-RHS
@pytest.mark.parametrize("backend", ["scan", "pallas"])
def test_multi_rhs_matches_column_solves(backend, nb_matrix):
    solver = TriangularSolver.plan(
        nb_matrix, k=4, backend=backend, steps_per_tile=4, interpret=True
    )
    B = np.random.default_rng(5).standard_normal((nb_matrix.n_rows, 5))
    X = np.asarray(solver.solve(B.astype(np.float32)))
    assert X.shape == B.shape
    for j in range(B.shape[1]):
        xj = np.asarray(solver.solve(B[:, j].astype(np.float32)))
        # batched and single-RHS einsums reduce in different orders -> f32
        # rounding differences scale with |x|
        scale = np.abs(xj).max()
        np.testing.assert_allclose(X[:, j] / scale, xj / scale, atol=1e-5)
        ref = solve_lower_scipy(nb_matrix, B[:, j])
        assert np.abs(X[:, j] - ref).max() / np.abs(ref).max() < 1e-4


def test_multi_rhs_upper(ichol_matrix):
    U = transpose_csr(ichol_matrix)
    solver = TriangularSolver.plan(U, lower=False, k=4)
    B = np.random.default_rng(6).standard_normal((U.n_rows, 3))
    X = np.asarray(solver.solve(B.astype(np.float32)))
    for j in range(B.shape[1]):
        xj = np.asarray(solver.solve(B[:, j].astype(np.float32)))
        scale = np.abs(xj).max()
        np.testing.assert_allclose(X[:, j] / scale, xj / scale, atol=1e-5)


# ------------------------------------------- strategy="auto" + plan cache
def test_auto_resolves_to_concrete_cache_key(er_matrix):
    """An auto plan is cached under the RESOLVED config: planning the same
    pattern with the explicit (strategy, options) the selector picked must
    be a cache hit on the very same entry."""
    cache = PlanCache()
    s1 = TriangularSolver.plan(er_matrix, strategy="auto", cache=cache)
    s2 = TriangularSolver.plan(
        er_matrix, strategy=s1.strategy, options=s1.selection.options,
        cache=cache,
    )
    assert s2 is s1
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_auto_refactorization_skips_reselection(er_matrix):
    """Regression: the §7.7 refactorization loop (same pattern, new values)
    on an auto-planned solver must hit both the selection memo and the plan
    cache — no feature extraction, no candidate scoring, no rescheduling."""
    cache = PlanCache()
    rng = np.random.default_rng(8)
    b = rng.standard_normal(er_matrix.n_rows)
    s1 = TriangularSolver.plan(er_matrix, strategy="auto", cache=cache)
    assert cache.stats.selections == 1 and cache.stats.misses == 1

    scaled = _with_data(
        er_matrix, er_matrix.data * (1.0 + rng.uniform(0.1, 1.0, er_matrix.nnz))
    )
    s2 = TriangularSolver.plan(scaled, strategy="auto", cache=cache)
    st = cache.stats
    assert st.selections == 1, "refactorization re-ran strategy selection"
    assert st.selection_hits == 1
    assert st.hits == 1 and st.misses == 1 and st.numeric_updates == 1
    # both solvers solve with their own values
    x2 = np.asarray(s2.solve(b))
    ref2 = solve_lower_scipy(scaled, b)
    assert np.abs(x2 - ref2).max() / np.abs(ref2).max() < 1e-4
    x1 = np.asarray(s1.solve(b))
    ref1 = solve_lower_scipy(er_matrix, b)
    assert np.abs(x1 - ref1).max() / np.abs(ref1).max() < 1e-4
    # an in-place numeric_update on the clone also never re-selects
    s2.numeric_update(_with_data(er_matrix, er_matrix.data * 2.0))
    assert cache.stats.selections == 1


def test_auto_hit_never_mutates_fixed_built_solver(er_matrix):
    """Regression: an auto plan that cache-hits an entry originally built
    by a FIXED-strategy plan returns it unchanged — cached solvers are
    never mutated behind earlier callers' backs. The resolved outcome
    still lands in the cache's selection memo."""
    from repro.autotune import resolve_auto

    probe = resolve_auto(er_matrix, options=ScheduleOptions())
    cache = PlanCache()
    s1 = TriangularSolver.plan(
        er_matrix, strategy=probe.strategy, options=probe.options, cache=cache
    )
    assert s1.selection is None
    s2 = TriangularSolver.plan(er_matrix, strategy="auto", cache=cache)
    assert s2 is s1 and cache.stats.hits == 1
    assert s1.selection is None  # untouched; memo has the Selection
    assert cache.stats.selections == 1


def test_cache_hit_clone_never_aliases_value_buffers(er_matrix):
    """Regression: the clone a cache hit returns for new values must own
    its numeric tensors — writing through one solver can never corrupt the
    other (the immutable schedule/index structure MAY be shared)."""
    cache = PlanCache()
    s1 = TriangularSolver.plan(er_matrix, strategy="auto", cache=cache)
    scaled = _with_data(er_matrix, er_matrix.data * 3.0)
    s2 = TriangularSolver.plan(scaled, strategy="auto", cache=cache)
    assert s2 is not s1
    assert not np.shares_memory(s2.exec_plan.vals, s1.exec_plan.vals)
    assert not np.shares_memory(s2.exec_plan.diag, s1.exec_plan.diag)
    assert s2._source_data is not s1._source_data
    before = s1.exec_plan.vals.copy()
    s2.numeric_update(_with_data(er_matrix, er_matrix.data * 5.0))
    np.testing.assert_array_equal(s1.exec_plan.vals, before)
