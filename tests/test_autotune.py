"""Autotuner tests: feature extraction, regime classification, corpus
acceptance (auto vs best/worst fixed by BSP cost), and hypothesis
properties (reorder invariance, validity, deterministic selection)."""
import dataclasses

import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.autotune import (
    chain_lower,
    classify,
    clear_selection_memo,
    corpus_entries,
    dag_features,
    independent_lower,
    matrix_features,
    resolve_auto,
    select_schedule,
    shortlist,
    star_lower,
)
from repro.core import apply_reordering, bsp_cost, check_validity, grow_local
from repro.pipeline import (
    PlanCache,
    ScheduleOptions,
    TriangularSolver,
    available_strategies,
    schedule,
)
from repro.solver import solve_lower_scipy
from repro.sparse import dag_from_lower_csr, erdos_renyi_lower


# ----------------------------------------------------------------- features
def test_features_of_known_shapes():
    n = 50
    chain = matrix_features(chain_lower(n))
    assert chain.depth == n and chain.max_wavefront == 1
    assert chain.avg_wavefront == 1.0 and chain.bandwidth == 1
    star = matrix_features(star_lower(n))
    assert star.depth == 2 and star.max_wavefront == n - 1
    assert star.bandwidth == n - 1
    indep = matrix_features(independent_lower(n))
    assert indep.depth == 1 and indep.n_edges == 0
    assert indep.max_wavefront == n and indep.bandwidth == 0
    assert indep.nnz == n  # diagonal only


def test_features_memoized_per_fingerprint():
    m = chain_lower(40)
    f1 = matrix_features(m)
    f2 = matrix_features(m)
    assert f1 is f2  # cache hit returns the same object


def test_features_invariant_under_section5_reorder(any_matrix):
    """The §5 locality reorder relabels the DAG topologically — every
    feature except the bandwidth pair must be preserved exactly."""
    dag = dag_from_lower_csr(any_matrix)
    f0 = dag_features(dag)
    s = grow_local(dag, 8)
    L2, _, _, _ = apply_reordering(any_matrix, s)
    f2 = dag_features(dag_from_lower_csr(L2))
    assert f0.invariant() == f2.invariant()
    # ... and the reorder is allowed to (and usually does) change bandwidth
    assert f0.invariant().keys() == {
        "n", "nnz", "n_edges", "depth", "avg_wavefront", "max_wavefront",
        "row_nnz_mean", "row_nnz_max", "row_skew",
    }


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(20, 150),
    density=st.floats(1e-3, 0.2),
    k=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_features_reorder_invariance_property(n, density, k, seed):
    m = erdos_renyi_lower(n, density, seed=seed)
    dag = dag_from_lower_csr(m)
    f0 = dag_features(dag)
    L2, _, _, _ = apply_reordering(m, grow_local(dag, k))
    f2 = dag_features(dag_from_lower_csr(L2))
    assert f0.invariant() == f2.invariant()


# ----------------------------------------------------------------- selector
def test_classify_matches_corpus_metadata():
    """Every corpus entry carries the regime label the classifier must
    derive for it — the rule thresholds are calibrated on exactly this."""
    for e in corpus_entries():
        f = matrix_features(e.matrix())
        assert classify(f) == e.regime, (e.name, classify(f), e.regime)


@pytest.mark.slow
def test_classify_stable_at_scale():
    """ROADMAP N>=1e5 recalibration: every scale-tier matrix (same
    families as the container corpus, scaled to 100k rows) classifies to
    its declared regime — in particular the deep narrow-band family must
    stay 'banded' even though its average wavefront crosses the absolute
    8k width threshold at this size (the rule-order fix). Feature
    extraction at 100k rows is seconds thanks to the vectorized
    inspector stack, which is what unblocked this test."""
    from repro.autotune import scale_corpus_entries

    assert len(scale_corpus_entries()) >= 5
    for e in scale_corpus_entries():
        m = e.matrix()
        assert m.n_rows >= 100_000
        f = matrix_features(m)
        assert classify(f) == e.regime, (e.name, classify(f), e.regime)
        # the scale tier mirrors container-corpus families: the label must
        # ALSO match its small sibling's where one exists (scale
        # stability) — except er_dense, whose mixed -> wide transition is
        # real physics, not threshold drift: at a fixed row degree the
        # average level width grows with n, so at 100k its levels are
        # thousands wide and barriers amortize
        small = e.name.replace("_100k", "")
        small_regimes = {s.name: s.regime for s in corpus_entries()}
        if small in small_regimes and e.name != "er_dense_100k":
            assert e.regime == small_regimes[small], (e.name, small)


@pytest.mark.slow
def test_scale_corpus_not_in_default_corpus():
    """The scale tier must never leak into the default corpus — the
    conformance grid and serve loadgen iterate corpus_names() and would
    pay the 100k inspector in every cell."""
    from repro.autotune import scale_corpus_entry, scale_corpus_names

    assert set(scale_corpus_names()).isdisjoint(corpus_entries_names())
    with pytest.raises(KeyError, match="unknown scale-corpus"):
        scale_corpus_entry("er_sparse")


def corpus_entries_names():
    return {e.name for e in corpus_entries()}


def test_shortlist_is_small_and_deterministic():
    for e in corpus_entries():
        f = matrix_features(e.matrix())
        cands = shortlist(f)
        assert 2 <= len(cands) <= 3
        assert cands == shortlist(f)
        names = [c.strategy for c in cands]
        assert len(set(names)) == len(names)
        for c in cands:
            assert c.strategy in available_strategies()


def test_selection_deterministic_for_fixed_fingerprint():
    m = erdos_renyi_lower(300, 0.01, seed=7)
    picks = []
    for _ in range(3):
        clear_selection_memo()
        sel = resolve_auto(m, options=ScheduleOptions())
        picks.append((sel.strategy, sel.options, sel.cost))
    assert picks[0] == picks[1] == picks[2]


def test_select_schedule_winner_is_argmin():
    for e in corpus_entries():
        dag = dag_from_lower_csr(e.matrix())
        sel, s = select_schedule(dag)
        check_validity(dag, s)
        costs = [c.cost for c in sel.candidates]
        assert sel.cost == min(costs)
        # returned schedule really is the winner's schedule
        assert abs(bsp_cost(dag, s, L=sel.options.L) - sel.cost) < 1e-9


# ------------------------------------------------- corpus acceptance bars
def test_auto_beats_worst_and_tracks_best_fixed():
    """The PR's acceptance criterion: on every corpus matrix, auto's BSP
    cost beats the worst fixed strategy and is within 10% of the best
    fixed strategy (all at default options, k=8)."""
    for e in corpus_entries():
        dag = dag_from_lower_csr(e.matrix())
        costs = {
            s: bsp_cost(dag, schedule(dag, 8, strategy=s))
            for s in available_strategies()
        }
        best, worst = min(costs.values()), max(costs.values())
        sel, _ = select_schedule(dag)
        assert sel.cost <= 1.1 * best, (
            f"{e.name}: auto={sel.cost} > 1.1 * best={best}"
        )
        assert sel.cost < worst, f"{e.name}: auto={sel.cost} >= worst={worst}"
        assert sel.strategy in e.expected_best, (e.name, sel.strategy)


def test_expected_best_metadata_is_accurate():
    """The corpus' expected_best annotations are re-derived, not trusted:
    each listed strategy must be within ~10% of the best fixed cost."""
    for e in corpus_entries():
        dag = dag_from_lower_csr(e.matrix())
        costs = {
            s: bsp_cost(dag, schedule(dag, 8, strategy=s))
            for s in available_strategies()
        }
        best = min(costs.values())
        for s in e.expected_best:
            assert costs[s] <= 1.1 * best, (e.name, s, costs[s], best)


# ------------------------------------------------------ end-to-end "auto"
def test_plan_auto_solves_correctly():
    m = corpus_entries()[0].matrix()
    solver = TriangularSolver.plan(m, strategy="auto")
    assert solver.strategy in available_strategies()
    assert solver.selection is not None
    b = np.random.default_rng(0).standard_normal(m.n_rows)
    x = np.asarray(solver.solve(b))
    ref = solve_lower_scipy(m, b)
    assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-3


def test_registry_schedule_auto(any_dag):
    s = schedule(any_dag, 8, strategy="auto")
    check_validity(any_dag, s)


def test_tune_requires_auto():
    m = corpus_entries()[0].matrix()
    with pytest.raises(ValueError, match="strategy='auto'"):
        TriangularSolver.plan(m, strategy="growlocal", tune=True)


def test_explicit_max_size_is_respected():
    """shortlist adapts the funnel cap only when the caller left it at
    the default — an explicit knob must survive auto selection."""
    f = matrix_features(corpus_entries()[2].matrix())  # band_narrow
    explicit = ScheduleOptions(max_size=32)
    for c in shortlist(f, explicit):
        assert c.options.max_size == 32
    adapted = [
        c for c in shortlist(f, ScheduleOptions()) if c.strategy == "funnel-gl"
    ]
    assert adapted and all(c.options.max_size != 32 for c in adapted)


def test_auto_not_registerable():
    from repro.pipeline import register_scheduler

    with pytest.raises(ValueError, match="reserved"):
        register_scheduler("auto")(lambda d, o: None)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(10, 120),
    density=st.floats(1e-3, 0.15),
    k=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_auto_schedule_always_valid(n, density, k, seed):
    """Property: strategy='auto' never returns an invalid schedule."""
    m = erdos_renyi_lower(n, density, seed=seed)
    dag = dag_from_lower_csr(m)
    s = schedule(dag, k, strategy="auto")
    check_validity(dag, s)
    assert s.n == dag.n


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_auto_selection_deterministic_property(seed):
    """Property: for a fixed fingerprint the selection never varies."""
    m = erdos_renyi_lower(80, 0.05, seed=seed)
    clear_selection_memo()
    s1 = resolve_auto(m, options=ScheduleOptions())
    clear_selection_memo()
    s2 = resolve_auto(m, options=ScheduleOptions())
    assert (s1.strategy, s1.options, s1.cost) == (s2.strategy, s2.options, s2.cost)
    assert dataclasses.asdict(s1.features) == dataclasses.asdict(s2.features)


@pytest.mark.slow
def test_tune_mode_times_candidates():
    """tune=True runs measured trials on the shortlist and records them."""
    clear_selection_memo()
    m = corpus_entries()[4].matrix()  # poisson2d_ichol
    cache = PlanCache()
    solver = TriangularSolver.plan(m, strategy="auto", tune=True, cache=cache)
    sel = solver.selection
    assert sel.tuned and sel.timings is not None
    assert {t[0] for t in sel.timings} == {c.strategy for c in sel.candidates}
    assert sel.strategy == min(sel.timings, key=lambda t: t[1])[0]
    # only the tuned winner entered the caller's cache (losing trial plans
    # stay private to the selection); re-planning is a pure hit
    assert len(cache) == 1 and cache.stats.misses == 1
    hits0 = cache.stats.hits
    TriangularSolver.plan(m, strategy="auto", tune=True, cache=cache)
    assert cache.stats.hits > hits0
    b = np.random.default_rng(1).standard_normal(m.n_rows)
    x = np.asarray(solver.solve(b))
    ref = solve_lower_scipy(m, b)
    assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-3


@pytest.mark.slow
def test_tune_mode_sweeps_slack():
    """On an elastic-capable backend tune=True additionally clocks the
    winning strategy across the slack grid: ``timings`` stays one row
    per shortlisted strategy, the swept windows land in
    ``slack_timings``, and the tuned options carry the measured-best
    slack."""
    from repro.autotune.selector import SLACK_GRID

    clear_selection_memo()
    m = corpus_entries()[6].matrix()  # chain: serial regime, elastic on
    solver = TriangularSolver.plan(
        m, strategy="auto", tune=True, cache=PlanCache()
    )
    sel = solver.selection
    assert sel.tuned
    assert {t[0] for t in sel.timings} == {c.strategy for c in sel.candidates}
    assert sel.slack_timings is not None
    assert {s for s, _ in sel.slack_timings} == {0, *SLACK_GRID}
    assert sel.options.slack == min(sel.slack_timings, key=lambda t: t[1])[0]
    assert sel.as_dict()["slack_timings"] == list(sel.slack_timings)
    b = np.random.default_rng(2).standard_normal(m.n_rows)
    x = np.asarray(solver.solve(b))
    ref = solve_lower_scipy(m, b)
    assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-3


@pytest.mark.slow
def test_tune_mode_on_pallas_backend():
    """tune=True trials honor the requested backend binding beyond the
    scan executor: the shortlist is compiled and timed through the
    Pallas kernel (interpret mode on this container), the winner solver
    is bound to that backend, and its solves are correct."""
    clear_selection_memo()
    m = corpus_entries()[6].matrix()  # chain: 2-candidate shortlist
    cache = PlanCache()
    solver = TriangularSolver.plan(
        m, strategy="auto", tune=True, cache=cache,
        backend="pallas", interpret=True,
    )
    sel = solver.selection
    assert sel.tuned and sel.timings is not None
    assert {t[0] for t in sel.timings} == {c.strategy for c in sel.candidates}
    assert all(t[1] > 0 for t in sel.timings)  # real measured trials
    assert solver.backend == "pallas"
    # the tuned winner is cached under its pallas binding: re-planning on
    # the same backend is a pure hit, while a scan plan is NOT conflated
    hits0 = cache.stats.hits
    again = TriangularSolver.plan(
        m, strategy="auto", tune=True, cache=cache,
        backend="pallas", interpret=True,
    )
    assert cache.stats.hits > hits0 and again.backend == "pallas"
    b = np.random.default_rng(2).standard_normal(m.n_rows)
    x = np.asarray(solver.solve(b))
    ref = solve_lower_scipy(m, b)
    assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-3
    # the scan-bound tuned selection is memoized separately (binding is
    # part of the tune-memo key)
    scan_solver = TriangularSolver.plan(
        m, strategy="auto", tune=True, cache=cache, backend="scan"
    )
    assert scan_solver.backend == "scan"
    assert cache.stats.selections >= 2


@pytest.mark.slow
def test_tune_mode_on_distributed_backend_subprocess():
    """tune=True measured trials through the distributed backend: the
    shortlist compiles and times on a real (forced-host) device mesh in
    a subprocess, the tuned winner is mesh-bound and correct, and — the
    distributed backend now executing the elastic fused-barrier
    certificate — the selector sweeps the slack grid on the clock
    winner and the tuned binding fuses its exchange rounds."""
    from _mesh import run_in_mesh_subprocess

    run_in_mesh_subprocess("""
        import numpy as np, jax
        from repro.autotune import clear_selection_memo
        from repro.autotune.selector import SLACK_GRID
        from repro.pipeline import PlanCache, TriangularSolver
        from repro.solver import solve_lower_scipy
        from repro.sparse import narrow_band_lower

        clear_selection_memo()
        m = narrow_band_lower(400, 0.14, 10, seed=77)  # "banded" regime
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cache = PlanCache()
        solver = TriangularSolver.plan(
            m, strategy="auto", tune=True, cache=cache, k=4,
            backend="distributed", mesh=mesh,
        )
        sel = solver.selection
        assert sel.tuned and sel.timings is not None
        assert {t[0] for t in sel.timings} == {
            c.strategy for c in sel.candidates}
        assert all(t[1] > 0 for t in sel.timings)
        assert solver.backend == "distributed"
        # distributed is elastic-capable: the slack grid was clocked and
        # the tuned options carry the measured winner
        assert sel.slack_timings is not None
        assert {s for s, _ in sel.slack_timings} == {0, *SLACK_GRID}
        assert sel.options.slack == min(
            sel.slack_timings, key=lambda t: t[1])[0]
        info = solver.info()
        assert info["mode"] == (
            "elastic" if sel.options.slack else "bsp")
        ex = info["binding"]["exchange"]
        if sel.options.slack:  # fused exchange rounds actually execute
            assert ex["rounds"] <= ex["n_supersteps"]
            assert ex["executed_fusion"] >= 1.0
        # the tuned winner is cached under its mesh binding: pure hit
        hits0 = cache.stats.hits
        again = TriangularSolver.plan(
            m, strategy="auto", tune=True, cache=cache, k=4,
            backend="distributed", mesh=mesh,
        )
        assert cache.stats.hits > hits0
        assert again.backend == "distributed"
        b = np.random.default_rng(3).standard_normal(m.n_rows)
        x = np.asarray(solver.solve(b))
        ref = solve_lower_scipy(m, b)
        assert np.abs(x - ref).max() / np.abs(ref).max() < 1e-3
        print("dist-tune-ok")
    """)
