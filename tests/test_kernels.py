"""Pallas kernel tests: shape/dtype sweep, allclose vs the pure-jnp oracle
(ref.py) and vs scipy, in interpret mode (CPU container; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_reordering, compile_plan, grow_local
from repro.kernels.ops import kernel_plan_arrays, sptrsv_kernel_solve
from repro.kernels.ref import sptrsv_ref
from repro.kernels.sptrsv import sptrsv_pallas
from repro.solver import solve_lower_scipy
from repro.sparse import dag_from_lower_csr, erdos_renyi_lower, narrow_band_lower


def _plan_for(n, density, seed, k=8, width=None):
    L = erdos_renyi_lower(n, density, seed=seed)
    dag = dag_from_lower_csr(L)
    s = grow_local(dag, k)
    L2, s2, _, _ = apply_reordering(L, s)
    return L2, compile_plan(L2, s2, width=width)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize(
    "n,density,k,width",
    [
        (64, 0.05, 2, None),
        (200, 0.02, 4, 3),
        (450, 0.01, 8, 16),
        (300, 0.08, 16, 2),  # heavy row-splitting
    ],
)
def test_kernel_matches_oracle_sweep(n, density, k, width, dtype):
    """Sweep shapes/dtypes; kernel (interpret) == ref.py oracle exactly."""
    if dtype == jnp.float64 and not jax.config.read("jax_enable_x64"):
        pytest.skip("x64 disabled")
    L2, plan = _plan_for(n, density, seed=n + k, k=k, width=width)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n)
    arrays = kernel_plan_arrays(plan, steps_per_tile=4, dtype=dtype)
    b_pad = jnp.concatenate([jnp.asarray(b, dtype), jnp.zeros(1, dtype)])
    x_kernel = sptrsv_pallas(*arrays, b_pad, steps_per_tile=4, interpret=True)
    x_oracle = sptrsv_ref(*arrays, b_pad)
    # f32 tolerance: the kernel's sum(v*g) and the oracle's einsum may
    # reassociate the reduction; solve recurrences amplify ~1 ulp to ~1e-5.
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        np.asarray(x_kernel), np.asarray(x_oracle), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("steps_per_tile", [1, 2, 8, 32])
def test_kernel_tile_size_invariance(steps_per_tile):
    """The kernel's answer must not depend on the grid tiling."""
    L2, plan = _plan_for(220, 0.03, seed=42, k=4)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(220)
    x = np.asarray(
        sptrsv_kernel_solve(plan, b, steps_per_tile=steps_per_tile, interpret=True)
    )
    x_ref = solve_lower_scipy(L2, b)
    assert np.abs(x - x_ref).max() / (np.abs(x_ref).max() + 1e-30) < 2e-3


def test_kernel_matches_scipy_nb():
    L = narrow_band_lower(400, 0.14, 8, seed=3)
    dag = dag_from_lower_csr(L)
    s = grow_local(dag, 8)
    L2, s2, _, _ = apply_reordering(L, s)
    plan = compile_plan(L2, s2)
    rng = np.random.default_rng(2)
    b = rng.standard_normal(400)
    x = np.asarray(sptrsv_kernel_solve(plan, b, interpret=True))
    x_ref = solve_lower_scipy(L2, b)
    assert np.abs(x - x_ref).max() / (np.abs(x_ref).max() + 1e-30) < 2e-3


def test_kernel_oracle_is_scan_executor():
    """ref.py and solver.executor implement the same dataflow."""
    from repro.solver.executor import plan_arrays, solve_with_plan

    L2, plan = _plan_for(150, 0.04, seed=9, k=4)
    rng = np.random.default_rng(5)
    b = rng.standard_normal(150)
    pa = plan_arrays(plan)
    x1 = np.asarray(solve_with_plan(pa, jnp.asarray(b, jnp.float32)))
    b_pad = jnp.concatenate(
        [jnp.asarray(b, jnp.float32), jnp.zeros(1, jnp.float32)]
    )
    x2 = np.asarray(
        sptrsv_ref(pa.row_ids, pa.col_idx, pa.vals, pa.diag, pa.accum, b_pad)
    )[:150]
    np.testing.assert_allclose(x1, x2, rtol=1e-6, atol=1e-6)
