"""End-to-end driver (the paper's motivating application): IC(0)-
preconditioned conjugate gradient on a 2D Poisson system, with BOTH
triangular solves per iteration executed from scheduled plans.

This serves a batch of solve requests against one factorization — the
amortization regime of paper §7.7: a shared ``PlanCache`` means the
inspector (DAG -> schedule -> reorder -> compile) runs once for the
pattern; every later request hits the cache and skips it.

    PYTHONPATH=src python examples/pcg_solve.py
"""
import time

import numpy as np

from repro.pipeline import PlanCache
from repro.solver import cg_solve, pcg_ichol
from repro.sparse import poisson2d_matrix

N = 96
A = poisson2d_matrix(N)
rng = np.random.default_rng(0)
print(f"system: n={A.n_rows} nnz={A.nnz}")

# a stream of right-hand sides (requests) against the same pattern
n_requests = 5
rhs = [rng.standard_normal(A.n_rows) for _ in range(n_requests)]

t0 = time.time()
x0, it0, rr0 = cg_solve(A, rhs[0], tol=1e-6, maxiter=4000)
t_plain = time.time() - t0
print(f"plain CG      : {it0:4d} iterations, relres {rr0:.1e}, {t_plain:.2f}s")

# strategy defaults to "auto": the autotuner picks per factor (the L and
# L^T solves see mirror-image DAGs and are selected independently)
cache = PlanCache()
t0 = time.time()
x1, it1, rr1, info = pcg_ichol(A, rhs[0], k=8, tol=1e-6, maxiter=4000,
                               cache=cache)
t_pcg_first = time.time() - t0
print(f"auto PCG      : {it1:4d} iterations, relres {rr1:.1e}, "
      f"{t_pcg_first:.2f}s (includes one-time inspector + selection)")
print(f"  schedules: fwd {info['fwd_strategy']} "
      f"({info['fwd_supersteps']} supersteps) / bwd {info['bwd_strategy']} "
      f"({info['bwd_supersteps']} supersteps)")
assert it1 < it0
np.testing.assert_allclose(x1, x0, rtol=2e-2, atol=2e-3)

# remaining requests amortize the inspector through the plan cache
t0 = time.time()
for b in rhs[1:]:
    x, it, rr, info = pcg_ichol(A, b, k=8, tol=1e-6, maxiter=4000, cache=cache)
    assert rr < 1e-4
t_rest = (time.time() - t0) / (n_requests - 1)
print(f"amortized request latency: {t_rest:.2f}s "
      f"(vs {t_plain:.2f}s unpreconditioned)")
print(f"plan cache: {info['cache']}")
assert info["cache"]["misses"] == 2  # fwd + bwd, planned exactly once
assert info["cache"]["hits"] == 2 * (n_requests - 1)
print("OK")
