"""Serve a small LM with batched requests: prefill a batch of prompts, then
decode tokens autoregressively with the stacked KV cache (the serving path
the decode_32k / long_500k dry-run cells lower at scale).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.launch.inputs import make_train_batch
from repro.models import decode_step, init_params, param_specs, prefill

ARCHS = ["granite_3_2b", "mixtral_8x7b", "rwkv6_7b", "recurrentgemma_2b"]
B, PROMPT, NEW = 4, 64, 16

for arch in ARCHS:
    cfg = get_reduced(arch)
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = make_train_batch(cfg, batch=B, seq_len=PROMPT, seed=0)
    max_len = PROMPT + NEW

    prefill_fn = jax.jit(lambda p, b: prefill(cfg, p, b, max_len=max_len))
    decode_fn = jax.jit(
        lambda p, c, pos, t: decode_step(cfg, p, c, pos, t)
    )

    logits, cache, pos = prefill_fn(params, batch)
    token = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    out_tokens = [token]
    t0 = time.time()
    for i in range(NEW - 1):
        logits, cache = decode_fn(
            params, cache, jnp.asarray(pos + i, jnp.int32), token
        )
        token = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        out_tokens.append(token)
    token.block_until_ready()
    dt = (time.time() - t0) / (NEW - 1) * 1000
    seqs = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    assert seqs.shape == (B, NEW)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{arch:22s} family={cfg.family:7s} {dt:7.1f} ms/token "
          f"first-request tokens: {seqs[0][:8].tolist()}")
print("OK")
