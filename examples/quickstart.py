"""Quickstart: schedule and solve one sparse triangular system through the
``repro.pipeline`` front door.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import bsp_cost, check_validity, schedule_stats
from repro.pipeline import PlanCache, TriangularSolver, schedule
from repro.solver import solve_lower_scipy
from repro.sparse import dag_from_lower_csr, ichol0, poisson2d_matrix

# 1. a realistic matrix: IC(0) factor of a 2D Poisson problem
A = poisson2d_matrix(64)
L = ichol0(A)
print(f"matrix: n={L.n_rows} nnz={L.nnz}")

# 2. peek under the hood: the registry runs any strategy on the solve DAG
dag = dag_from_lower_csr(L)
sched = schedule(dag, 8, strategy="growlocal")
check_validity(dag, sched)
stats = schedule_stats(dag, sched)
print(f"GrowLocal: {stats['n_supersteps']} supersteps, "
      f"modeled speed-up {stats['speedup_model']:.2f}x")
for name in ("serial", "hdagg"):
    s = schedule(dag, 8, strategy=name)
    print(f"  vs {name:7s}: BSP cost ratio "
          f"{bsp_cost(dag, s) / bsp_cost(dag, sched):.2f}x")

# 3. the one-call pipeline: plan (DAG -> schedule -> reorder -> compile ->
#    bind) and solve; permutations are handled internally
cache = PlanCache()
solver = TriangularSolver.plan(L, strategy="growlocal", k=8, cache=cache)
print(f"plan: {solver.exec_plan.stats()}")

rng = np.random.default_rng(0)
b = rng.standard_normal(L.n_rows)
x = np.asarray(solver.solve(b))

# 4. verify against scipy
x_ref = solve_lower_scipy(L, b)
err = np.abs(x - x_ref).max() / np.abs(x_ref).max()
print(f"relative error vs scipy: {err:.2e}")
assert err < 1e-3

# 5. batched multi-RHS: one plan traversal solves all columns
B = rng.standard_normal((L.n_rows, 4))
X = np.asarray(solver.solve(B))
for j in range(B.shape[1]):
    ref = solve_lower_scipy(L, B[:, j])
    assert np.abs(X[:, j] - ref).max() / np.abs(ref).max() < 1e-3
print(f"multi-RHS: solved {B.shape[1]} systems in one traversal")

# 6. a second plan on the same pattern is a cache hit — no rescheduling
TriangularSolver.plan(L, strategy="growlocal", k=8, cache=cache)
print(f"cache: {cache.stats.as_dict()}")
assert cache.stats.hits == 1

# 7. or skip choosing altogether: strategy="auto" extracts DAG features,
#    shortlists candidate configs by regime and scores them with the §2.2
#    cost model — the whole selection is memoized per sparsity pattern
#    (fresh cache here so the auto-built solver, not the step-3 entry,
#    is what comes back — `selection` records how a solver was built)
auto_cache = PlanCache()
auto = TriangularSolver.plan(L, strategy="auto", k=8, cache=auto_cache)
sel = auto.selection
print(f"auto: regime={sel.regime!r} picked {sel.strategy!r} from "
      f"{[(s, round(c)) for s, c in sel.as_dict()['candidates']]}")
x_auto = np.asarray(auto.solve(b))
assert np.abs(x_auto - x_ref).max() / np.abs(x_ref).max() < 1e-3
best_cand = min(c for _, c in sel.as_dict()["candidates"])
assert sel.cost <= best_cand  # the winner is the argmin of the shortlist
# replanning is free: the selection memo + plan cache absorb everything
TriangularSolver.plan(L, strategy="auto", k=8, cache=auto_cache)
assert auto_cache.stats.selections == 1
assert auto_cache.stats.selection_hits == 1
print("OK")
