"""Quickstart: schedule and solve one sparse triangular system.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    apply_reordering,
    bsp_cost,
    check_validity,
    compile_plan,
    grow_local,
    hdagg_schedule,
    schedule_stats,
    serial_schedule,
)
from repro.solver import make_solver, solve_lower_scipy
from repro.sparse import dag_from_lower_csr, ichol0, poisson2d_matrix

# 1. a realistic matrix: IC(0) factor of a 2D Poisson problem
A = poisson2d_matrix(64)
L = ichol0(A)
print(f"matrix: n={L.n_rows} nnz={L.nnz}")

# 2. build the solve DAG and run the paper's scheduler
dag = dag_from_lower_csr(L)
sched = grow_local(dag, k=8)
check_validity(dag, sched)
stats = schedule_stats(dag, sched)
print(f"GrowLocal: {stats['n_supersteps']} supersteps, "
      f"modeled speed-up {stats['speedup_model']:.2f}x")
for name, s in [("serial", serial_schedule(dag)), ("hdagg", hdagg_schedule(dag, 8))]:
    print(f"  vs {name:7s}: BSP cost ratio "
          f"{bsp_cost(dag, s) / bsp_cost(dag, sched):.2f}x")

# 3. reorder for locality (§5), compile the execution plan, solve
rng = np.random.default_rng(0)
b = rng.standard_normal(L.n_rows)
L2, sched2, b2, r = apply_reordering(L, sched, b)
plan = compile_plan(L2, sched2)
print(f"plan: {plan.stats()}")
solve = make_solver(plan)
x2 = np.asarray(solve(b2))

# 4. verify against scipy, un-permute
x = np.empty_like(x2)
x[r.perm] = x2
x_ref = solve_lower_scipy(L, b)
err = np.abs(x - x_ref).max() / np.abs(x_ref).max()
print(f"relative error vs scipy: {err:.2e}")
assert err < 1e-3
print("OK")
