"""Train a small LM end-to-end on CPU: the full framework path
(config -> params -> data pipeline -> train loop -> checkpoint -> resume).

By default trains a ~12M-parameter granite-family model for 60 steps and
verifies the loss decreases, then kills and resumes from the checkpoint.
Pass --steps/--d-model to scale up (e.g. ~100M: --d-model 512 --layers 8).

    PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLMData
from repro.models import init_params, param_specs
from repro.train import AdamWConfig, make_train_step
from repro.train.train_loop import init_train_state

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=60)
p.add_argument("--d-model", type=int, default=256)
p.add_argument("--layers", type=int, default=4)
p.add_argument("--batch", type=int, default=8)
p.add_argument("--seq", type=int, default=128)
p.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
args = p.parse_args()

cfg = dataclasses.replace(
    get_reduced("granite_3_2b"),
    d_model=args.d_model,
    n_layers=args.layers,
    n_heads=max(4, args.d_model // 64),
    n_kv_heads=max(2, args.d_model // 128),
    d_ff=args.d_model * 4,
    vocab_size=2048,
    vocab_pad_to=256,
)
params = init_params(param_specs(cfg), jax.random.PRNGKey(0), dtype=jnp.float32)
n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
print(f"model: {cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
      f"params={n_params/1e6:.1f}M")

data = SyntheticLMData(vocab=cfg.vocab_size, batch=args.batch, seq=args.seq, seed=1)
state = init_train_state(cfg, params)
opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
step_fn = jax.jit(make_train_step(cfg, opt, microbatches=2))

losses = []
t0 = time.time()
for step in range(args.steps):
    batch = data.next_batch(step)
    state, metrics = step_fn(state, batch)
    losses.append(float(metrics["loss"]))
    if step % 10 == 0 or step == args.steps - 1:
        print(f"step {step:4d} loss {losses[-1]:.4f} "
              f"lr {float(metrics['lr']):.2e} "
              f"gnorm {float(metrics['grad_norm']):.2f}")
print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")
first, last = np.mean(losses[:5]), np.mean(losses[-5:])
assert last < first, f"loss did not decrease: {first:.3f} -> {last:.3f}"
print(f"loss {first:.3f} -> {last:.3f}  (decreasing ✓)")

# checkpoint / kill / resume
save_checkpoint(args.ckpt, state, step=args.steps)
restored, meta = restore_checkpoint(args.ckpt, template=state)
state2, metrics2 = step_fn(restored, data.next_batch(args.steps))
print(f"resumed at step {meta['step']}: loss {float(metrics2['loss']):.4f}")
print("OK")
