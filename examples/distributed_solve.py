"""Distributed SpTRSV: the BSP schedule executed across a device mesh,
barriers realized as all-gathers (DESIGN.md §3). Runs on 8 forced host
devices — the same code path the 512-chip dry-run lowers. The whole
matrix -> plan -> mesh binding is one ``TriangularSolver.plan`` call with
``backend="distributed"``.

    PYTHONPATH=src python examples/distributed_solve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.pipeline import TriangularSolver  # noqa: E402
from repro.solver import solve_lower_scipy  # noqa: E402
from repro.sparse import erdos_renyi_lower  # noqa: E402

K_DEVICES = 4  # 'model' axis: schedule cores = devices
BATCH = 2  # RHS batch over 'data'

L = erdos_renyi_lower(2000, 1e-3, seed=7)
mesh = jax.make_mesh((2, K_DEVICES), ("data", "model"))
solver = TriangularSolver.plan(
    L, strategy="growlocal", backend="distributed", k=K_DEVICES, mesh=mesh
)
print(f"n={L.n_rows} nnz={L.nnz} supersteps={solver.n_supersteps} "
      f"(= all-gathers in the lowered graph)")

# multi-RHS: solver.solve takes f[n, m]; the batch shards over 'data'
b = np.random.default_rng(0).standard_normal((L.n_rows, BATCH))
x = np.asarray(solver.solve(b))

for i in range(BATCH):
    ref = solve_lower_scipy(L, b[:, i])
    err = np.abs(x[:, i] - ref).max() / np.abs(ref).max()
    print(f"rhs {i}: rel err {err:.2e}")
    assert err < 1e-3
print("OK")
