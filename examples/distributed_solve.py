"""Distributed SpTRSV: the BSP schedule executed across a device mesh,
barriers realized as all-gathers (DESIGN.md §3). Runs on 8 forced host
devices — the same code path the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/distributed_solve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import apply_reordering, compile_plan, grow_local  # noqa: E402
from repro.solver import solve_lower_scipy  # noqa: E402
from repro.solver.distributed import run_distributed_solve  # noqa: E402
from repro.sparse import dag_from_lower_csr, erdos_renyi_lower  # noqa: E402

K_DEVICES = 4  # 'model' axis: schedule cores = devices
BATCH = 2  # RHS batch over 'data'

L = erdos_renyi_lower(2000, 1e-3, seed=7)
dag = dag_from_lower_csr(L)
sched = grow_local(dag, K_DEVICES)
L2, s2, _, _ = apply_reordering(L, sched)
plan = compile_plan(L2, s2)
print(f"n={L.n_rows} nnz={L.nnz} supersteps={s2.n_supersteps} "
      f"(= all-gathers in the lowered graph)")

mesh = jax.make_mesh((2, K_DEVICES), ("data", "model"))
b = np.random.default_rng(0).standard_normal((BATCH, L.n_rows))
x = run_distributed_solve(plan, b, mesh)

for i in range(BATCH):
    ref = solve_lower_scipy(L2, b[i])
    err = np.abs(x[i] - ref).max() / np.abs(ref).max()
    print(f"rhs {i}: rel err {err:.2e}")
    assert err < 1e-3
print("OK")
