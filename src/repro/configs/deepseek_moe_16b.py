"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained
[arXiv:2401.06066; hf]. Experts shard over 'model' (EP)."""
from repro.models.lm import ModelConfig
from repro.models.moe import MoEConfig

MODEL = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(
        d_model=2048,
        d_expert=1408,
        n_experts=64,
        top_k=6,
        n_shared=2,
        shard_mode="ep",
    ),
)

REDUCED = ModelConfig(
    name="deepseek-moe-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=256,
    vocab_pad_to=64,
    attn_kv_chunk=32,
    moe=MoEConfig(
        d_model=64, d_expert=96, n_experts=8, top_k=2, n_shared=2,
        shard_mode="ep",
    ),
)
