"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407; unverified].
head_dim=128 (96*128=12288)."""
from repro.models.lm import ModelConfig

MODEL = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
)

REDUCED = ModelConfig(
    name="mistral-large-reduced",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    vocab_pad_to=64,
    attn_kv_chunk=32,
)
