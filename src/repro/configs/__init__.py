"""Architecture registry: ``get_config(arch_id)`` -> ModelConfig,
``get_reduced(arch_id)`` -> CPU-smoke-testable ModelConfig of the same
family, plus the canonical input-shape sets.

Shapes (assigned to every LM arch):
  train_4k     seq 4096,   global batch 256  (train_step)
  prefill_32k  seq 32768,  global batch 32   (prefill)
  decode_32k   kv 32768,   global batch 128  (decode_step)
  long_500k    kv 524288,  global batch 1    (decode_step; sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from repro.models.lm import ModelConfig

ARCH_IDS = [
    "granite_3_2b",
    "phi3_mini_3_8b",
    "mistral_large_123b",
    "qwen3_32b",
    "rwkv6_7b",
    "deepseek_moe_16b",
    "mixtral_8x7b",
    "seamless_m4t_large_v2",
    "recurrentgemma_2b",
    "llava_next_mistral_7b",
]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    microbatches: int = 1

    def cell_name(self, arch: str) -> str:
        return f"{arch}×{self.name}"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def canonical(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.MODEL


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.REDUCED


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; otherwise the skip reason
    (recorded in EXPERIMENTS.md §Dry-run)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "full quadratic attention: 500k-token decode is out of scope by "
            "assignment (sub-quadratic archs only)"
        )
    return None


def all_cells():
    """Every (arch, shape) pair with its skip status."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            out.append((arch, shape.name, shape_applicable(cfg, shape)))
    return out
