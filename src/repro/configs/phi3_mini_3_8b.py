"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32, i.e. MHA)
d_ff=8192 vocab=32064 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]."""
from repro.models.lm import ModelConfig

MODEL = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
)

REDUCED = ModelConfig(
    name="phi3-mini-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    vocab_pad_to=64,
    attn_kv_chunk=32,
)
