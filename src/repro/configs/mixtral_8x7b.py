"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, SWA 4096 [arXiv:2401.04088; hf].
8 experts < 16 'model' devices -> shard_mode='tp' (expert-internal TP)."""
from repro.models.lm import ModelConfig
from repro.models.moe import MoEConfig

MODEL = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    window=4096,  # sliding-window attention => long_500k runs
    moe=MoEConfig(
        d_model=4096, d_expert=14336, n_experts=8, top_k=2, n_shared=0,
        shard_mode="tp",
    ),
)

REDUCED = ModelConfig(
    name="mixtral-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    vocab_pad_to=64,
    window=32,
    attn_kv_chunk=32,
    moe=MoEConfig(
        d_model=64, d_expert=128, n_experts=4, top_k=2, n_shared=0,
        shard_mode="tp",
    ),
)
