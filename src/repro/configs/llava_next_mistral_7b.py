"""llava-next-mistral-7b [vlm]: mistral-7b backbone (32L d_model=4096 32H
GQA kv=8 d_ff=14336 vocab=32000) + anyres patch embeddings
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. The vision tower is a
stub by assignment: input_specs provides precomputed patch embeddings
(anyres tiling -> up to 2880 patches) that are projected and prepended."""
from repro.models.lm import ModelConfig

N_PATCHES = 2880  # anyres: up to 4 tiles + base, 576 patches each

MODEL = ModelConfig(
    name="llava-next-mistral-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    frontend="vision",
)

REDUCED = ModelConfig(
    name="llava-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    vocab_pad_to=64,
    frontend="vision",
    attn_kv_chunk=32,
)
