"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892; hf]. 64 heads of dim 64."""
from repro.models.lm import ModelConfig

MODEL = ModelConfig(
    name="rwkv6-7b",
    family="rwkv6",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # head_dim 64 (RWKV6 standard)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    # beyond-paper optimized default (§Perf): chunked WKV, 43-50x lower
    # HBM traffic than the per-token scan; exactness cross-checked in
    # tests/test_rwkv_chunked.py. Set wkv_chunk=None for the faithful scan.
    wkv_chunk=32,
)

REDUCED = ModelConfig(
    name="rwkv6-reduced",
    family="rwkv6",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    vocab_pad_to=64,
)
