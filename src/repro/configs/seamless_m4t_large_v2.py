"""seamless-m4t-large-v2 [audio]: enc-dec, 24L each, d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf]. The speech frontend
is a stub by assignment: input_specs provides precomputed frame embeddings
[B, T_frames, d_model]."""
from repro.models.lm import ModelConfig

MODEL = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
)

REDUCED = ModelConfig(
    name="seamless-reduced",
    family="encdec",
    n_layers=2,
    n_dec_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    vocab_pad_to=64,
    frontend="audio",
    attn_kv_chunk=32,
)
