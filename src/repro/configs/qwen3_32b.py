"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]. head_dim=128 per the
Qwen3 family (q projection is 64*128=8192 wide)."""
from repro.models.lm import ModelConfig

MODEL = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
)

REDUCED = ModelConfig(
    name="qwen3-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_to=64,
    qk_norm=True,
    attn_kv_chunk=32,
)
