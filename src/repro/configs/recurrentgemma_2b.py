"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1 attn : 2 rec [arXiv:2402.19427;
hf]. Local window 2048; lru width 2560; 26 = 8 superblocks (rec,rec,attn)
+ 2 tail rec layers."""
from repro.models.lm import ModelConfig

MODEL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    local_window=2048,
    d_rnn=2560,
)

REDUCED = ModelConfig(
    name="recurrentgemma-reduced",
    family="hybrid",
    n_layers=5,  # 1 superblock + 2 tail
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_to=64,
    local_window=32,
    d_rnn=64,
    attn_kv_chunk=32,
)
