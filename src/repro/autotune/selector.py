"""Layer 2 of the autotuner: transparent, feature-driven strategy selection.

Two stages, both inspectable:

1.  ``classify`` maps the DAG features to a *regime* label, and
    ``shortlist`` maps the regime to 2–3 candidate
    ``(strategy, ScheduleOptions)`` configs. The rules are a small,
    documented table calibrated on the scenario corpus
    (``autotune.corpus``; thresholds re-checked by
    ``tests/test_autotune.py``):

      regime     trigger (features f, cores k, in order)   candidates
      ---------  ----------------------------------------  ----------------
      serial     f.avg_wavefront < 2  or  f.n <= 64        serial, growlocal
      wide       f.depth <= 8                              hdagg, growlocal,
                                                           serial
      banded     0 < f.mean_band <= 0.1 * f.n              growlocal, serial,
                                                           funnel-gl
      wide       f.avg_wavefront >= 8k                     (as above)
      mixed      everything else                           growlocal,
                                                           funnel-gl, serial

    Rationale: chain-like DAGs cannot amortize a single barrier (§2.2's L
    dwarfs the work), so serial wins; shallow-wide DAGs are the one place
    level-set schedulers (HDagg) beat GrowLocal because every level is
    wide enough to balance; locality-friendly banded/FEM DAGs are
    GrowLocal/Funnel territory (the paper's headline regime); the funnel
    coarsening only pays off when there is depth to collapse.

    The rule ORDER is part of the N>=1e5 recalibration (ROADMAP): the
    locality rule must fire before the wavefront-width rule because
    ``avg_wavefront >= 8k`` stops implying "few barriers" at scale — a
    deep narrow-band DAG at N=1e5 has avg_wavefront ~ 80 yet thousands
    of L-costed supersteps, so it must stay "banded". The depth <= 8
    trigger (definitionally shallow) still precedes it, and the banded
    rule requires mean_band > 0 so edge-free (fully parallel) DAGs keep
    classifying "wide". Scale stability is asserted by
    ``tests/test_autotune.py::test_classify_stable_at_scale`` over the
    ``scale_corpus`` tier (``autotune.corpus``).

2.  ``select_schedule`` runs every shortlisted candidate and scores it
    with the exact §2.2 objective ``bsp_cost(dag, s, L)`` — the model the
    schedulers themselves optimize — keeping the first minimum
    (deterministic: the shortlist order is the tie-break).

    With ``allow_elastic=True`` (the solver passes it when the target
    backend advertises the ``"elastic"`` capability and the caller did
    not force ``mode="bsp"``) a second, step-granular rule runs on the
    winner: in the deep-DAG regimes ("serial", "banded") — where the
    plan's scan trip count ``T`` (``schedule_step_count``), not the
    barrier count, dominates single-chip wall-clock — elastic execution
    is turned on (``options.slack = DEFAULT_SLACK``) whenever fusing
    slack-sized runs shrinks the trip count at least 2x, i.e.
    ``elastic_cost(dag, s, slack)`` halves the ``l_step`` term of
    ``step_cost(dag, s)``. The selection's ``cost`` stays the winner's
    ``bsp_cost`` — elastic changes how the schedule is *executed*, not
    which schedule wins.

``resolve_auto`` wraps this for ``TriangularSolver.plan(strategy="auto")``
and memoizes the outcome per (sparsity fingerprint, options, orientation)
— in the passed ``PlanCache`` when there is one (so refactorizations skip
selection entirely and resolve straight to a concrete plan-cache key),
else in a module-level table. With ``tune=True`` it additionally *times*
the shortlisted compiled plans on the real backend (measured trials, like
"Elasticity in Parallel Sparse Triangular Solve" adapts execution mode to
the instance) and lets wall-clock override the model; when elastic is
allowed, the winner is further swept over the small ``SLACK_GRID`` of
staleness windows so the slack too is clock-picked (memoized per
fingerprint with the rest of the selection).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro import obs
from repro.autotune.features import MatrixFeatures, dag_features, matrix_features
from repro.core import DEFAULT_SLACK, Schedule, bsp_cost, schedule_step_count
from repro.pipeline.registry import ScheduleOptions, get_scheduler
from repro.sparse.csr import CSRMatrix, pattern_fingerprint
from repro.sparse.dag import SolveDAG, dag_from_lower_csr

REGIMES = ("serial", "wide", "banded", "mixed")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One shortlisted config; ``cost`` is filled in once scored."""

    strategy: str
    options: ScheduleOptions
    cost: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Selection:
    """Outcome of one auto-selection — the winner plus the full scored
    shortlist, so callers can audit why a strategy was chosen."""

    strategy: str
    options: ScheduleOptions
    cost: float  # bsp_cost of the winner (model units)
    regime: str
    features: MatrixFeatures
    candidates: Tuple[Candidate, ...]  # scored, in shortlist order
    tuned: bool = False
    # (strategy, median solve seconds) per candidate when tune=True
    timings: Optional[Tuple[Tuple[str, float], ...]] = None
    # (slack, median solve seconds) per swept staleness window on the
    # clock winner when tune=True ran with elastic allowed
    slack_timings: Optional[Tuple[Tuple[int, float], ...]] = None

    def as_dict(self) -> dict:
        return {
            "strategy": self.strategy,
            "regime": self.regime,
            "cost": self.cost,
            "slack": self.options.slack,  # > 0 when elastic was enabled
            "candidates": [(c.strategy, c.cost) for c in self.candidates],
            "tuned": self.tuned,
            "timings": None if self.timings is None else list(self.timings),
            "slack_timings": (
                None if self.slack_timings is None
                else list(self.slack_timings)
            ),
        }


def classify(f: MatrixFeatures, k: int = 8) -> str:
    """Map features to a regime label (see module docstring table — the
    rule order matters and is part of the N>=1e5 recalibration)."""
    if f.avg_wavefront < 2.0 or f.n <= 64:
        return "serial"
    if f.depth <= 8:
        return "wide"
    if 0.0 < f.mean_band <= 0.1 * f.n:
        return "banded"
    if f.avg_wavefront >= 8 * max(k, 1):
        return "wide"
    return "mixed"


_SHORTLISTS: Dict[str, Tuple[str, ...]] = {
    "serial": ("serial", "growlocal"),
    "wide": ("hdagg", "growlocal", "serial"),
    "banded": ("growlocal", "serial", "funnel-gl"),
    "mixed": ("growlocal", "funnel-gl", "serial"),
}


def shortlist(
    f: MatrixFeatures, options: Optional[ScheduleOptions] = None
) -> Tuple[Candidate, ...]:
    """2–3 candidate configs for these features, in tie-break order.
    Strategy-specific knobs are adapted from the features where it is
    known to matter: the funnel coarsening cap tracks the average
    wavefront so funnels span whole levels (§4)."""
    o = options or ScheduleOptions()
    out = []
    for name in _SHORTLISTS[classify(f, o.k)]:
        oc = o
        if name == "funnel-gl" and o.max_size == ScheduleOptions.max_size:
            # cap funnels near the average level width: big enough to
            # collapse whole wavefronts, small enough to keep k busy —
            # but only when the caller left max_size at its default (an
            # explicitly passed knob is respected as-is)
            oc = o.replace(
                max_size=int(np.clip(2 * f.avg_wavefront, 16, 256))
            )
        out.append(Candidate(strategy=name, options=oc))
    return tuple(out)


def select_schedule(
    dag: SolveDAG,
    options: Optional[ScheduleOptions] = None,
    *,
    features: Optional[MatrixFeatures] = None,
    allow_elastic: bool = False,
) -> Tuple[Selection, Schedule]:
    """Pick a strategy for ``dag``: classify -> shortlist -> score every
    candidate with ``bsp_cost`` -> first minimum wins. Returns the
    audit-friendly ``Selection`` together with the winning schedule (so
    ``schedule(dag, strategy="auto")`` costs nothing extra).

    ``allow_elastic=True`` additionally applies the step-granular elastic
    rule (module docstring): in the "serial"/"banded" regimes, when the
    winning schedule's step count fuses >= 2x at ``DEFAULT_SLACK``, the
    returned options carry ``slack=DEFAULT_SLACK`` so the solver binds
    the elastic executor. The slack is applied to EVERY candidate's
    options, not just the winner's — measured trials (``tune=True``)
    rebuild the tuned Selection from whichever candidate wins the clock,
    and that candidate must keep the elastic decision."""
    o = options or ScheduleOptions()
    if features is not None:
        f = features
    else:
        with obs.span("autotune.features", cat="autotune", n=dag.n):
            f = dag_features(dag)
    regime = classify(f, o.k)
    with obs.span(
        "autotune.select", cat="autotune", regime=regime, n=dag.n
    ) as sel_sp:
        best = None  # (cost, candidate, schedule)
        scored = []
        for c in shortlist(f, o):
            with obs.span(
                f"autotune.score.{c.strategy}", cat="autotune"
            ):
                s = get_scheduler(c.strategy)(dag, c.options)
                cost = bsp_cost(dag, s, L=c.options.L)
            scored.append(dataclasses.replace(c, cost=cost))
            if best is None or cost < best[0]:
                best = (cost, scored[-1], s)
        cost, c, s = best
        sel_sp.set(strategy=c.strategy)
    if allow_elastic and o.slack == 0 and regime in ("serial", "banded"):
        # step-granular rule: elastic pays when the fused trip count
        # ceil(T / slack) is at most half the plan's step count T (the
        # l_step term of step_cost vs elastic_cost; critical work is
        # identical, so comparing the fusion ratio IS comparing costs)
        n_steps = schedule_step_count(s)
        n_macro = -(-n_steps // DEFAULT_SLACK)
        if n_steps >= 2 * n_macro:
            scored = [
                dataclasses.replace(
                    sc, options=sc.options.replace(slack=DEFAULT_SLACK)
                )
                for sc in scored
            ]
            c = dataclasses.replace(
                c, options=c.options.replace(slack=DEFAULT_SLACK)
            )
    sel = Selection(
        strategy=c.strategy,
        options=c.options,
        cost=cost,
        regime=regime,
        features=f,
        candidates=tuple(scored),
    )
    return sel, s


# ------------------------------------------------------------ plan() hook
# Fallback memo for cache-less plans. Unlike a PlanCache's selection dict
# (tiny, scoped to the cache's lifetime) this table is process-global, so
# it is FIFO-capped: a serving loop streaming distinct patterns through
# cache=None must not grow it forever.
_MEMO_LOCK = threading.Lock()
_MEMO_MAX = 4096
_SELECTION_MEMO: Dict[tuple, Selection] = {}


def _memo_store(key: tuple, sel: Selection) -> None:
    with _MEMO_LOCK:
        while len(_SELECTION_MEMO) >= _MEMO_MAX:
            _SELECTION_MEMO.pop(next(iter(_SELECTION_MEMO)))
        _SELECTION_MEMO[key] = sel


def clear_selection_memo() -> None:
    with _MEMO_LOCK:
        _SELECTION_MEMO.clear()


def _binding_key(plan_kwargs: Optional[dict]) -> tuple:
    """The plan_kwargs that influence measured-trial timings (tune=True):
    two bindings that compile differently must not share a tuned pick.
    Delegates to the same ``binding_fingerprint`` that keys the plan
    cache, so the two identities can never drift apart. The backend name
    is resolved against ``repro.backends.registry`` — measured trials run
    on whatever backend the registry serves for that name, so an unknown
    name fails here instead of inside a half-timed trial."""
    from repro.backends import get_backend
    from repro.pipeline.solver import binding_fingerprint

    pk = plan_kwargs or {}
    return binding_fingerprint(
        backend=get_backend(pk.get("backend", "scan")).name,
        dtype=pk.get("dtype", np.float32),
        width=pk.get("width"),
        steps_per_tile=pk.get("steps_per_tile", 8),
        interpret=pk.get("interpret"),
        mesh=pk.get("mesh"),
        shard=pk.get("shard", "model"),
    )


def selection_key(
    fp: str, options: ScheduleOptions, lower: bool, tune: bool,
    binding: Optional[tuple] = None, elastic: bool = False,
) -> tuple:
    """Memo key for one auto-selection. ``binding`` (see ``_binding_key``)
    only matters for measured trials; the model-based path is binding-free.
    ``elastic`` is the caller's ``allow_elastic`` flag — the same pattern
    resolved for an elastic-capable binding and for one that cannot run
    elastic (e.g. the distributed backend) must not share a memo entry,
    or the slack decision would leak across backends."""
    return (fp, options, lower, tune, binding if tune else None, elastic)


def resolve_auto(
    a: CSRMatrix,
    *,
    options: ScheduleOptions,
    lower: bool = True,
    tune: bool = False,
    cache=None,
    fp: Optional[str] = None,
    plan_kwargs: Optional[dict] = None,
    allow_elastic: bool = False,
) -> Selection:
    """Resolve ``strategy="auto"`` for matrix ``a`` to a concrete
    ``Selection``, memoized per sparsity fingerprint — in ``cache`` (a
    ``PlanCache``) when given, else module-level. On a memo hit nothing
    is recomputed: the caller goes straight to a concrete plan-cache key.
    """
    sel, _, _ = resolve_auto_full(
        a, options=options, lower=lower, tune=tune, cache=cache, fp=fp,
        plan_kwargs=plan_kwargs, allow_elastic=allow_elastic,
    )
    return sel


def resolve_auto_full(
    a: CSRMatrix,
    *,
    options: ScheduleOptions,
    lower: bool = True,
    tune: bool = False,
    cache=None,
    fp: Optional[str] = None,
    plan_kwargs: Optional[dict] = None,
    allow_elastic: bool = False,
) -> Tuple[Selection, Optional[Schedule], Optional[object]]:
    """``resolve_auto`` plus two cold-path artifacts for ``plan()``:

    * the winner's already-computed ``Schedule`` when the model-based
      selection ran fresh (skips re-running the winning scheduler), or
    * the winner's fully-built trial *solver* when ``tune=True`` ran
      measured trials (skips recompiling the winner).

    Both are None on a memo hit — the caller's plan cache already has, or
    will rebuild, the concrete plan."""
    fp = fp if fp is not None else pattern_fingerprint(a)
    key = selection_key(
        fp, options, lower, tune, _binding_key(plan_kwargs), allow_elastic
    )
    if cache is not None:
        sel = cache.get_selection(key)
    else:
        with _MEMO_LOCK:
            sel = _SELECTION_MEMO.get(key)
    if sel is not None:
        return sel, None, None

    # the same mirror step plan() uses, so the features and candidate
    # costs describe the DAG that will actually be scheduled
    from repro.pipeline.solver import mirror_to_lower

    m0, _ = mirror_to_lower(a, lower)
    dag = dag_from_lower_csr(m0)
    with obs.span("autotune.features", cat="autotune", n=m0.n_rows):
        f = matrix_features(m0, dag=dag)
    sel, winning_sched = select_schedule(
        dag, options, features=f, allow_elastic=allow_elastic
    )
    winner_solver = None
    if tune:
        sel, winner_solver = _timed_refine(
            a, sel, lower=lower, plan_kwargs=plan_kwargs,
            allow_elastic=allow_elastic,
        )
        winning_sched = None

    if cache is not None:
        cache.store_selection(key, sel)
    else:
        _memo_store(key, sel)
    return sel, winning_sched, winner_solver


# tune=True slack grid: the elastic staleness windows measured trials
# sweep on the winning strategy (plus slack=0, bulk-synchronous, and the
# model rule's pick when it differs). Small on purpose — each point is a
# compile + timed solves; the tuned pick is memoized per fingerprint via
# the selection memo, so the sweep runs once per pattern.
SLACK_GRID = (4, 8, 16)


def _timed_refine(
    a: CSRMatrix,
    sel: Selection,
    *,
    lower: bool,
    plan_kwargs: Optional[dict],
    reps: int = 3,
    allow_elastic: bool = False,
) -> Tuple[Selection, object]:
    """Measured-trial mode: compile every shortlisted candidate through
    the real pipeline and let the median wall-clock of an actual solve
    pick the winner. Trials run against a PRIVATE plan cache — losing
    plans never pollute (or evict hot entries from) the caller's cache,
    and the winner solver is still private when the tuned Selection is
    recorded on it, so no published object is ever mutated. The winner is
    returned for ``plan()`` to insert under its concrete key.

    With ``allow_elastic=True`` the winning strategy is additionally
    swept over ``SLACK_GRID`` (and slack=0): the model's step-granular
    elastic rule picks a fusion ratio, but the best staleness window is
    an instance property only the clock can settle. The swept points
    ride the Selection's ``slack_timings`` (``timings`` stays one row
    per shortlisted strategy), and the tuned options carry whichever
    slack won."""
    import time

    from repro.pipeline.cache import PlanCache
    from repro.pipeline.solver import TriangularSolver

    kw = dict(plan_kwargs or {})
    kw.pop("strategy", None)
    kw.pop("options", None)
    kw["cache"] = PlanCache()  # private to this selection
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.n_rows)

    def _time_plan(label, strategy, options):
        with obs.span(
            f"autotune.trial.{label}", cat="autotune", reps=reps
        ) as tr_sp:
            solver = TriangularSolver.plan(
                a, strategy=strategy, options=options, lower=lower, **kw
            )
            solver.solve(b)  # compile + warm up
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                np.asarray(solver.solve(b))
                ts.append(time.perf_counter() - t0)
            median = float(np.median(ts))
            tr_sp.set(median_us=round(median * 1e6, 1))
        return solver, median

    timings = []
    trial = {}  # strategy -> solver
    for c in sel.candidates:
        solver, median = _time_plan(c.strategy, c.strategy, c.options)
        trial[c.strategy] = solver
        timings.append((c.strategy, median))
    t_of = dict(timings)
    winner = min(sel.candidates, key=lambda c: t_of[c.strategy])
    win_options = winner.options
    winner_solver = trial[winner.strategy]

    slack_timings = None
    if allow_elastic:
        # sweep the slack dimension on the clock winner; the point the
        # model already picked (win_options.slack) reuses its timing
        base_slack = win_options.slack
        best = (t_of[winner.strategy], base_slack, winner_solver)
        slack_rows = [(base_slack, best[0])]
        for s in sorted({0, *SLACK_GRID} - {base_slack}):
            solver_s, median = _time_plan(
                f"{winner.strategy}.slack{s}",
                winner.strategy,
                win_options.replace(slack=s),
            )
            slack_rows.append((s, median))
            if median < best[0]:
                best = (median, s, solver_s)
        if best[1] != base_slack:
            win_options = win_options.replace(slack=best[1])
            winner_solver = best[2]
        slack_timings = tuple(sorted(slack_rows))

    tuned = dataclasses.replace(
        sel,
        strategy=winner.strategy,
        options=win_options,
        cost=winner.cost,
        tuned=True,
        timings=tuple(timings),
        slack_timings=slack_timings,
    )
    winner_solver._selection = tuned  # still private — safe to record
    return tuned, winner_solver
