"""Layer 3 of the autotuner: the named scenario corpus.

One shared, named set of lower-triangular test matrices spanning every
scheduling regime the paper's data sets exercise (§6.2) plus the
pathological DAG shapes the selector must not mispick on:

  * Erdős–Rényi sparse / dense        (§6.2.4 — shallow-wide vs mixed)
  * narrow-band, two (p, B) points    (§6.2.5 — deep, locality-friendly)
  * IC(0) factors of Poisson 2D / 3D  (§6.2.1/§6.2.3 FEM stand-ins)
  * chain / star / independent DAGs   (worst cases: zero parallelism,
                                       two-level fan-out, fully parallel)

Each entry carries *expected-regime metadata* — the selector's
``classify`` label and the fixed strategies expected to be near-optimal —
so the selector's calibration, the conformance suite and
``benchmarks/table7x_auto.py`` all reason about the same ground truth.
Matrices are sized for the CPU container (n ≈ 400–800); the generators
scale the same way the benchmark data sets do (benchmarks/common.py).

A second, N >= 1e5 *scale tier* (``scale_corpus_names`` /
``scale_corpus_entry``) holds the same families scaled to 100k rows —
ER keeps the expected row degree, the band keeps its (p, B). It
deliberately does NOT join ``corpus_names()``: the default corpus feeds
the conformance grid and the serve load generator, which would take the
100k inspector+compile hit in every cell. The scale tier is consumed by
the selector's scale-stability test (the ROADMAP N>=1e5 recalibration)
and ``benchmarks/inspector_bench.py``.

Pathological generators keep |off-diagonal| / |diagonal| ≤ 0.45 so
forward substitution is well conditioned even on an n-long chain
(error growth ~ 0.45^distance instead of the paper value distribution's
up-to-4x per step, which would swamp an f32 conformance check).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable, Dict, Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo, lower_triangle_of
from repro.sparse.generators import (
    erdos_renyi_lower,
    narrow_band_lower,
    poisson2d_matrix,
    poisson3d_matrix,
)
from repro.sparse.ichol import ichol0


def _stable_values(
    rng: np.random.Generator, n_off: int, n_diag: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Off-diagonal ~ U[-0.45, 0.45], diagonal sign·U[1, 2] — contraction
    along every dependency path (see module docstring)."""
    off = rng.uniform(-0.45, 0.45, size=n_off)
    diag = rng.uniform(1.0, 2.0, size=n_diag) * rng.choice(
        [-1.0, 1.0], size=n_diag
    )
    return off, diag


def chain_lower(n: int, *, seed: int = 0) -> CSRMatrix:
    """Pure dependency chain: row i needs row i-1. Depth n, width 1 —
    the zero-parallelism worst case where 'serial' must win."""
    rng = np.random.default_rng(seed)
    rows = np.arange(1, n, dtype=np.int64)
    cols = rows - 1
    off, diag = _stable_values(rng, len(rows), n)
    ar = np.concatenate([rows, np.arange(n, dtype=np.int64)])
    ac = np.concatenate([cols, np.arange(n, dtype=np.int64)])
    av = np.concatenate([off, diag])
    return csr_from_coo(n, n, ar, ac, av)


def star_lower(n: int, *, seed: int = 0) -> CSRMatrix:
    """Star: every row depends only on row 0. Depth 2, one huge second
    wavefront — a fan-out stress test for load balancing."""
    rng = np.random.default_rng(seed)
    rows = np.arange(1, n, dtype=np.int64)
    cols = np.zeros(n - 1, dtype=np.int64)
    off, diag = _stable_values(rng, len(rows), n)
    ar = np.concatenate([rows, np.arange(n, dtype=np.int64)])
    ac = np.concatenate([cols, np.arange(n, dtype=np.int64)])
    av = np.concatenate([off, diag])
    return csr_from_coo(n, n, ar, ac, av)


def independent_lower(n: int, *, seed: int = 0) -> CSRMatrix:
    """Diagonal-only: n independent rows, depth 1 — the fully parallel
    wide-DAG extreme (any one-superstep schedule is optimal)."""
    rng = np.random.default_rng(seed)
    _, diag = _stable_values(rng, 0, n)
    idx = np.arange(n, dtype=np.int64)
    return csr_from_coo(n, n, idx, idx, diag)


@dataclasses.dataclass(frozen=True)
class CorpusEntry:
    """A named scenario: matrix factory + expected-regime metadata."""

    name: str
    make: Callable[[], CSRMatrix]
    regime: str  # selector.classify() label this matrix should get
    expected_best: Tuple[str, ...]  # fixed strategies expected near-optimal
    description: str

    def matrix(self) -> CSRMatrix:
        return _corpus_matrix(self.name)


_ENTRIES: Dict[str, CorpusEntry] = {}


def _entry(name, make, regime, expected_best, description):
    _ENTRIES[name] = CorpusEntry(
        name=name, make=make, regime=regime,
        expected_best=tuple(expected_best), description=description,
    )


# ``regime`` is the ``selector.classify`` label the matrix must get;
# ``expected_best`` lists the fixed strategies whose default-options BSP
# cost is within ~10% of the best fixed strategy at k=8 (measured at
# these container sizes — tests/test_autotune.py re-derives and checks).
# -- §6.2.4 Erdős–Rényi -----------------------------------------------------
_entry(
    "er_sparse", lambda: erdos_renyi_lower(800, 0.002, seed=101),
    regime="wide",
    expected_best=("hdagg",),
    description="ER n=800 p=0.002 — shallow, wide, nearly independent rows",
)
_entry(
    "er_dense", lambda: erdos_renyi_lower(500, 0.03, seed=102),
    regime="mixed",
    expected_best=("growlocal", "funnel-gl", "serial"),
    description="ER n=500 p=0.03 — deeper DAG, heavy rows near the bottom",
)
# -- §6.2.5 narrow band -----------------------------------------------------
_entry(
    "band_narrow", lambda: narrow_band_lower(800, 0.14, 10, seed=103),
    regime="banded",
    expected_best=("serial", "growlocal"),
    description="band n=800 p=0.14 B=10 — deep chain-of-blocks, good locality",
)
_entry(
    "band_wide", lambda: narrow_band_lower(800, 0.03, 42, seed=104),
    regime="banded",
    expected_best=("serial",),
    description="band n=800 p=0.03 B=42 — wider band, moderate depth",
)
# -- §6.2.1/§6.2.3 FEM stand-ins -------------------------------------------
_entry(
    "poisson2d_ichol", lambda: ichol0(poisson2d_matrix(26)),
    regime="banded",
    expected_best=("growlocal", "funnel-gl", "serial"),
    description="IC(0) of 26x26 Poisson — the PCG workload's own factor",
)
_entry(
    "poisson3d_ichol", lambda: ichol0(poisson3d_matrix(9)),
    regime="banded",
    expected_best=("growlocal", "funnel-gl", "serial"),
    description="IC(0) of 9^3 Poisson — 3D connectivity, wider wavefronts",
)
# -- pathological DAG shapes ------------------------------------------------
_entry(
    "chain", lambda: chain_lower(400, seed=105),
    regime="serial",
    expected_best=("serial", "growlocal", "funnel-gl"),
    description="pure chain n=400 — zero parallelism; barriers only hurt",
)
_entry(
    "star", lambda: star_lower(600, seed=106),
    regime="wide",
    expected_best=("hdagg", "spmp", "wavefront"),
    description="star n=600 — depth 2, one huge fan-out wavefront",
)
_entry(
    "independent", lambda: independent_lower(600, seed=107),
    regime="wide",
    expected_best=("hdagg", "spmp", "wavefront"),
    description="diagonal n=600 — depth 1, embarrassingly parallel",
)


# -- N >= 1e5 scale tier (see module docstring) -----------------------------
# ``expected_best`` here is indicative (the regime's shortlist leaders),
# not re-derived at scale by the container tests — scheduling 100k-row
# matrices across all 7 strategies is benchmark territory, not tier-1.
_SCALE_N = 100_000
_SCALE_ENTRIES: Dict[str, CorpusEntry] = {}


def _scale_entry(name, make, regime, expected_best, description):
    _SCALE_ENTRIES[name] = CorpusEntry(
        name=name, make=make, regime=regime,
        expected_best=tuple(expected_best), description=description,
    )


_scale_entry(
    "er_sparse_100k",
    lambda: erdos_renyi_lower(_SCALE_N, 0.002 * 800 / _SCALE_N, seed=201),
    regime="wide",
    expected_best=("hdagg",),
    description="ER n=100k, row degree matched to er_sparse — shallow, wide",
)
_scale_entry(
    "er_dense_100k",
    lambda: erdos_renyi_lower(_SCALE_N, 0.03 * 500 / _SCALE_N, seed=202),
    regime="wide",
    expected_best=("hdagg", "growlocal"),
    description="ER n=100k, row degree matched to er_dense — deep but every "
    "level is thousands wide, so barriers amortize at this scale",
)
_scale_entry(
    "band_narrow_100k",
    lambda: narrow_band_lower(_SCALE_N, 0.14, 10, seed=203),
    regime="banded",
    expected_best=("growlocal", "serial"),
    description="band n=100k p=0.14 B=10 — same (p, B) as band_narrow; "
    "thousands of wavefronts, locality-bound",
)
_scale_entry(
    "poisson2d_100k",
    lambda: lower_triangle_of(poisson2d_matrix(317)),
    regime="banded",
    expected_best=("growlocal", "funnel-gl", "serial"),
    description="lower triangle of 317x317 Poisson (n=100489) — FEM-style "
    "banded structure at paper scale",
)
_scale_entry(
    "chain_100k",
    lambda: chain_lower(_SCALE_N, seed=205),
    regime="serial",
    expected_best=("serial", "growlocal"),
    description="pure chain n=100k — zero parallelism at any scale",
)
_scale_entry(
    "independent_100k",
    lambda: independent_lower(_SCALE_N, seed=207),
    regime="wide",
    expected_best=("hdagg", "spmp", "wavefront"),
    description="diagonal n=100k — depth 1, embarrassingly parallel",
)


@lru_cache(maxsize=None)
def _corpus_matrix(name: str) -> CSRMatrix:
    entry = _ENTRIES.get(name) or _SCALE_ENTRIES[name]
    return entry.make()


def corpus_names() -> Tuple[str, ...]:
    return tuple(_ENTRIES)


def corpus_entries() -> Tuple[CorpusEntry, ...]:
    return tuple(_ENTRIES.values())


def corpus_entry(name: str) -> CorpusEntry:
    try:
        return _ENTRIES[name]
    except KeyError:
        raise KeyError(
            f"unknown corpus matrix {name!r}; available: {corpus_names()}"
        ) from None


def scale_corpus_names() -> Tuple[str, ...]:
    return tuple(_SCALE_ENTRIES)


def scale_corpus_entries() -> Tuple[CorpusEntry, ...]:
    return tuple(_SCALE_ENTRIES.values())


def scale_corpus_entry(name: str) -> CorpusEntry:
    try:
        return _SCALE_ENTRIES[name]
    except KeyError:
        raise KeyError(
            f"unknown scale-corpus matrix {name!r}; available: "
            f"{scale_corpus_names()}"
        ) from None
