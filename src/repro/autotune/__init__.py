"""Autotuner: let the pipeline pick the scheduling strategy.

The paper's experiments show no single schedule dominates — the best
strategy (and its knobs) depends on the matrix's DAG shape. This package
makes ``TriangularSolver.plan(L, strategy="auto")`` choose it:

  * ``features``  — cheap DAG/matrix feature extraction, memoized per
                    sparsity fingerprint (depth, wavefront widths, skew,
                    bandwidth, ...)
  * ``selector``  — transparent rule table features -> candidate configs,
                    scored with the §2.2 BSP cost model; optional
                    ``tune=True`` measured trials on the real backend
  * ``corpus``    — the named scenario corpus (ER, narrow-band, Poisson
                    IC(0), chain/star/independent) with expected-regime
                    metadata, shared by calibration, conformance tests and
                    ``benchmarks/table7x_auto.py``
"""
from repro.autotune.corpus import (
    CorpusEntry,
    chain_lower,
    corpus_entries,
    corpus_entry,
    corpus_names,
    independent_lower,
    scale_corpus_entries,
    scale_corpus_entry,
    scale_corpus_names,
    star_lower,
)
from repro.autotune.features import (
    MatrixFeatures,
    clear_feature_cache,
    dag_features,
    matrix_features,
)
from repro.autotune.selector import (
    REGIMES,
    Candidate,
    Selection,
    classify,
    clear_selection_memo,
    resolve_auto,
    resolve_auto_full,
    select_schedule,
    selection_key,
    shortlist,
)

__all__ = [
    "CorpusEntry",
    "chain_lower",
    "corpus_entries",
    "corpus_entry",
    "corpus_names",
    "independent_lower",
    "scale_corpus_entries",
    "scale_corpus_entry",
    "scale_corpus_names",
    "star_lower",
    "MatrixFeatures",
    "clear_feature_cache",
    "dag_features",
    "matrix_features",
    "REGIMES",
    "Candidate",
    "Selection",
    "classify",
    "clear_selection_memo",
    "resolve_auto",
    "resolve_auto_full",
    "select_schedule",
    "selection_key",
    "shortlist",
]
