"""Layer 1 of the autotuner: cheap DAG/matrix feature extraction.

The strategy selector (``autotune.selector``) never looks at the matrix
itself — it reasons over a handful of scalar features of the solve DAG
that together pin down the scheduling regime (paper §6.2's data-set axes):

  * size            — ``n``, ``nnz``, ``n_edges``
  * depth           — level-set depth (= #wavefronts = longest path), the
                      hard lower bound on barrier-synchronized supersteps
  * wavefront shape — average / maximum wavefront width: how much
                      parallelism each level actually exposes
  * row-length skew — max/mean row nnz: load-balance hazard for
                      wavefront-style schedulers
  * bandwidth       — max / mean distance |i - j| of off-diagonal entries:
                      the locality axis (§6.2.5 narrow-band family)

Everything is one ``topological_levels`` sweep plus O(nnz) reductions —
orders of magnitude cheaper than any scheduler — and is computed once per
sparsity fingerprint (``matrix_features`` memoizes; schedulers and the
plan cache already key on the same fingerprint).

All features except the bandwidth pair are invariants of the DAG up to
relabeling, so they are preserved by any topological reorder — in
particular the §5 locality reorder (``features.invariant()`` returns
exactly that subset; the property test in ``tests/test_autotune.py``
asserts it). Bandwidth is a property of the current row numbering and is
deliberately *not* invariant: it is what the §5 reorder improves.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

import numpy as np

from repro.sparse.csr import CSRMatrix, pattern_fingerprint
from repro.sparse.dag import SolveDAG, dag_from_lower_csr, topological_levels


@dataclasses.dataclass(frozen=True)
class MatrixFeatures:
    """Scalar summary of a solve DAG. See the module docstring for the
    meaning of each axis; ``invariant()`` is the relabeling-invariant
    subset the permutation-invariance property is stated over."""

    n: int
    nnz: int  # total stored entries incl. the diagonal
    n_edges: int  # strictly-lower entries = DAG edges
    depth: int  # level-set depth (#wavefronts)
    avg_wavefront: float  # n / depth — paper §6.2's parallelizability proxy
    max_wavefront: int
    row_nnz_mean: float
    row_nnz_max: int
    row_skew: float  # row_nnz_max / row_nnz_mean (>= 1)
    bandwidth: int  # max (i - j) over strictly-lower entries; 0 if none
    mean_band: float  # mean (i - j) over strictly-lower entries; 0 if none

    @property
    def density(self) -> float:
        """Fraction of the strictly-lower triangle that is populated."""
        slots = self.n * (self.n - 1) / 2
        return self.n_edges / slots if slots else 0.0

    def invariant(self) -> dict:
        """The features preserved by any symmetric topological reorder
        (DAG isomorphism invariants) — everything except the bandwidth
        pair, which depends on the row numbering itself."""
        d = dataclasses.asdict(self)
        d.pop("bandwidth")
        d.pop("mean_band")
        return d

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def dag_features(dag: SolveDAG) -> MatrixFeatures:
    """Extract features from a solve DAG (one Kahn sweep + O(|E|) math)."""
    n = dag.n
    if n == 0:
        return MatrixFeatures(
            n=0, nnz=0, n_edges=0, depth=0, avg_wavefront=0.0,
            max_wavefront=0, row_nnz_mean=0.0, row_nnz_max=0, row_skew=1.0,
            bandwidth=0, mean_band=0.0,
        )
    levels = topological_levels(dag)
    widths = np.bincount(levels)
    depth = len(widths)
    # DAG weights are row nnz (incl. diagonal) by construction (§2.2)
    w = dag.weights
    row_mean = float(w.mean())
    # edge list (v = row, u = column of a strictly-lower entry)
    v_of_edge = np.repeat(
        np.arange(n, dtype=np.int64), np.diff(dag.parent_ptr)
    )
    dist = v_of_edge - dag.parent_idx
    return MatrixFeatures(
        n=n,
        nnz=int(w.sum()),
        n_edges=dag.n_edges,
        depth=depth,
        avg_wavefront=n / depth,
        max_wavefront=int(widths.max()),
        row_nnz_mean=row_mean,
        row_nnz_max=int(w.max()),
        row_skew=float(w.max() / row_mean),
        bandwidth=int(dist.max()) if len(dist) else 0,
        mean_band=float(dist.mean()) if len(dist) else 0.0,
    )


# process-global, so FIFO-capped: a long-lived server streaming distinct
# sparsity patterns must not accumulate features forever (each entry is a
# dozen scalars; the cap is generous)
_CACHE_LOCK = threading.Lock()
_CACHE_MAX = 8192
_FEATURE_CACHE: Dict[str, MatrixFeatures] = {}


def matrix_features(
    a: CSRMatrix, *, dag: Optional[SolveDAG] = None
) -> MatrixFeatures:
    """Features of lower-triangular ``a``, memoized per sparsity
    fingerprint (values never matter — features are pure pattern
    properties). Pass ``dag`` if the caller already built it."""
    fp = pattern_fingerprint(a)
    with _CACHE_LOCK:
        cached = _FEATURE_CACHE.get(fp)
    if cached is not None:
        return cached
    f = dag_features(dag if dag is not None else dag_from_lower_csr(a))
    with _CACHE_LOCK:
        while len(_FEATURE_CACHE) >= _CACHE_MAX:
            _FEATURE_CACHE.pop(next(iter(_FEATURE_CACHE)))
        _FEATURE_CACHE[fp] = f
    return f


def clear_feature_cache() -> None:
    with _CACHE_LOCK:
        _FEATURE_CACHE.clear()
