"""Wavefront (level-set) scheduler — the classical baseline [AS89, Sal90].

Every wavefront becomes one superstep; vertices of a wavefront are split
across cores. Two splitting rules:
  * ``contiguous`` — ID-contiguous weight-balanced chunks (good locality;
    this is also the synchronous projection of SpMP's level scheduling,
    see ``spmp_like``),
  * ``cyclic`` — round-robin (the classic locality-oblivious variant).
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import Schedule
from repro.sparse.dag import SolveDAG, wavefronts


def wavefront_schedule(
    dag: SolveDAG, k: int, *, split: str = "contiguous"
) -> Schedule:
    pi = np.zeros(dag.n, dtype=np.int32)
    sigma = np.zeros(dag.n, dtype=np.int32)
    rank = np.zeros(dag.n, dtype=np.int64)
    levels = wavefronts(dag)
    for s, verts in enumerate(levels):
        verts = np.sort(verts)
        sigma[verts] = s
        if split == "cyclic":
            cores = np.arange(len(verts)) % k
        elif split == "contiguous":
            w = dag.weights[verts].astype(np.float64)
            cum = np.cumsum(w) - w / 2.0
            total = max(float(w.sum()), 1e-30)
            cores = np.minimum((cum / total * k).astype(np.int64), k - 1)
        else:
            raise ValueError(f"unknown split rule: {split}")
        pi[verts] = cores
        # rank: position within (superstep, core)
        for p in range(k):
            sel = verts[cores == p]
            rank[sel] = np.arange(len(sel))
    return Schedule(
        n=dag.n,
        k=k,
        pi=pi,
        sigma=sigma,
        rank=rank,
        n_supersteps=len(levels),
    )
