"""Row partitioner — split one compiled ``ExecPlan`` across mesh shards,
with a static *halo exchange plan* instead of an O(n) all-gather.

The ``distributed`` backend's model-axis mode assigns one schedule core
per device and broadcasts **all** x-fragments with a full ``all_gather``
every superstep — a single solve must fit one device's plan, and the
barrier traffic is O(k * T) values per device regardless of how few
values actually cross device boundaries.  This module is the scalable
alternative (cf. the multi-GPU SpTRSV literature): partition the
dependency DAG itself and communicate only the boundary x-entries each
consumer shard actually reads.

The partition rides the paper's own machinery instead of a graph
partitioner:

  * The §5 reordering has already laid rows out contiguously by
    (superstep, core, rank), so *cores are contiguous row blocks*.
    ``partition_plan`` groups the plan's ``k`` cores into ``n_shards``
    contiguous blocks of ``k_local = k / n_shards`` cores — on
    banded/locality DAGs neighboring cores hold neighboring row bands,
    so almost all dependencies stay inside a shard.
  * BSP validity (Def. 2.1) guarantees every cross-core — hence every
    cross-shard — dependency crosses a superstep barrier.  The schedule
    certificate is therefore *also* the halo-exchange correctness
    certificate: exchanging boundary values only at barriers suffices.
  * The elastic fused-run certificate (``core.elastic``) extends this:
    a fused run has no cross-core reads of values written inside it, so
    one exchange per fused run (``exchange_bounds``) is equally valid —
    barrier fusion and halo exchange compose.

Each shard gets a *local* ``ExecPlan`` over its own index space:
``[0, n_loc)`` owned rows (global-id order), ``[n_loc, n_loc+n_halo)``
halo slots for remote rows it reads, and a trailing scratch slot that
padding reads/writes (always zero).  Row/column ids are remapped to
local slots so the per-shard executor is the ordinary scan executor —
same gathers, same fixed-order lane reduction, same scatter — which is
what makes the sharded solve *bitwise-identical* to the single-device
scan solve.

For every exchange round the partitioner emits exact
(source shard, row) -> (dest shard, slot) index tensors in two lowered
forms (``HaloRound``): a **ring** form (one ``ppermute`` per occupied
hop distance; bitwise-safe) and a **sparse-psum** form (one ``psum`` of
a compact boundary buffer per round).  Both move each boundary value to
each consumer exactly once per solve.

Pure NumPy, inspector-phase work: everything is O(nnz + n) vectorized
passes, no device state is touched.  The device half lives in
``repro.solver.rowsharded``; bind through
``get_backend("distributed").bind(plan, mesh=mesh, shard="rows")``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.elastic import step_dependencies
from repro.core.plan import ExecPlan


@dataclasses.dataclass
class HaloRound:
    """Static exchange plan for one barrier: the boundary x-entries
    finalized during this round, as gather/scatter index tensors.

    Ring form (``hops``): one ``(h, send_slot, recv_slot)`` triple per
    occupied hop distance ``h`` — shard ``i`` sends
    ``x[send_slot[i, :]]`` to shard ``(i + h) % n_shards`` (one
    ``ppermute``), and the receiver scatters position ``p`` into local
    slot ``recv_slot[dst, p]``.  Sender and receiver tables are ordered
    identically (by global row id within each (src, dst) pair), so the
    positional correspondence IS the routing.  Padding positions send
    the scratch slot (always 0) and land on the receiver's scratch slot.

    Sparse-psum form: a shared buffer of ``buf_size`` distinct boundary
    rows (+1 trash position).  Owners scatter-add their fresh values at
    ``send_pos``, one ``psum`` reduces across shards, consumers gather
    ``recv_pos`` into their halo slots.  One collective per round
    regardless of hop structure, at the price of the ``x + 0.0``
    negative-zero hazard (``-0.0 + 0.0 == +0.0``): not bitwise-safe
    when a solved boundary value is ``-0.0``, which is why the executor
    defaults to the ring form.
    """

    hops: Tuple  # ((h, send_slot i32[n_shards, H], recv_slot ...), ...)
    send_slot: np.ndarray  # int32[n_shards, Hs]  (psum form)
    send_pos: np.ndarray  # int32[n_shards, Hs]
    recv_pos: np.ndarray  # int32[n_shards, Hr]
    recv_slot: np.ndarray  # int32[n_shards, Hr]
    buf_size: int  # distinct boundary rows this round
    n_values: int  # real (row -> dest shard) pairs exchanged this round

    @property
    def ring_values(self) -> int:
        """Values moved per device this round in ring form (padded)."""
        return int(sum(ss.shape[1] for _, ss, _ in self.hops))


@dataclasses.dataclass
class RowShardPlan:
    """A row-partitioned plan: per-shard local ``ExecPlan``s plus the
    halo exchange schedule.  ``shards[j]`` is a complete, valid plan
    over shard ``j``'s local slot space (its scratch slot is
    ``n_loc + n_halo``); all shards share identical tensor shapes so
    they stack into SPMD operands.

    ``owner[g]`` / ``local_slot[g]`` map global row ``g`` (plan order)
    to its shard and owned slot; ``b_scatter``/``x_gather`` are the
    precomputed flat index maps the executor uses to scatter the rhs
    into per-shard buffers and gather the solution back out.
    ``exchange_bounds`` are superstep indices: exchange round ``r``
    covers supersteps ``[exchange_bounds[r], exchange_bounds[r+1])``
    and is followed by one halo exchange (``rounds[r]``, absent after
    the last round).
    """

    n: int
    n_shards: int
    k_local: int
    n_loc: int
    n_halo: int
    W: int
    T: int
    shards: List[ExecPlan]
    owner: np.ndarray  # int32[n]
    local_slot: np.ndarray  # int64[n]
    step_bounds: tuple  # len S+1 (plan step indices)
    exchange_bounds: tuple  # len F+1 (superstep indices)
    rounds: List[HaloRound]  # len F-1 (no exchange after the last round)
    halo_pairs: int  # total (boundary row -> dest shard) pairs

    @property
    def slots(self) -> int:
        """Local x length: owned + halo + trailing scratch slot."""
        return self.n_loc + self.n_halo + 1

    @property
    def scratch(self) -> int:
        return self.n_loc + self.n_halo

    @property
    def n_rounds(self) -> int:
        return len(self.exchange_bounds) - 1

    @property
    def b_scatter(self) -> np.ndarray:
        """int64[n]: flat index into ``[n_shards * slots]`` placing
        ``b[g]`` at (owner, owned slot)."""
        return self.owner.astype(np.int64) * self.slots + self.local_slot

    @property
    def x_gather(self) -> np.ndarray:
        """int64[n]: flat index into ``[n_shards * n_loc]`` recovering
        ``x[g]`` from the stacked owned regions."""
        return self.owner.astype(np.int64) * self.n_loc + self.local_slot

    def comm_stats(self, itemsize: int = 4) -> dict:
        """The comm-volume model, per device per RHS (JSON-ready).

        ``allgather_values`` is what the model-axis executor's full
        ``all_gather`` moves (every core's xv at every step: ``k * T``);
        the halo numbers are what this partition moves instead.
        ``halo_ratio`` is the headline: padded ring traffic over the
        all-gather baseline."""
        ring = int(sum(r.ring_values for r in self.rounds))
        psum = int(sum(r.buf_size for r in self.rounds))
        per_round = [r.ring_values for r in self.rounds]
        ag = int(self.n_shards * self.k_local * self.T)
        return {
            "n_shards": self.n_shards,
            "exchange_rounds": self.n_rounds,
            "active_exchanges": int(sum(1 for r in self.rounds if r.n_values)),
            "halo_pairs": int(self.halo_pairs),
            "halo_values_per_solve": ring,
            "halo_bytes_per_solve": ring * itemsize,
            "halo_values_psum": psum,
            "halo_values_max_round": int(max(per_round, default=0)),
            "allgather_values": ag,
            "allgather_bytes": ag * itemsize,
            "halo_ratio": ring / max(ag, 1),
        }


def _pad_lanes(plan: ExecPlan, kp: int) -> ExecPlan:
    """Pad the plan's core axis UP to ``kp`` lanes so the cores split
    evenly into shards.  Padding lanes follow the plan's own protocol —
    row id n (scratch), self-gathers, val 0 / diag 1, source maps -1 —
    so they compute harmless writes to the scratch slot."""
    k = plan.k
    if kp == k:
        return plan
    T, pad = plan.n_steps, kp - k

    def padk(a, fill):
        block = np.full((T, pad, *a.shape[2:]), fill, dtype=a.dtype)
        return np.concatenate([a, block], axis=1)

    return dataclasses.replace(
        plan,
        k=kp,
        row_ids=padk(plan.row_ids, plan.n),
        col_idx=padk(plan.col_idx, plan.n),
        vals=padk(plan.vals, 0),
        diag=padk(plan.diag, 1),
        accum=padk(plan.accum, False),
        val_src=None if plan.val_src is None else padk(plan.val_src, -1),
        diag_src=None if plan.diag_src is None else padk(plan.diag_src, -1),
    )


def _group_pad(shard_ids, values, n_shards: int, fill: int) -> np.ndarray:
    """``int32[n_shards, H]`` table: ``values`` grouped by ``shard_ids``
    (input order preserved within each group — callers pre-sort), padded
    with ``fill``.  ``H`` is the max group size (0 groups everywhere ->
    ``[n_shards, 0]``)."""
    shard_ids = np.asarray(shard_ids, dtype=np.int64)
    values = np.asarray(values, dtype=np.int64)
    c = np.bincount(shard_ids, minlength=n_shards)
    H = int(c.max()) if shard_ids.size else 0
    out = np.full((n_shards, H), fill, dtype=np.int32)
    if shard_ids.size:
        order = np.argsort(shard_ids, kind="stable")
        offs = np.concatenate([[0], np.cumsum(c)])
        sid = shard_ids[order]
        ranks = np.arange(shard_ids.size, dtype=np.int64) - offs[sid]
        out[sid, ranks] = values[order]
    return out


def _build_round(
    n_shards: int, scratch: int, u, src, dst, send, recv
) -> HaloRound:
    """Lower one round's (row, src shard, dest shard) pairs to both
    exchange forms.  ``send``/``recv`` are the per-pair local slots."""
    u = np.asarray(u, dtype=np.int64)
    nv = int(u.size)
    hops = []
    if nv:
        hop = (dst - src) % n_shards
        for h in np.unique(hop):
            m = hop == h
            # order pairs by (src, row id): sender and receiver tables
            # get the same per-pair positions (dst = src + h is a
            # bijection, so per-shard group sizes match on both sides)
            o = np.lexsort((u[m], src[m]))
            ss = _group_pad(src[m][o], send[m][o], n_shards, scratch)
            rt = _group_pad(dst[m][o], recv[m][o], n_shards, scratch)
            hops.append((int(h), ss, rt))
    # sparse-psum form: one buffer position per distinct boundary row
    # (a row read by several shards is sent once, gathered by each)
    u_uniq, first = np.unique(u, return_index=True)
    R = int(u_uniq.size)
    pos_of = np.searchsorted(u_uniq, u) if nv else np.zeros(0, np.int64)
    send_slot = _group_pad(src[first], send[first], n_shards, scratch)
    send_pos = _group_pad(
        src[first], np.arange(R, dtype=np.int64), n_shards, R
    )
    recv_pos = _group_pad(dst, pos_of, n_shards, R)
    recv_slot = _group_pad(dst, recv, n_shards, scratch)
    return HaloRound(
        hops=tuple(hops),
        send_slot=send_slot,
        send_pos=send_pos,
        recv_pos=recv_pos,
        recv_slot=recv_slot,
        buf_size=R,
        n_values=nv,
    )


def partition_plan(
    plan: ExecPlan, n_shards: int, *, exchange_bounds=None
) -> RowShardPlan:
    """Partition ``plan``'s rows across ``n_shards`` by contiguous core
    blocks and derive the halo exchange schedule.

    ``exchange_bounds`` (optional, ``int[F+1]`` superstep indices) fuses
    barriers: one exchange per run instead of per superstep.  Pass the
    elastic certificate's ``fused_bounds`` (``core.elastic``) — the
    partitioner *verifies* that no cross-shard dependency is read in the
    round that writes it, so an invalid fusion fails here, at inspection
    time, instead of producing silent garbage on device."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if plan.n_steps == 0 or plan.n == 0:
        raise ValueError("cannot partition an empty plan")
    n, W = plan.n, plan.W
    padded = _pad_lanes(plan, -(-plan.k // n_shards) * n_shards)
    kp = padded.k
    k_local = kp // n_shards
    T = padded.n_steps
    S = padded.n_supersteps
    sb = np.asarray(padded.step_bounds, dtype=np.int64)

    if exchange_bounds is None:
        fb = np.arange(S + 1, dtype=np.int64)
    else:
        fb = np.asarray(exchange_bounds, dtype=np.int64)
    if len(fb) < 2 or fb[0] != 0 or fb[-1] != S or np.any(np.diff(fb) < 1):
        raise ValueError(
            f"exchange_bounds must be increasing superstep bounds "
            f"covering [0, {S}]; got {fb.tolist()}"
        )
    F = len(fb) - 1
    round_of_sup = np.repeat(np.arange(F, dtype=np.int64), np.diff(fb))
    sup_of_step = np.repeat(np.arange(S, dtype=np.int64), np.diff(sb))

    writer_step, writer_lane, _ = step_dependencies(padded)
    owner = (writer_lane // k_local).astype(np.int32)
    writer_round = round_of_sup[sup_of_step[writer_step]]  # per row

    # owned slots: rows sorted by (owner, global id) — after the §5
    # reorder global ids are (superstep, core, rank)-sorted, so each
    # shard's owned region is a run of contiguous row bands
    order = np.argsort(owner, kind="stable")
    counts = np.bincount(owner, minlength=n_shards)
    offs = np.concatenate([[0], np.cumsum(counts)])
    local_slot = np.empty(n, dtype=np.int64)
    local_slot[order] = np.arange(n, dtype=np.int64) - offs[owner[order]]
    n_loc = max(int(counts.max()), 1)

    # cross-shard dependency edges: every real gather whose column's
    # owner differs from the reading lane's shard
    shape = padded.col_idx.shape
    lane = np.broadcast_to(np.arange(kp, dtype=np.int64)[None, :, None], shape)
    reader_shard = lane // k_local
    owner_pad = np.concatenate([owner.astype(np.int64), [-1]])
    cross = (padded.col_idx != n) & (owner_pad[padded.col_idx] != reader_shard)
    u_all = padded.col_idx[cross].astype(np.int64)
    dst_all = reader_shard[cross]

    # the certificate check: a cross-shard value must be written in an
    # earlier exchange round than every read of it (Def. 2.1 for the
    # per-superstep bounds; the fused-run certificate otherwise)
    t_idx = np.broadcast_to(
        np.arange(T, dtype=np.int64)[:, None, None], shape
    )
    reader_round = round_of_sup[sup_of_step[t_idx[cross]]]
    bad = writer_round[u_all] >= reader_round
    if np.any(bad):
        g = int(u_all[bad][0])
        raise ValueError(
            f"exchange_bounds do not certify this partition: row {g} is "
            f"read across shards in the round that writes it "
            f"(round {int(writer_round[g])}) — the schedule/fusion "
            f"certificate is violated"
        )

    key = u_all * n_shards + dst_all
    ukey = np.unique(key)
    u_h = ukey // n_shards
    dst_h = ukey % n_shards
    halo_pairs = int(ukey.size)

    # halo slot ranks per dest shard, ordered by (dst, global row id)
    order_h = np.lexsort((u_h, dst_h))
    hcounts = np.bincount(dst_h, minlength=n_shards)
    hoffs = np.concatenate([[0], np.cumsum(hcounts)])
    halo_rank = np.empty(halo_pairs, dtype=np.int64)
    halo_rank[order_h] = (
        np.arange(halo_pairs, dtype=np.int64) - hoffs[dst_h[order_h]]
    )
    n_halo = int(hcounts.max()) if halo_pairs else 0

    # per-shard global -> local slot lookup (scratch by default, so the
    # global scratch column n and never-referenced rows stay harmless)
    scratch = n_loc + n_halo
    g2l = np.full((n_shards, n + 1), scratch, dtype=np.int64)
    g2l[owner, np.arange(n)] = local_slot
    if halo_pairs:
        g2l[dst_h, u_h] = n_loc + halo_rank

    sidx = np.arange(n_shards)

    def stack(a):  # [T, kp, ...] -> [n_shards, T, k_local, ...]
        moved = a.reshape(T, n_shards, k_local, *a.shape[2:])
        return np.ascontiguousarray(np.moveaxis(moved, 1, 0))

    rows_st = stack(padded.row_ids)
    cols_st = stack(padded.col_idx)
    row_loc = g2l[sidx[:, None, None], rows_st].astype(np.int32)
    col_loc = g2l[sidx[:, None, None, None], cols_st].astype(np.int32)
    vals_st = stack(padded.vals)
    diag_st = stack(padded.diag)
    acc_st = stack(padded.accum)
    vsrc_st = None if padded.val_src is None else stack(padded.val_src)
    dsrc_st = None if padded.diag_src is None else stack(padded.diag_src)

    shards = [
        ExecPlan(
            n=scratch,
            k=k_local,
            W=W,
            row_ids=row_loc[j],
            col_idx=col_loc[j],
            vals=vals_st[j],
            diag=diag_st[j],
            accum=acc_st[j],
            step_bounds=np.asarray(padded.step_bounds).copy(),
            val_src=None if vsrc_st is None else vsrc_st[j],
            diag_src=None if dsrc_st is None else dsrc_st[j],
        )
        for j in range(n_shards)
    ]

    # exchange rounds: boundary rows grouped by the round that writes
    # them (each value moves to each consumer exactly once, right after
    # it is finalized; it then stays resident in the halo slot)
    src_h = owner_pad[u_h]
    wr_h = writer_round[u_h]
    send_local = local_slot[u_h]
    recv_local = n_loc + halo_rank
    rounds = []
    for r in range(max(F - 1, 0)):
        m = wr_h == r
        rounds.append(
            _build_round(
                n_shards, scratch,
                u_h[m], src_h[m], dst_h[m], send_local[m], recv_local[m],
            )
        )

    return RowShardPlan(
        n=n,
        n_shards=n_shards,
        k_local=k_local,
        n_loc=n_loc,
        n_halo=n_halo,
        W=W,
        T=T,
        shards=shards,
        owner=owner,
        local_slot=local_slot,
        step_bounds=tuple(int(t) for t in padded.step_bounds),
        exchange_bounds=tuple(int(s) for s in fb),
        rounds=rounds,
        halo_pairs=halo_pairs,
    )
