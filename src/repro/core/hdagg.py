"""HDagg-like baseline (Zarebavani et al. [ZCL+22]).

HDagg glues consecutive wavefronts into one superstep while a balanced
workload can be maintained. Its unit of placement is a *weakly-connected
component* of the sub-DAG induced by the glued window: placing whole
components on one core guarantees no cross-core dependency inside a
superstep (Def. 2.1 then holds within the superstep for free).

Window acceptance follows HDagg's balance test: after LPT bin-packing the
components onto k cores, the window is kept while
    max_p Omega_p  <=  tau * (sum_p Omega_p) / k.
If a single wavefront already violates the test (giant component), it is
still emitted (the algorithm must make progress) — exactly the failure mode
that makes HDagg collapse on narrow-band matrices (paper Table 7.1: 0.88x,
i.e. slower than serial).

The union-find over window components is incremental: gluing one more
wavefront only unions the new vertices' edges, so a full schedule is
O(|E| alpha(|V|) + #windows * k log k).

This is a faithful re-implementation of the published algorithm's scheduling
logic (not a binding of the original C++).
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import Schedule
from repro.sparse.dag import SolveDAG, gather_ranges, wavefronts


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def _lpt_pack(comp_w: np.ndarray, k: int):
    """LPT bin-packing; returns (core per component, max load, total)."""
    order = np.argsort(-comp_w, kind="stable")
    loads = np.zeros(k, dtype=np.float64)
    comp_core = np.zeros(len(comp_w), dtype=np.int32)
    for c in order:
        p = int(np.argmin(loads))
        comp_core[c] = p
        loads[p] += comp_w[c]
    return comp_core, float(loads.max()), float(loads.sum())


def hdagg_schedule(
    dag: SolveDAG, k: int, *, balance_tau: float = 1.15
) -> Schedule:
    levels = wavefronts(dag)
    pi = np.zeros(dag.n, dtype=np.int32)
    sigma = np.zeros(dag.n, dtype=np.int32)
    rank = np.zeros(dag.n, dtype=np.int64)
    weights = dag.weights.astype(np.float64)

    uf = _UnionFind(dag.n)
    in_window = np.zeros(dag.n, dtype=bool)

    superstep = 0
    i = 0
    while i < len(levels):
        window_verts = [levels[i]]
        _absorb(dag, uf, in_window, levels[i])
        accepted = _try_pack(uf, np.concatenate(window_verts), weights, k, np.inf)
        j = i + 1
        while j < len(levels):
            _absorb(dag, uf, in_window, levels[j])
            cand_verts = np.concatenate(window_verts + [levels[j]])
            cand = _try_pack(uf, cand_verts, weights, k, balance_tau)
            if cand is None:
                # level j is evicted; _absorb re-initializes its union-find
                # roots when it seeds the next window, so the failed unions
                # cannot leak into later windows.
                in_window[levels[j]] = False
                break
            accepted = cand
            window_verts.append(levels[j])
            j += 1
        verts = np.concatenate(window_verts)
        cores = accepted
        sigma[verts] = superstep
        pi[verts] = cores
        order = np.argsort(verts, kind="stable")  # ID order is topological
        sv, sc = verts[order], cores[order]
        for p in range(k):
            sel = sv[sc == p]
            rank[sel] = np.arange(len(sel))
        in_window[verts] = False
        superstep += 1
        i = j
    return Schedule(
        n=dag.n, k=k, pi=pi, sigma=sigma, rank=rank, n_supersteps=superstep
    )


def _absorb(dag: SolveDAG, uf: _UnionFind, in_window: np.ndarray, verts: np.ndarray):
    """Add one wavefront to the window: re-initialize the new vertices as
    fresh union-find roots (windows never share components with finalized
    supersteps) and union each new vertex with its in-window parents."""
    uf.parent[verts] = verts
    in_window[verts] = True
    parents, srcs = gather_ranges(dag.parent_ptr, dag.parent_idx, verts)
    mask = in_window[parents]
    for a, b in zip(srcs[mask], parents[mask]):
        uf.union(int(a), int(b))


def _try_pack(uf: _UnionFind, verts: np.ndarray, weights: np.ndarray, k: int, tau: float):
    roots = np.asarray([uf.find(int(v)) for v in verts], dtype=np.int64)
    comp_ids, comp_inv = np.unique(roots, return_inverse=True)
    comp_w = np.zeros(len(comp_ids), dtype=np.float64)
    np.add.at(comp_w, comp_inv, weights[verts])
    comp_core, max_load, total = _lpt_pack(comp_w, k)
    if total > 0 and max_load > tau * total / k:
        return None
    return comp_core[comp_inv]
