"""Elastic macro-step transform — bounded-slack fusion of plan steps.

The bulk-synchronous executors pay one ``lax.scan`` step (scan backend)
or one grid step (Pallas) per plan step, and — on the distributed
backend — one cross-device barrier per *superstep*.  On deep, narrow
DAGs (chain/banded regimes) that per-step overhead, not FLOPs, sets
wall-clock: the solve is a long sequence of tiny dependent steps.

``elastic_transform`` computes the *slack certificate* that lets an
executor break the step barrier safely.  For every plan step ``t`` it
derives

  * ``writer_step[row]`` — the step at which ``row``'s final (non-accum)
    virtual row executes, i.e. when ``x[row]`` becomes valid;
  * ``ready_step[t]``    — the earliest step at which every value step
    ``t`` gathers is valid: ``max(writer_step[col] + 1)`` over its real
    column indices (0 when it has none).

Step ``t`` may execute any time at or after ``ready_step[t]`` — the
elastic analogue of the paper's §4 funnel depth: instead of waiting for
the global step counter to reach ``t``, a worker only has to respect a
bounded *staleness window* of unresolved predecessors.

Two fused views are derived from the certificate, one per executor
layer:

  * **Macro-steps** (scan executor): the ``T`` plan steps are tiled into
    windows of ``slack`` consecutive steps.  One ``lax.scan`` step then
    executes a whole window with the step bodies unrolled sequentially
    *inside* it — the scan trip count drops from ``T`` to
    ``ceil(T / slack)``.  Because the window is made of the *same* steps
    in the *same* order, each row's accumulation order is untouched and
    the result is bitwise-identical to the bulk-synchronous scan.
  * **Waves** (Pallas kernel): within each window, consecutive steps
    whose dependencies all resolve *before* the window join one
    readiness wave (``wave_id``).  A wave's steps are mutually
    independent, so the kernel's ``fori_loop`` iterates per *wave*
    (``n_waves[w] <= slack``) with per-row readiness masks instead of
    one iteration per step — per-row readiness flags replace the level
    barrier.
  * **Fused superstep bounds** (barrier certificate): runs of
    supersteps whose *cross-core* dependencies all resolve before the
    run starts, capped at ``slack`` supersteps per run.  A distributed
    executor could replace the per-superstep barrier with one barrier
    per fused run; ``ExecPlan.stats()`` reports the before/after
    barrier counts.

A step starts a new wave when ``ready_step[t]`` falls inside the
current wave, or when step ``t-1`` carries a partial-sum accumulator in
any lane (``accum`` chains are same-lane consecutive steps — the carry
forces sequential order even though the gather columns may be ready).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.plan import ExecPlan

# Default staleness window (plan steps fused per macro-step).  Calibrated
# on the deep-DAG corpus regimes (chain/banded) in
# benchmarks/table7e_elastic.py: large enough to amortize per-scan-step
# dispatch, small enough to keep the unrolled window body cheap to
# compile (measured best on chain/banded at 20k rows: 1.3-1.7x over the
# bulk scan, degrading past ~16 as the unrolled body's fixed cost grows).
DEFAULT_SLACK = 8


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """The slack certificate + fused geometry for ``mode="elastic"``.

    Shapes (``T`` = plan steps, ``M = ceil(T / slack)`` macro-steps,
    ``F`` = fused superstep runs):

    slack          staleness window (plan steps per macro-step)
    n_steps        T — bulk-synchronous scan trip count
    n_macro_steps  M — elastic scan trip count
    ready_step     int64[T]  earliest step each plan step may execute
    wave_id        int32[M, slack]  readiness wave of each step within
                   its window (padding steps join the last wave)
    n_waves        int32[M]  waves per window (kernel inner trip count)
    fused_bounds   int64[F+1]  fused superstep runs: run f covers
                   supersteps [fused_bounds[f], fused_bounds[f+1])
    n_supersteps   superstep count of the underlying schedule
    """

    slack: int
    n_steps: int
    n_macro_steps: int
    ready_step: np.ndarray
    wave_id: np.ndarray
    n_waves: np.ndarray
    fused_bounds: np.ndarray
    n_supersteps: int

    @property
    def n_fused_supersteps(self) -> int:
        return len(self.fused_bounds) - 1

    def stats(self) -> dict:
        """Barrier/step accounting before vs after elastic fusion."""
        t, m = self.n_steps, self.n_macro_steps
        s, f = self.n_supersteps, self.n_fused_supersteps
        return {
            "slack": self.slack,
            "n_steps": t,
            "n_macro_steps": m,
            "step_fusion": t / max(m, 1),
            "n_supersteps": s,
            "n_fused_supersteps": f,
            "barrier_fusion": s / max(f, 1),
            "mean_waves_per_macro": float(self.n_waves.mean()) if m else 0.0,
        }


def step_dependencies(plan: ExecPlan):
    """Per-row writer step/lane and per-step readiness for ``plan``.

    Returns ``(writer_step, writer_lane, ready_step)``:
    ``writer_step[row]`` / ``writer_lane[row]`` locate the step and core
    that finalize ``x[row]`` (the row's last, non-accum virtual row);
    ``ready_step[t] = max(writer_step[col] + 1)`` over step ``t``'s real
    gather columns, 0 when it gathers none.  All pure NumPy passes —
    this is inspector-phase work and must stay O(nnz).
    """
    T, k = plan.row_ids.shape
    n = plan.n
    real = plan.row_ids != n
    final = real & ~plan.accum  # slots that write x

    writer_step = np.zeros(n, dtype=np.int64)
    writer_lane = np.zeros(n, dtype=np.int32)
    t_idx = np.broadcast_to(np.arange(T, dtype=np.int64)[:, None], (T, k))
    l_idx = np.broadcast_to(np.arange(k, dtype=np.int32)[None, :], (T, k))
    writer_step[plan.row_ids[final]] = t_idx[final]
    writer_lane[plan.row_ids[final]] = l_idx[final]

    # gather readiness: pad the writer map with -1 at the scratch slot n
    # so padded columns contribute ready step 0 (-1 + 1) for free
    ws_pad = np.concatenate([writer_step, [-1]])
    ready = (ws_pad[plan.col_idx] + 1).max(axis=(1, 2)) if T else (
        np.zeros(0, dtype=np.int64)
    )
    return writer_step, writer_lane, ready


def _wave_ids(plan: ExecPlan, ready: np.ndarray, slack: int):
    """Readiness waves within each ``slack``-step window.

    Vectorized across windows: one Python pass over the ``slack``
    in-window positions maintains, per window, the absolute step index
    of the current wave's first step and breaks a new wave when a step's
    dependencies resolve inside the wave or the previous step carries an
    accumulator.
    """
    T = plan.n_steps
    M = max(1, -(-T // slack))
    pad = M * slack - T
    # padding steps: no deps (ready 0), no accum carry -> join last wave
    ready_p = np.concatenate([ready, np.zeros(pad, dtype=np.int64)])
    carry = np.zeros(T, dtype=bool)
    if T > 1:
        carry[1:] = plan.accum[:-1].any(axis=1)
    carry_p = np.concatenate([carry, np.zeros(pad, dtype=bool)])

    rs = ready_p.reshape(M, slack)
    cb = carry_p.reshape(M, slack)
    wave = np.zeros((M, slack), dtype=np.int32)
    base = np.arange(M, dtype=np.int64) * slack
    wave_start = base.copy()  # absolute step of the current wave's head
    for j in range(1, slack):
        brk = (rs[:, j] > wave_start) | cb[:, j]
        wave[:, j] = wave[:, j - 1] + brk
        wave_start = np.where(brk, base + j, wave_start)
    return wave, wave[:, -1] + 1, M


def _fused_superstep_bounds(
    plan: ExecPlan, writer_step, writer_lane, slack: int
) -> np.ndarray:
    """Greedy fusion of superstep runs under the slack certificate.

    A run of supersteps needs only ONE barrier (before the run) iff no
    superstep in it reads a *cross-core* value written inside the run:
    same-core chains are sequential on their core anyway, so only
    cross-lane gathers force synchronization.  Runs are capped at
    ``slack`` supersteps so the staleness bound also bounds how far any
    worker can run ahead.
    """
    S = plan.n_supersteps
    if S == 0:
        return np.zeros(1, dtype=np.int64)
    T, k = plan.row_ids.shape
    sb = np.asarray(plan.step_bounds, dtype=np.int64)
    sup_of_step = np.repeat(np.arange(S, dtype=np.int64), np.diff(sb))

    # cross-core readiness per superstep: over entries whose writer sits
    # on a different core, the latest writer superstep + 1
    wl_pad = np.concatenate([writer_lane, [-1]])
    ws_pad = np.concatenate([writer_step, [-1]])
    lane = np.broadcast_to(
        np.arange(k, dtype=np.int32)[None, :, None], plan.col_idx.shape
    )
    real_col = plan.col_idx != plan.n
    cross = real_col & (wl_pad[plan.col_idx] != lane)
    xready = np.zeros(S, dtype=np.int64)
    if cross.any():
        sup_writer = sup_of_step[ws_pad[plan.col_idx[cross]]] + 1
        sup_reader = sup_of_step[
            np.broadcast_to(
                np.arange(T, dtype=np.int64)[:, None, None],
                plan.col_idx.shape,
            )[cross]
        ]
        np.maximum.at(xready, sup_reader, sup_writer)

    bounds = [0]
    start = 0
    for s in range(1, S):
        if xready[s] > start or s - start >= slack:
            bounds.append(s)
            start = s
    bounds.append(S)
    return np.asarray(bounds, dtype=np.int64)


def elastic_transform(plan: ExecPlan, slack: int = DEFAULT_SLACK) -> ElasticPlan:
    """Compute the elastic certificate and fused geometry for ``plan``.

    ``slack`` is the staleness window: the scan executor fuses runs of
    ``slack`` consecutive plan steps into one macro-step, the Pallas
    kernel iterates readiness waves within that window, and fused
    superstep runs are capped at ``slack`` supersteps.  Any ``slack >=
    1`` is valid — correctness never depends on the choice (the window
    replays the same steps in the same order), only the fused counts do.
    """
    if slack < 1:
        raise ValueError(f"slack must be >= 1, got {slack}")
    writer_step, writer_lane, ready = step_dependencies(plan)
    wave, n_waves, M = _wave_ids(plan, ready, slack)
    fused = _fused_superstep_bounds(plan, writer_step, writer_lane, slack)
    return ElasticPlan(
        slack=int(slack),
        n_steps=plan.n_steps,
        n_macro_steps=M,
        ready_step=ready,
        wave_id=wave,
        n_waves=n_waves,
        fused_bounds=fused,
        n_supersteps=plan.n_supersteps,
    )
