"""ExecPlan — compile a BSP schedule into padded tensors for TPU executors.

The executor view of a schedule (DESIGN.md §3/§4):

  * supersteps execute one after another (scan steps / kernel grid steps);
  * within a superstep, each of the k cores processes its chain of rows
    **sequentially** (same-core dependencies are legal — that is GrowLocal's
    main source of barrier savings);
  * the k cores advance in lock-step: sequential position t of every chain
    executes simultaneously (vector parallelism across cores).

The plan therefore pads every superstep to a rectangle:

    step t = 0..chain_len(s)-1 of superstep s processes rows
    row_ids[s_off + t, 0..k-1], each row with up to W off-diagonal entries
    col_idx[..., w] / vals[..., w] (padded with col -> self, val -> 0).

Rows are padded with a sentinel id pointing at a scratch slot (n), so padding
lanes write to scratch and never corrupt x. The off-diagonal width W is a
per-plan maximum; rows wider than W are split into multiple *virtual rows*
(partial-sum rows that accumulate into the same x slot — the last virtual row
finishes with the diagonal division). The plan compiler reports padding
efficiency; the §Perf loop iterates on it.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.schedule import Schedule
from repro.sparse.csr import CSRMatrix


@dataclasses.dataclass
class ExecPlan:
    """Padded execution plan. Shapes:
    row_ids   int32[T, k]      — target row of each (step, core); n = padding
    col_idx   int32[T, k, W]   — gather indices into x (self-padded)
    vals      float32/64[T,k,W]— off-diagonal values (0-padded)
    diag      float[T, k]      — diagonal value of the row (1 for padding)
    accum     bool[T, k]       — True: this step only accumulates partial
                                  sums (row split over multiple steps)
    step_bounds int32[S+1]     — superstep s covers steps
                                  [step_bounds[s], step_bounds[s+1])
    val_src   int32[T, k, W]   — index into L.data feeding vals (-1 padding)
    diag_src  int32[T, k]      — index into L.data feeding diag (-1 padding)

    ``val_src``/``diag_src`` let a caller refresh the numeric values for a
    new matrix with the *same* sparsity pattern without recompiling — the
    plan-cache ``numeric_update`` path.
    """

    n: int
    k: int
    W: int
    row_ids: np.ndarray
    col_idx: np.ndarray
    vals: np.ndarray
    diag: np.ndarray
    accum: np.ndarray
    step_bounds: np.ndarray
    val_src: np.ndarray | None = None
    diag_src: np.ndarray | None = None

    def numeric_update(self, data: np.ndarray) -> None:
        """Overwrite ``vals``/``diag`` in place from ``data`` — the ``.data``
        of a matrix with the sparsity pattern this plan was compiled for
        (same entry order as the ``L`` passed to ``compile_plan``)."""
        assert self.val_src is not None and self.diag_src is not None
        data = np.asarray(data)
        vmask = self.val_src >= 0
        self.vals[vmask] = data[self.val_src[vmask]].astype(self.vals.dtype)
        dmask = self.diag_src >= 0
        self.diag[dmask] = data[self.diag_src[dmask]].astype(self.diag.dtype)

    @property
    def n_steps(self) -> int:
        return self.row_ids.shape[0]

    @property
    def n_supersteps(self) -> int:
        return len(self.step_bounds) - 1

    def stats(self) -> dict:
        real = self.row_ids != self.n
        nnz_slots = self.col_idx.shape[0] * self.k * self.W
        real_nnz = int((self.vals != 0).sum())
        return {
            "n_steps": self.n_steps,
            "n_supersteps": self.n_supersteps,
            "k": self.k,
            "W": self.W,
            "row_slot_utilization": float(real.mean()),
            "nnz_slot_utilization": real_nnz / max(nnz_slots, 1),
            "bytes_streamed": int(
                self.col_idx.size * 4 + self.vals.size * self.vals.itemsize
                + self.row_ids.size * 4 + self.diag.size * self.diag.itemsize
            ),
        }


def compile_plan(
    L: CSRMatrix,
    sched: Schedule,
    *,
    width: int | None = None,
    dtype=np.float32,
) -> ExecPlan:
    """Compile (matrix, schedule) into an ExecPlan.

    ``width``: max off-diagonal entries per virtual row (W). Defaults to the
    95th percentile of row nnz (clipped to [4, 512]) — wide rows are split,
    narrow rows padded; the §Perf loop tunes this."""
    n, k = L.n_rows, sched.k
    row_nnz_off = L.row_nnz() - 1  # off-diagonal count (diag always present)
    assert (row_nnz_off >= 0).all(), "matrix must have a full diagonal"
    if width is None:
        width = int(np.clip(np.percentile(row_nnz_off, 95) if n else 4, 4, 512))
        width = max(width, 1)
    W = int(width)

    chains = sched.chains()
    diag_vals = L.diagonal()

    # per (superstep, core): expand each row into ceil(off_nnz / W) virtual
    # rows; chain length = sum of virtual rows; superstep step count = max
    # chain length over cores.
    step_bounds = [0]
    vrows: List[List[List[tuple]]] = []  # superstep -> core -> [(row, seg)]
    for s in range(sched.n_supersteps):
        per_core: List[List[tuple]] = []
        for p in range(k):
            chain = chains.get((s, p), np.empty(0, dtype=np.int64))
            vr: List[tuple] = []
            for v in chain:
                v = int(v)
                segs = max(1, -(-int(row_nnz_off[v]) // W))
                for g in range(segs):
                    vr.append((v, g, g == segs - 1))
            per_core.append(vr)
        vrows.append(per_core)
        step_bounds.append(step_bounds[-1] + max(len(c) for c in per_core))

    T = step_bounds[-1]
    row_ids = np.full((T, k), n, dtype=np.int32)
    col_idx = np.zeros((T, k, W), dtype=np.int32)
    vals = np.zeros((T, k, W), dtype=dtype)
    diag = np.ones((T, k), dtype=dtype)
    accum = np.zeros((T, k), dtype=bool)
    # int32 matches col_idx and halves the host-side footprint; entry ids
    # are bounded by nnz << 2^31
    val_src = np.full((T, k, W), -1, dtype=np.int32)
    diag_src = np.full((T, k), -1, dtype=np.int32)
    # padding gathers read x[n] (scratch) -> harmless 0 contribution
    col_idx[:] = n

    for s in range(sched.n_supersteps):
        base = step_bounds[s]
        for p in range(k):
            for t, (v, g, last) in enumerate(vrows[s][p]):
                cols, values = L.row(v)
                e0 = int(L.indptr[v])  # entry index of this row's first slot
                off = cols != v
                off_src = e0 + np.nonzero(off)[0]
                cols, values = cols[off], values[off]
                lo, hi = g * W, min((g + 1) * W, len(cols))
                row_ids[base + t, p] = v
                col_idx[base + t, p, : hi - lo] = cols[lo:hi]
                vals[base + t, p, : hi - lo] = values[lo:hi]
                val_src[base + t, p, : hi - lo] = off_src[lo:hi]
                diag[base + t, p] = diag_vals[v]
                dpos = np.nonzero(~off)[0]
                if len(dpos):
                    diag_src[base + t, p] = e0 + int(dpos[0])
                accum[base + t, p] = not last
    return ExecPlan(
        n=n,
        k=k,
        W=W,
        row_ids=row_ids,
        col_idx=col_idx,
        vals=vals,
        diag=diag,
        accum=accum,
        step_bounds=np.asarray(step_bounds, dtype=np.int32),
        val_src=val_src,
        diag_src=diag_src,
    )
