"""ExecPlan — compile a BSP schedule into padded tensors for TPU executors.

The executor view of a schedule (DESIGN.md §3/§4):

  * supersteps execute one after another (scan steps / kernel grid steps);
  * within a superstep, each of the k cores processes its chain of rows
    **sequentially** (same-core dependencies are legal — that is GrowLocal's
    main source of barrier savings);
  * the k cores advance in lock-step: sequential position t of every chain
    executes simultaneously (vector parallelism across cores).

The plan therefore pads every superstep to a rectangle:

    step t = 0..chain_len(s)-1 of superstep s processes rows
    row_ids[s_off + t, 0..k-1], each row with up to W off-diagonal entries
    col_idx[..., w] / vals[..., w] (padded with col -> self, val -> 0).

Rows are padded with a sentinel id pointing at a scratch slot (n), so padding
lanes write to scratch and never corrupt x. The off-diagonal width W is a
per-plan maximum; rows wider than W are split into multiple *virtual rows*
(partial-sum rows that accumulate into the same x slot — the last virtual row
finishes with the diagonal division). The plan compiler reports padding
efficiency; the §Perf loop iterates on it.

Compilation is the paper's *inspector* phase (§7.7 amortizes it over many
executes), so it must be O(nnz), not O(n) Python iterations:
``compile_plan`` is pure NumPy array passes — virtual-row expansion via
``repeat``/``cumsum`` segment arithmetic and one bulk scatter per plan
tensor. The original per-row compiler is kept as
``_reference_compile_plan``; ``tests/test_plan_vectorized.py`` and
``benchmarks/inspector_bench.py`` assert the two are bitwise-identical.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro import obs
from repro.core.schedule import Schedule
from repro.sparse.csr import CSRMatrix


@dataclasses.dataclass
class ExecPlan:
    """Padded execution plan. Shapes:
    row_ids   int32[T, k]      — target row of each (step, core); n = padding
    col_idx   int32[T, k, W]   — gather indices into x (self-padded)
    vals      float32/64[T,k,W]— off-diagonal values (0-padded)
    diag      float[T, k]      — diagonal value of the row (1 for padding)
    accum     bool[T, k]       — True: this step only accumulates partial
                                  sums (row split over multiple steps)
    step_bounds int32[S+1]     — superstep s covers steps
                                  [step_bounds[s], step_bounds[s+1])
    val_src   int32[T, k, W]   — index into L.data feeding vals (-1 padding)
    diag_src  int32[T, k]      — index into L.data feeding diag (-1 padding)

    ``val_src``/``diag_src`` let a caller refresh the numeric values for a
    new matrix with the *same* sparsity pattern without recompiling — the
    plan-cache ``numeric_update`` path (and, device-side, the
    ``repro.backends`` ``BoundSolve.update_values`` gather).

    ``elastic`` (optional) attaches the bounded-slack certificate from
    ``core.elastic.elastic_transform`` when the plan was built for
    ``mode="elastic"`` — the executors' macro-step/wave geometry;
    ``stats()`` then reports barrier counts before/after fusion.
    """

    n: int
    k: int
    W: int
    row_ids: np.ndarray
    col_idx: np.ndarray
    vals: np.ndarray
    diag: np.ndarray
    accum: np.ndarray
    step_bounds: np.ndarray
    val_src: np.ndarray | None = None
    diag_src: np.ndarray | None = None
    elastic: "object | None" = None  # core.elastic.ElasticPlan when elastic

    def numeric_update(self, data: np.ndarray) -> None:
        """Overwrite ``vals``/``diag`` in place from ``data`` — the ``.data``
        of a matrix with the sparsity pattern this plan was compiled for
        (same entry order as the ``L`` passed to ``compile_plan``)."""
        assert self.val_src is not None and self.diag_src is not None
        data = np.asarray(data)
        vmask = self.val_src >= 0
        self.vals[vmask] = data[self.val_src[vmask]].astype(self.vals.dtype)
        dmask = self.diag_src >= 0
        self.diag[dmask] = data[self.diag_src[dmask]].astype(self.diag.dtype)

    @property
    def n_steps(self) -> int:
        return self.row_ids.shape[0]

    @property
    def n_supersteps(self) -> int:
        return len(self.step_bounds) - 1

    def stats(self) -> dict:
        real = self.row_ids != self.n
        nnz_slots = self.col_idx.shape[0] * self.k * self.W
        # count populated slots from the value-source map, not from
        # (vals != 0): a factor may legitimately store explicit zeros, and
        # a padding slot may transiently hold a zero from numeric_update —
        # val_src >= 0 is the structural truth
        if self.val_src is not None:
            real_nnz = int((self.val_src >= 0).sum())
        else:  # plans built without source maps fall back to the value test
            real_nnz = int((self.vals != 0).sum())
        out = {
            "n_steps": self.n_steps,
            "n_supersteps": self.n_supersteps,
            "k": self.k,
            "W": self.W,
            "row_slot_utilization": float(real.mean()),
            "nnz_slot_utilization": real_nnz / max(nnz_slots, 1),
            "bytes_streamed": int(
                self.col_idx.size * 4 + self.vals.size * self.vals.itemsize
                + self.row_ids.size * 4 + self.diag.size * self.diag.itemsize
            ),
        }
        if self.elastic is not None:
            # barrier accounting before/after bounded-slack fusion: the
            # bulk executors pay one scan/grid step per plan step and one
            # barrier per superstep; elastic pays one macro-step per
            # slack window and one barrier per fused superstep run
            out["elastic"] = self.elastic.stats()
        return out


def _resolve_width(row_nnz_off: np.ndarray, n: int, width: int | None) -> int:
    """Default W: 95th percentile of off-diagonal row nnz, clipped to
    [4, 512] (wide rows are split, narrow rows padded; §Perf tunes this)."""
    if width is None:
        width = int(np.clip(np.percentile(row_nnz_off, 95) if n else 4, 4, 512))
        width = max(width, 1)
    return int(width)


def compile_plan(
    L: CSRMatrix,
    sched: Schedule,
    *,
    width: int | None = None,
    dtype=np.float32,
) -> ExecPlan:
    """Compile (matrix, schedule) into an ExecPlan — vectorized inspector.

    O(nnz) NumPy passes, no per-row Python: the schedule order comes from
    one lexsort, virtual rows from a ``repeat``/``cumsum`` expansion, and
    each plan tensor is filled by a single bulk scatter. Bitwise-identical
    to ``_reference_compile_plan`` (property-tested across the scenario
    corpus).

    ``width``: max off-diagonal entries per virtual row (W); see
    ``_resolve_width`` for the default.
    """
    n, k = L.n_rows, sched.k
    with obs.span(
        "inspector.compile_plan", cat="inspector", n=n, k=k
    ) as sp:
        row_nnz_off = L.row_nnz() - 1  # off-diag count (diag always present)
        assert (row_nnz_off >= 0).all(), "matrix must have a full diagonal"
        W = _resolve_width(row_nnz_off, n, width)
        S = sched.n_supersteps
        diag_vals = L.diagonal()

        # -- schedule order: vertices grouped by (superstep, core), chain
        # order (the stable lexsort Schedule.chains() uses, minus the dict)
        with obs.span("inspector.order", cat="inspector"):
            order = np.lexsort((sched.rank, sched.pi, sched.sigma))

        # -- virtual-row expansion: vertex v becomes ceil(off_nnz/W) rows --
        with obs.span("inspector.expand", cat="inspector"):
            segs = np.maximum(1, -(-row_nnz_off // W)).astype(np.int64)
            segs_o = segs[order]
            vr_v = np.repeat(order, segs_o)  # vertex of each virtual row
            starts = np.cumsum(segs_o) - segs_o  # first v-row per vertex
            vr_g = (
                np.arange(len(vr_v), dtype=np.int64)
                - np.repeat(starts, segs_o)
            )
            vr_last = vr_g == segs[vr_v] - 1

            # chain position of each virtual row within (superstep, core)
            key = sched.sigma[vr_v].astype(np.int64) * k + sched.pi[vr_v]
            group_len = np.bincount(key, minlength=S * k)  # sorted already
            group_start = np.cumsum(group_len) - group_len
            t_in_chain = (
                np.arange(len(vr_v), dtype=np.int64) - group_start[key]
            )

            # superstep step count = max chain length over its k cores
            chain_len = group_len.reshape(S, k)
            step_bounds = np.zeros(S + 1, dtype=np.int64)
            np.cumsum(chain_len.max(axis=1), out=step_bounds[1:])
            T = int(step_bounds[-1])

            # flat (step, core) slot of every virtual row
            slot = (
                step_bounds[sched.sigma[vr_v]] + t_in_chain
            ) * k + sched.pi[vr_v]

        # -- row-level tensors: one scatter each --------------------------
        with obs.span("inspector.row_scatter", cat="inspector"):
            row_ids = np.full(T * k, n, dtype=np.int32)
            row_ids[slot] = vr_v
            diag = np.ones(T * k, dtype=dtype)
            diag[slot] = diag_vals[vr_v]
            accum = np.zeros(T * k, dtype=bool)
            accum[slot] = ~vr_last

            # first diagonal entry id per row (reverse scatter keeps first)
            rows_of_entry = L.row_of_entry()
            off_mask = L.indices != rows_of_entry
            diag_entry = np.full(n, -1, dtype=np.int64)
            d_ids = np.nonzero(~off_mask)[0]
            diag_entry[rows_of_entry[d_ids[::-1]]] = d_ids[::-1]
            diag_src = np.full(T * k, -1, dtype=np.int32)
            diag_src[slot] = diag_entry[vr_v]

        # -- entry-level tensors: off-diagonal entries, row-major ---------
        with obs.span("inspector.entry_scatter", cat="inspector"):
            off_entries = np.nonzero(off_mask)[0]  # entry ids by row
            n_off = np.bincount(
                rows_of_entry[off_mask], minlength=n
            ).astype(np.int64)
            off_start = np.cumsum(n_off) - n_off  # row -> first off slot

            # virtual row (v, g) takes off slots [gW, min((g+1)W, n_off))
            cnt = np.clip(n_off[vr_v] - vr_g * W, 0, W)
            total = int(cnt.sum())
            e_start = np.cumsum(cnt) - cnt
            lane = (
                np.arange(total, dtype=np.int64) - np.repeat(e_start, cnt)
            )
            src = off_entries[
                off_start[np.repeat(vr_v, cnt)]
                + np.repeat(vr_g, cnt) * W
                + lane
            ]
            dest = np.repeat(slot, cnt) * W + lane

            # padding gathers read x[n] (scratch) -> harmless 0 contribution
            col_idx = np.full(T * k * W, n, dtype=np.int32)
            col_idx[dest] = L.indices[src]
            vals = np.zeros(T * k * W, dtype=dtype)
            vals[dest] = L.data[src]
            # int32 matches col_idx and halves the host-side footprint;
            # entry ids are bounded by nnz << 2^31
            val_src = np.full(T * k * W, -1, dtype=np.int32)
            val_src[dest] = src

        sp.set(T=T, W=W, supersteps=S)

    return ExecPlan(
        n=n,
        k=k,
        W=W,
        row_ids=row_ids.reshape(T, k),
        col_idx=col_idx.reshape(T, k, W),
        vals=vals.reshape(T, k, W),
        diag=diag.reshape(T, k),
        accum=accum.reshape(T, k),
        step_bounds=step_bounds.astype(np.int32),
        val_src=val_src.reshape(T, k, W),
        diag_src=diag_src.reshape(T, k),
    )


def _reference_compile_plan(
    L: CSRMatrix,
    sched: Schedule,
    *,
    width: int | None = None,
    dtype=np.float32,
) -> ExecPlan:
    """The original per-row plan compiler (superstep x core x virtual row
    Python loops). Kept solely as the equivalence oracle for the
    vectorized ``compile_plan`` — do not call it on large matrices."""
    n, k = L.n_rows, sched.k
    row_nnz_off = L.row_nnz() - 1  # off-diagonal count (diag always present)
    assert (row_nnz_off >= 0).all(), "matrix must have a full diagonal"
    W = _resolve_width(row_nnz_off, n, width)

    chains = sched.chains()
    diag_vals = L.diagonal()

    # per (superstep, core): expand each row into ceil(off_nnz / W) virtual
    # rows; chain length = sum of virtual rows; superstep step count = max
    # chain length over cores.
    step_bounds = [0]
    vrows: List[List[List[tuple]]] = []  # superstep -> core -> [(row, seg)]
    for s in range(sched.n_supersteps):
        per_core: List[List[tuple]] = []
        for p in range(k):
            chain = chains.get((s, p), np.empty(0, dtype=np.int64))
            vr: List[tuple] = []
            for v in chain:
                v = int(v)
                segs = max(1, -(-int(row_nnz_off[v]) // W))
                for g in range(segs):
                    vr.append((v, g, g == segs - 1))
            per_core.append(vr)
        vrows.append(per_core)
        step_bounds.append(step_bounds[-1] + max(len(c) for c in per_core))

    T = step_bounds[-1]
    row_ids = np.full((T, k), n, dtype=np.int32)
    col_idx = np.zeros((T, k, W), dtype=np.int32)
    vals = np.zeros((T, k, W), dtype=dtype)
    diag = np.ones((T, k), dtype=dtype)
    accum = np.zeros((T, k), dtype=bool)
    val_src = np.full((T, k, W), -1, dtype=np.int32)
    diag_src = np.full((T, k), -1, dtype=np.int32)
    col_idx[:] = n

    for s in range(sched.n_supersteps):
        base = step_bounds[s]
        for p in range(k):
            for t, (v, g, last) in enumerate(vrows[s][p]):
                cols, values = L.row(v)
                e0 = int(L.indptr[v])  # entry index of this row's first slot
                off = cols != v
                off_src = e0 + np.nonzero(off)[0]
                cols, values = cols[off], values[off]
                lo, hi = g * W, min((g + 1) * W, len(cols))
                row_ids[base + t, p] = v
                col_idx[base + t, p, : hi - lo] = cols[lo:hi]
                vals[base + t, p, : hi - lo] = values[lo:hi]
                val_src[base + t, p, : hi - lo] = off_src[lo:hi]
                diag[base + t, p] = diag_vals[v]
                dpos = np.nonzero(~off)[0]
                if len(dpos):
                    diag_src[base + t, p] = e0 + int(dpos[0])
                accum[base + t, p] = not last
    return ExecPlan(
        n=n,
        k=k,
        W=W,
        row_ids=row_ids,
        col_idx=col_idx,
        vals=vals,
        diag=diag,
        accum=accum,
        step_bounds=np.asarray(step_bounds, dtype=np.int32),
        val_src=val_src,
        diag_src=diag_src,
    )


def plans_bitwise_equal(a: ExecPlan, b: ExecPlan) -> bool:
    """True iff two plans are bitwise identical — every tensor equal in
    value AND dtype, plus the scalar geometry. The acceptance check for
    the vectorized inspector; shared by tests and the inspector bench."""
    if (a.n, a.k, a.W) != (b.n, b.k, b.W):
        return False
    for name in (
        "row_ids", "col_idx", "vals", "diag", "accum", "step_bounds",
        "val_src", "diag_src",
    ):
        ta, tb = getattr(a, name), getattr(b, name)
        if ta is None or tb is None:
            if ta is not tb:
                return False
            continue
        if ta.dtype != tb.dtype or ta.shape != tb.shape:
            return False
        if not np.array_equal(ta, tb):
            return False
    return True
