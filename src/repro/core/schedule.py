"""BSP schedules (paper Def. 2.1) — representation, validity, cost model.

A schedule assigns every DAG vertex a core ``pi``, a superstep ``sigma`` and an
in-chain execution rank. Validity (Def. 2.1): for every edge (u, v):
  * sigma(u) <= sigma(v);
  * if pi(u) != pi(v) then sigma(u) < sigma(v);
  * if sigma(u) == sigma(v) and pi(u) == pi(v), u executes before v (rank).

Cost model (§2.2): the BSP cost of a schedule is
    sum_s max_p Omega_p(s)  +  L * n_supersteps
in vertex-weight units (weight = row nnz ~ 2 flops per nnz); L is the barrier
penalty (paper: 500; architecture-dependent — see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.sparse.dag import SolveDAG

DEFAULT_L = 500.0


@dataclasses.dataclass
class Schedule:
    n: int
    k: int  # number of cores
    pi: np.ndarray  # int32[n] — core assignment
    sigma: np.ndarray  # int32[n] — superstep assignment, 0-based
    rank: np.ndarray  # int64[n] — execution order within (superstep, core)
    n_supersteps: int

    def __post_init__(self):
        assert self.pi.shape == (self.n,)
        assert self.sigma.shape == (self.n,)
        assert self.rank.shape == (self.n,)

    def chains(self) -> Dict[Tuple[int, int], np.ndarray]:
        """Map (superstep, core) -> vertex ids in execution order."""
        order = np.lexsort((self.rank, self.pi, self.sigma))
        out: Dict[Tuple[int, int], np.ndarray] = {}
        if self.n == 0:
            return out
        key = self.sigma[order].astype(np.int64) * self.k + self.pi[order]
        cuts = np.nonzero(np.diff(key))[0] + 1
        for seg in np.split(order, cuts):
            v0 = seg[0]
            out[(int(self.sigma[v0]), int(self.pi[v0]))] = seg
        return out

    def superstep_loads(self, weights: np.ndarray) -> np.ndarray:
        """float64[n_supersteps, k]: Omega_p(s)."""
        loads = np.zeros((self.n_supersteps, self.k), dtype=np.float64)
        np.add.at(loads, (self.sigma, self.pi), weights.astype(np.float64))
        return loads


def check_validity(dag: SolveDAG, s: Schedule) -> None:
    """Raise AssertionError if the schedule violates Def. 2.1. Vectorized."""
    assert s.n == dag.n
    assert (s.pi >= 0).all() and (s.pi < s.k).all()
    assert (s.sigma >= 0).all() and (s.sigma < s.n_supersteps).all()
    # edge list: (parent u = parent_idx entry, child v = row)
    v_of_edge = np.repeat(
        np.arange(dag.n, dtype=np.int64), np.diff(dag.parent_ptr)
    )
    u_of_edge = dag.parent_idx
    su, sv = s.sigma[u_of_edge], s.sigma[v_of_edge]
    assert (su <= sv).all(), "edge goes backwards in supersteps"
    cross = s.pi[u_of_edge] != s.pi[v_of_edge]
    assert (su[cross] < sv[cross]).all(), "cross-core edge without barrier"
    same_step_same_core = (~cross) & (su == sv)
    assert (
        s.rank[u_of_edge[same_step_same_core]]
        < s.rank[v_of_edge[same_step_same_core]]
    ).all(), "in-chain order violates a dependency"


def bsp_cost(dag: SolveDAG, s: Schedule, L: float = DEFAULT_L) -> float:
    loads = s.superstep_loads(dag.weights)
    return float(loads.max(axis=1).sum() + L * s.n_supersteps)


# Per-plan-step dispatch penalty for the *step-granular* cost model used
# by the elastic mode decision. The BSP model above charges L per
# superstep barrier; the single-chip executors additionally pay a small
# fixed cost per scan/grid step (dispatch, carry shuffling), which
# dominates on deep, narrow DAGs where steps are tiny. Like L it is
# architecture-dependent; the ratio to L is what matters for the
# elastic-vs-bulk decision, not the absolute value.
DEFAULT_L_STEP = 50.0


def schedule_step_count(s: Schedule) -> int:
    """Row-level executor step count T of a schedule: sum over supersteps
    of the longest per-core chain (the scan trip count before virtual-row
    expansion widens rows past W)."""
    if s.n == 0:
        return 0
    key = s.sigma.astype(np.int64) * s.k + s.pi
    chain_len = np.bincount(key, minlength=s.n_supersteps * s.k)
    return int(chain_len.reshape(s.n_supersteps, s.k).max(axis=1).sum())


def step_cost(dag: SolveDAG, s: Schedule, *, l_step: float = DEFAULT_L_STEP) -> float:
    """Step-granular cost of the bulk-synchronous scan executor:
    critical-path work plus one dispatch per plan step."""
    loads = s.superstep_loads(dag.weights)
    return float(loads.max(axis=1).sum() + l_step * schedule_step_count(s))


def elastic_cost(
    dag: SolveDAG, s: Schedule, slack: int, *, l_step: float = DEFAULT_L_STEP
) -> float:
    """Step-granular cost of the elastic executor at staleness window
    ``slack``: critical-path work plus one macro-step dispatch per slack
    window (``ceil(T / slack)`` instead of ``T``). Compare against
    ``step_cost`` to score ``mode="elastic"`` in the autotuner."""
    loads = s.superstep_loads(dag.weights)
    t = schedule_step_count(s)
    macro = -(-t // slack) if t else 0
    return float(loads.max(axis=1).sum() + l_step * macro)


def schedule_stats(dag: SolveDAG, s: Schedule, L: float = DEFAULT_L) -> dict:
    loads = s.superstep_loads(dag.weights)
    maxima = loads.max(axis=1)
    means = loads.sum(axis=1) / s.k
    total = float(dag.weights.sum())
    return {
        "n_supersteps": s.n_supersteps,
        "bsp_cost": float(maxima.sum() + L * s.n_supersteps),
        "work": total,
        "critical_work": float(maxima.sum()),
        # perfect parallelization would give total/k; >= 1, lower is better
        "imbalance": float(maxima.sum() / max(total / s.k, 1e-30)),
        "mean_superstep_load": float(means.mean()) if len(means) else 0.0,
        "speedup_model": total / float(maxima.sum() + L * s.n_supersteps),
    }


def serial_schedule(dag: SolveDAG) -> Schedule:
    """Everything on core 0 in one superstep, topological (ID) order."""
    return Schedule(
        n=dag.n,
        k=1,
        pi=np.zeros(dag.n, dtype=np.int32),
        sigma=np.zeros(dag.n, dtype=np.int32),
        rank=np.arange(dag.n, dtype=np.int64),
        n_supersteps=1 if dag.n else 0,
    )
