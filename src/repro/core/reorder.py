"""Reordering for locality (paper §5).

After scheduling, relabel vertices by (superstep, core, in-chain rank) and
symmetrically permute the matrix and RHS. The permutation is a topological
order (Def. 2.1 + in-chain order), so the permuted matrix stays lower
triangular, and rows computed together on one core become contiguous —
contiguous CSR tiles and contiguous x writes on the executor side.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedule import Schedule
from repro.sparse.csr import CSRMatrix, permute_symmetric


@dataclasses.dataclass(frozen=True)
class Reordering:
    perm: np.ndarray  # perm[new_id] = old_id
    inv: np.ndarray  # inv[old_id] = new_id


def schedule_order(s: Schedule) -> Reordering:
    """Vertices sorted by (sigma, pi, rank) — §5's traversal order."""
    perm = np.lexsort((s.rank, s.pi, s.sigma)).astype(np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(s.n, dtype=np.int64)
    return Reordering(perm=perm, inv=inv)


def apply_reordering(
    L: CSRMatrix, s: Schedule, b: np.ndarray | None = None
):
    """Permute matrix (and optionally RHS) by the schedule order; returns
    (L', schedule', b' | None, reordering). ``schedule'`` relabels pi/sigma
    onto the new IDs; the solve of L'x' = b' satisfies x = x'[inv]."""
    r = schedule_order(s)
    L2 = permute_symmetric(L, r.perm)
    s2 = Schedule(
        n=s.n,
        k=s.k,
        pi=s.pi[r.perm].copy(),
        sigma=s.sigma[r.perm].copy(),
        rank=s.rank[r.perm].copy(),
        n_supersteps=s.n_supersteps,
    )
    b2 = None if b is None else np.asarray(b)[r.perm]
    return L2, s2, b2, r
