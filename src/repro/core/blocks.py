"""Block-parallel scheduling (paper §3.1, Fig. 3.1, Table 7.7).

The lower-triangular matrix is split into ``n_blocks`` contiguous diagonal
blocks. Each block's *diagonal sub-DAG* (edges with both endpoints in the
block) is scheduled independently — in parallel across scheduler threads —
and the per-block schedules are concatenated with a barrier between blocks
(superstep offsets). Cross-block dependencies always point to earlier blocks,
so the concatenation is valid (Def. 2.1) by construction.

Vertex weights still use the FULL row nnz (paper §3.1 last remark: "for the
weight of the vertices ... we still use the number of non-zeros in the full
matrix" — the executor computes the whole row, including the off-diagonal
block part).
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Sequence

import numpy as np

from repro.core.schedule import Schedule
from repro.sparse.csr import CSRMatrix
from repro.sparse.dag import SolveDAG, dag_from_edges


def split_ranges(n: int, n_blocks: int) -> List[tuple]:
    bounds = np.linspace(0, n, n_blocks + 1).astype(np.int64)
    return [(int(bounds[i]), int(bounds[i + 1])) for i in range(n_blocks)]


def block_sub_dag(dag: SolveDAG, lo: int, hi: int) -> SolveDAG:
    """Sub-DAG induced by vertices [lo, hi) — only intra-block edges; weights
    keep the full-row weight."""
    v_of_edge = np.repeat(
        np.arange(dag.n, dtype=np.int64), np.diff(dag.parent_ptr)
    )
    u_of_edge = dag.parent_idx
    mask = (u_of_edge >= lo) & (u_of_edge < hi) & (v_of_edge >= lo) & (v_of_edge < hi)
    edges = np.stack([u_of_edge[mask] - lo, v_of_edge[mask] - lo], axis=1)
    return dag_from_edges(hi - lo, edges, dag.weights[lo:hi])


def block_parallel_schedule(
    dag: SolveDAG,
    k: int,
    n_blocks: int,
    scheduler: Callable[[SolveDAG, int], Schedule],
    *,
    parallel: bool = True,
) -> Schedule:
    """Schedule each diagonal block independently and concatenate."""
    ranges = split_ranges(dag.n, n_blocks)
    subs = [block_sub_dag(dag, lo, hi) for (lo, hi) in ranges]
    if parallel and n_blocks > 1:
        with ThreadPoolExecutor(max_workers=min(n_blocks, 16)) as pool:
            scheds = list(pool.map(lambda d: scheduler(d, k), subs))
    else:
        scheds = [scheduler(d, k) for d in subs]
    return concatenate_schedules(dag.n, k, ranges, scheds)


def concatenate_schedules(
    n: int, k: int, ranges: Sequence[tuple], scheds: Sequence[Schedule]
) -> Schedule:
    pi = np.zeros(n, dtype=np.int32)
    sigma = np.zeros(n, dtype=np.int32)
    rank = np.zeros(n, dtype=np.int64)
    offset = 0
    for (lo, hi), s in zip(ranges, scheds):
        pi[lo:hi] = s.pi
        sigma[lo:hi] = s.sigma + offset
        rank[lo:hi] = s.rank
        offset += s.n_supersteps
    return Schedule(n=n, k=k, pi=pi, sigma=sigma, rank=rank, n_supersteps=offset)
