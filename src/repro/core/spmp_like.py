"""SpMP-like baseline (Park et al. [PSSD14]) — synchronous projection.

SpMP is an *asynchronous* wavefront scheduler: threads advance to their part
of the next wavefront as soon as the point-to-point prerequisites are met,
with an approximate transitive reduction sparsifying the synchronization
edges. The point-to-point flag mechanism has no SPMD/TPU analogue
(DESIGN.md §3, §8.2), so we reproduce the parts that do transfer:

  1. the approximate transitive reduction ('remove long edges in triangles',
     [PSSD14 §2.3]) — implemented in ``core.coarsen.transitive_sparsify``;
  2. level scheduling with ID-contiguous, weight-balanced per-core chunks
     (SpMP's per-thread portion of a wavefront is ID-contiguous).

The synchronous projection charges a full barrier per wavefront; SpMP's
async advantage is modeled in the BSP cost model by an effective barrier
cost L_p2p < L (a thread waits only for its neighbours, not the world).
``bsp_cost(dag, spmp_like_schedule(...), L=L_P2P_EFFECTIVE)`` is the
number we report next to measured executor baselines.
"""
from __future__ import annotations

import numpy as np

from repro.core.coarsen import transitive_sparsify
from repro.core.schedule import Schedule
from repro.core.wavefront import wavefront_schedule
from repro.sparse.dag import SolveDAG

# Effective barrier price for a p2p-synchronized wavefront step, relative to
# the L=500-cycle global barrier of the BSP model (paper §C.2): SpMP's
# per-edge spin-wait costs tens of cycles, not hundreds.
L_P2P_EFFECTIVE = 50.0


def spmp_like_schedule(dag: SolveDAG, k: int, *, sparsify: bool = True) -> Schedule:
    """Level schedule on the transitively-sparsified DAG with ID-contiguous
    weight-balanced chunks. The schedule is valid for the original DAG
    (transitive reduction preserves the dependency closure)."""
    work_dag = transitive_sparsify(dag) if sparsify else dag
    return wavefront_schedule(work_dag, k, split="contiguous")
