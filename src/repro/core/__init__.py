"""Core library: the paper's scheduling contribution.

  * ``grow_local`` — the GrowLocal scheduler (§3, Alg. 3.1)
  * ``funnel_grow_local`` / ``funnel_partition`` / ``coarsen_dag`` /
    ``pull_back_schedule`` — §4 (``core/funnel.py`` / ``core/coarsen.py``)
  * ``apply_reordering`` — §5 locality reordering
  * ``block_parallel_schedule`` — §3.1
  * baselines: ``wavefront_schedule``, ``hdagg_schedule``, ``spmp_like_schedule``
  * ``Schedule`` / ``check_validity`` / ``bsp_cost`` — Def. 2.1 + cost model
  * ``compile_plan`` — schedule -> padded ExecPlan for the TPU executors

These are the building blocks. The front door for actually *solving* —
matrix in, bound solver out, with strategy selection, plan caching,
forward/backward factor pairs and batched RHS — is ``repro.pipeline``
(``TriangularSolver.plan(L)`` / ``factor_pair(Lf)``); prefer it over wiring
these stages by hand.
"""
from repro.core.blocks import block_parallel_schedule, block_sub_dag, split_ranges
from repro.core.coarsen import (
    Coarsening,
    coarsen_dag,
    funnel_partition,
    is_cascade,
    pull_back_schedule,
    transitive_sparsify,
)
from repro.core.elastic import (
    DEFAULT_SLACK,
    ElasticPlan,
    elastic_transform,
    step_dependencies,
)
from repro.core.funnel import funnel_grow_local
from repro.core.growlocal import grow_local
from repro.core.hdagg import hdagg_schedule
from repro.core.plan import ExecPlan, compile_plan
from repro.core.reorder import Reordering, apply_reordering, schedule_order
from repro.core.rowshard import HaloRound, RowShardPlan, partition_plan
from repro.core.schedule import (
    DEFAULT_L,
    DEFAULT_L_STEP,
    Schedule,
    bsp_cost,
    check_validity,
    elastic_cost,
    schedule_stats,
    schedule_step_count,
    serial_schedule,
    step_cost,
)
from repro.core.spmp_like import L_P2P_EFFECTIVE, spmp_like_schedule
from repro.core.wavefront import wavefront_schedule

__all__ = [
    "grow_local",
    "funnel_grow_local",
    "hdagg_schedule",
    "spmp_like_schedule",
    "wavefront_schedule",
    "serial_schedule",
    "Schedule",
    "check_validity",
    "bsp_cost",
    "schedule_stats",
    "DEFAULT_L",
    "L_P2P_EFFECTIVE",
    "funnel_partition",
    "coarsen_dag",
    "pull_back_schedule",
    "is_cascade",
    "transitive_sparsify",
    "Coarsening",
    "apply_reordering",
    "schedule_order",
    "Reordering",
    "block_parallel_schedule",
    "block_sub_dag",
    "split_ranges",
    "ExecPlan",
    "compile_plan",
    "DEFAULT_SLACK",
    "ElasticPlan",
    "elastic_transform",
    "step_dependencies",
    "DEFAULT_L_STEP",
    "schedule_step_count",
    "step_cost",
    "elastic_cost",
    "partition_plan",
    "RowShardPlan",
    "HaloRound",
]
