"""Beyond-paper: GrowLocal as a pipeline-parallel schedule generator.

The paper notes GrowLocal "can also be interpreted as scheduler for general
DAGs". A pipeline-parallel training step IS a DAG-scheduling instance:
vertices = (microbatch m, stage s, phase fwd/bwd), edges = fwd(m,s) ->
fwd(m,s+1), bwd(m,s+1) -> bwd(m,s), fwd(m,S-1) -> bwd(m,S-1). Cores =
pipeline stages is fixed by placement, so here GrowLocal's degree of freedom
is the SUPERSTEP structure: how many microbatch units run between device
synchronizations — exactly the 1F1B-vs-GPipe trade-off expressed in BSP
terms (L = pipeline flush cost).

``pipeline_dag`` builds the DAG; ``grow_local_pipeline`` schedules it with
the stage-placement constraint (pi is fixed, sigma/rank from a wavefront-
with-gluing pass using the paper's beta score); ``pipeline_stats`` reports
bubble fraction vs GPipe.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.schedule import DEFAULT_L, Schedule
from repro.sparse.dag import SolveDAG, dag_from_edges


@dataclasses.dataclass(frozen=True)
class PipelineProblem:
    n_stages: int
    n_microbatches: int
    fwd_cost: float = 1.0
    bwd_cost: float = 2.0


def _vid(p: PipelineProblem, m: int, s: int, phase: int) -> int:
    """vertex id; phase 0 = fwd, 1 = bwd. IDs are topologically ordered by
    (m + s) so smallest-ID selection keeps the pipeline front moving."""
    return (m * p.n_stages + s) * 2 + phase


def pipeline_dag(p: PipelineProblem) -> Tuple[SolveDAG, np.ndarray]:
    """-> (DAG, stage_of_vertex). Weights in fwd_cost units (x2 for bwd)."""
    edges = []
    n = p.n_stages * p.n_microbatches * 2
    stage = np.zeros(n, dtype=np.int64)
    w = np.ones(n, dtype=np.int64)
    for m in range(p.n_microbatches):
        for s in range(p.n_stages):
            stage[_vid(p, m, s, 0)] = s
            stage[_vid(p, m, s, 1)] = s
            w[_vid(p, m, s, 1)] = int(round(p.bwd_cost / p.fwd_cost))
            if s + 1 < p.n_stages:
                edges.append((_vid(p, m, s, 0), _vid(p, m, s + 1, 0)))
                edges.append((_vid(p, m, s + 1, 1), _vid(p, m, s, 1)))
            else:
                edges.append((_vid(p, m, s, 0), _vid(p, m, s, 1)))
            # in-stage serialization of same-phase microbatches keeps the
            # DAG honest about one-executor-per-stage
            if m + 1 < p.n_microbatches:
                edges.append((_vid(p, m, s, 0), _vid(p, m + 1, s, 0)))
                edges.append((_vid(p, m, s, 1), _vid(p, m + 1, s, 1)))
    dag = dag_from_edges(n, np.asarray(edges), w)
    return dag, stage


def _schedule_with_alpha(p: PipelineProblem, alpha: float) -> Schedule:
    """Fixed-alpha barrier schedule: every superstep gives each stage up to
    alpha units of ready work (ID order, cross-stage hand-offs barriered)."""
    dag, stage = pipeline_dag(p)
    n, k = dag.n, p.n_stages
    remaining = dag.in_degrees().copy()
    done = np.zeros(n, dtype=bool)
    sigma = np.full(n, -1, dtype=np.int32)
    rank = np.zeros(n, dtype=np.int64)
    ready = sorted(np.nonzero(remaining == 0)[0].tolist())
    superstep = 0
    n_done = 0
    while n_done < n:
        sel, _ = _fill(dag, stage, ready, remaining, done, k, alpha)
        chain_pos = np.zeros(k, dtype=np.int64)
        for v in sel:
            done[v] = True
            sigma[v] = superstep
            rank[v] = chain_pos[stage[v]]
            chain_pos[stage[v]] += 1
            n_done += 1
            for u in dag.children(v):
                remaining[u] -= 1
                if remaining[u] == 0:
                    ready.append(int(u))
        ready = sorted(set(r for r in ready if not done[r]))
        superstep += 1
    return Schedule(n=n, k=k, pi=stage.astype(np.int32), sigma=sigma,
                    rank=rank, n_supersteps=superstep)


def grow_local_pipeline(
    p: PipelineProblem, *, L: float = DEFAULT_L, growth: float = 1.5,
) -> Schedule:
    """GrowLocal economics applied to pipeline scheduling.

    The paper's per-superstep alpha-growth loop degenerates on pipeline DAGs
    (a superstep that only activates stage 0 has monotonically increasing
    beta, so the 0.97-of-best rule never cuts — the same single-source
    behaviour §3 exhibits, see core/growlocal.py). For pipelines the
    superstep length trade-off is GLOBAL (alpha ticks repeat), so we apply
    the same geometric alpha ladder but score each candidate by its full BSP
    cost  sum_s max_p Omega_p(s) + L * S  and keep the argmin: small L ->
    alpha=1 wavefront ticks (1F1B-flavoured, bubble-light), large L -> glued
    supersteps (GPipe-flavoured, barrier-light)."""
    dag, _ = pipeline_dag(p)
    weights = dag.weights.astype(np.float64)
    best, best_cost = None, np.inf
    alpha = 1.0
    max_alpha = p.n_microbatches * max(p.bwd_cost / p.fwd_cost, 1.0) * 2
    while alpha <= max_alpha:
        sched = _schedule_with_alpha(p, alpha)
        loads = sched.superstep_loads(weights)
        cost = float(loads.max(axis=1).sum()) + L * sched.n_supersteps
        if cost < best_cost:
            best, best_cost = sched, cost
        alpha *= growth
    return best


def _fill(dag, stage, ready, remaining, done, k, alpha):
    """One speculative iteration: stages consume ready vertices in ID order.
    Def. 2.1 constraint: a vertex finished in THIS superstep can feed a
    same-superstep child only on the same core — with pi pinned to stages,
    any cross-stage hand-off blocks the child until the next barrier."""
    rem = remaining.copy()
    blocked = set()
    omega = np.zeros(k)
    counts = np.zeros(k)
    sel = []
    frontier = sorted(ready)
    progress = True
    while progress:
        progress = False
        for v in list(frontier):
            s = stage[v]
            if counts[s] >= alpha:
                continue
            sel.append(v)
            frontier.remove(v)
            counts[s] += 1
            omega[s] += dag.weights[v]
            for u in dag.children(v):
                rem[u] -= 1
                if stage[u] != s:
                    blocked.add(int(u))  # needs a barrier first
                if rem[u] == 0 and not done[u] and int(u) not in blocked:
                    frontier.append(int(u))
            frontier.sort()
            progress = True
    return sel, omega


def pipeline_stats(p: PipelineProblem, sched: Schedule) -> dict:
    dag, stage = pipeline_dag(p)
    loads = sched.superstep_loads(dag.weights.astype(np.float64))
    crit = float(loads.max(axis=1).sum())
    total = float(dag.weights.sum())
    ideal = total / p.n_stages
    # GPipe reference: fwd sweep + bwd sweep with full flushes
    unit_f, unit_b = p.fwd_cost, p.bwd_cost
    gpipe = (p.n_microbatches + p.n_stages - 1) * (unit_f + unit_b) * (
        total / (p.n_microbatches * p.n_stages * (unit_f + unit_b) / 1.0)
    ) / p.n_microbatches if p.n_microbatches else 0.0
    return {
        "supersteps": sched.n_supersteps,
        "critical_work": crit,
        "bubble_fraction": 1.0 - ideal / crit if crit else 0.0,
        "ideal_work_per_stage": ideal,
    }
