"""Funnel+GL — the paper's combined pipeline (Tables 7.1–7.2).

Transitive sparsification, in-funnel coarsening, GrowLocal on the coarse
DAG, pull-back to the fine DAG. Lived in ``core/__init__.py`` historically;
it is a first-class scheduler and now a real module so the pipeline
registry (``repro.pipeline.registry``) can treat it like the others.
"""
from __future__ import annotations

from repro.core.coarsen import (
    coarsen_dag,
    funnel_partition,
    pull_back_schedule,
    transitive_sparsify,
)
from repro.core.growlocal import grow_local
from repro.core.schedule import DEFAULT_L, Schedule
from repro.sparse.dag import SolveDAG


def funnel_grow_local(
    dag: SolveDAG,
    k: int,
    *,
    max_size: int = 64,
    L: float = DEFAULT_L,
    sparsify: bool = True,
) -> Schedule:
    """Funnel+GL (paper Tables 7.1–7.2): transitive sparsification, in-funnel
    coarsening, GrowLocal on the coarse DAG, pull-back."""
    work = transitive_sparsify(dag) if sparsify else dag
    part = funnel_partition(work, max_size=max_size)
    c = coarsen_dag(work, part)
    coarse_sched = grow_local(c.coarse, k, L=L)
    return pull_back_schedule(c, coarse_sched, dag.n)
