"""Acyclicity-preserving DAG coarsening (paper §4).

* ``is_cascade`` — Def. 4.2 checker (used by tests to validate Prop. 4.3
  empirically on random partitions).
* ``funnel_partition`` — Algorithm 4.1: in-funnel coarsening by a reverse
  topological sweep; a vertex u joins the growing funnel U exactly when all
  of its children are already in U, so only the seed has outgoing cut edges
  and every member reaches the seed (in-funnel => cascade => Prop. 4.3
  applies). A size/weight cap keeps parts bounded (paper §4.2: without it, a
  single-sink DAG would collapse to one vertex).
* ``transitive_sparsify`` — the 'remove all long edges in triangles'
  approximate transitive reduction of SpMP [PSSD14 §2.3], O(sum_v deg(v)^2),
  applied before coarsening to expose larger funnels.
* ``coarsen_dag`` / ``pull_back_schedule`` — quotient graph construction
  (Def. 4.1) and schedule pull-back to the fine DAG.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List

import numpy as np

from repro.core.schedule import Schedule
from repro.sparse.dag import SolveDAG, dag_from_edges, gather_ranges


# ---------------------------------------------------------------------------
# cascades (Def. 4.2)
# ---------------------------------------------------------------------------
def is_cascade(dag: SolveDAG, part: np.ndarray) -> bool:
    """Check Def. 4.2 for vertex subset ``part``: every vertex with an
    incoming cut edge must reach (via a directed walk inside G — which, for
    walks between members, can WLOG be taken inside the part's reachability)
    every vertex with an outgoing cut edge.

    Note Def. 4.2 allows the connecting walk to leave U; for DAGs a walk
    v ->* u that leaves U and re-enters is still a witness. We therefore
    check reachability in the full DAG restricted to descendants."""
    part = np.asarray(part, dtype=np.int64)
    in_part = np.zeros(dag.n, dtype=bool)
    in_part[part] = True
    has_in_cut = [
        v for v in part if any(not in_part[p] for p in dag.parents(v))
    ]
    has_out_cut = [
        v for v in part if any(not in_part[c] for c in dag.children(v))
    ]
    if not has_in_cut or not has_out_cut:
        return True
    # BFS descendants of each in-cut vertex; must cover all out-cut vertices
    targets = set(int(x) for x in has_out_cut)
    for v in has_in_cut:
        seen = {int(v)}
        stack = [int(v)]
        reached = {int(v)} & targets
        while stack and len(reached) < len(targets):
            x = stack.pop()
            for c in dag.children(x):
                c = int(c)
                if c not in seen:
                    seen.add(c)
                    if c in targets:
                        reached.add(c)
                    stack.append(c)
        if len(reached) < len(targets):
            return False
    return True


# ---------------------------------------------------------------------------
# transitive sparsification [PSSD14 §2.3]
# ---------------------------------------------------------------------------
def transitive_sparsify(dag: SolveDAG) -> SolveDAG:
    """Remove every edge (u, v) for which a triangle u -> w -> v exists.
    Scheduling on the sparsified DAG remains valid for the original (the
    removed dependency is implied transitively — see tests for the formal
    argument exercised empirically)."""
    keep_edges: List[np.ndarray] = []
    parent_sets = [set(int(p) for p in dag.parents(v)) for v in range(dag.n)]
    for v in range(dag.n):
        ps = dag.parents(v)
        if len(ps) == 0:
            continue
        pset = parent_sets[v]
        kept = [
            u
            for u in ps
            # u is redundant iff some other parent w of v has u as parent
            if not any(int(u) in parent_sets[w] for w in pset if w != int(u))
        ]
        if kept:
            arr = np.empty((len(kept), 2), dtype=np.int64)
            arr[:, 0] = kept
            arr[:, 1] = v
            keep_edges.append(arr)
    edges = (
        np.concatenate(keep_edges, axis=0)
        if keep_edges
        else np.empty((0, 2), dtype=np.int64)
    )
    return dag_from_edges(dag.n, edges, dag.weights)


# ---------------------------------------------------------------------------
# Algorithm 4.1 — in-funnel partition
# ---------------------------------------------------------------------------
def funnel_partition(
    dag: SolveDAG,
    *,
    max_size: int = 64,
    max_weight: float = np.inf,
) -> np.ndarray:
    """Partition V into in-funnels; returns part[v] = part id (0..P-1).

    Reverse-topological sweep; each unvisited seed v grows U by repeatedly
    popping the priority queue of vertices whose children are all in U
    (Alg. 4.1), until the size/weight cap."""
    # reverse topological order: for solve DAGs IDs are topological, but we
    # recompute generically from levels so coarse/pipeline DAGs work too.
    from repro.sparse.dag import topological_levels

    levels = topological_levels(dag)
    order = np.argsort(levels, kind="stable")[::-1]  # deepest first

    out_deg = dag.out_degrees()
    visited = np.zeros(dag.n, dtype=bool)
    children_count = np.zeros(dag.n, dtype=np.int64)
    part = -np.ones(dag.n, dtype=np.int64)
    part_id = 0

    for v in order:
        v = int(v)
        if visited[v]:
            continue
        # grow funnel seeded at v
        members: List[int] = []
        weight = 0.0
        pq: List[int] = [v]
        touched: List[int] = []
        while pq:
            if len(members) >= max_size or weight >= max_weight:
                break
            w = heapq.heappop(pq)
            if visited[w]:
                continue
            members.append(w)
            weight += float(dag.weights[w])
            for u in dag.parents(w):
                u = int(u)
                if visited[u]:
                    continue
                children_count[u] += 1
                touched.append(u)
                if children_count[u] == out_deg[u]:
                    heapq.heappush(pq, u)
        for u in touched:
            children_count[u] = 0
        for w in members:
            visited[w] = True
            part[w] = part_id
        part_id += 1
    assert (part >= 0).all()
    return part


# ---------------------------------------------------------------------------
# quotient graph (Def. 4.1) and pull-back
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Coarsening:
    part: np.ndarray  # int64[n_fine] -> coarse id
    coarse: SolveDAG
    members: List[np.ndarray]  # coarse id -> sorted fine ids


def coarsen_dag(dag: SolveDAG, part: np.ndarray) -> Coarsening:
    part = np.asarray(part, dtype=np.int64)
    n_coarse = int(part.max()) + 1 if len(part) else 0
    # coarse edges: (part[u], part[v]) for fine edges, self-loops dropped
    v_of_edge = np.repeat(np.arange(dag.n, dtype=np.int64), np.diff(dag.parent_ptr))
    u_of_edge = dag.parent_idx
    cu, cv = part[u_of_edge], part[v_of_edge]
    mask = cu != cv
    edges = np.unique(np.stack([cu[mask], cv[mask]], axis=1), axis=0)
    weights = np.zeros(n_coarse, dtype=np.int64)
    np.add.at(weights, part, dag.weights)
    coarse = dag_from_edges(n_coarse, edges, weights)
    members = [np.sort(np.nonzero(part == c)[0]) for c in range(n_coarse)]
    return Coarsening(part=part, coarse=coarse, members=members)


def pull_back_schedule(c: Coarsening, coarse_sched: Schedule, n_fine: int) -> Schedule:
    """Pull a coarse schedule back to the fine DAG: every member of a part
    inherits (sigma, pi); in-chain order = coarse rank, then fine ID
    (ID order is topological inside a part for solve DAGs)."""
    pi = np.zeros(n_fine, dtype=np.int32)
    sigma = np.zeros(n_fine, dtype=np.int32)
    rank = np.zeros(n_fine, dtype=np.int64)
    # order parts per (superstep, core) chain by coarse rank
    chains = coarse_sched.chains()
    for (s, p), parts_in_order in chains.items():
        pos = 0
        for cp in parts_in_order:
            m = c.members[int(cp)]
            pi[m] = p
            sigma[m] = s
            rank[m] = np.arange(pos, pos + len(m))
            pos += len(m)
    return Schedule(
        n=n_fine,
        k=coarse_sched.k,
        pi=pi,
        sigma=sigma,
        rank=rank,
        n_supersteps=coarse_sched.n_supersteps,
    )
