"""GrowLocal — the paper's scheduler (§3, Algorithm 3.1).

Superstep formation: iterations with a growing length parameter alpha
(20, 30, 45, ... — factor 1.5). In an iteration, core 1 receives up to alpha
vertices (weight Omega_1); cores 2..k are filled until their weight reaches
Omega_1. The iteration's parallelization score is

    beta = sum_p Omega_p / (max_p Omega_p + L).

An iteration is *worthy* iff beta >= WORTHY_FACTOR * best beta seen in this
superstep (first iteration always worthy; Appendix B uses 0.97). Worthy
iterations are remembered and invalidated; alpha grows; the first unworthy
iteration finalizes the last worthy assignment as the superstep.

Vertex selection — Rule I: when assigning to core p, prefer vertices that are
executable *only on p* in this superstep (a parent was assigned to p since the
last barrier); among candidates, smallest ID. Exclusive-first is the
[PAKY24]-inspired rule; smallest-ID keeps consecutive matrix rows together,
which the reordering step (§5) then turns into locality.

Complexity: O(|E| log |V|) under the paper's Thm 3.1 assumptions — iteration
sizes grow geometrically, so speculative assignments are amortized by the
finalized superstep size.
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

import numpy as np

from repro.core.schedule import DEFAULT_L, Schedule
from repro.sparse.dag import SolveDAG

ALPHA_INIT = 20
ALPHA_GROWTH = 1.5
WORTHY_FACTOR = 0.97

_FREE = -1  # claimed[] sentinel: executable on any core
_BLOCKED = -2  # parents on >= 2 distinct cores this superstep


def grow_local(
    dag: SolveDAG,
    k: int,
    *,
    L: float = DEFAULT_L,
    alpha_init: int = ALPHA_INIT,
    alpha_growth: float = ALPHA_GROWTH,
    worthy_factor: float = WORTHY_FACTOR,
    frontier_widening: bool = False,
) -> Schedule:
    """Run GrowLocal on ``dag`` for ``k`` cores; returns a valid Schedule.

    ``frontier_widening`` (beyond-paper, off by default to stay faithful):
    on single-/few-source DAGs the paper's worthiness rule never cuts — beta
    = Omega_1/(Omega_1 + L) increases monotonically while one core's
    exclusive chain swallows the whole DAG (their SuiteSparse filter,
    avg wavefront >= 2k, hides this regime). When the DAG has parallelism to
    unlock (avg wavefront >= 2) but the current superstep keeps less than
    half the cores busy, we stop growing alpha: the barrier releases the
    frontier to the free pool and the next superstep engages all cores.
    EXPERIMENTS.md §Perf quantifies the effect (narrow-band: ~2x model
    speed-up; ichol grids: serial -> parallel)."""
    n = dag.n
    if n == 0:
        return Schedule(
            n=0,
            k=k,
            pi=np.zeros(0, np.int32),
            sigma=np.zeros(0, np.int32),
            rank=np.zeros(0, np.int64),
            n_supersteps=0,
        )
    weights = dag.weights
    child_ptr, child_idx = dag.child_ptr, dag.child_idx

    widen_cut = False
    if frontier_widening:
        from repro.sparse.dag import average_wavefront_size

        widen_cut = average_wavefront_size(dag) >= 2.0

    # --- global (cross-superstep) state -----------------------------------
    final_remaining = dag.in_degrees().astype(np.int64)  # unfinalized parents
    scheduled = np.zeros(n, dtype=bool)
    pi = np.full(n, -1, dtype=np.int32)
    sigma = np.full(n, -1, dtype=np.int32)
    rank = np.zeros(n, dtype=np.int64)
    free_heap: List[int] = np.nonzero(final_remaining == 0)[0].tolist()
    heapq.heapify(free_heap)

    # --- per-iteration scratch (reset via touched lists) ------------------
    cur_done = np.zeros(n, dtype=np.int64)  # parents assigned this iteration
    claimed = np.full(n, _FREE, dtype=np.int64)
    iter_tag = np.zeros(n, dtype=np.int64)  # last iteration id touching v
    assigned_tag = np.zeros(n, dtype=np.int64)  # last iteration id assigning v
    iteration_id = 0

    n_scheduled = 0
    superstep = 0

    def _touch(v: int):
        if iter_tag[v] != iteration_id:
            iter_tag[v] = iteration_id
            cur_done[v] = 0
            claimed[v] = _FREE

    while n_scheduled < n:
        alpha = float(alpha_init)
        best_beta = -np.inf
        last_worthy: Optional[List[Tuple[int, int]]] = None
        prev_total = -1

        while True:
            iteration_id += 1
            assignment: List[Tuple[int, int]] = []  # (vertex, core) in order
            popped_free: List[int] = []
            excl_heaps: List[List[int]] = [[] for _ in range(k)]
            omega = np.zeros(k, dtype=np.float64)

            def _next_vertex(p: int) -> int:
                """Rule I pop for core p; -1 if nothing assignable."""
                eh = excl_heaps[p]
                while eh:
                    v = heapq.heappop(eh)
                    # exclusive entries are iteration-local; always fresh
                    return v
                while free_heap:
                    v = free_heap[0]
                    if scheduled[v] or assigned_tag[v] == iteration_id:
                        heapq.heappop(free_heap)  # stale
                        continue
                    heapq.heappop(free_heap)
                    popped_free.append(v)
                    return v
                return -1

            def _assign(v: int, p: int):
                assigned_tag[v] = iteration_id
                assignment.append((v, p))
                omega[p] += weights[v]
                lo, hi = child_ptr[v], child_ptr[v + 1]
                for u in child_idx[lo:hi]:
                    _touch(u)
                    cur_done[u] += 1
                    if claimed[u] == _FREE:
                        claimed[u] = p
                    elif claimed[u] != p:
                        claimed[u] = _BLOCKED
                    if (
                        final_remaining[u] - cur_done[u] == 0
                        and claimed[u] == p
                        and not scheduled[u]
                    ):
                        heapq.heappush(excl_heaps[p], int(u))

            # I. assign up to alpha vertices to core 1 (index 0)
            quota = max(1, int(alpha))
            for _ in range(quota):
                v = _next_vertex(0)
                if v < 0:
                    break
                _assign(v, 0)
            # cores 2..k: fill until Omega_p reaches Omega_1
            for p in range(1, k):
                while omega[p] < omega[0]:
                    v = _next_vertex(p)
                    if v < 0:
                        break
                    _assign(v, p)

            # II. parallelization score
            total_w = float(omega.sum())
            max_w = float(omega.max())
            beta = total_w / (max_w + L) if (max_w + L) > 0 else 0.0
            total_assigned = len(assignment)

            first_iteration = last_worthy is None
            worthy = first_iteration or beta >= worthy_factor * best_beta
            if widen_cut and not first_iteration:
                # economics of the cut: a barrier (price L) only pays off if
                # the superstep already carries >= L weight on under-utilized
                # cores — then stop growing and let the barrier release the
                # frontier to the free pool. (The unconditional cut was
                # tried and refuted: it drowns in barrier cost — see
                # EXPERIMENTS.md §Perf, scheduler iteration log.)
                active = int((omega > 0).sum())
                if active <= 1 and total_w >= L:
                    worthy = False
            best_beta = max(best_beta, beta)

            exhausted = n_scheduled + total_assigned >= n
            stalled = total_assigned <= prev_total  # alpha growth gained nothing
            prev_total = total_assigned

            if worthy:
                last_worthy = assignment
                if exhausted or stalled:
                    finalize = last_worthy
                    # nothing to restore: pool entries already popped are
                    # exactly the free vertices of `finalize`
                    restore = []
                    break
                # invalidate: restore popped free vertices, grow alpha
                for v in popped_free:
                    heapq.heappush(free_heap, v)
                alpha *= alpha_growth
            else:
                finalize = last_worthy
                restore = popped_free  # current (rejected) iteration's pops
                break

        # --- finalize the superstep ---------------------------------------
        for v in restore:
            heapq.heappush(free_heap, v)
        chain_pos = np.zeros(k, dtype=np.int64)
        newly_ready: List[int] = []
        for (v, p) in finalize:
            scheduled[v] = True
            pi[v] = p
            sigma[v] = superstep
            rank[v] = chain_pos[p]
            chain_pos[p] += 1
            n_scheduled += 1
        for (v, p) in finalize:
            lo, hi = child_ptr[v], child_ptr[v + 1]
            for u in child_idx[lo:hi]:
                final_remaining[u] -= 1
                if final_remaining[u] == 0 and not scheduled[u]:
                    newly_ready.append(int(u))
        for u in newly_ready:
            heapq.heappush(free_heap, u)
        superstep += 1

    return Schedule(
        n=n, k=k, pi=pi, sigma=sigma, rank=rank, n_supersteps=superstep
    )
