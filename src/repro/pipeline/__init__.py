"""The front door for every triangular solve in the repo.

One call chain replaces the hand-wired matrix -> DAG -> scheduler ->
reorder -> ``compile_plan`` -> executor plumbing that used to be copied
into every example, benchmark and the CG driver:

    from repro.pipeline import TriangularSolver, PlanCache, factor_pair

    cache = PlanCache()
    solver = TriangularSolver.plan(L, strategy="funnel-gl", k=8, cache=cache)
    x = solver.solve(b)           # b: f[n] or batched f[n, m]

    fwd, bwd = factor_pair(Lf)    # L y = b, then L^T x = y (PCG's M^{-1})

``strategy="auto"`` hands the choice to the autotuner (``repro.autotune``:
DAG features -> rule shortlist -> §2.2 cost model; ``tune=True`` adds
measured trials); the outcome is memoized in the ``PlanCache``.

Module map:

  * ``registry``  — named scheduling strategies behind one signature
  * ``solver``    — ``TriangularSolver`` / ``factor_pair`` (plan + bind)
  * ``cache``     — sparsity-pattern-keyed plan cache with hit/miss stats
"""
from repro.pipeline.cache import CacheStats, PlanCache
from repro.pipeline.registry import (
    ScheduleOptions,
    available_strategies,
    get_scheduler,
    register_scheduler,
    schedule,
)
from repro.pipeline.solver import (
    GroupBank,
    TriangularSolver,
    factor_pair,
    grouped_solve,
)

# the cheap pattern handle (re-exported so serving clients can fingerprint
# once and submit by handle without importing the sparse layer)
from repro.sparse.csr import pattern_fingerprint

__all__ = [
    "CacheStats",
    "PlanCache",
    "pattern_fingerprint",
    "ScheduleOptions",
    "available_strategies",
    "get_scheduler",
    "register_scheduler",
    "schedule",
    "GroupBank",
    "TriangularSolver",
    "factor_pair",
    "grouped_solve",
]
