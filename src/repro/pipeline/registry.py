"""Scheduler registry: every strategy behind one uniform signature.

The paper compares five schedulers (plus serial and the §3.1 block
variant); benchmarks, examples and the ``TriangularSolver`` front-end all
want to swap them per call. Each registered strategy is a callable

    fn(dag: SolveDAG, opts: ScheduleOptions) -> Schedule

and ``schedule(dag, k, strategy=..., **opts)`` is the public entry point.
Third-party strategies can join via ``@register_scheduler("name")``.

``strategy="auto"`` is a *meta*-strategy, not a registry entry: it asks
the autotuner (``repro.autotune``) to pick among the registered strategies
by DAG features + the §2.2 cost model. It is accepted by ``schedule`` and
``TriangularSolver.plan`` but deliberately absent from
``available_strategies()`` — everything listed there is a concrete
schedule an auto-selection can resolve *to*.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro import obs
from repro.core import (
    DEFAULT_L,
    Schedule,
    block_parallel_schedule,
    funnel_grow_local,
    grow_local,
    hdagg_schedule,
    serial_schedule,
    spmp_like_schedule,
    wavefront_schedule,
)
from repro.sparse.dag import SolveDAG


@dataclasses.dataclass(frozen=True)
class ScheduleOptions:
    """Knobs shared by all strategies (strategy-specific ones are simply
    ignored by strategies that don't use them — the point is that one
    options object can drive any registry entry)."""

    k: int = 8  # cores / devices
    L: float = DEFAULT_L  # barrier penalty (paper §2.2)
    max_size: int = 64  # funnel coarsening cap (§4)
    sparsify: bool = True  # transitive sparsification pre-pass
    reorder: bool = True  # §5 locality reordering (consumed by the solver)
    n_blocks: int = 4  # diagonal blocks for the "block" strategy (§3.1)
    # elastic staleness window (consumed by the solver's backend binding,
    # not the schedulers): 0 = bulk-synchronous, s > 0 fuses runs of s
    # plan steps into one macro-step (core.elastic; mode="elastic")
    slack: int = 0

    def replace(self, **kw) -> "ScheduleOptions":
        return dataclasses.replace(self, **kw)


SchedulerFn = Callable[[SolveDAG, ScheduleOptions], Schedule]

_REGISTRY: Dict[str, SchedulerFn] = {}


def register_scheduler(name: str):
    """Decorator: ``@register_scheduler("mine")`` on a
    ``fn(dag, opts) -> Schedule``."""

    def deco(fn: SchedulerFn) -> SchedulerFn:
        key = name.lower()
        if key == "auto":
            raise ValueError(
                "'auto' is reserved for the autotuner meta-strategy"
            )
        if key in _REGISTRY:
            raise ValueError(f"scheduler {name!r} already registered")
        _REGISTRY[key] = fn
        return fn

    return deco


def get_scheduler(name: str) -> SchedulerFn:
    try:
        return _REGISTRY[name.lower()]
    except KeyError:
        if name.lower() == "auto":
            raise KeyError(
                "'auto' is a meta-strategy with no registry entry; call "
                "schedule(dag, strategy='auto') or "
                "TriangularSolver.plan(a, strategy='auto') instead"
            ) from None
        raise KeyError(
            f"unknown strategy {name!r}; available: {available_strategies()}"
        ) from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def schedule(
    dag: SolveDAG,
    k: int | None = None,
    *,
    strategy: str = "growlocal",
    options: ScheduleOptions | None = None,
    **opts,
) -> Schedule:
    """Run a registered strategy (or ``"auto"`` — the autotuner picks one
    by DAG features). ``k``/keyword opts override ``options``."""
    strategy = strategy.lower()
    o = options or ScheduleOptions()
    if k is not None:
        o = o.replace(k=k)
    if opts:
        o = o.replace(**opts)
    if strategy == "auto":
        from repro.autotune.selector import select_schedule

        return select_schedule(dag, o)[1]
    with obs.span(
        f"inspector.schedule.{strategy}",
        cat="inspector",
        n=dag.n,
        k=o.k,
    ):
        return get_scheduler(strategy)(dag, o)


@register_scheduler("growlocal")
def _growlocal(dag: SolveDAG, o: ScheduleOptions) -> Schedule:
    return grow_local(dag, o.k, L=o.L)


@register_scheduler("funnel-gl")
def _funnel_gl(dag: SolveDAG, o: ScheduleOptions) -> Schedule:
    return funnel_grow_local(
        dag, o.k, max_size=o.max_size, L=o.L, sparsify=o.sparsify
    )


@register_scheduler("hdagg")
def _hdagg(dag: SolveDAG, o: ScheduleOptions) -> Schedule:
    return hdagg_schedule(dag, o.k)


@register_scheduler("spmp")
def _spmp(dag: SolveDAG, o: ScheduleOptions) -> Schedule:
    return spmp_like_schedule(dag, o.k, sparsify=o.sparsify)


@register_scheduler("wavefront")
def _wavefront(dag: SolveDAG, o: ScheduleOptions) -> Schedule:
    return wavefront_schedule(dag, o.k)


@register_scheduler("serial")
def _serial(dag: SolveDAG, o: ScheduleOptions) -> Schedule:
    return serial_schedule(dag)


@register_scheduler("block")
def _block(dag: SolveDAG, o: ScheduleOptions) -> Schedule:
    return block_parallel_schedule(
        dag, o.k, o.n_blocks, lambda d, k: grow_local(d, k, L=o.L)
    )
