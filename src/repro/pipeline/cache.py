"""Plan cache — pay the inspector once per sparsity pattern.

The paper's motivating workload (§1, Table 7.7) reuses one sparsity
pattern across hundreds of solves; iterative methods even reuse it across
*factorizations* (same pattern, new values every Newton step). The cache
keys on everything that determines the compiled plan:

    (pattern fingerprint, strategy, k, W, dtype, backend, lower, reorder)

On a hit the whole DAG-build -> schedule -> reorder -> compile chain is
skipped; only the numeric values are refreshed in place (``numeric_update``
via the plan's value-source maps), which is O(nnz) instead of
O(|E| log |V|).

The cache also memoizes ``strategy="auto"`` outcomes per fingerprint
(``get_selection`` / ``store_selection``): a repeated pattern resolves to
the previously selected concrete config with zero selection work, then
hits the plan entry stored under that concrete key.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Hashable, Optional

from repro import obs


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    numeric_updates: int = 0
    evictions: int = 0
    # strategy="auto" bookkeeping: selections = feature-extraction +
    # shortlist-scoring runs actually performed; selection_hits = plans
    # that resolved to a concrete config without re-running selection
    selections: int = 0
    selection_hits: int = 0

    @property
    def entries_built(self) -> int:
        return self.misses

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanCache:
    """LRU cache from plan key -> bound ``TriangularSolver``. Thread-safe;
    shared freely across solves, requests and factor pairs."""

    def __init__(self, maxsize: Optional[int] = None):
        self.maxsize = maxsize
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, object]" = OrderedDict()
        # strategy="auto" selections live beside the plans. Selection
        # objects are tiny, so they outlive plan eviction (a pattern whose
        # plan was evicted still skips re-selection) — but not unboundedly:
        # FIFO-capped so a stream of distinct patterns cannot grow this
        # forever while the plan entries themselves are being evicted.
        self._selections: "OrderedDict[Hashable, object]" = OrderedDict()
        self._selections_max = max(4 * maxsize, 64) if maxsize else 4096
        # keys exempt from LRU eviction (live-serving plans — see pin())
        self._pinned: set = set()
        # width-class index: structural solve-graph identity -> the plan
        # keys sharing it (``TriangularSolver.width_class``). Lets the
        # serve layer discover which cached plans can ride one grouped
        # dispatch and surfaces class sizes in telemetry. Index entries
        # leave with their plan (LRU eviction drops them too), so the
        # index stays bounded by the live entry set under pattern churn.
        self._width_classes: "OrderedDict[Hashable, set]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------- auto-strategy memo
    def get_selection(self, key: Hashable):
        """Memoized ``strategy="auto"`` outcome for ``key`` (see
        ``autotune.selector.selection_key``), or None. A hit means
        ``plan()`` resolves straight to a concrete plan key with zero
        selection work."""
        with self._lock:
            sel = self._selections.get(key)
            if sel is not None:
                self.stats.selection_hits += 1
            return sel

    def store_selection(self, key: Hashable, selection: object) -> None:
        with self._lock:
            if key not in self._selections:
                # racing first-plans may both compute a selection (same
                # deterministic outcome, mirroring get_or_build's racing
                # builders); count the distinct key once
                self.stats.selections += 1
            self._selections[key] = selection
            while len(self._selections) > self._selections_max:
                self._selections.popitem(last=False)

    def get_or_build(self, key: Hashable, builder: Callable[[], object]):
        """Return ``(entry, hit)``. ``builder`` runs outside the lock on a
        miss — concurrent misses on the same key keep the first-inserted
        entry (last writer returns the canonical one)."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                obs.counter_add("cache.hit")
                return entry, True
        with obs.span("cache.build", cat="cache"):
            built = builder()
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:  # lost the race; count as a hit
                self._entries.move_to_end(key)
                self.stats.hits += 1
                obs.counter_add("cache.hit")
                return entry, True
            self.stats.misses += 1
            self._entries[key] = built
            self._evict_locked()
        obs.counter_add("cache.miss")
        return built, False

    def _evict_locked(self) -> None:
        """Evict oldest *unpinned* entries down to maxsize. Pinned entries
        never leave (the cache may exceed maxsize while everything is
        pinned — bounded by the number of live pins, i.e. the serving
        set, which is exactly what the pins protect)."""
        if self.maxsize is None:
            return
        over = len(self._entries) - self.maxsize
        if over <= 0:
            return
        for key in [k for k in self._entries if k not in self._pinned]:
            self._entries.pop(key)
            self._drop_width_class_locked(key)
            self.stats.evictions += 1
            obs.counter_add("cache.evict")
            over -= 1
            if over <= 0:
                break

    def _drop_width_class_locked(self, key: Hashable) -> None:
        """Remove ``key`` from the width-class index (and drop classes
        that emptied) — keeps the index bounded by the live entries."""
        for wc in [
            wc for wc, keys in self._width_classes.items() if key in keys
        ]:
            keys = self._width_classes[wc]
            keys.discard(key)
            if not keys:
                del self._width_classes[wc]

    # --------------------------------------------------- eviction-safe pins
    def pin(self, key: Hashable) -> None:
        """Exempt ``key`` from LRU eviction while it serves live traffic
        (``repro.serve`` pins every registered pattern's plan). Idempotent;
        pinning a key with no entry yet is allowed — it protects the entry
        whenever it appears."""
        with self._lock:
            self._pinned.add(key)
        obs.counter_add("cache.pin")

    def unpin(self, key: Hashable) -> None:
        """Drop the eviction exemption (idempotent); the entry itself
        stays until normal LRU pressure removes it."""
        with self._lock:
            self._pinned.discard(key)
            self._evict_locked()
        obs.counter_add("cache.unpin")

    @property
    def pinned(self) -> frozenset:
        with self._lock:
            return frozenset(self._pinned)

    # ------------------------------------------------- width-class index
    def note_width_class(self, width_class: Hashable, key: Hashable) -> None:
        """Record that plan ``key`` belongs to ``width_class`` (the
        structural solve-graph identity from
        ``TriangularSolver.width_class``). Idempotent."""
        with self._lock:
            self._width_classes.setdefault(width_class, set()).add(key)

    def width_class_members(self, width_class: Hashable) -> frozenset:
        with self._lock:
            return frozenset(self._width_classes.get(width_class, ()))

    def width_class_sizes(self) -> dict:
        """{width_class: member count} — classes with >1 member are the
        cross-pattern batching opportunities."""
        with self._lock:
            return {wc: len(keys) for wc, keys in self._width_classes.items()}

    def replace(self, key: Hashable, entry: object) -> None:
        """Swap the canonical entry for ``key`` (e.g. after a value
        refresh). No-op on the stats; the key must already exist or the
        entry is simply inserted."""
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)

    def note_numeric_update(self) -> None:
        with self._lock:
            self.stats.numeric_updates += 1
        obs.counter_add("cache.numeric_update")

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._selections.clear()
            self._pinned.clear()
            self._width_classes.clear()
