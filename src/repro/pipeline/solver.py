"""``TriangularSolver`` — plan once, solve many times.

``TriangularSolver.plan(L)`` runs the full inspector pipeline

    DAG build -> schedule (registry strategy) -> §5 reordering ->
    ``compile_plan`` -> backend binding (``repro.backends`` registry)

and returns a bound solver whose ``solve(b)`` applies and undoes every
permutation internally — callers never see reordered indices. ``b`` may be
``f[n]`` or batched ``f[n, m]`` (multi-RHS; one plan traversal).

Backends come from ``repro.backends.registry`` (scan | pallas |
distributed built in; register your own), and every binding is a
``BoundSolve``: numeric refreshes go through its device-side
``update_values`` gather — no plan tensor ever round-trips host memory
after the first bind.

``lower=False`` solves an *upper*-triangular system via the
reverse-permutation trick (an upper-triangular matrix reversed
symmetrically is lower triangular again), which together with
``factor_pair`` gives the forward/backward pair PCG needs:

    fwd, bwd = factor_pair(Lf)        # Lf y = b, then Lf^T x = y

Pass a ``PlanCache`` to amortize the inspector across solves that share a
sparsity pattern — hits skip scheduling entirely and only refresh the
numeric values (paper §7.7's regime).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import apply_reordering, compile_plan
from repro.core.plan import ExecPlan
from repro.pipeline.cache import PlanCache
from repro.pipeline.registry import ScheduleOptions, get_scheduler
from repro.sparse.csr import (
    CSRMatrix,
    pattern_fingerprint,
    permute_symmetric,
    transpose_csr,
)
from repro.sparse.dag import dag_from_lower_csr


def mesh_fingerprint(mesh) -> tuple | None:
    """Structural mesh identity for cache keys (axes + device list) —
    not ``id()``: CPython reuses freed ids (a dead mesh's id can alias a
    new, different mesh), and a rebuilt identical Mesh should hit the
    same entry. Shared with the autotuner's tune-memo binding key."""
    if mesh is None:
        return None
    return (
        tuple(sorted(mesh.shape.items())),
        tuple(str(d) for d in np.asarray(mesh.devices).ravel()),
    )


def binding_fingerprint(
    *, backend, dtype, width, steps_per_tile, interpret, mesh, slack=0,
    shard="model",
) -> tuple:
    """The backend-binding part of a plan's identity — everything beyond
    (pattern, strategy, options, orientation) that changes the compiled
    solver. One helper shared by ``plan()``'s cache key and the
    autotuner's tune-memo key so the two can never drift apart.
    ``slack > 0`` marks an elastic (macro-step) binding — a different
    compiled graph from the bulk-synchronous one, so it must key (and
    split width classes) even though the plan tensors match. ``shard``
    keys the mesh decomposition the same way: ``"rows"`` row-partitions
    the plan across the mesh (``core.rowshard``), a completely different
    sharded graph from the default ``"model"`` core sharding."""
    return (
        backend,
        np.dtype(dtype).str,
        width if width is not None else "auto",
        steps_per_tile,
        interpret,
        mesh_fingerprint(mesh),
        slack,
        shard,
    )


def mirror_to_lower(a: CSRMatrix, lower: bool):
    """``(m0, outer)``: the lower-triangular matrix the schedulers actually
    see, plus the outer reverse permutation (None when ``lower=True``).
    Reversed symmetrically, an upper-triangular matrix is lower triangular
    again (the L^T trick, paper §5 footnote). Shared by ``plan()`` and the
    autotuner's ``resolve_auto`` so feature extraction and candidate
    scoring always describe the DAG that is actually scheduled."""
    # ValueError, not assert: a wrongly-oriented matrix planned under
    # python -O would otherwise produce silently-garbage solutions
    if lower:
        if not a.is_lower_triangular():
            raise ValueError("expected a lower-triangular matrix")
        return a, None
    if not bool(np.all(a.indices >= a.row_of_entry())):
        raise ValueError("lower=False expects an upper-triangular matrix")
    outer = np.arange(a.n_rows, dtype=np.int64)[::-1].copy()
    return permute_symmetric(a, outer), outer


def _entry_permutation(m: CSRMatrix, perm: np.ndarray) -> np.ndarray:
    """``e`` such that ``permute_symmetric(m, perm).data == m.data[e]``.

    Pure scatter/argsort passes — two relabel gathers and one ``lexsort``
    — instead of riding entry ids through ``permute_symmetric`` on a
    float64 carrier matrix (the old inspector hot spot: it re-ran the
    full ``csr_from_coo`` duplicate-merge machinery per plan). The
    ``lexsort`` key order (cols minor, rows major) matches
    ``csr_from_coo`` exactly and the (row, col) pairs of a CSR pattern
    are unique, so the result is identical entry-for-entry.
    """
    perm = np.asarray(perm, dtype=np.int64)
    inv = np.empty(m.n_rows, dtype=np.int64)
    inv[perm] = np.arange(m.n_rows, dtype=np.int64)
    return np.lexsort((inv[m.indices], inv[m.row_of_entry()]))


class TriangularSolver:
    """A bound, permutation-transparent triangular solver. Construct via
    :meth:`plan` (or :func:`factor_pair`), not directly."""

    def __init__(
        self,
        *,
        exec_plan: ExecPlan,
        total_perm: np.ndarray,
        backend: str,
        dtype,
        fingerprint: str,
        strategy: str,
        lower: bool,
        inspector_seconds: float,
        mesh=None,
        steps_per_tile: int = 8,
        interpret: Optional[bool] = None,
        slack: int = 0,
        shard: str = "model",
        timed: bool = False,
    ):
        self.exec_plan = exec_plan
        self.backend = backend
        self.dtype = dtype
        self.fingerprint = fingerprint
        self.strategy = strategy
        self.lower = lower
        self.inspector_seconds = inspector_seconds
        self._mesh = mesh
        self._steps_per_tile = steps_per_tile
        self._interpret = interpret
        self._slack = slack  # > 0: elastic (macro-step) execution mode
        self._shard = shard  # mesh decomposition ("model" | "rows")
        # per-step timed execution (observability toggle, NOT part of the
        # plan identity — flip it any time; results are identical, only
        # dispatch granularity and telemetry change)
        self.timed = bool(timed)
        self.last_step_timings: Optional[list] = None
        self._source_data: Optional[np.ndarray] = None  # set by plan()
        self._selection = None  # autotune Selection, set by plan(auto)
        self.plan_key = None  # concrete plan-cache key, set by plan()
        total_inv = np.empty_like(total_perm)
        total_inv[total_perm] = np.arange(len(total_perm))
        self._perm = jnp.asarray(total_perm, jnp.int32)
        self._inv = jnp.asarray(total_inv, jnp.int32)
        self._bind()

    # ---------------------------------------------------------- binding
    def _bind(self) -> None:
        """Bind device-resident plan tensors through the
        ``repro.backends`` registry — called once at construction.
        Numeric refreshes never come back here: they go through the
        bound solve's device-side ``update_values`` gather."""
        from repro.backends import get_backend

        self._bound = get_backend(self.backend).bind(
            self.exec_plan,
            dtype=self.dtype,
            steps_per_tile=self._steps_per_tile,
            interpret=self._interpret,
            mesh=self._mesh,
            slack=self._slack,
            shard=self._shard,
        )

    @property
    def bound(self):
        """The backend ``BoundSolve`` this solver executes through
        (telemetry via ``bound.describe()``)."""
        return self._bound

    @property
    def width_class(self) -> tuple:
        """Structural identity of this solver's compiled solve graph:
        two solvers with equal width classes execute identically-shaped
        ``ExecPlan`` tensors through the same backend binding, so they
        share every compiled XLA variant — and, when the backend
        supports it, their requests can ride one grouped dispatch
        (``grouped_solve``; the serve layer's cross-pattern batching).
        Orientation (``lower``) is deliberately excluded: it only
        changes the host-side permutation, never the solve graph."""
        p = self.exec_plan
        return (
            p.n,
            p.n_steps,
            p.k,
            p.W,
            tuple(int(x) for x in p.step_bounds),
        ) + binding_fingerprint(
            backend=self.backend,
            dtype=self.dtype,
            width=p.W,
            steps_per_tile=self._steps_per_tile,
            interpret=self._interpret,
            mesh=self._mesh,
            slack=self._slack,
            shard=self._shard,
        )

    @property
    def supports_grouping(self) -> bool:
        """True when this solver's backend can serve width-class grouped
        solves (one fused dispatch, one plan per column)."""
        return bool(getattr(self._bound, "supports_grouped", False))

    # ---------------------------------------------------------- solving
    def _check_b(self, b):
        b = jnp.asarray(b, self.dtype)
        # XLA clamps out-of-range gather indices, so a mis-sized b would
        # silently produce garbage — reject it here.
        if b.ndim not in (1, 2) or b.shape[0] != self.n:
            raise ValueError(
                f"b must be [n] or [n, m] with n={self.n}; got {b.shape}"
            )
        return b

    def solve(self, b):
        """Solve the planned system for ``b``: f[n] or f[n, m] (multi-RHS).
        Input/output live in the caller's original row ordering. With the
        ``timed`` toggle on, routes through :meth:`solve_timed` (per-step
        device timings land in ``last_step_timings`` and the active trace
        buffer)."""
        if self.timed:
            return self.solve_timed(b)[0]
        b = self._check_b(b)
        with obs.span("executor.solve", cat="executor", n=self.n):
            x = self._bound.solve(b[self._perm])
            return x[self._inv]

    def solve_timed(self, b):
        """``solve`` with per-step device timing: returns ``(x, steps)``
        where ``steps`` holds one JSON-ready dict per superstep (bulk) /
        macro-step (elastic) at the finest granularity the backend can
        observe (``BoundSolve.solve_timed``). The last timing list is
        kept on ``last_step_timings``; per-step spans land in the active
        trace buffer when tracing is enabled."""
        b = self._check_b(b)
        with obs.span(
            "executor.solve", cat="executor", n=self.n, timed=True
        ):
            x, steps = self._bound.solve_timed(b[self._perm])
            x = x[self._inv]
        self.last_step_timings = steps
        return x, steps

    __call__ = solve

    def numeric_update(self, a) -> None:
        """Refresh values from ``a`` — a CSRMatrix with the planned sparsity
        pattern, or its raw ``.data`` — without rescheduling. Mutates THIS
        solver in place (plan-cache hits clone instead, so solvers returned
        from earlier ``plan`` calls are never touched behind their backs)."""
        if isinstance(a, CSRMatrix):
            if pattern_fingerprint(a) != self.fingerprint:
                raise ValueError(
                    "numeric_update requires the sparsity pattern the plan "
                    "was built for (pattern fingerprint mismatch)"
                )
            data = a.data
        else:
            data = np.asarray(a)
        # host mirror: bind() reads the host plan tensors, so they must
        # stay a faithful source for any future (re)bind of this plan —
        # letting them go stale would make such a bind silently solve
        # with old values. A deliberate O(plan) host cost per refresh.
        self.exec_plan.numeric_update(data)
        self._source_data = np.array(data)
        # device refresh: an O(nnz) gather through val_src/diag_src — the
        # plan's index tensors stay on device, nothing retransfers
        self._bound = self._bound.update_values(data)

    def _with_values(self, data: np.ndarray) -> "TriangularSolver":
        """A sibling solver with new numeric values: shares the (read-only)
        schedule/index structure, owns its value tensors and binding."""
        import copy
        import dataclasses

        new = copy.copy(self)
        new.exec_plan = dataclasses.replace(
            self.exec_plan,
            vals=self.exec_plan.vals.copy(),
            diag=self.exec_plan.diag.copy(),
        )
        new.numeric_update(data)
        return new

    def clone_with_values(self, a) -> "TriangularSolver":
        """Public sibling-with-new-values: ``a`` is a CSRMatrix with the
        planned pattern (fingerprint-checked) or its raw ``.data``. THIS
        solver is untouched — the live-refactorization primitive
        ``repro.serve`` version-swaps with (in-flight batches keep reading
        the old solver's tensors)."""
        if isinstance(a, CSRMatrix):
            if pattern_fingerprint(a) != self.fingerprint:
                raise ValueError(
                    "clone_with_values requires the sparsity pattern the "
                    "plan was built for (pattern fingerprint mismatch)"
                )
            data = a.data
        else:
            data = np.asarray(a)
        return self._with_values(data)

    @property
    def source_values(self) -> Optional[np.ndarray]:
        """The caller-order entry values this solver was built/refreshed
        from (read-only view — used to detect value changes cheaply)."""
        return self._source_data

    @property
    def n(self) -> int:
        return self.exec_plan.n

    @property
    def n_supersteps(self) -> int:
        return self.exec_plan.n_supersteps

    def info(self) -> dict:
        out = {
            "strategy": self.strategy,
            "backend": self.backend,
            "mode": "elastic" if self._slack else "bsp",
            "slack": self._slack,
            "shard": self._shard,
            "timed": self.timed,
            "lower": self.lower,
            "n_supersteps": self.n_supersteps,
            "inspector_seconds": self.inspector_seconds,
            "plan": self.exec_plan.stats(),
            "binding": self._bound.describe(),
        }
        if self._selection is not None:
            out["selection"] = self._selection.as_dict()
        return out

    @property
    def selection(self):
        """The autotuner's ``Selection`` recorded when this solver object
        was *built* by a ``strategy="auto"`` plan (None when it was built
        with a fixed strategy). Cached solvers are never mutated after the
        fact: an auto plan that cache-hits an entry originally built by a
        fixed-strategy plan returns it with ``selection`` still None — the
        resolved outcome remains available via the cache's selection memo.
        """
        return self._selection

    # ---------------------------------------------------------- planning
    @classmethod
    def plan(
        cls,
        a: CSRMatrix,
        *,
        strategy: str = "growlocal",
        backend: str = "scan",
        lower: bool = True,
        k: Optional[int] = None,
        dtype=jnp.float32,
        width: Optional[int] = None,
        options: Optional[ScheduleOptions] = None,
        cache: Optional[PlanCache] = None,
        mesh=None,
        steps_per_tile: int = 8,
        interpret: Optional[bool] = None,
        sched=None,
        tune: bool = False,
        mode: Optional[str] = None,
        shard: str = "model",
        timed: bool = False,
        validate: Optional[str] = None,
        **opts,
    ) -> "TriangularSolver":
        """Plan a solver for triangular ``a`` (lower, or upper with
        ``lower=False``). With ``cache``, a repeated sparsity pattern skips
        the inspector: identical values return the cached solver as-is; new
        values return a clone with refreshed numerics (solvers from earlier
        calls are never mutated). ``sched`` bypasses the registry with a
        pre-built Schedule (never cached — the cache cannot key on
        arbitrary schedules).

        ``mode`` selects the execution mode: ``"bsp"`` (bulk-synchronous,
        the default) or ``"elastic"`` — bounded-slack macro-step execution
        (``core.elastic``; bitwise-identical results, fewer scan/grid
        steps on deep DAGs). ``mode="elastic"`` uses the staleness window
        from ``slack=...`` (a ``ScheduleOptions`` knob) or the calibrated
        ``core.DEFAULT_SLACK``; passing ``slack > 0`` alone also enables
        elastic. The backend must advertise the ``"elastic"`` capability.

        ``shard`` selects the mesh decomposition for distributed
        backends: ``"model"`` (default — lanes sharded, x replicated via
        all-gather) or ``"rows"`` — the plan is row-partitioned across
        the mesh's ``"model"`` axis (``core.rowshard``) with per-superstep
        halo exchange instead of O(n) all-gathers. Requires the backend
        to advertise ``"shard-rows"``.

        ``strategy="auto"`` lets the autotuner choose: DAG features ->
        rule-based shortlist -> §2.2 cost model (``repro.autotune``); with
        ``tune=True`` the shortlisted plans are additionally compiled and
        *timed* on the real backend. When the backend supports elastic
        (and ``mode`` does not force ``"bsp"``), the selector may also
        turn elastic mode on via its step-granular cost rule. The
        resolved config is memoized per sparsity fingerprint (inside
        ``cache`` when given), and the plan is cached under the resolved
        *concrete* key — so repeated auto plans on one pattern skip both
        selection and scheduling.

        ``validate`` runs the independent static verifier
        (``repro.analysis``) over the freshly built artifacts —
        schedule, reorder permutation, plan tensors, elastic
        certificate, and (``shard="rows"``) the halo partition:
        ``"fast"`` is the vectorized invariant set, ``"full"`` adds
        value provenance and per-shard audits, ``"off"`` (default)
        skips. ``None`` defers to the ``REPRO_VALIDATE`` env var. A
        finding raises ``analysis.VerificationError`` with the findings
        table. Build-time only: cache hits return the already-verified
        entry without re-checking.

        ``timed=True`` turns on per-step timed execution (``repro.obs``):
        every ``solve`` routes through ``solve_timed`` and records
        per-superstep / per-macro-step device timings. Deliberately NOT
        part of the plan identity — it is a mutable observability toggle
        on the solver (``solver.timed``), so a cache hit returns the same
        entry with the toggle set to THIS call's value."""
        # normalize once: the registry is case-insensitive, and the raw
        # string enters the plan-cache key ("GrowLocal" vs "growlocal"
        # must not schedule twice); also makes strategy="Auto" work
        strategy = strategy.lower()
        # resolve (and reject) the validation level before any scheduling
        # work; "off" keeps the verifier entirely off the build path
        from repro.analysis import resolve_level

        check_level = resolve_level(validate)
        # fail fast on an unknown backend — before any scheduling work and
        # with the registry (not a hard-coded tuple) naming the options
        from repro.backends import get_backend

        backend_caps = get_backend(backend).capabilities()
        if tune and (strategy != "auto" or sched is not None):
            raise ValueError(
                "tune=True runs measured trials to refine an auto "
                "selection; it requires strategy='auto' (and no pre-built "
                "sched)"
            )
        o = options or ScheduleOptions()
        if k is not None:
            o = o.replace(k=k)
        if opts:
            o = o.replace(**opts)
        if mode is not None and mode not in ("bsp", "elastic"):
            raise ValueError(
                f"mode must be 'bsp' or 'elastic'; got {mode!r}"
            )
        if mode == "elastic" and o.slack == 0:
            from repro.core import DEFAULT_SLACK

            o = o.replace(slack=DEFAULT_SLACK)
        if mode == "bsp" and o.slack > 0:
            raise ValueError(
                f"mode='bsp' conflicts with slack={o.slack}; drop one"
            )
        if o.slack > 0 and "elastic" not in backend_caps:
            raise ValueError(
                f"backend {backend!r} does not support mode='elastic' "
                f"(requested slack={o.slack}, no 'elastic' capability)"
            )
        if shard != "model" and f"shard-{shard}" not in backend_caps:
            raise ValueError(
                f"backend {backend!r} does not support shard={shard!r} "
                f"(no 'shard-{shard}' capability)"
            )
        # the selector may only turn elastic ON when the binding can run
        # it and the caller did not force bulk-synchronous
        elastic_ok = mode != "bsp" and "elastic" in backend_caps

        fp = pattern_fingerprint(a)
        selection = None
        pre_sched = None  # winning Schedule the selector already computed
        pre_solver = None  # winner's trial solver (tune=True measured run)
        if strategy == "auto" and sched is None:
            from repro.autotune.selector import resolve_auto_full

            selection, pre_sched, pre_solver = resolve_auto_full(
                a,
                options=o,
                lower=lower,
                tune=tune,
                cache=cache,
                fp=fp,
                allow_elastic=elastic_ok,
                plan_kwargs=dict(
                    backend=backend, dtype=dtype, width=width,
                    mesh=mesh, steps_per_tile=steps_per_tile,
                    interpret=interpret, shard=shard,
                ),
            )
            strategy, o = selection.strategy, selection.options
        # o (a frozen dataclass) covers every scheduling knob incl. k,
        # reorder and the elastic slack; binding params (mesh identity,
        # tile size, interpret, slack again) also change the built solver
        # and must key too.
        key = (fp, strategy, o, lower) + binding_fingerprint(
            backend=backend, dtype=dtype, width=width,
            steps_per_tile=steps_per_tile, interpret=interpret, mesh=mesh,
            slack=o.slack, shard=shard,
        )

        def build() -> "TriangularSolver":
            t0 = time.perf_counter()
            n = a.n_rows
            m0, outer = mirror_to_lower(a, lower)

            if sched is not None:
                s = sched
            elif pre_sched is not None:
                s = pre_sched  # already computed while scoring candidates
            else:
                with obs.span("inspector.dag", cat="inspector", n=n):
                    dag = dag_from_lower_csr(m0)
                with obs.span(
                    f"inspector.schedule.{strategy}", cat="inspector",
                    n=n, k=o.k,
                ):
                    s = get_scheduler(strategy)(dag, o)
            if o.reorder:
                with obs.span("inspector.reorder", cat="inspector", n=n):
                    m2, s2, _, r = apply_reordering(m0, s)
                inner = r.perm
            else:
                m2, s2, inner = m0, s, np.arange(n, dtype=np.int64)

            plan = compile_plan(m2, s2, width=width, dtype=np.dtype(dtype))
            if o.slack > 0:
                # attach the slack certificate so the backend bind (and
                # ExecPlan.stats barrier accounting) reuse one transform
                from repro.core import elastic_transform

                plan.elastic = elastic_transform(plan, o.slack)

            if check_level != "off":
                # verify against m2 BEFORE the val_src rebase below —
                # the provenance audit matches sources against the
                # matrix the plan was actually compiled from
                from repro import analysis

                analysis.verify_artifacts(
                    analysis.Artifacts(
                        L=m2, sched=s2, plan=plan,
                        perm=inner if o.reorder else None,
                        sched_pre=s if o.reorder else None,
                    ),
                    level=check_level,
                ).raise_if_failed()

            # rebase the plan's value-source maps onto a's entry order so
            # numeric_update() consumes a.data directly
            entry_map = _entry_permutation(m0, inner)  # m2 entry -> m0 entry
            if outer is not None:
                entry_map = _entry_permutation(a, outer)[entry_map]
            vmask = plan.val_src >= 0
            plan.val_src[vmask] = entry_map[plan.val_src[vmask]]
            dmask = plan.diag_src >= 0
            plan.diag_src[dmask] = entry_map[plan.diag_src[dmask]]

            total_perm = inner if outer is None else outer[inner]
            solver = cls(
                exec_plan=plan,
                total_perm=total_perm,
                backend=backend,
                dtype=dtype,
                fingerprint=fp,
                strategy=strategy if sched is None else "(prebuilt)",
                lower=lower,
                inspector_seconds=time.perf_counter() - t0,
                mesh=mesh,
                steps_per_tile=steps_per_tile,
                interpret=interpret,
                slack=o.slack,
                shard=shard,
            )
            solver._source_data = np.array(a.data)
            # selection is recorded at build time only — cached solvers are
            # never mutated after being handed out (see the property doc)
            solver._selection = selection
            if check_level != "off" and shard == "rows":
                # the halo partition is produced at backend bind time;
                # audit it against the plan it was cut from (the value
                # check deliberately skips the rebased source maps)
                from repro import analysis

                rsp = getattr(solver.bound, "_rsp", None)
                if rsp is not None:
                    analysis.verify_rowshard_report(
                        plan, rsp, level=check_level
                    ).raise_if_failed()
            return solver

        # the tuned winner was compiled+warmed during the measured trials
        # (against a private cache) and carries its Selection — use it as
        # the builder so the work is not redone; it enters the shared
        # cache fully formed, so no published solver is ever mutated
        builder = build if pre_solver is None else (lambda: pre_solver)
        if cache is None or sched is not None:
            solver = builder()
            if sched is None:  # prebuilt schedules have no cacheable key
                solver.plan_key = key
            solver.timed = timed
            return solver
        solver, hit = cache.get_or_build(key, builder)
        # idempotent on hits (the key IS the entry's key); lets callers
        # pin/unpin the entry (PlanCache.pin) without recomputing the key
        solver.plan_key = key
        if hit and not np.array_equal(solver._source_data, a.data):
            # same pattern, new values: clone with refreshed numerics (the
            # cached entry — and anyone holding it — stays untouched), then
            # make the clone canonical so repeats of THESE values are free
            solver = solver._with_values(a.data)
            cache.replace(key, solver)
            cache.note_numeric_update()
        solver.timed = timed
        return solver


def grouped_solve(solvers, B) -> jnp.ndarray:
    """Solve column j of ``B`` f[n, m] with ``solvers[j]`` — one fused
    width-class dispatch (``BoundSolve.solve_grouped``), each column
    against its own plan tensors (pattern AND values may differ per
    column; only the tensor shapes must match — equal ``width_class``).

    Per-column permutations are applied/undone here, so columns may even
    mix orientations. The compiled variant is cached per (width class,
    group width): a serving mix of structurally-identical patterns pays
    for log2(max_batch) compilations total, not per pattern.

    Bitwise contract: vmap lanes are data-independent, so a column's
    bits depend only on its own (plan, b) — never on what the neighbor
    columns hold. The replay reference for a grouped result is therefore
    the same call with the request's own solver replicated into every
    lane (``repro.serve.service.GroupReplay``)."""
    if not solvers:
        raise ValueError("grouped_solve needs at least one solver")
    wc = solvers[0].width_class
    for s in solvers[1:]:
        if s.width_class != wc:
            raise ValueError(
                "grouped_solve requires one width class; got solvers "
                f"with {s.width_class} vs {wc}"
            )
    bound0 = solvers[0]._bound
    if not getattr(bound0, "supports_grouped", False):
        raise NotImplementedError(
            f"backend {solvers[0].backend!r} does not support width-class "
            "grouped solves"
        )
    B = jnp.asarray(B, solvers[0].dtype)
    if B.ndim != 2 or B.shape[0] != solvers[0].n or B.shape[1] != len(solvers):
        raise ValueError(
            f"B must be [n={solvers[0].n}, m={len(solvers)}] (one column "
            f"per solver); got {B.shape}"
        )
    b_cols = jnp.stack(
        [B[:, j][s._perm] for j, s in enumerate(solvers)]
    )
    X = type(bound0).solve_grouped([s._bound for s in solvers], b_cols)
    return jnp.stack(
        [X[j][s._inv] for j, s in enumerate(solvers)], axis=1
    )


class GroupBank:
    """Device-side bank of one width class's live plans — the serving
    fast path for cross-pattern grouped batches.

    ``grouped_solve`` restacks plan tensors on every call (fine for
    replay/verification); a bank stacks each member ONCE
    (``executor.stack_plan_bank``) and lets every microbatch index its
    lanes inside a single jitted call (``executor.solve_with_bank``) —
    bitwise-identical results, an order of magnitude less per-dispatch
    overhead. Members are keyed by caller-chosen hashable keys (the
    serve layer uses ``(fingerprint, version)``); adding or dropping a
    member invalidates the stack, which is rebuilt lazily on the next
    solve (lane count pads to a power of two, bounding compile churn as
    plan versions come and go).

    Backend-agnostic: the bank dispatches through the ``BoundSolve``
    bank contract (``stack_bank``/``solve_bank``), which every backend
    advertising ``supports_grouped`` must implement — today that is the
    scan backend, the one whose compiled graph is shape-only."""

    def __init__(self):
        self._lock = threading.Lock()
        self._solvers: Dict = {}  # key -> solver; dict order = lane order
        self._index: Dict = {}
        self._bank = None
        self.rebuilds = 0  # telemetry: restacks actually performed

    def __len__(self) -> int:
        with self._lock:
            return len(self._solvers)

    def add(self, key, solver: "TriangularSolver") -> None:
        """Register ``solver`` under ``key`` (idempotent). The solver
        must support grouping and share the bank's width class."""
        if not solver.supports_grouping:
            raise NotImplementedError(
                f"backend {solver.backend!r} does not support width-class "
                "grouped solves"
            )
        with self._lock:
            if key in self._solvers:
                return
            if self._solvers:
                wc0 = next(iter(self._solvers.values())).width_class
                if solver.width_class != wc0:
                    raise ValueError(
                        "GroupBank requires one width class; got "
                        f"{solver.width_class} vs {wc0}"
                    )
            self._solvers[key] = solver
            self._bank = None

    def drop(self, key) -> None:
        with self._lock:
            if self._solvers.pop(key, None) is not None:
                self._bank = None

    def prune(self, keep) -> None:
        """Drop every member whose key fails ``keep(key)`` — the serve
        layer retires lanes of superseded, drained plan versions.
        ``keep`` runs under the bank lock, serialized with concurrent
        ``add``s (callers rely on that for liveness checks)."""
        with self._lock:
            dead = [k for k in self._solvers if not keep(k)]
            for k in dead:
                del self._solvers[k]
            if dead:
                self._bank = None

    def _ensure_locked(self):
        if self._bank is None:
            solvers = list(self._solvers.values())
            cls = type(solvers[0]._bound)
            self._bank = cls.stack_bank(
                [s._bound for s in solvers],
                [s._perm for s in solvers],
                [s._inv for s in solvers],
            )
            self._bound_cls = cls
            self._index = {k: i for i, k in enumerate(self._solvers)}
            self.rebuilds += 1
        return self._bound_cls, self._bank, self._index

    def solve(self, keys, B) -> jnp.ndarray:
        """Solve column j of ``B`` f[n, m] (caller row order) against
        the member registered under ``keys[j]``; returns x f[n, m].
        Bitwise-identical to ``grouped_solve`` on the same members
        (property-tested), so ``GroupReplay`` remains the replay
        reference for bank-served results."""
        with self._lock:
            cls, bank, index = self._ensure_locked()
            lane_idx = np.fromiter(
                (index[k] for k in keys), np.int32, count=len(keys)
            )
        return cls.solve_bank(bank, lane_idx, B)

    def solve_resident(self, keys, B_res) -> jnp.ndarray:
        """One continuous-mode dispatch pass: solve column j of the
        *device-resident* ``B_res`` f[n, S] against the member under
        ``keys[j]`` — bitwise-identical to :meth:`solve` on the same
        keys (``BoundSolve.solve_resident`` delegates to the same banked
        kernel), but ``B_res`` never re-uploads: the continuous serve
        engine (``repro.serve.slots``) mutates it slot-by-slot with
        ``insert_lane`` and keeps it on device across passes."""
        with self._lock:
            cls, bank, index = self._ensure_locked()
            lane_idx = np.fromiter(
                (index[k] for k in keys), np.int32, count=len(keys)
            )
        return cls.solve_resident(bank, lane_idx, B_res)

    def describe(self) -> dict:
        with self._lock:
            return {
                "n_lanes": len(self._solvers),
                "rebuilds": self.rebuilds,
            }


def factor_pair(lf: CSRMatrix, *, cache: Optional[PlanCache] = None, **kw):
    """Plan the (L, L^T) solver pair of a factorization: ``fwd`` solves
    ``Lf y = b``, ``bwd`` solves ``Lf^T x = y`` — together an application of
    ``(Lf Lf^T)^{-1}``, PCG's preconditioner."""
    fwd = TriangularSolver.plan(lf, lower=True, cache=cache, **kw)
    bwd = TriangularSolver.plan(transpose_csr(lf), lower=False, cache=cache, **kw)
    return fwd, bwd
