"""Schedule race detector — independent BSP validity check (paper Def. 2.1).

The dependency edge set is re-derived directly from the raw CSR arrays
of the lower-triangular matrix (an entry ``L[v, u]`` with ``u < v`` is
the edge ``u -> v``), NOT from ``sparse.dag`` — the DAG builder is part
of the pipeline under audit.  For every edge ``u -> v`` a valid BSP
schedule must satisfy:

  * ``sigma(u) <= sigma(v)``                       (no backward edge);
  * ``pi(u) != pi(v)  =>  sigma(u) < sigma(v)``    (cross-core values
    only travel through a superstep barrier — same-step cross-core is a
    race);
  * same (superstep, core): ``rank(u) < rank(v)``  (in-chain sequential
    order must respect the dependency).

``verify_reorder`` audits the §5 reordering: the permutation is a
bijection, the post-reorder schedule is exactly the pre-reorder one
relabeled through it, and new vertex ids are nondecreasing in
(superstep, core, rank) order — the property the executor's slot
layout relies on.

Levels: ``fast`` keeps the O(n) screen (sizes, core/superstep bounds,
reorder bijection and monotone order); ``full`` adds the O(nnz) edge
sweep (backward edges, cross-core races, chain order), the rank
collision census and the relabel pullback.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.analysis.findings import Finding, finding

CHECK = "schedule"


def strict_lower_edges(L) -> tuple:
    """Dependency edges (u, v) from raw CSR arrays: one per strictly
    lower-triangular entry L[v, u]."""
    indptr = np.asarray(L.indptr, dtype=np.int64)
    indices = np.asarray(L.indices, dtype=np.int64)
    n = len(indptr) - 1
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    mask = indices < rows
    return indices[mask], rows[mask]  # u (source), v (target)


def verify_schedule(L, sched, *, level: str = "full") -> List[Finding]:
    """Race-detect ``sched`` against the matrix it claims to schedule."""
    out: List[Finding] = []
    n = len(np.asarray(L.indptr)) - 1
    pi = np.asarray(sched.pi)
    sigma = np.asarray(sched.sigma)
    rank = np.asarray(sched.rank)
    k = int(sched.k)
    S = int(sched.n_supersteps)

    if not (len(pi) == len(sigma) == len(rank) == n):
        out.append(finding(
            CHECK, "SCHED_SIZE",
            f"schedule arrays cover {len(pi)}/{len(sigma)}/{len(rank)} "
            f"vertices, matrix has {n} rows",
        ))
        return out
    if n and (pi.min() < 0 or pi.max() >= k):
        bad = (pi < 0) | (pi >= k)
        out.append(finding(
            CHECK, "SCHED_CORE_OOB",
            f"{int(bad.sum())} vertices assigned to cores outside "
            f"[0, {k})",
        ))
    if n and (sigma.min() < 0 or sigma.max() >= S):
        bad = (sigma < 0) | (sigma >= S)
        out.append(finding(
            CHECK, "SCHED_STEP_OOB",
            f"{int(bad.sum())} vertices assigned to supersteps outside "
            f"[0, {S})",
        ))
    if out or level != "full":
        return out

    u, v = strict_lower_edges(L)
    su, sv = sigma[u], sigma[v]
    back = su > sv
    if back.any():
        i = np.nonzero(back)[0][0]
        out.append(finding(
            CHECK, "SCHED_EDGE_BACKWARD",
            f"{int(back.sum())} dependency edges point to an earlier "
            f"superstep (e.g. {int(u[i])}@{int(su[i])} -> "
            f"{int(v[i])}@{int(sv[i])})",
        ))
    cross_race = (su == sv) & (pi[u] != pi[v])
    if cross_race.any():
        i = np.nonzero(cross_race)[0][0]
        out.append(finding(
            CHECK, "SCHED_RACE_CROSS_CORE",
            f"{int(cross_race.sum())} cross-core edges inside one "
            f"superstep (e.g. {int(u[i])} on core {int(pi[u[i]])} -> "
            f"{int(v[i])} on core {int(pi[v[i]])} in superstep "
            f"{int(su[i])})",
        ))
    chain = (su == sv) & (pi[u] == pi[v])
    chain_bad = chain & (rank[u] >= rank[v])
    if chain_bad.any():
        i = np.nonzero(chain_bad)[0][0]
        out.append(finding(
            CHECK, "SCHED_CHAIN_ORDER",
            f"{int(chain_bad.sum())} same-chain edges with "
            f"rank(u) >= rank(v) (e.g. {int(u[i])} rank "
            f"{int(rank[u[i]])} -> {int(v[i])} rank {int(rank[v[i]])})",
        ))
    # duplicate (superstep, core, rank) triples leave chain order to the
    # sort's tiebreak — deterministic with a stable sort, but fragile
    key = (sigma.astype(np.int64) * k + pi) * (
        int(rank.max()) + 2 if n else 1
    ) + rank
    if n and len(np.unique(key)) != n:
        out.append(finding(
            CHECK, "SCHED_RANK_COLLISION",
            "two vertices share (superstep, core, rank); chain order "
            "falls back to the sort tiebreak", severity="warn",
        ))
    return out


def verify_reorder(
    perm: np.ndarray,
    sched_after,
    sched_before=None,
    *,
    level: str = "full",
) -> List[Finding]:
    """Audit the §5 reorder permutation against the relabeled schedule.

    ``perm`` maps new vertex id -> old vertex id (``schedule_order``'s
    convention: position i of the lexsorted order).  ``sched_after`` is
    the post-reorder schedule; ``sched_before``, when given, must equal
    ``sched_after`` pulled back through the permutation.
    """
    out: List[Finding] = []
    perm = np.asarray(perm)
    n = len(perm)
    bijective = True
    if n:
        if int(perm.min()) < 0 or int(perm.max()) >= n:
            bijective = False
        else:
            seen = np.zeros(n, dtype=bool)
            seen[perm] = True
            bijective = bool(seen.all())
    if not bijective:
        counts = np.bincount(
            np.clip(perm, 0, n - 1).astype(np.int64), minlength=n
        )
        out.append(finding(
            "reorder", "REORDER_NOT_BIJECTION",
            f"permutation over {n} vertices is not a bijection "
            f"({int((counts != 1).sum())} ids repeated or missing)",
        ))
        return out
    sig = np.asarray(sched_after.sigma)
    pi = np.asarray(sched_after.pi)
    rank = np.asarray(sched_after.rank)
    if len(sig) != n:
        out.append(finding(
            "reorder", "REORDER_SIZE",
            f"permutation covers {n} vertices, schedule {len(sig)}",
        ))
        return out
    if n > 1:
        ds, dp, dr = np.diff(sig), np.diff(pi), np.diff(rank)
        eq = ds == 0
        viol = (ds < 0) | (eq & (dp < 0)) | (eq & (dp == 0) & (dr < 0))
        if viol.any():
            i = int(np.nonzero(viol)[0][0])
            out.append(finding(
                "reorder", "REORDER_ORDER_MISMATCH",
                f"relabeled vertex ids are not sorted by (superstep, "
                f"core, rank): first violation between new ids {i} and "
                f"{i + 1}",
            ))
    if sched_before is not None and level == "full":
        sb = np.asarray(sched_before.sigma, dtype=np.int64)
        pb = np.asarray(sched_before.pi, dtype=np.int64)
        rb = np.asarray(sched_before.rank, dtype=np.int64)
        if len(sb) != n:
            out.append(finding(
                "reorder", "REORDER_SIZE",
                f"pre-reorder schedule covers {len(sb)} vertices, "
                f"permutation {n}",
            ))
        elif (
            (sb[perm] != sig).any() or (pb[perm] != pi).any()
            or (rb[perm] != rank).any()
        ):
            out.append(finding(
                "reorder", "REORDER_RELABEL_MISMATCH",
                "post-reorder schedule is not the pre-reorder schedule "
                "relabeled through the permutation",
            ))
    return out


def verify_schedule_report(
    L, sched, perm: Optional[np.ndarray] = None, *, level: str = "full",
):
    """Convenience wrapper returning a findings list for (matrix,
    schedule) plus an optional reorder permutation of a *separate*
    original schedule."""
    out = verify_schedule(L, sched, level=level)
    if perm is not None:
        out.extend(verify_reorder(perm, sched, level=level))
    return out
