"""``repro.analysis`` — independent static verification of inspector
artifacts, plus a determinism lint for executor source.

Four passes, all re-implemented from first principles (no code shared
with ``core/plan.py`` / ``core/elastic.py`` / ``core/rowshard.py``, so
a compiler bug cannot self-certify):

  * **schedule** (``schedule_check``) — BSP validity (Def. 2.1) against
    edges re-derived from the raw CSR arrays, §5 reorder bijection and
    (superstep, core, rank) order;
  * **plan** (``plan_check``) — ``ExecPlan`` tensor audit: bounds,
    padding inertness, write-once-before-read, accum-chain ordering,
    scratch containment, value provenance, lane-layout agreement;
  * **elastic** (``elastic_check``) — slack-certificate soundness:
    readiness never underestimated, waves dependency-free, accum
    carries break waves, fused runs respect cross-core readiness;
  * **rowshard** (``rowshard_check``) — halo tables cover exactly the
    re-derived cross-shard edge set, writer-round < reader-round, ring
    and psum forms consistent, local plans are faithful remaps.

Plus the AST ``lint`` (``LINT_NONDET_REDUCTION`` /
``LINT_JIT_MUTABLE_CAPTURE`` — the PR 9 bug class) and a mutation
harness (``analysis.mutate``) whose seeded corruptions double as the
verifier's own false-negative test.

Entry points: ``TriangularSolver.plan(validate="fast"|"full")`` (or the
``REPRO_VALIDATE`` env var) verifies at build time;
``python -m repro.launch.check`` sweeps the corpus;
``python -m repro.analysis.lint`` runs the source lint.

Levels: ``"fast"`` is the O(n) structural screen that rides along on
every build — tensor geometry and bounds, padding inertness, writer
bijection, reorder bijection + monotone order, lane/superstep layout
agreement (bounded at <= 15% of ``compile_plan`` time,
``benchmarks/check_overhead.py``).  ``"full"`` adds the O(nnz) proofs:
edge race detection, scratch containment, accum chains,
read-after-write, value provenance, load accounting and per-shard
local plan audits — the depth the CI sweep and the mutation harness
run at.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import numpy as np

from repro.analysis.elastic_check import true_ready_steps, verify_elastic
from repro.analysis.findings import (
    Finding,
    Report,
    VerificationError,
    finding,
)
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.plan_check import (
    packed_writers,
    plan_writers,
    verify_exec_plan,
    verify_lane_layout,
)
from repro.analysis.rowshard_check import verify_rowshard
from repro.analysis.schedule_check import verify_reorder, verify_schedule

__all__ = [
    "Artifacts",
    "Finding",
    "Report",
    "VerificationError",
    "VALIDATE_LEVELS",
    "finding",
    "lint_paths",
    "lint_source",
    "plan_writers",
    "resolve_level",
    "true_ready_steps",
    "verify_artifacts",
    "verify_elastic",
    "verify_exec_plan",
    "verify_lane_layout",
    "verify_reorder",
    "verify_rowshard",
    "verify_rowshard_report",
    "verify_schedule",
]

VALIDATE_LEVELS = ("off", "fast", "full")


def resolve_level(validate: Optional[str] = None) -> str:
    """Normalize a ``validate=`` argument: explicit value wins, then the
    ``REPRO_VALIDATE`` env var, then ``"off"``."""
    if validate is None:
        validate = os.environ.get("REPRO_VALIDATE", "") or "off"
    level = str(validate).lower()
    if level not in VALIDATE_LEVELS:
        raise ValueError(
            f"validate must be one of {VALIDATE_LEVELS}; got {validate!r}"
        )
    return level


@dataclasses.dataclass
class Artifacts:
    """One inspector run's verifiable artifacts.

    L          lower-triangular CSRMatrix the plan solves (post-reorder)
    sched      the (post-reorder) Schedule the plan was compiled from
    plan       the compiled ExecPlan
    perm       §5 reorder permutation (new id -> old id), optional
    sched_pre  pre-reorder Schedule (checked against perm), optional
    elastic    ElasticPlan certificate, optional (falls back to
               ``plan.elastic``)
    rowshard   RowShardPlan partition, optional
    """

    L: object
    sched: object
    plan: object
    perm: Optional[np.ndarray] = None
    sched_pre: object = None
    elastic: object = None
    rowshard: object = None


def verify_artifacts(art: Artifacts, *, level: str = "fast") -> Report:
    """Run every applicable pass over ``art``; returns the full report
    (``.raise_if_failed()`` to gate)."""
    from repro import obs

    level = resolve_level(level)
    rep = Report()
    if level == "off":
        return rep
    n = int(art.plan.n) if art.plan is not None else 0
    with obs.span(
        "analysis.verify", cat="analysis", level=level, n=n
    ) as sp:
        if art.sched is not None and art.L is not None:
            rep.extend("schedule", verify_schedule(
                art.L, art.sched, level=level
            ))
        if art.perm is not None:
            rep.extend("reorder", verify_reorder(
                art.perm, art.sched, art.sched_pre, level=level
            ))
        if art.plan is not None:
            # one writer derivation shared by the plan and lane passes
            writers = None
            rid = np.asarray(art.plan.row_ids)
            acc = np.asarray(art.plan.accum)
            if rid.ndim == 2 and rid.shape == acc.shape:
                writers = packed_writers(rid, acc, int(art.plan.n))
            plan_found = verify_exec_plan(
                art.plan, art.L, level=level, writers=writers
            )
            rep.extend("plan", plan_found)
            if art.sched is not None:
                rep.extend("plan", verify_lane_layout(
                    art.plan, art.sched, level=level, writers=writers
                ))
            # certificates are judged against the plan; once the plan
            # itself is corrupt their findings would only cascade
            plan_ok = not any(f.severity == "error" for f in plan_found)
            ep = art.elastic
            if ep is None:
                ep = getattr(art.plan, "elastic", None)
            if ep is not None and plan_ok:
                rep.extend("elastic", verify_elastic(
                    art.plan, ep, level=level
                ))
            if art.rowshard is not None and plan_ok:
                rep.extend("rowshard", verify_rowshard(
                    art.plan, art.rowshard, level=level
                ))
        sp.set(findings=len(rep.findings), ok=rep.ok)
    obs.counter_add("analysis.verifications", 1)
    if rep.findings:
        obs.counter_add("analysis.findings", len(rep.findings))
    return rep


def verify_rowshard_report(plan, rsp, *, level: str = "fast") -> Report:
    """Rowshard-only report — the post-bind hook for sharded solves
    (the partition is produced at backend bind time)."""
    from repro import obs

    level = resolve_level(level)
    rep = Report()
    if level == "off":
        return rep
    with obs.span(
        "analysis.verify.rowshard", cat="analysis", level=level,
        n=int(plan.n),
    ) as sp:
        rep.extend("rowshard", verify_rowshard(plan, rsp, level=level))
        sp.set(findings=len(rep.findings), ok=rep.ok)
    obs.counter_add("analysis.verifications", 1)
    if rep.findings:
        obs.counter_add("analysis.findings", len(rep.findings))
    return rep
