"""Determinism lint — AST rules over executor/kernel source.

The PR 9 bug class, machine-checked: bitwise determinism of the solve
depends on every lane reduction being a *fixed-order* left-to-right
fold (``for w: acc = acc + v[:, w] * x[cols[:, w]]``).  Library
reductions (``einsum`` / ``jnp.sum`` / ``dot`` / ...) let XLA
reassociate the adds, so the same row can produce 1-ulp-different
results at different lane widths (k=8 vs a k_local=1 shard) — exactly
the drift that broke the sharded conformance grid before PR 9 fixed it
by hand.  Jitted functions that close over *mutable module state* are
the other half of the class: the first trace bakes the state in, later
host mutations silently diverge from device behavior.

Rules (scoped to ``src/repro/solver/`` and ``src/repro/kernels/``):

  * ``LINT_NONDET_REDUCTION`` — a call to a known reassociating
    reduction (``einsum``, ``sum``, ``dot``, ``matmul``, ``vdot``,
    ``inner``, ``tensordot``, ``prod``, ``psum``) on a numeric module
    (``jnp``/``np``/``lax``/``jax.numpy``/``jax.lax``) or as an array
    method.
  * ``LINT_JIT_MUTABLE_CAPTURE`` — a jitted function whose free names
    resolve to module-level mutable bindings (container literals,
    rebound module names, ``global``-mutated names).

Blessing: a reduction that is *proven* safe (validated against a
fixed-order oracle, or deliberately outside the bitwise contract like
the sparse-psum exchange) carries a pragma comment on its line or the
line above::

    acc = jnp.sum(v * g, axis=-1)  # repro: blessed-reduction — <why>

``# repro: blessed-capture`` plays the same role for rule 2.  The lint
never blesses implicitly — every escape is a visible, grep-able pragma.

Run standalone: ``python -m repro.analysis.lint [paths...]``.
"""
from __future__ import annotations

import ast
import builtins
import os
import sys
from typing import Iterable, List, Sequence

from repro.analysis.findings import Finding, finding

CHECK = "lint"

REDUCTION_NAMES = frozenset({
    "einsum", "sum", "dot", "matmul", "vdot", "inner", "tensordot",
    "prod", "psum",
})
NUMERIC_MODULES = frozenset({"jnp", "np", "numpy", "lax"})
MUTABLE_CALLS = frozenset({
    "dict", "list", "set", "bytearray", "defaultdict", "deque",
    "OrderedDict", "Counter",
})
PRAGMA_REDUCTION = "repro: blessed-reduction"
PRAGMA_CAPTURE = "repro: blessed-capture"
_BUILTINS = frozenset(dir(builtins))


def default_lint_roots() -> List[str]:
    """The executor surface the determinism contract covers."""
    # two levels up from this file: src/repro (repro itself is a
    # namespace package, so repro.__file__ is None)
    pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return [os.path.join(pkg, "solver"), os.path.join(pkg, "kernels")]


def _blessed(lines: Sequence[str], node: ast.AST, pragma: str) -> bool:
    """Pragma on any line the node spans, or anywhere in the contiguous
    comment block directly above it (multi-line justifications).  For
    decorated defs the block sits above the *first decorator*, which is
    where a human writes it."""
    lo = min(
        [node.lineno]
        + [d.lineno for d in getattr(node, "decorator_list", [])]
    )
    hi = getattr(node, "end_lineno", node.lineno)
    if any(pragma in ln for ln in lines[lo - 1:hi]):
        return True
    i = lo - 2  # 0-based index of the line above
    while i >= 0 and lines[i].lstrip().startswith("#"):
        if pragma in lines[i]:
            return True
        i -= 1
    return False


def _is_numeric_base(node: ast.expr) -> bool:
    if isinstance(node, ast.Name):
        return node.id in NUMERIC_MODULES
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
        # jax.numpy / jax.lax / scipy-style dotted modules
        return node.value.id == "jax" and node.attr in ("numpy", "lax")
    return False


def _is_jit_expr(node: ast.expr) -> bool:
    """``jax.jit`` / ``jit`` / ``partial(jax.jit, ...)`` /
    ``functools.partial(jax.jit, ...)``."""
    if isinstance(node, ast.Name) and node.id == "jit":
        return True
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    if isinstance(node, ast.Call):
        f = node.func
        is_partial = (
            (isinstance(f, ast.Name) and f.id == "partial")
            or (isinstance(f, ast.Attribute) and f.attr == "partial")
        )
        if is_partial:
            return any(_is_jit_expr(a) for a in node.args)
    return False


class _ModuleScan(ast.NodeVisitor):
    """Module-level binding census: which names are mutable state."""

    def __init__(self) -> None:
        self.assign_count: dict = {}
        self.mutable: set = set()
        self.global_mutated: set = set()

    def _record(self, name: str, value: ast.expr | None) -> None:
        self.assign_count[name] = self.assign_count.get(name, 0) + 1
        if value is not None and self._is_mutable_value(value):
            self.mutable.add(name)

    @staticmethod
    def _is_mutable_value(v: ast.expr) -> bool:
        if isinstance(v, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
            return True
        if isinstance(v, ast.Call):
            f = v.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None
            )
            return name in MUTABLE_CALLS
        return False

    def scan(self, tree: ast.Module) -> None:
        for node in tree.body:  # module level only
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        self._record(t.id, node.value)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name
            ):
                self._record(node.target.id, node.value)
            elif isinstance(node, ast.AugAssign) and isinstance(
                node.target, ast.Name
            ):
                self._record(node.target.id, None)
        # names mutated through `global` anywhere in the module
        for node in ast.walk(tree):
            if isinstance(node, ast.Global):
                self.global_mutated.update(node.names)

    def mutable_names(self) -> set:
        rebound = {n for n, c in self.assign_count.items() if c > 1}
        return self.mutable | rebound | self.global_mutated


def _free_names(fn: ast.AST) -> set:
    """Names a function loads but never binds (args, stores, nested
    defs).  Approximate lexical scoping: one binding set for the whole
    subtree — good enough to resolve module-level captures."""
    bound: set = set()
    loads: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Load):
                loads.add(node.id)
            else:
                bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(node.name)
            a = node.args
            for arg in (
                list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
                + ([a.vararg] if a.vararg else [])
                + ([a.kwarg] if a.kwarg else [])
            ):
                bound.add(arg.arg)
        elif isinstance(node, ast.Lambda):
            a = node.args
            for arg in (
                list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
                + ([a.vararg] if a.vararg else [])
                + ([a.kwarg] if a.kwarg else [])
            ):
                bound.add(arg.arg)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                bound.add((alias.asname or alias.name).split(".")[0])
    return loads - bound - _BUILTINS


def lint_source(src: str, filename: str = "<string>") -> List[Finding]:
    """Lint one source string; returns findings (empty = clean)."""
    out: List[Finding] = []
    try:
        tree = ast.parse(src, filename=filename)
    except SyntaxError as e:
        out.append(finding(
            CHECK, "LINT_SYNTAX", f"cannot parse: {e}",
            file=filename, line=e.lineno or 0,
        ))
        return out
    lines = src.splitlines()

    # rule 1: reassociating reductions
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        attr = node.func.attr
        if attr not in REDUCTION_NAMES:
            continue
        base = node.func.value
        is_module = _is_numeric_base(base)
        # array-method form (`x.sum(...)`) — same reassociation hazard;
        # restricted to the unambiguous reduction names to avoid
        # flagging unrelated objects' methods
        is_method = not is_module and attr in (
            "sum", "dot", "matmul", "prod",
        )
        if not (is_module or is_method):
            continue
        if _blessed(lines, node, PRAGMA_REDUCTION):
            continue
        out.append(finding(
            CHECK, "LINT_NONDET_REDUCTION",
            f"{filename}:{node.lineno}: `{attr}` reduction may "
            "reassociate across lanes — use a fixed-order fold or "
            f"bless with `# {PRAGMA_REDUCTION}`",
            file=filename, line=node.lineno,
        ))

    # rule 2: jitted functions over mutable module state
    scan = _ModuleScan()
    scan.scan(tree)
    mutable = scan.mutable_names()
    if mutable:
        jitted: List[ast.AST] = []
        fn_defs = {
            n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_jit_expr(d) for d in node.decorator_list):
                    jitted.append(node)
            elif (
                isinstance(node, ast.Call) and _is_jit_expr(node.func)
                and node.args and isinstance(node.args[0], ast.Name)
                and node.args[0].id in fn_defs
            ):
                jitted.append(fn_defs[node.args[0].id])
        seen: set = set()
        for fn in jitted:
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            captured = sorted(_free_names(fn) & mutable)
            if not captured:
                continue
            if _blessed(lines, fn, PRAGMA_CAPTURE):
                continue
            out.append(finding(
                CHECK, "LINT_JIT_MUTABLE_CAPTURE",
                f"{filename}:{fn.lineno}: jitted "
                f"`{getattr(fn, 'name', '<fn>')}` closes over mutable "
                f"module state {', '.join(captured)} — the first trace "
                "bakes it in; pass it as an argument or bless with "
                f"`# {PRAGMA_CAPTURE}`",
                file=filename, line=fn.lineno,
            ))
    return out


def lint_paths(paths: Iterable[str] | None = None) -> List[Finding]:
    """Lint every ``.py`` file under ``paths`` (files or directories);
    defaults to the solver + kernels trees."""
    roots = list(paths) if paths else default_lint_roots()
    files: List[str] = []
    for root in roots:
        if os.path.isfile(root):
            files.append(root)
            continue
        for dirpath, _, names in sorted(os.walk(root)):
            files.extend(
                os.path.join(dirpath, f)
                for f in sorted(names) if f.endswith(".py")
            )
    out: List[Finding] = []
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), filename=f))
    return out


def main(argv: Sequence[str] | None = None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="determinism lint over executor/kernel source",
    )
    p.add_argument(
        "paths", nargs="*",
        help="files/directories to lint (default: solver + kernels)",
    )
    args = p.parse_args(argv)
    found = lint_paths(args.paths or None)
    for f in found:
        print(f"{f.code}  {f.message}")
    n_files = len(args.paths) if args.paths else 2
    print(
        f"determinism lint: {len(found)} finding(s) over "
        f"{n_files} root(s)"
    )
    return 1 if found else 0


if __name__ == "__main__":
    sys.exit(main())
