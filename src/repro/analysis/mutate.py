"""Mutation harness — seeded corruptions that MUST fail verification.

A verifier's dangerous failure mode is silence: it runs, reports
nothing, and everyone trusts a plan it never actually checked.  The
harness closes that hole by construction — each operator injects one
realistic bug (the kind a scheduler/compiler/partitioner regression
would produce) into an otherwise-valid artifact set, and the tier-1
suite asserts ``verify_artifacts`` flags **every** mutant while the
pristine artifacts stay clean.

Operators never touch producer code: they corrupt the *artifacts*
(schedule arrays, plan tensors, certificates, halo tables), exactly
where a buggy producer would have left the damage.  An operator may
return ``None`` when the artifact set has no site for its bug (e.g. no
accum chains at wide W, one shard); the runner treats that as "not
applicable", and the harness setup guarantees every family has at least
one applicable artifact set.

Usage::

    arts = build_artifacts(matrix, strategy="growlocal", k=8,
                           slack=4, n_shards=4)
    for m in MUTATIONS:
        bad = m.apply(arts, np.random.default_rng(0))
        assert bad is None or not verify_artifacts(bad, level="full").ok
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.analysis import Artifacts

__all__ = [
    "MUTATIONS",
    "Mutation",
    "build_artifacts",
    "run_harness",
]


def build_artifacts(
    a,
    *,
    strategy: str = "growlocal",
    k: int = 8,
    lower: bool = True,
    slack: int = 0,
    n_shards: int = 1,
    width: Optional[int] = None,
    dtype=np.float32,
) -> Artifacts:
    """Run the inspector pipeline on matrix ``a`` and keep every
    intermediate artifact (the pipeline's ``plan()`` discards the
    pre-rebase state the verifier wants).  Mirrors ``pipeline.solver``'s
    build: mirror -> DAG -> schedule -> §5 reorder -> compile ->
    elastic -> rowshard.  Host-side only — no backend is bound, so
    sharded artifacts need no mesh."""
    from repro.core.elastic import elastic_transform
    from repro.core.plan import compile_plan
    from repro.core.reorder import apply_reordering
    from repro.core.rowshard import partition_plan
    from repro.pipeline.registry import ScheduleOptions, get_scheduler
    from repro.pipeline.solver import mirror_to_lower
    from repro.sparse.dag import dag_from_lower_csr

    m0, _ = mirror_to_lower(a, lower)
    dag = dag_from_lower_csr(m0)
    o = ScheduleOptions(k=k, slack=slack)
    s = get_scheduler(strategy)(dag, o)
    m2, s2, _, r = apply_reordering(m0, s)
    plan = compile_plan(m2, s2, width=width, dtype=dtype)
    ep = None
    if slack > 0:
        ep = elastic_transform(plan, slack)
        plan.elastic = ep
    rsp = None
    if n_shards > 1:
        rsp = partition_plan(
            plan, n_shards,
            exchange_bounds=None if ep is None else ep.fused_bounds,
        )
    return Artifacts(
        L=m2, sched=s2, plan=plan, perm=r.perm, sched_pre=s,
        elastic=ep, rowshard=rsp,
    )


# -- copy helpers (operators must never alias the pristine artifacts) -------

def _copy_sched(s):
    return dataclasses.replace(
        s, pi=np.array(s.pi), sigma=np.array(s.sigma), rank=np.array(s.rank)
    )


def _copy_plan(p):
    q = dataclasses.replace(
        p,
        row_ids=np.array(p.row_ids),
        col_idx=np.array(p.col_idx),
        vals=np.array(p.vals),
        diag=np.array(p.diag),
        accum=np.array(p.accum),
        step_bounds=np.array(p.step_bounds),
        val_src=None if p.val_src is None else np.array(p.val_src),
        diag_src=None if p.diag_src is None else np.array(p.diag_src),
    )
    q.elastic = p.elastic
    return q


def _with_sched(art: Artifacts, s2) -> Artifacts:
    return dataclasses.replace(art, sched=s2)


def _with_plan(art: Artifacts, p) -> Artifacts:
    return dataclasses.replace(art, plan=p)


# -- schedule family --------------------------------------------------------

def schedule_swap_steps(art: Artifacts, rng) -> Optional[Artifacts]:
    """Move a dependent vertex to its producer's superstep on another
    core — the classic barrier-elision race."""
    from repro.analysis.schedule_check import strict_lower_edges

    s = _copy_sched(art.sched)
    u, v = strict_lower_edges(art.L)
    cross = (s.pi[u] != s.pi[v]) & (s.sigma[u] < s.sigma[v])
    if not cross.any():
        return None
    i = int(rng.choice(np.nonzero(cross)[0]))
    s.sigma[v[i]] = s.sigma[u[i]]
    return _with_sched(art, s)


def schedule_backward_edge(art: Artifacts, rng) -> Optional[Artifacts]:
    """Schedule a consumer strictly before its producer."""
    from repro.analysis.schedule_check import strict_lower_edges

    s = _copy_sched(art.sched)
    u, v = strict_lower_edges(art.L)
    fwd = s.sigma[u] < s.sigma[v]
    if not fwd.any():
        return None
    i = int(rng.choice(np.nonzero(fwd)[0]))
    s.sigma[v[i]] = s.sigma[u[i]] - 1
    if s.sigma[v[i]] < 0:
        s.sigma[u[i]] += 1
        s.sigma[v[i]] += 1
    return _with_sched(art, s)


def schedule_chain_rank_flip(art: Artifacts, rng) -> Optional[Artifacts]:
    """Reverse in-chain rank across a same-(step, core) dependency."""
    from repro.analysis.schedule_check import strict_lower_edges

    s = _copy_sched(art.sched)
    u, v = strict_lower_edges(art.L)
    chain = (s.pi[u] == s.pi[v]) & (s.sigma[u] == s.sigma[v])
    if not chain.any():
        return None
    i = int(rng.choice(np.nonzero(chain)[0]))
    s.rank[u[i]], s.rank[v[i]] = s.rank[v[i]], int(s.rank[u[i]])
    return _with_sched(art, s)


def reorder_collide(art: Artifacts, rng) -> Optional[Artifacts]:
    """Duplicate an id in the §5 permutation (a broken argsort)."""
    if art.perm is None or len(art.perm) < 2:
        return None
    perm = np.array(art.perm)
    perm[0] = perm[1]
    return dataclasses.replace(art, perm=perm)


# -- plan family ------------------------------------------------------------

def plan_swap_rows(art: Artifacts, rng) -> Optional[Artifacts]:
    """Swap two finalizing slots across supersteps — rows finish in the
    wrong step, breaking both write discipline and the lane layout."""
    p = _copy_plan(art.plan)
    sb = np.asarray(p.step_bounds)
    final = (p.row_ids != p.n) & ~p.accum
    t, lane = np.nonzero(final)
    if len(t) < 2:
        return None
    sup = np.searchsorted(sb, t, side="right") - 1
    first = (t == t.min()) if (sup == sup[0]).all() else (sup == sup.min())
    a = int(np.nonzero(first)[0][0])
    b = int(np.nonzero(~first)[0][-1]) if (~first).any() else -1
    if b < 0:
        return None
    (ta, la), (tb, lb) = (t[a], lane[a]), (t[b], lane[b])
    ra, rb = int(p.row_ids[ta, la]), int(p.row_ids[tb, lb])
    p.row_ids[ta, la], p.row_ids[tb, lb] = rb, ra
    return _with_plan(art, p)


def plan_oob_gather(art: Artifacts, rng) -> Optional[Artifacts]:
    """Point one real gather past the scratch slot."""
    p = _copy_plan(art.plan)
    real = p.val_src is not None and (np.asarray(p.val_src) >= 0)
    if not np.any(real):
        return None
    t, lane, w = (int(x[0]) for x in np.nonzero(real))
    p.col_idx[t, lane, w] = p.n + 5
    return _with_plan(art, p)


def plan_double_write(art: Artifacts, rng) -> Optional[Artifacts]:
    """A padding slot claims a row some other slot already finalizes."""
    p = _copy_plan(art.plan)
    pad = p.row_ids == p.n
    if not pad.any():
        return None
    t, lane = (int(x[0]) for x in np.nonzero(pad))
    real = p.row_ids[p.row_ids != p.n]
    if not len(real):
        return None
    p.row_ids[t, lane] = int(real[0])
    return _with_plan(art, p)


def plan_corrupt_padding(art: Artifacts, rng) -> Optional[Artifacts]:
    """Nonzero values on a padding slot — inert lanes start contributing."""
    p = _copy_plan(art.plan)
    pad = p.row_ids == p.n
    if not pad.any():
        return None
    t, lane = (int(x[0]) for x in np.nonzero(pad))
    p.vals[t, lane, :] = 1.0
    return _with_plan(art, p)


def plan_scratch_escape(art: Artifacts, rng) -> Optional[Artifacts]:
    """A real slot's scratch-padded gather gets a nonzero coefficient —
    the scratch slot's transient garbage leaks into the solve."""
    p = _copy_plan(art.plan)
    if p.val_src is None:
        return None
    realrow = p.row_ids != p.n
    scratch = (np.asarray(p.col_idx) == p.n) & realrow[:, :, None]
    if not scratch.any():
        return None
    t, lane, w = (int(x[0]) for x in np.nonzero(scratch))
    p.vals[t, lane, w] = 0.5
    return _with_plan(art, p)


def plan_accum_reorder(art: Artifacts, rng) -> Optional[Artifacts]:
    """Flip a split row's accum flags so the chain finalizes first and
    accumulates afterwards — the partial sums are lost."""
    p = _copy_plan(art.plan)
    acc = np.asarray(p.accum)
    if not acc.any():
        return None
    t, lane = (int(x[0]) for x in np.nonzero(acc))
    row = int(p.row_ids[t, lane])
    chain = np.nonzero((p.row_ids == row).any(axis=1))[0]
    last = int(chain[-1])
    lane_last = int(np.nonzero(p.row_ids[last] == row)[0][0])
    p.accum[t, lane] = False
    p.accum[last, lane_last] = True
    return _with_plan(art, p)


def plan_zero_diag(art: Artifacts, rng) -> Optional[Artifacts]:
    """Zero diagonal on a finalizing slot — a guaranteed NaN/Inf row."""
    p = _copy_plan(art.plan)
    final = (p.row_ids != p.n) & ~p.accum
    if not final.any():
        return None
    t, lane = (int(x[0]) for x in np.nonzero(final))
    p.diag[t, lane] = 0.0
    return _with_plan(art, p)


# -- elastic family ---------------------------------------------------------

def _copy_elastic(ep):
    return dataclasses.replace(
        ep,
        ready_step=np.array(ep.ready_step),
        wave_id=np.array(ep.wave_id),
        n_waves=np.array(ep.n_waves),
        fused_bounds=np.array(ep.fused_bounds),
    )


def _with_elastic(art: Artifacts, ep) -> Artifacts:
    p = _copy_plan(art.plan)
    p.elastic = ep
    return dataclasses.replace(art, plan=p, elastic=ep)


def elastic_widen_wave(art: Artifacts, rng) -> Optional[Artifacts]:
    """Fuse a dependent step into its producer's wave (erase the first
    wave break of some window)."""
    if art.elastic is None:
        return None
    ep = _copy_elastic(art.elastic)
    wave = ep.wave_id
    brk = np.nonzero(np.diff(wave, axis=1) == 1)
    if not len(brk[0]):
        return None
    m, j = int(brk[0][0]), int(brk[1][0])
    wave[m, j + 1:] -= 1
    ep = dataclasses.replace(ep, n_waves=wave[:, -1] + 1)
    return _with_elastic(art, ep)


def elastic_shrink_ready(art: Artifacts, rng) -> Optional[Artifacts]:
    """Certify a step ready one plan-step early."""
    if art.elastic is None:
        return None
    ep = _copy_elastic(art.elastic)
    pos = np.nonzero(ep.ready_step > 0)[0]
    if not len(pos):
        return None
    ep.ready_step[int(pos[0])] -= 1
    return _with_elastic(art, ep)


def elastic_widen_fused_run(art: Artifacts, rng) -> Optional[Artifacts]:
    """Drop an interior fused-bounds barrier — either a cross-core read
    lands inside its own run, or the run exceeds the slack cap."""
    if art.elastic is None or len(art.elastic.fused_bounds) < 3:
        return None
    ep = _copy_elastic(art.elastic)
    fb = np.delete(ep.fused_bounds, 1)
    ep = dataclasses.replace(ep, fused_bounds=fb)
    return _with_elastic(art, ep)


# -- rowshard family --------------------------------------------------------

def _copy_rsp(rsp):
    rounds = []
    for rd in rsp.rounds:
        rounds.append(dataclasses.replace(
            rd,
            hops=tuple(
                (h, np.array(ss), np.array(rt)) for h, ss, rt in rd.hops
            ),
            send_slot=np.array(rd.send_slot),
            send_pos=np.array(rd.send_pos),
            recv_pos=np.array(rd.recv_pos),
            recv_slot=np.array(rd.recv_slot),
        ))
    return dataclasses.replace(
        rsp,
        shards=list(rsp.shards),
        owner=np.array(rsp.owner),
        local_slot=np.array(rsp.local_slot),
        rounds=rounds,
    )


def _with_rsp(art: Artifacts, rsp) -> Artifacts:
    return dataclasses.replace(art, rowshard=rsp)


def _first_psum_round(rsp):
    for i, rd in enumerate(rsp.rounds):
        realR = np.asarray(rd.recv_slot) != rsp.scratch
        if realR.any():
            return i, realR
    return None, None


def rowshard_drop_halo(art: Artifacts, rng) -> Optional[Artifacts]:
    """Silence one shipment in both lowered forms — a consumer's halo
    slot never receives its boundary value."""
    if art.rowshard is None:
        return None
    rsp = _copy_rsp(art.rowshard)
    i, realR = _first_psum_round(rsp)
    if i is None:
        return None
    rd = rsp.rounds[i]
    d, p_ = (int(x[0]) for x in np.nonzero(realR))
    slot = int(rd.recv_slot[d, p_])
    rd.recv_slot[d, p_] = rsp.scratch
    rd.recv_pos[d, p_] = int(rd.buf_size)
    for h, ss, rt in rd.hops:
        hit = rt[d] == slot
        if hit.any():
            rt[d, np.nonzero(hit)[0]] = rsp.scratch
            src = (d - h) % rsp.n_shards
            ss[src, np.nonzero(hit)[0]] = rsp.scratch
    return _with_rsp(art, rsp)


def rowshard_wrong_round(art: Artifacts, rng) -> Optional[Artifacts]:
    """Swap two occupied exchange rounds' tables — the later round's
    values now ship before the round that writes them."""
    if art.rowshard is None or len(art.rowshard.rounds) < 2:
        return None
    rsp = _copy_rsp(art.rowshard)
    occ = [
        i for i, rd in enumerate(rsp.rounds)
        if (np.asarray(rd.recv_slot) != rsp.scratch).any()
    ]
    if len(occ) < 2:
        return None
    i, j = occ[0], occ[1]
    rsp.rounds[i], rsp.rounds[j] = rsp.rounds[j], rsp.rounds[i]
    return _with_rsp(art, rsp)


def rowshard_wrong_slot(art: Artifacts, rng) -> Optional[Artifacts]:
    """Rotate one psum recv slot inside the halo region — the consumer's
    gathers now read a different boundary row's value."""
    if art.rowshard is None or art.rowshard.n_halo < 1:
        return None
    rsp = _copy_rsp(art.rowshard)
    i, realR = _first_psum_round(rsp)
    if i is None:
        return None
    rd = rsp.rounds[i]
    d, p_ = (int(x[0]) for x in np.nonzero(realR))
    n_loc, n_halo = rsp.n_loc, rsp.n_halo
    slot = int(rd.recv_slot[d, p_])
    rot = n_loc + (slot - n_loc + 1) % n_halo
    if rot == slot:
        return None
    rd.recv_slot[d, p_] = rot
    return _with_rsp(art, rsp)


def rowshard_owner_flip(art: Artifacts, rng) -> Optional[Artifacts]:
    """Assign one row to a shard whose lanes never finalize it."""
    if art.rowshard is None or art.rowshard.n_shards < 2:
        return None
    rsp = _copy_rsp(art.rowshard)
    rsp.owner[0] = (int(rsp.owner[0]) + 1) % rsp.n_shards
    return _with_rsp(art, rsp)


@dataclasses.dataclass(frozen=True)
class Mutation:
    name: str
    family: str  # schedule | plan | elastic | rowshard
    apply: Callable[[Artifacts, np.random.Generator], Optional[Artifacts]]


MUTATIONS: Tuple[Mutation, ...] = tuple(
    Mutation(fn.__name__, family, fn)
    for family, fns in (
        ("schedule", (
            schedule_swap_steps, schedule_backward_edge,
            schedule_chain_rank_flip, reorder_collide,
        )),
        ("plan", (
            plan_swap_rows, plan_oob_gather, plan_double_write,
            plan_corrupt_padding, plan_scratch_escape,
            plan_accum_reorder, plan_zero_diag,
        )),
        ("elastic", (
            elastic_widen_wave, elastic_shrink_ready,
            elastic_widen_fused_run,
        )),
        ("rowshard", (
            rowshard_drop_halo, rowshard_wrong_round,
            rowshard_wrong_slot, rowshard_owner_flip,
        )),
    )
    for fn in fns
)


def run_harness(
    artifact_sets: List[Tuple[str, Artifacts]], *, seed: int = 0
) -> List[dict]:
    """Apply every mutation to every artifact set; one record per
    (mutation, set) with the verifier's verdict.  ``caught`` is None
    where the operator found no site (not applicable)."""
    from repro.analysis import verify_artifacts

    rows: List[dict] = []
    for m in MUTATIONS:
        for label, art in artifact_sets:
            rng = np.random.default_rng(seed)
            bad = m.apply(art, rng)
            caught = None
            codes: Tuple[str, ...] = ()
            if bad is not None:
                rep = verify_artifacts(bad, level="full")
                caught = not rep.ok
                codes = rep.codes()
            rows.append({
                "mutation": m.name,
                "family": m.family,
                "artifacts": label,
                "caught": caught,
                "codes": list(codes),
            })
    return rows
