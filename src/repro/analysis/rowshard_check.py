"""Rowshard checker — independent audit of ``RowShardPlan`` artifacts.

The cross-shard edge set is re-derived from the *global* ``ExecPlan``
(writer lanes + gather columns), never from the partitioner's own
tables, and the halo tables are then judged against it:

  * ownership — ``owner`` / ``local_slot`` match the writer-lane block
    partition and the (owner, global id) slot ordering the executor's
    ``b_scatter`` / ``x_gather`` maps rely on;
  * certificate — every cross-shard value is finalized in a strictly
    earlier exchange round than every read of it (re-derived
    writer-round < reader-round);
  * coverage — the halo tables ship *exactly* the cross-shard pair set:
    each (boundary row, consumer shard) pair exactly once, in a round
    at or after the writer's and strictly before the first reader's, in
    both lowered forms (ring and sparse-psum);
  * slot soundness — halo slots stay inside ``[n_loc, n_loc+n_halo)``,
    distinct boundary rows of one consumer never share a slot, ring and
    psum forms agree positionally, and padding stays on scratch;
  * locality — each shard-local plan is exactly the global plan's lane
    block remapped through (ownership + halo assignment); full level
    additionally audits every local plan with the plan sanitizer and
    compares the numeric tensors.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.analysis.findings import Finding, finding
from repro.analysis.plan_check import plan_writers, verify_exec_plan

CHECK = "rowshard"


def _pairs_from_plan(plan, owner_true, kl, round_of_sup, sup_of_step):
    """Cross-shard (row, consumer shard) pairs and first-reader rounds,
    derived from the global plan's gathers alone."""
    n = int(plan.n)
    col_idx = np.asarray(plan.col_idx).astype(np.int64)
    T, k, W = col_idx.shape
    lane3 = np.broadcast_to(
        np.arange(k, dtype=np.int64)[None, :, None], col_idx.shape
    )
    reader_shard = lane3 // kl
    owner_pad = np.concatenate(
        [owner_true, np.asarray([-1], dtype=np.int64)]
    )
    real = col_idx < n
    cross = real & (owner_pad[np.minimum(col_idx, n)] != reader_shard)
    u_all = col_idx[cross]
    d_all = reader_shard[cross]
    t3 = np.broadcast_to(
        np.arange(T, dtype=np.int64)[:, None, None], col_idx.shape
    )
    r_round = round_of_sup[sup_of_step[t3[cross]]]
    return u_all, d_all, r_round


def verify_rowshard(plan, rsp, *, level: str = "fast") -> List[Finding]:
    """Audit ``rsp`` (a ``RowShardPlan``) against the global ``plan`` it
    was partitioned from."""
    out: List[Finding] = []
    n = int(plan.n)
    ns, kl = int(rsp.n_shards), int(rsp.k_local)
    kp = ns * kl

    # ---- geometry -----------------------------------------------------
    if (
        int(rsp.n) != n or int(rsp.W) != int(plan.W)
        or int(rsp.T) != int(plan.n_steps) or kp < int(plan.k)
    ):
        out.append(finding(
            CHECK, "RS_GEOMETRY",
            f"partition geometry disagrees with the plan: n={rsp.n}/{n} "
            f"W={rsp.W}/{plan.W} T={rsp.T}/{plan.n_steps} "
            f"k={kp}(={ns}x{kl}) vs {plan.k}",
        ))
        return out
    sb = np.asarray(plan.step_bounds, dtype=np.int64)
    S = len(sb) - 1
    if tuple(int(x) for x in rsp.step_bounds) != tuple(int(x) for x in sb):
        out.append(finding(
            CHECK, "RS_GEOMETRY",
            "partition step_bounds differ from the plan's",
        ))
        return out
    fb = np.asarray(rsp.exchange_bounds, dtype=np.int64)
    if len(fb) < 2 or fb[0] != 0 or fb[-1] != S or (np.diff(fb) < 1).any():
        out.append(finding(
            CHECK, "RS_EXCHANGE_BOUNDS",
            f"exchange_bounds is not a strictly increasing superstep "
            f"cover of [0, {S}]: {fb.tolist()}",
        ))
        return out
    F = len(fb) - 1
    if len(rsp.rounds) != max(F - 1, 0):
        out.append(finding(
            CHECK, "RS_ROUND_COUNT",
            f"{len(rsp.rounds)} exchange rounds for {F} compute rounds "
            f"(expected {max(F - 1, 0)})",
        ))
        return out

    # ---- ownership (independent writer-lane derivation) ---------------
    w_step, w_lane, w_count = plan_writers(
        np.asarray(plan.row_ids), np.asarray(plan.accum), n
    )
    if (w_count != 1).any():
        out.append(finding(
            CHECK, "RS_PLAN_WRITERS",
            f"{int((w_count != 1).sum())} rows not finalized exactly "
            "once by the global plan — ownership is undefined "
            "(see the plan sanitizer findings)",
        ))
        return out
    owner_true = w_lane // kl
    if (np.asarray(rsp.owner, dtype=np.int64) != owner_true).any():
        bad = int((np.asarray(rsp.owner, np.int64) != owner_true).sum())
        out.append(finding(
            CHECK, "RS_OWNER_MISMATCH",
            f"{bad} rows assigned to a shard other than the one whose "
            "lane block finalizes them",
        ))
    counts = np.bincount(owner_true, minlength=ns)
    offs = np.concatenate([[0], np.cumsum(counts)])
    order = np.argsort(owner_true, kind="stable")
    ls_true = np.empty(n, dtype=np.int64)
    ls_true[order] = (
        np.arange(n, dtype=np.int64) - offs[owner_true[order]]
    )
    if (np.asarray(rsp.local_slot, dtype=np.int64) != ls_true).any():
        bad = int((np.asarray(rsp.local_slot, np.int64) != ls_true).sum())
        out.append(finding(
            CHECK, "RS_SLOT_MISMATCH",
            f"{bad} rows at a local slot that breaks the (owner, global "
            "id) ordering b_scatter/x_gather assume",
        ))
    if int(rsp.n_loc) != max(int(counts.max()), 1):
        out.append(finding(
            CHECK, "RS_GEOMETRY",
            f"n_loc={rsp.n_loc} but the largest shard owns "
            f"{int(counts.max())} rows",
        ))
    if out:
        return out  # the maps below would cascade misleading findings

    n_loc, n_halo = int(rsp.n_loc), int(rsp.n_halo)
    scratch = n_loc + n_halo

    # ---- cross-shard pair set + certificate ---------------------------
    round_of_sup = np.repeat(np.arange(F, dtype=np.int64), np.diff(fb))
    sup_of_step = np.repeat(np.arange(S, dtype=np.int64), np.diff(sb))
    writer_round = round_of_sup[sup_of_step[w_step]]
    u_all, d_all, r_rounds = _pairs_from_plan(
        plan, owner_true, kl, round_of_sup, sup_of_step
    )
    key = u_all * ns + d_all
    ukey, inv = (
        np.unique(key, return_inverse=True)
        if key.size else (np.zeros(0, np.int64), np.zeros(0, np.int64))
    )
    P = len(ukey)
    u_h, dst_h = ukey // ns, ukey % ns
    min_rd = np.full(P, F, dtype=np.int64)
    if key.size:
        np.minimum.at(min_rd, inv, r_rounds)
    wr_pair = writer_round[u_h] if P else np.zeros(0, np.int64)
    bad = wr_pair >= min_rd
    if bad.any():
        g = int(u_h[bad][0])
        out.append(finding(
            CHECK, "RS_CERT_VIOLATION",
            f"{int(bad.sum())} boundary rows are read across shards in "
            f"or before the exchange round that writes them (e.g. row "
            f"{g}: written round {int(writer_round[g])}, first read "
            f"round {int(min_rd[bad][0])})",
        ))
    if int(rsp.halo_pairs) != P:
        out.append(finding(
            CHECK, "RS_HALO_COUNT",
            f"partition claims {int(rsp.halo_pairs)} halo pairs, the "
            f"plan's cross-shard edge set has {P}",
        ))

    # ---- halo table audit (both lowered forms) ------------------------
    glob_of = np.full((ns, max(n_loc, 1)), -1, dtype=np.int64)
    glob_of[owner_true, ls_true] = np.arange(n, dtype=np.int64)

    ship_cnt = np.zeros(P, dtype=np.int64)  # psum shipments per pair
    ship_slot = np.full(P, -1, dtype=np.int64)
    ring_cnt = np.zeros(P, dtype=np.int64)
    ring_slot = np.full(P, -1, dtype=np.int64)

    def pair_lookup(rows, dsts, form, r):
        """Map shipped (row, dst) to pair ids; flag pairs the plan's
        edge set does not contain."""
        pk = rows * ns + dsts
        j = np.searchsorted(ukey, pk)
        ok = (j < P) & (ukey[np.minimum(j, max(P - 1, 0))] == pk) if P \
            else np.zeros(len(pk), dtype=bool)
        if (~ok).any():
            out.append(finding(
                CHECK, "RS_HALO_EXTRA",
                f"round {r} {form} tables ship {int((~ok).sum())} "
                "(row, shard) pairs outside the cross-shard edge set",
            ))
        return j, ok

    def check_timing(j, r, form):
        early = r < wr_pair[j]
        if early.any():
            out.append(finding(
                CHECK, "RS_HALO_EARLY",
                f"round {r} {form} tables ship {int(early.sum())} rows "
                "before the round that finalizes them (stale value)",
            ))
        late = r >= min_rd[j]
        if late.any():
            out.append(finding(
                CHECK, "RS_HALO_LATE",
                f"round {r} {form} tables ship {int(late.sum())} rows "
                "at or after their first cross-shard read",
            ))

    for r, rd in enumerate(rsp.rounds):
        # -- sparse-psum form: send side builds the pos -> row map
        ss = np.asarray(rd.send_slot, dtype=np.int64)
        sp_ = np.asarray(rd.send_pos, dtype=np.int64)
        src_row = np.broadcast_to(
            np.arange(ns, dtype=np.int64)[:, None], ss.shape
        )
        realS = ss != scratch
        R = int(rd.buf_size)
        pos_row = np.full(R, -1, dtype=np.int64)
        if ((ss[realS] < 0) | (ss[realS] >= n_loc)).any():
            out.append(finding(
                CHECK, "RS_SEND_SLOT",
                f"round {r} psum send slots outside the owned region",
            ))
        else:
            su = glob_of[src_row[realS], ss[realS]]
            sposes = sp_[realS]
            if (su < 0).any():
                out.append(finding(
                    CHECK, "RS_SEND_NOT_OWNED",
                    f"round {r} psum send slots name unoccupied owned "
                    "slots",
                ))
            elif ((sposes < 0) | (sposes >= R)).any():
                out.append(finding(
                    CHECK, "RS_PSUM_SEND",
                    f"round {r} psum send positions outside the "
                    f"boundary buffer [0, {R})",
                ))
            else:
                occupied = np.bincount(sposes, minlength=R)
                if (occupied > 1).any() or (occupied == 0).any():
                    out.append(finding(
                        CHECK, "RS_PSUM_SEND",
                        f"round {r} psum buffer positions not covered "
                        "exactly once by senders "
                        f"({int((occupied != 1).sum())} positions)",
                    ))
                pos_row[sposes] = su

        # -- sparse-psum form: recv side ships pairs
        rs_ = np.asarray(rd.recv_slot, dtype=np.int64)
        rp = np.asarray(rd.recv_pos, dtype=np.int64)
        dst_row = np.broadcast_to(
            np.arange(ns, dtype=np.int64)[:, None], rs_.shape
        )
        realR = rs_ != scratch
        if ((rs_[realR] < n_loc) | (rs_[realR] >= scratch)).any():
            out.append(finding(
                CHECK, "RS_HALO_SLOT_RANGE",
                f"round {r} psum recv slots outside the halo region "
                f"[{n_loc}, {scratch})",
            ))
        elif ((rp[realR] < 0) | (rp[realR] >= R)).any() or (
            R and (pos_row[rp[realR]] < 0).any()
        ):
            out.append(finding(
                CHECK, "RS_PSUM_RECV",
                f"round {r} psum recv positions unmapped in the "
                "boundary buffer",
            ))
        else:
            ru = pos_row[rp[realR]]
            j, ok = pair_lookup(ru, dst_row[realR], "psum", r)
            jv = j[ok]
            np.add.at(ship_cnt, jv, 1)
            ship_slot[jv] = rs_[realR][ok]
            check_timing(jv, r, "psum")

        # -- ring form: positional correspondence per hop
        for (h, hss, hrt) in rd.hops:
            hss = np.asarray(hss, dtype=np.int64)
            hrt = np.asarray(hrt, dtype=np.int64)
            if hss.shape != hrt.shape:
                out.append(finding(
                    CHECK, "RS_RING_SHAPE",
                    f"round {r} hop {h}: send/recv tables have "
                    "different shapes",
                ))
                continue
            rows_i = np.broadcast_to(
                np.arange(ns, dtype=np.int64)[:, None], hss.shape
            )
            cols_p = np.broadcast_to(
                np.arange(hss.shape[1], dtype=np.int64)[None, :],
                hss.shape,
            )
            realH = hss != scratch
            # receiver entries aligned to each sender position
            rt_at = hrt[(rows_i + h) % ns, cols_p]
            pad_mismatch = realH != (rt_at != scratch)
            if pad_mismatch.any():
                out.append(finding(
                    CHECK, "RS_RING_PAD",
                    f"round {r} hop {h}: {int(pad_mismatch.sum())} "
                    "positions padded on one side only",
                ))
            hm = realH & (rt_at != scratch)
            if ((hss[hm] < 0) | (hss[hm] >= n_loc)).any():
                out.append(finding(
                    CHECK, "RS_SEND_SLOT",
                    f"round {r} hop {h}: ring send slots outside the "
                    "owned region",
                ))
                continue
            hu = glob_of[rows_i[hm], hss[hm]]
            if (hu < 0).any():
                out.append(finding(
                    CHECK, "RS_SEND_NOT_OWNED",
                    f"round {r} hop {h}: ring send slots name "
                    "unoccupied owned slots",
                ))
                continue
            hdst = (rows_i[hm] + h) % ns
            hslot = rt_at[hm]
            if ((hslot < n_loc) | (hslot >= scratch)).any():
                out.append(finding(
                    CHECK, "RS_HALO_SLOT_RANGE",
                    f"round {r} hop {h}: ring recv slots outside the "
                    f"halo region [{n_loc}, {scratch})",
                ))
                continue
            j, ok = pair_lookup(hu, hdst, "ring", r)
            jv = j[ok]
            np.add.at(ring_cnt, jv, 1)
            ring_slot[jv] = hslot[ok]
            check_timing(jv, r, "ring")

    for name, cnt in (("psum", ship_cnt), ("ring", ring_cnt)):
        if (cnt == 0).any():
            rows = u_h[cnt == 0][:4]
            out.append(finding(
                CHECK, "RS_HALO_MISSING",
                f"{int((cnt == 0).sum())} cross-shard pairs never "
                f"shipped by the {name} tables (e.g. rows "
                f"{', '.join(str(int(x)) for x in rows)})",
            ))
        if (cnt > 1).any():
            out.append(finding(
                CHECK, "RS_HALO_DUP",
                f"{int((cnt > 1).sum())} cross-shard pairs shipped more "
                f"than once by the {name} tables",
            ))

    both = (ship_slot >= 0) & (ring_slot >= 0)
    if (ship_slot[both] != ring_slot[both]).any():
        out.append(finding(
            CHECK, "RS_RING_MISALIGNED",
            f"{int((ship_slot[both] != ring_slot[both]).sum())} pairs "
            "land on different halo slots in ring vs psum form",
        ))
    # one halo slot per (consumer, boundary row): distinct rows of one
    # consumer must not share a slot, or a later arrival overwrites an
    # earlier value that is still being read
    halo_slot = np.where(ship_slot >= 0, ship_slot, ring_slot)
    have = halo_slot >= 0
    if have.any():
        skey = dst_h[have] * (scratch + 1) + halo_slot[have]
        if len(np.unique(skey)) != int(have.sum()):
            out.append(finding(
                CHECK, "RS_HALO_SLOT_CLASH",
                "two boundary rows of one consumer shard share a halo "
                "slot",
            ))

    # ---- local plans: global lane blocks remapped through the halo map
    out.extend(_verify_local_plans(
        plan, rsp, owner_true, ls_true, u_h, dst_h, halo_slot, level=level
    ))
    return out


def _verify_local_plans(
    plan, rsp, owner_true, ls_true, u_h, dst_h, halo_slot, *, level: str
) -> List[Finding]:
    """Each shard's local plan must be the global plan's lane block with
    rows/cols remapped through (ownership + the tables' halo slots)."""
    out: List[Finding] = []
    n = int(plan.n)
    ns, kl = int(rsp.n_shards), int(rsp.k_local)
    kp, k = ns * kl, int(plan.k)
    T = int(plan.n_steps)
    n_loc, n_halo = int(rsp.n_loc), int(rsp.n_halo)
    scratch = n_loc + n_halo

    g2l = np.full((ns, n + 1), scratch, dtype=np.int64)
    g2l[owner_true, np.arange(n)] = ls_true
    have = halo_slot >= 0
    g2l[dst_h[have], u_h[have]] = halo_slot[have]

    def padk(a, fill):
        if kp == k:
            return np.asarray(a)
        a = np.asarray(a)
        block = np.full((T, kp - k, *a.shape[2:]), fill, dtype=a.dtype)
        return np.concatenate([a, block], axis=1)

    # clip into the g2l domain: an out-of-range id (a corrupt plan — the
    # plan sanitizer owns that finding) lands on scratch instead of
    # crashing the remap comparison
    rows_p = np.clip(padk(plan.row_ids, n), 0, n)
    cols_p = np.clip(padk(plan.col_idx, n), 0, n)
    if level == "full":
        vals_p = padk(plan.vals, 0)
        diag_p = padk(plan.diag, 1)
        acc_p = padk(plan.accum, False)

    for j, sp in enumerate(rsp.shards):
        lanes = slice(j * kl, (j + 1) * kl)
        if (
            int(sp.n) != scratch or int(sp.k) != kl
            or int(sp.W) != int(plan.W) or int(sp.n_steps) != T
        ):
            out.append(finding(
                CHECK, "RS_LOCAL_GEOMETRY",
                f"shard {j} local plan geometry disagrees with the "
                f"partition (n={sp.n}/{scratch}, k={sp.k}/{kl})",
            ))
            continue
        exp_rows = g2l[j, rows_p[:, lanes]]
        if (np.asarray(sp.row_ids, np.int64) != exp_rows).any():
            out.append(finding(
                CHECK, "RS_LOCAL_ROWS",
                f"shard {j} local row slots differ from the remapped "
                "global lane block",
            ))
        exp_cols = g2l[j, cols_p[:, lanes]]
        if (np.asarray(sp.col_idx, np.int64) != exp_cols).any():
            out.append(finding(
                CHECK, "RS_LOCAL_COLS",
                f"shard {j} local gather slots differ from the "
                "ownership + halo-table remap",
            ))
        if level == "full":
            num_ok = (
                np.array_equal(np.asarray(sp.vals), vals_p[:, lanes])
                and np.array_equal(np.asarray(sp.diag), diag_p[:, lanes])
                and np.array_equal(np.asarray(sp.accum), acc_p[:, lanes])
            )
            if not num_ok:
                out.append(finding(
                    CHECK, "RS_LOCAL_NUMERIC",
                    f"shard {j} numeric tensors differ bitwise from the "
                    "global plan's lane block",
                ))
            for f in verify_exec_plan(
                sp, None, level="fast", expect_coverage=False,
            ):
                out.append(dataclasses.replace(
                    f, where=f.where + (("shard", str(j)),)
                ))
    return out
