"""Plan sanitizer — independent audit of ``ExecPlan`` tensors.

Everything here is re-derived from first principles (raw CSR arrays and
the plan tensors themselves); nothing is imported from
``core/plan.py``'s compilation logic, so a compiler bug cannot
self-certify.  The pass proves, for a plan claiming to solve ``Lx = b``:

fast level (the O(n) structural screen, bounded at <= 15% of
``compile_plan`` time — ``benchmarks/check_overhead.py``):

  * geometry — tensor shapes agree, ``step_bounds`` is a monotone cover
    of ``[0, T]``;
  * bounds — every ``row_ids`` / ``col_idx`` / ``val_src`` / ``diag_src``
    index is inside its target array (min/max reductions; the violating
    slots are only materialized when a bound actually breaks);
  * padding inertness — a padding slot (``row_ids == n``) carries
    exactly the inert tuple (scratch gathers, zero vals, unit diag, no
    accum, no sources), so it can never perturb ``x``;
  * write discipline — every row is finalized exactly once, and every
    final write divides by a nonzero diagonal.

full level adds the O(nnz) elementwise proofs:

  * scratch containment — a scratch-directed gather in a real row is
    inert (zero value, no source), so scratch never escapes into ``x``;
  * accum chains — same-lane consecutive steps ending in their single
    final write, never crossing a superstep barrier;
  * read-after-write — every real gather reads a row finalized at a
    strictly earlier step (scratch reads excluded);
  * value provenance — ``vals`` / ``diag`` / ``col_idx`` are exactly
    the matrix entries named by ``val_src`` / ``diag_src``, and the
    source maps cover each off-diagonal entry exactly once.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.analysis.findings import Finding, finding

CHECK = "plan"


def _ex(idx: np.ndarray, limit: int = 4) -> str:
    """Format the first few flat indices of a violation mask."""
    flat = np.asarray(idx).ravel()[:limit]
    return ", ".join(str(int(i)) for i in flat)


def _final_slots(
    row_ids: np.ndarray, accum: np.ndarray, n: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Flat indices of final (non-accum, real) slots and the rows they
    finalize."""
    flat = row_ids.ravel()
    fi = np.flatnonzero((flat >= 0) & (flat < n) & ~accum.ravel())
    return fi, np.take(flat, fi)


def packed_writers(
    row_ids: np.ndarray, accum: np.ndarray, n: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Packed writer derivation: ``w_pack[j] = step * k + lane`` of row
    ``j``'s final write (a final slot's flat index IS that packed
    coordinate), ``-1`` for rows never finalized; plus the written-row
    mask and the total count of final slots.  ``n_final >
    have.sum()`` means some row was finalized more than once (the last
    scatter wins, matching the executor's last-write semantics)."""
    fi, rows = _final_slots(row_ids, accum, n)
    w_pack = np.full(n, -1, dtype=np.int64)
    w_pack[rows] = fi
    return w_pack, w_pack >= 0, len(fi)


def plan_writers(
    row_ids: np.ndarray, accum: np.ndarray, n: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Independent writer derivation: for each row, the (step, lane) of
    its final (non-accum) virtual row, plus how many final writes it
    received.  ``-1`` marks rows never finalized."""
    T, k = row_ids.shape
    w_pack, have, _ = packed_writers(row_ids, accum, n)
    w_step = w_pack // k  # floor division keeps -1 at -1
    w_lane = np.where(have, w_pack % k, -1)
    _, rows = _final_slots(row_ids, accum, n)
    w_count = np.bincount(
        rows.astype(np.int64), minlength=n
    )[:n] if n else np.zeros(0, dtype=np.int64)
    return w_step, w_lane, w_count


def verify_exec_plan(
    plan,
    L=None,
    *,
    level: str = "fast",
    expect_coverage: bool = True,
    writers: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
) -> List[Finding]:
    """Audit ``plan`` (an ``ExecPlan``-shaped object).  ``L`` is the
    CSR matrix the plan was compiled from (required for the full-level
    value-provenance checks; fast-level works without it).

    ``expect_coverage=False`` relaxes the every-row-finalized and
    entry-coverage requirements — shard-local plans only own a subset
    of rows (halo slots are written by the exchange, not the plan).
    ``writers`` accepts a precomputed ``packed_writers`` triple so one
    derivation can be shared with ``verify_lane_layout``.
    """
    out: List[Finding] = []
    n, k, W = int(plan.n), int(plan.k), int(plan.W)
    row_ids = np.asarray(plan.row_ids)
    col_idx = np.asarray(plan.col_idx)
    vals = np.asarray(plan.vals)
    diag = np.asarray(plan.diag)
    accum = np.asarray(plan.accum)
    sb = np.asarray(plan.step_bounds, dtype=np.int64)
    val_src = None if plan.val_src is None else np.asarray(plan.val_src)
    diag_src = None if plan.diag_src is None else np.asarray(plan.diag_src)

    # ---- geometry -----------------------------------------------------
    T = row_ids.shape[0]
    shapes_ok = (
        row_ids.shape == (T, k)
        and col_idx.shape == (T, k, W)
        and vals.shape == (T, k, W)
        and diag.shape == (T, k)
        and accum.shape == (T, k)
        and (val_src is None or val_src.shape == (T, k, W))
        and (diag_src is None or diag_src.shape == (T, k))
    )
    if not shapes_ok:
        out.append(finding(
            CHECK, "PLAN_SHAPE",
            f"tensor shapes disagree with (T={T}, k={k}, W={W})",
        ))
        return out  # nothing downstream is meaningful
    if len(sb) < 1 or sb[0] != 0 or sb[-1] != T or (np.diff(sb) < 0).any():
        out.append(finding(
            CHECK, "PLAN_STEP_BOUNDS",
            f"step_bounds is not a monotone cover of [0, {T}]: "
            f"first={sb[0] if len(sb) else '?'}, "
            f"last={sb[-1] if len(sb) else '?'}",
        ))
        return out

    # ---- index bounds (min/max screen; masks only on violation) -------
    if row_ids.size and (row_ids.min() < 0 or row_ids.max() > n):
        bad = (row_ids < 0) | (row_ids > n)
        out.append(finding(
            CHECK, "PLAN_ROW_OOB",
            f"{int(bad.sum())} row_ids outside [0, {n}] "
            f"(slots {_ex(np.nonzero(bad.ravel())[0])})",
        ))
    if col_idx.size and (col_idx.min() < 0 or col_idx.max() > n):
        bad = (col_idx < 0) | (col_idx > n)
        out.append(finding(
            CHECK, "PLAN_COL_OOB",
            f"{int(bad.sum())} col_idx outside [0, {n}] "
            f"(slots {_ex(np.nonzero(bad.ravel())[0])})",
        ))
    nnz = len(L.data) if L is not None else None
    for name, src in (("val_src", val_src), ("diag_src", diag_src)):
        if src is None or not src.size:
            continue
        if src.min() < -1 or (nnz is not None and src.max() >= nnz):
            bad = src < -1
            if nnz is not None:
                bad = bad | (src >= nnz)
            out.append(finding(
                CHECK, "PLAN_SRC_OOB",
                f"{int(bad.sum())} {name} entries outside [-1, nnz) "
                f"(slots {_ex(np.nonzero(bad.ravel())[0])})",
            ))
    if out:
        return out  # out-of-bounds indices poison the gather checks below

    # ---- padding lane inertness --------------------------------------
    pad = row_ids == n
    pidx = np.flatnonzero(pad.ravel())
    if pidx.size:
        # np.take is several times faster than boolean/fancy indexing
        # for these strided row gathers
        p_acc = np.take(accum.ravel(), pidx)
        if p_acc.any():
            out.append(finding(
                CHECK, "PLAN_PAD_ACCUM",
                f"{int(p_acc.sum())} padding slots flagged accum",
            ))
        p_diag = np.take(diag.ravel(), pidx)
        if (p_diag != 1).any():
            out.append(finding(
                CHECK, "PLAN_PAD_DIAG",
                f"{int((p_diag != 1).sum())} padding slots with diag != 1",
            ))
        p_vals = np.take(vals.reshape(-1, W), pidx, axis=0)
        if (p_vals != 0).any():
            out.append(finding(
                CHECK, "PLAN_PAD_VALS",
                f"{int((p_vals != 0).sum())} nonzero vals in padding "
                "slots",
            ))
        p_cols = np.take(col_idx.reshape(-1, W), pidx, axis=0)
        if (p_cols != n).any():
            out.append(finding(
                CHECK, "PLAN_PAD_COLS",
                f"{int((p_cols != n).sum())} padding gathers not aimed "
                "at the scratch slot",
            ))
        if val_src is not None:
            p_src = np.take(val_src.reshape(-1, W), pidx, axis=0)
            if (p_src != -1).any():
                out.append(finding(
                    CHECK, "PLAN_PAD_SRC",
                    f"{int((p_src != -1).sum())} padding slots with live "
                    "val_src",
                ))
        if diag_src is not None:
            p_dsrc = np.take(diag_src.ravel(), pidx)
            if (p_dsrc != -1).any():
                out.append(finding(
                    CHECK, "PLAN_PAD_SRC",
                    f"{int((p_dsrc != -1).sum())} padding slots with "
                    "live diag_src",
                ))

    # ---- write discipline --------------------------------------------
    if writers is None:
        writers = packed_writers(row_ids, accum, n)
    w_pack, have, n_final = writers
    n_written = int(have.sum()) if n else 0
    if expect_coverage and n_written < n:
        out.append(finding(
            CHECK, "PLAN_ROW_UNWRITTEN",
            f"{n - n_written} rows never finalized "
            f"(rows {_ex(np.nonzero(~have)[0])})",
        ))
    if n_final > n_written:
        # slow path only to name the culprits
        _, rows = _final_slots(row_ids, accum, n)
        w_count = np.bincount(rows.astype(np.int64), minlength=n)
        out.append(finding(
            CHECK, "PLAN_DOUBLE_WRITE",
            f"{int((w_count > 1).sum())} rows finalized more than once "
            f"(rows {_ex(np.nonzero(w_count > 1)[0])})",
        ))

    # diagonal of every final write must be nonzero (division)
    if (diag == 0).any():
        zd = (diag == 0) & ~pad & ~accum
        if zd.any():
            out.append(finding(
                CHECK, "PLAN_ZERO_DIAG",
                f"{int(zd.sum())} final rows with zero diagonal",
            ))

    if level != "full":
        return out

    # ---- scratch never escapes (full) --------------------------------
    # a scratch-directed gather in a REAL row must be inert padding:
    # zero value and no source entry feeding it
    real3 = ~pad[:, :, None] & np.ones((1, 1, W), dtype=bool)
    scratch_gather = real3 & (col_idx == n)
    if (vals[scratch_gather] != 0).any():
        out.append(finding(
            CHECK, "PLAN_SCRATCH_VAL",
            f"{int((vals[scratch_gather] != 0).sum())} scratch gathers "
            "carry a nonzero value (scratch contribution escapes into x)",
        ))
    if val_src is not None and (val_src[scratch_gather] != -1).any():
        out.append(finding(
            CHECK, "PLAN_SCRATCH_SRC",
            f"{int((val_src[scratch_gather] != -1).sum())} scratch "
            "gathers wired to a matrix entry (numeric_update would make "
            "scratch escape)",
        ))
    real_gather = real3 & (col_idx < n)
    if val_src is not None and (val_src[real_gather] < 0).any():
        out.append(finding(
            CHECK, "PLAN_SRC_MISSING",
            f"{int((val_src[real_gather] < 0).sum())} real gathers with "
            "no val_src (numeric_update would go stale)",
        ))

    # ---- accum chains (full) -----------------------------------------
    # all slots of one row sit on one lane, on consecutive steps,
    # all-but-last flagged accum, and inside one superstep
    flat_rows = row_ids.ravel().astype(np.int64)
    realf = flat_rows < n
    r_rows = flat_rows[realf]
    r_steps = np.repeat(np.arange(T, dtype=np.int64), k)[realf]
    r_lanes = np.tile(np.arange(k, dtype=np.int64), T)[realf]
    r_accum = accum.ravel()[realf]
    o = np.lexsort((r_steps, r_rows))
    rr, rs, rl, ra = r_rows[o], r_steps[o], r_lanes[o], r_accum[o]
    same = rr[1:] == rr[:-1] if len(rr) > 1 else np.zeros(0, dtype=bool)
    if same.any():
        if ((rl[1:] != rl[:-1]) & same).any():
            out.append(finding(
                CHECK, "PLAN_CHAIN_LANE",
                "accum chain spans multiple lanes (partial sums would "
                "race across cores)",
            ))
        if ((rs[1:] != rs[:-1] + 1) & same).any():
            out.append(finding(
                CHECK, "PLAN_CHAIN_GAP",
                "accum chain steps are not consecutive",
            ))
        if (~ra[:-1] & same).any():
            out.append(finding(
                CHECK, "PLAN_CHAIN_ORDER",
                "non-final virtual row not flagged accum (a later slot "
                "of the same row follows a final write)",
            ))
    # a chain's last slot must be final (rows that are all-accum never
    # produce x); only meaningful when the row was written at all
    last_of_row = np.ones(len(rr), dtype=bool)
    if len(rr) > 1:
        last_of_row[:-1] = ~same
    if (ra[last_of_row]).any():
        out.append(finding(
            CHECK, "PLAN_CHAIN_NO_FINAL",
            f"{int(ra[last_of_row].sum())} rows whose last virtual row "
            "is still accum (x never finalized by the chain)",
        ))
    # chains must not cross a superstep barrier
    if T:
        sup_of_step = np.repeat(
            np.arange(len(sb) - 1, dtype=np.int64), np.diff(sb)
        )
        if same.any() and (
            (sup_of_step[rs[1:]] != sup_of_step[rs[:-1]]) & same
        ).any():
            out.append(finding(
                CHECK, "PLAN_CHAIN_SPANS_BARRIER",
                "accum chain crosses a superstep boundary",
            ))

    # ---- read-after-write (full) -------------------------------------
    # every real gather must read a row finalized at a strictly earlier
    # step; one gather through an extended writer table covers all slots
    # (scratch and unwritten rows map to -1, which no step can precede)
    if T:
        wmap = np.empty(n + 1, dtype=np.int64)
        wmap[:n] = w_pack // k  # unwritten rows stay at -1
        wmap[n] = -1
        early = wmap[col_idx] >= np.arange(T, dtype=np.int64)[:, None, None]
        if early.any():
            out.append(finding(
                CHECK, "PLAN_READ_BEFORE_WRITE",
                f"{int(early.sum())} gathers read a row at or before the "
                f"step that finalizes it (rows {_ex(col_idx[early])})",
            ))
        if expect_coverage and n_written < n:
            unw = np.zeros(n + 1, dtype=bool)
            unw[:n] = ~have
            ru = unw[col_idx] & real_gather
            if ru.any():
                out.append(finding(
                    CHECK, "PLAN_READ_UNWRITTEN",
                    f"{int(ru.sum())} gathers read rows no slot ever "
                    f"finalizes (rows {_ex(col_idx[ru])})",
                ))

    if L is not None:
        out.extend(_verify_values(
            plan, L, real_gather, expect_coverage=expect_coverage,
        ))
    return out


def _verify_values(
    plan, L, real_gather: np.ndarray, *, expect_coverage: bool
) -> List[Finding]:
    """Full-level value provenance: the plan's numeric content is exactly
    the matrix entries its source maps name, and those maps tile the
    matrix (each off-diagonal entry once, each diagonal entry once)."""
    out: List[Finding] = []
    n = int(plan.n)
    indptr = np.asarray(L.indptr, dtype=np.int64)
    indices = np.asarray(L.indices, dtype=np.int64)
    data = np.asarray(L.data)
    # row of each entry, derived from indptr alone
    erow = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    val_src = None if plan.val_src is None else np.asarray(plan.val_src)
    diag_src = None if plan.diag_src is None else np.asarray(plan.diag_src)
    vals = np.asarray(plan.vals)
    diag = np.asarray(plan.diag)
    row_ids = np.asarray(plan.row_ids)
    col_idx = np.asarray(plan.col_idx)

    if val_src is not None:
        src = val_src[real_gather]
        live = src >= 0
        s = src[live]
        rows3 = np.broadcast_to(row_ids[:, :, None], col_idx.shape)
        if (indices[s] != col_idx[real_gather][live]).any():
            out.append(finding(
                CHECK, "PLAN_SRC_COL_MISMATCH",
                "val_src names entries whose column differs from col_idx",
            ))
        if (erow[s] != rows3[real_gather][live]).any():
            out.append(finding(
                CHECK, "PLAN_SRC_ROW_MISMATCH",
                "val_src names entries from a different row than the slot",
            ))
        mism = vals[real_gather][live] != data[s].astype(vals.dtype)
        if mism.any():
            out.append(finding(
                CHECK, "PLAN_VALUE_MISMATCH",
                f"{int(mism.sum())} vals differ bitwise from the matrix "
                "entries val_src names",
            ))
        # off-diagonal coverage: each off-diag entry sourced exactly once
        off_ids = np.nonzero(indices != erow)[0]
        cnt = np.bincount(s, minlength=len(data)) if len(data) else (
            np.zeros(0, dtype=np.int64)
        )
        if len(data):
            dup = cnt[off_ids] > 1
            if dup.any():
                out.append(finding(
                    CHECK, "PLAN_ENTRY_DUP",
                    f"{int(dup.sum())} off-diagonal entries sourced more "
                    "than once",
                ))
            miss = cnt[off_ids] == 0
            if expect_coverage and miss.any():
                out.append(finding(
                    CHECK, "PLAN_ENTRY_MISSING",
                    f"{int(miss.sum())} off-diagonal entries never enter "
                    "the plan",
                ))
            on_diag = cnt[np.nonzero(indices == erow)[0]] > 0
            if on_diag.any():
                out.append(finding(
                    CHECK, "PLAN_ENTRY_DIAG_AS_OFF",
                    f"{int(on_diag.sum())} diagonal entries wired as "
                    "off-diagonal gathers",
                ))
    if diag_src is not None:
        live = diag_src >= 0
        s = diag_src[live].astype(np.int64)
        if len(s):
            if (indices[s] != erow[s]).any():
                out.append(finding(
                    CHECK, "PLAN_DIAG_SRC_OFFDIAG",
                    "diag_src names off-diagonal entries",
                ))
            if (erow[s] != row_ids[live].astype(np.int64)).any():
                out.append(finding(
                    CHECK, "PLAN_DIAG_SRC_ROW",
                    "diag_src names a different row's diagonal",
                ))
            mism = diag[live] != data[s].astype(diag.dtype)
            if mism.any():
                out.append(finding(
                    CHECK, "PLAN_DIAG_MISMATCH",
                    f"{int(mism.sum())} diag values differ bitwise from "
                    "the entries diag_src names",
                ))
    return out


def verify_lane_layout(
    plan,
    sched,
    *,
    level: str = "fast",
    writers: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
) -> List[Finding]:
    """Cross-check plan layout against the schedule that produced it:
    each vertex's slots sit on its assigned core, inside its assigned
    superstep, and (full level) per-(superstep, core) chain loads equal
    the schedule's expansion ``sum(ceil(off_nnz/W))`` with step counts
    equal to the max core load.  ``writers`` accepts a precomputed
    ``packed_writers`` triple shared with ``verify_exec_plan``."""
    out: List[Finding] = []
    n, k = int(plan.n), int(plan.k)
    row_ids = np.asarray(plan.row_ids)
    accum = np.asarray(plan.accum)
    sb = np.asarray(plan.step_bounds, dtype=np.int64)
    T = row_ids.shape[0]
    pi = np.asarray(sched.pi)
    sigma = np.asarray(sched.sigma)
    if len(pi) != n or len(sigma) != n:
        out.append(finding(
            CHECK, "PLAN_SCHED_SIZE",
            f"schedule covers {len(pi)} vertices, plan has n={n}",
        ))
        return out
    if int(sched.k) != k:
        out.append(finding(
            CHECK, "PLAN_SCHED_K",
            f"schedule k={int(sched.k)} != plan k={k}",
        ))
        return out
    if len(sb) - 1 != int(sched.n_supersteps):
        out.append(finding(
            CHECK, "PLAN_SUPERSTEP_COUNT",
            f"plan has {len(sb) - 1} supersteps, schedule claims "
            f"{int(sched.n_supersteps)}",
        ))
        return out

    if writers is None:
        writers = packed_writers(row_ids, accum, n)
    w_pack, have, _ = writers
    # the common case is full coverage — skip the compressions then
    if bool(have.all()):
        wp, piv, sigv = w_pack, pi, sigma
    else:
        wp, piv, sigv = w_pack[have], pi[have], sigma[have]
    ws, wl = np.divmod(wp, k)
    lane_bad = wl != piv
    if lane_bad.any():
        out.append(finding(
            CHECK, "PLAN_LANE_MISMATCH",
            f"{int(lane_bad.sum())} rows execute on a "
            "different core than the schedule assigns",
        ))
    if T:
        sup_of_step = np.repeat(
            np.arange(len(sb) - 1, dtype=np.int64), np.diff(sb)
        )
        step_bad = sup_of_step[ws] != sigv
        if step_bad.any():
            out.append(finding(
                CHECK, "PLAN_STEP_MISMATCH",
                f"{int(step_bad.sum())} rows "
                "execute in a different superstep than the schedule "
                "assigns",
            ))

    if level == "full":
        # per-(superstep, core) load accounting: virtual-row counts per
        # lane must match the schedule's expansion, and each superstep's
        # step count must be the max lane load
        S = len(sb) - 1
        flat = row_ids.ravel().astype(np.int64)
        realf = flat < n
        steps = np.repeat(np.arange(T, dtype=np.int64), k)[realf]
        lanes = np.tile(np.arange(k, dtype=np.int64), T)[realf]
        if T:
            key = sup_of_step[steps] * k + lanes
            load = np.bincount(key, minlength=S * k).reshape(S, k)
        else:
            load = np.zeros((S, k), dtype=np.int64)
        # expected load: every vertex contributes its virtual-row count
        # to lane pi[v] of superstep sigma[v]; the count is recovered
        # from the plan itself (slots per row) so the check stays
        # matrix-free — verify_exec_plan ties slot counts to L
        vrows_per_row = np.bincount(flat[realf], minlength=n)[:n]
        exp = np.zeros((S, k), dtype=np.int64)
        np.add.at(exp, (sigma[have], pi[have]), vrows_per_row[have])
        if (load != exp).any():
            out.append(finding(
                CHECK, "PLAN_STEP_LOADS",
                "per-(superstep, core) slot counts disagree with the "
                "schedule's virtual-row expansion",
            ))
        widths = np.diff(sb)
        if (widths != load.max(axis=1)).any():
            out.append(finding(
                CHECK, "PLAN_STEP_WIDTH",
                "superstep step count differs from its max core load "
                "(padded rectangle is the wrong height)",
            ))
    return out
