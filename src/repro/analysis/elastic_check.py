"""Elastic certificate checker — soundness audit of ``ElasticPlan``.

The certificate is judged against dependencies re-derived from the
``ExecPlan`` tensors alone (writer map + gather columns), never against
``core.elastic``'s own helpers.  The verifier checks *soundness*, not
bit-identity with the producer: a more conservative certificate (later
readiness, smaller waves, shorter fused runs) is still valid — what can
never happen is a step running before its inputs exist.

Proved properties:

  * geometry — ``M = ceil(T / slack)``, wave ids start at 0 and grow by
    at most 1 per in-window step, ``n_waves`` matches;
  * readiness soundness — the certified ``ready_step[t]`` is never
    *earlier* than the true earliest step at which every gathered value
    exists (an underestimate lets an elastic worker read garbage);
  * wave independence — every step of a wave has its dependencies
    resolved before the wave's first step, so the wave's steps are
    mutually independent (no intra-wave dependency);
  * accum ordering — a step whose predecessor carries a partial-sum
    accumulator in any lane must start a new wave (the carry forces
    sequential order even when gathers are ready);
  * fused-run soundness — within a fused superstep run no superstep
    reads a cross-core value written inside the run, and runs respect
    the ``slack`` staleness cap.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.analysis.findings import Finding, finding
from repro.analysis.plan_check import plan_writers

CHECK = "elastic"


def true_ready_steps(plan) -> np.ndarray:
    """Independent readiness derivation: for each plan step, the
    earliest step at which all its real gathers are valid —
    ``max(writer_step[col] + 1)``, 0 with no real gathers."""
    row_ids = np.asarray(plan.row_ids)
    col_idx = np.asarray(plan.col_idx).astype(np.int64)
    accum = np.asarray(plan.accum)
    n = int(plan.n)
    T = row_ids.shape[0]
    if T == 0:
        return np.zeros(0, dtype=np.int64)
    w_step, _, _ = plan_writers(row_ids, accum, n)
    ws_pad = np.concatenate([w_step, np.asarray([-1], dtype=np.int64)])
    ws = np.where(col_idx < n, ws_pad[np.minimum(col_idx, n)], -1)
    return (ws.max(axis=(1, 2)) + 1).astype(np.int64)


def verify_elastic(plan, ep, *, level: str = "fast") -> List[Finding]:
    """Audit elastic certificate ``ep`` against ``plan``."""
    out: List[Finding] = []
    T = int(plan.n_steps)
    slack = int(ep.slack)
    if slack < 1:
        out.append(finding(
            CHECK, "ELASTIC_SLACK", f"slack must be >= 1, got {slack}",
        ))
        return out
    M_true = max(1, -(-T // slack))
    wave = np.asarray(ep.wave_id)
    n_waves = np.asarray(ep.n_waves)
    ready_cert = np.asarray(ep.ready_step, dtype=np.int64)
    if (
        int(ep.n_steps) != T
        or int(ep.n_macro_steps) != M_true
        or wave.shape != (M_true, slack)
        or n_waves.shape != (M_true,)
        or ready_cert.shape != (T,)
    ):
        out.append(finding(
            CHECK, "ELASTIC_GEOMETRY",
            f"certificate geometry disagrees with the plan: T={T}, "
            f"slack={slack} implies M={M_true}, certificate claims "
            f"M={int(ep.n_macro_steps)} wave_id{tuple(wave.shape)}",
        ))
        return out
    if int(ep.n_supersteps) != int(plan.n_supersteps):
        out.append(finding(
            CHECK, "ELASTIC_GEOMETRY",
            f"certificate superstep count {int(ep.n_supersteps)} != "
            f"plan {int(plan.n_supersteps)}",
        ))

    # wave ids: start at 0, nondecreasing, step by at most 1
    if T and (wave[:, 0] != 0).any():
        out.append(finding(
            CHECK, "ELASTIC_WAVE_BASE",
            "a window's first step is not wave 0",
        ))
    if slack > 1:
        d = np.diff(wave, axis=1)
        if ((d < 0) | (d > 1)).any():
            out.append(finding(
                CHECK, "ELASTIC_WAVE_MONOTONE",
                "wave ids must grow by 0 or 1 per in-window step",
            ))
    if T and (n_waves != wave[:, -1] + 1).any():
        out.append(finding(
            CHECK, "ELASTIC_WAVE_COUNT",
            "n_waves disagrees with the last wave id per window",
        ))
    if out:
        return out

    ready_true = true_ready_steps(plan)
    under = ready_cert < ready_true
    if under.any():
        i = int(np.nonzero(under)[0][0])
        out.append(finding(
            CHECK, "ELASTIC_READY_UNDERESTIMATE",
            f"{int(under.sum())} steps certified ready before their "
            f"inputs exist (e.g. step {i}: certified "
            f"{int(ready_cert[i])}, true {int(ready_true[i])})",
        ))
    over = ready_cert > np.arange(T, dtype=np.int64)
    if over.any():
        out.append(finding(
            CHECK, "ELASTIC_READY_UNSATISFIABLE",
            f"{int(over.sum())} steps certified ready only after their "
            "own position (the schedule itself would deadlock)",
        ))

    # wave independence: every step's TRUE dependencies must resolve
    # before its wave's first step (the wave executes concurrently)
    pad = M_true * slack - T
    ready_p = np.concatenate([
        ready_true, np.zeros(pad, dtype=np.int64)
    ]).reshape(M_true, slack)
    base = np.arange(M_true, dtype=np.int64)[:, None] * slack
    pos = np.arange(slack, dtype=np.int64)[None, :]
    abs_step = base + pos
    head = np.zeros((M_true, slack), dtype=bool)
    head[:, 0] = True
    if slack > 1:
        head[:, 1:] = wave[:, 1:] != wave[:, :-1]
    # absolute step of each step's wave head, via cummax over head marks
    head_step = np.maximum.accumulate(
        np.where(head, abs_step, -1), axis=1
    )
    realm = abs_step < T
    viol = realm & (ready_p > head_step)
    if viol.any():
        t = int(abs_step[viol][0])
        out.append(finding(
            CHECK, "ELASTIC_INTRA_WAVE_DEP",
            f"{int(viol.sum())} steps depend on a value produced inside "
            f"their own wave (e.g. step {t}: ready "
            f"{int(ready_p[viol][0])}, wave starts at "
            f"{int(head_step[viol][0])})",
        ))

    # accum carry: predecessor carrying a partial sum forces a wave break
    carry = np.zeros(T, dtype=bool)
    if T > 1:
        carry[1:] = np.asarray(plan.accum)[:-1].any(axis=1)
    carry_p = np.concatenate([carry, np.zeros(pad, dtype=bool)]).reshape(
        M_true, slack
    )
    fused_carry = carry_p & ~head & realm
    if fused_carry.any():
        t = int(abs_step[fused_carry][0])
        out.append(finding(
            CHECK, "ELASTIC_ACCUM_CHAIN_FUSED",
            f"{int(fused_carry.sum())} steps share a wave with a "
            f"predecessor that carries a partial-sum accumulator "
            f"(e.g. step {t}) — the accum chain order is lost",
        ))

    out.extend(_verify_fused_bounds(plan, ep))
    return out


def _verify_fused_bounds(plan, ep) -> List[Finding]:
    """Fused superstep runs: a run needs one barrier iff no superstep in
    it reads a cross-core value written inside the run.  Cross-core
    readiness is re-derived from the plan's writer map."""
    out: List[Finding] = []
    S = int(plan.n_supersteps)
    fb = np.asarray(ep.fused_bounds, dtype=np.int64)
    slack = int(ep.slack)
    if len(fb) < 1 or fb[0] != 0 or fb[-1] != S or (np.diff(fb) <= 0).any():
        out.append(finding(
            CHECK, "ELASTIC_FUSED_BOUNDS",
            f"fused_bounds is not a strictly monotone cover of [0, {S}]",
        ))
        return out
    runs = np.diff(fb)
    if (runs > slack).any():
        out.append(finding(
            CHECK, "ELASTIC_RUN_TOO_LONG",
            f"{int((runs > slack).sum())} fused runs exceed the slack "
            f"cap of {slack} supersteps",
        ))
    if S == 0:
        return out

    row_ids = np.asarray(plan.row_ids)
    col_idx = np.asarray(plan.col_idx).astype(np.int64)
    accum = np.asarray(plan.accum)
    n = int(plan.n)
    T, k = row_ids.shape
    sb = np.asarray(plan.step_bounds, dtype=np.int64)
    sup_of_step = np.repeat(np.arange(S, dtype=np.int64), np.diff(sb))
    w_step, w_lane, _ = plan_writers(row_ids, accum, n)

    # cross-core readiness per reader superstep: latest writer superstep
    # (+1) over gathers whose writer lane differs from the reader lane
    lane3 = np.broadcast_to(
        np.arange(k, dtype=np.int64)[None, :, None], col_idx.shape
    )
    real = col_idx < n
    cols = np.minimum(col_idx, n - 1 if n else 0)
    cross = real & (w_lane[cols] != lane3) & (w_step[cols] >= 0)
    xready = np.zeros(S, dtype=np.int64)
    if cross.any():
        writer_sup = sup_of_step[w_step[cols[cross]]] + 1
        reader_sup = sup_of_step[np.broadcast_to(
            np.arange(T, dtype=np.int64)[:, None, None], col_idx.shape
        )[cross]]
        np.maximum.at(xready, reader_sup, writer_sup)

    # each superstep's cross-core inputs must exist before its run starts
    run_of_sup = np.repeat(np.arange(len(runs), dtype=np.int64), runs)
    run_start = fb[run_of_sup]
    viol = xready > run_start
    if viol.any():
        s = int(np.nonzero(viol)[0][0])
        out.append(finding(
            CHECK, "ELASTIC_FUSED_RACE",
            f"{int(viol.sum())} supersteps read cross-core values "
            f"written inside their own fused run (e.g. superstep {s}: "
            f"cross-ready {int(xready[s])}, run starts at "
            f"{int(run_start[s])})",
        ))
    return out
