"""Findings and reports — the verifier's output vocabulary.

Every ``repro.analysis`` pass returns a list of :class:`Finding`s rather
than raising on first failure: a corrupted artifact usually violates
several invariants at once, and the mutation harness / ``launch.check``
sweep want the full picture (and a stable, comparable representation —
verifier determinism is itself a tested property).

Severity: ``error`` findings fail verification (``Report.ok`` is False);
``warn`` findings are surfaced but do not gate — used for invariants
that are suspicious rather than provably wrong (e.g. duplicate in-chain
ranks, which a stable sort still resolves deterministically).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

SEVERITIES = ("error", "warn")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation.

    check    verifier pass ("schedule" | "reorder" | "plan" | "elastic"
             | "rowshard" | "lint")
    code     stable machine code, e.g. "PLAN_READ_BEFORE_WRITE"
    message  human-readable description (includes counts / first examples)
    where    sorted (key, value) context pairs — kept hashable so findings
             can be set-compared across verifier runs
    severity "error" (gates) or "warn" (reported only)
    """

    check: str
    code: str
    message: str
    where: Tuple[Tuple[str, str], ...] = ()
    severity: str = "error"

    def as_dict(self) -> dict:
        return {
            "check": self.check,
            "code": self.code,
            "severity": self.severity,
            "message": self.message,
            "where": dict(self.where),
        }


def finding(
    check: str, code: str, message: str, severity: str = "error", **where
) -> Finding:
    """Build a :class:`Finding` with normalized, hashable context."""
    assert severity in SEVERITIES, severity
    ctx = tuple(sorted((str(k), str(v)) for k, v in where.items()))
    return Finding(
        check=check, code=code, message=message, where=ctx,
        severity=severity,
    )


class VerificationError(ValueError):
    """Raised by ``Report.raise_if_failed`` — carries the full report."""

    def __init__(self, report: "Report"):
        self.report = report
        super().__init__(
            f"static verification failed with "
            f"{len(report.errors)} error finding(s):\n{report.table()}"
        )


@dataclasses.dataclass
class Report:
    """Aggregated verifier output: findings + which passes actually ran
    (a pass that never ran proves nothing — the sweep asserts coverage,
    not just absence of findings)."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    checks_run: List[str] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warn"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def extend(self, check: str, found: List[Finding]) -> "Report":
        self.findings.extend(found)
        if check not in self.checks_run:
            self.checks_run.append(check)
        return self

    def merge(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        for c in other.checks_run:
            if c not in self.checks_run:
                self.checks_run.append(c)
        return self

    def codes(self) -> Tuple[str, ...]:
        return tuple(sorted({f.code for f in self.findings}))

    def table(self) -> str:
        """Fixed-width findings table (empty string when clean)."""
        if not self.findings:
            return ""
        rows = [
            (f.severity.upper(), f.check, f.code, f.message)
            for f in self.findings
        ]
        w0 = max(len(r[0]) for r in rows)
        w1 = max(len(r[1]) for r in rows)
        w2 = max(len(r[2]) for r in rows)
        return "\n".join(
            f"{r[0]:{w0}s}  {r[1]:{w1}s}  {r[2]:{w2}s}  {r[3]}"
            for r in rows
        )

    def as_dict(self) -> Dict:
        return {
            "ok": self.ok,
            "checks_run": list(self.checks_run),
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "findings": [f.as_dict() for f in self.findings],
        }

    def raise_if_failed(self) -> "Report":
        if not self.ok:
            raise VerificationError(self)
        return self
