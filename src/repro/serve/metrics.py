"""Serving telemetry — per-pattern and global, lock-guarded, snapshot-able.

What a serving operator actually wants to see, per sparsity pattern and
in aggregate:

  * queue depth (how far behind the workers are),
  * the batch-size histogram (is microbatching actually coalescing?),
  * p50/p95/p99/p99.9 end-to-end latency plus the queue-wait share of
    it (p99.9 because the continuous engine exists for the tail of the
    tail — the open-loop regime where a batch-formation deadline shows
    up two nines out),
  * continuous mode: slot-pass count, the occupancy histogram (are the
    lanes actually full?) and time-in-queue vs time-in-slot — the split
    that says whether latency is spent waiting for a lane or solving,
  * throughput (completed solves per second),
  * plan-cache hit rate and live plan versions.

Latencies go through a bounded reservoir (the most recent ``cap``
samples) so a long-running service computes percentiles over recent
traffic in O(cap) instead of growing without bound. ``snapshot()``
returns a plain dict (JSON-ready, consumed by ``benchmarks/serve_load``)
and ``pretty()`` renders it for humans.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from typing import Dict, Optional

import numpy as np

PERCENTILES = (50, 95, 99, 99.9)


class LatencyReservoir:
    """Bounded sample window; percentiles over the most recent ``cap``.

    Internally thread-safe: ``add`` and ``percentiles_us`` may race from
    different threads. Without the lock, iterating the deque
    (``np.fromiter``) while a concurrent ``add`` rotates it past
    ``maxlen`` raises ``RuntimeError: deque mutated during iteration`` —
    a real crash under serving load, regression-tested by
    ``tests/test_obs.py::test_latency_reservoir_threaded``. The lock is
    a leaf (nothing is called while holding it), so reservoir methods
    are safe to call under the ``ServeMetrics`` lock."""

    def __init__(self, cap: int = 4096):
        self._lock = threading.Lock()
        self._samples: deque = deque(maxlen=cap)
        self.count = 0  # lifetime, not window

    def add(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(seconds)
            self.count += 1

    def extend(self, seconds_iter) -> None:
        # one lock round-trip for the whole batch, not one per sample
        seconds = list(seconds_iter)
        with self._lock:
            self._samples.extend(seconds)
            self.count += len(seconds)

    def samples(self) -> list:
        """A consistent copy of the current window (the accessor
        ``ServeMetrics.snapshot`` pools global percentiles from —
        never iterate ``_samples`` directly)."""
        with self._lock:
            return list(self._samples)

    def percentiles_us(self) -> Dict[str, float]:
        """{"p50": ..., ..., "p99.9": ...} in microseconds (NaN-free:
        empty reservoirs report 0.0 so JSON stays parseable)."""
        return _percentiles_us(np.asarray(self.samples(), dtype=np.float64))


def _percentiles_us(arr: np.ndarray) -> Dict[str, float]:
    if arr.size == 0:
        return {f"p{q}": 0.0 for q in PERCENTILES}
    vals = np.percentile(arr, PERCENTILES)
    return {
        f"p{q}": round(float(v) * 1e6, 1)
        for q, v in zip(PERCENTILES, vals)
    }


class _PatternStats:
    __slots__ = (
        "submitted", "completed", "failed", "rejected", "batches",
        "batch_hist", "queue_wait", "e2e", "updates",
    )

    def __init__(self):
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0  # bounced at admission (max_queue back-pressure)
        self.batches = 0
        self.updates = 0  # numeric_update version swaps
        self.batch_hist: Counter = Counter()  # actual batch size -> count
        self.queue_wait = LatencyReservoir()
        self.e2e = LatencyReservoir()


class ServeMetrics:
    """Thread-safe telemetry sink shared by the service and its workers."""

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        """Zero all counters and reservoirs — benchmarks call this after
        their warm-up phase so compile-time latencies don't pollute the
        measured percentiles."""
        with self._lock:
            self._patterns: Dict[str, _PatternStats] = {}
            self._solve = LatencyReservoir()  # per-batch device solve time
            self._grouped_batches = 0  # cross-pattern width-class batches
            self._grouped_hist: Counter = Counter()
            # continuous mode: dispatch passes over the resident slots
            self._slot_passes = 0
            self._slot_occ_hist: Counter = Counter()  # occupancy -> passes
            self._slot_width = 0  # lane count S of the last-seen engine
            self._slot_time = LatencyReservoir()  # per-request time in slot
            self._t_first: Optional[float] = None
            self._t_last: Optional[float] = None

    # ------------------------------------------------------------- record
    def _pat(self, fp: str) -> _PatternStats:
        p = self._patterns.get(fp)
        if p is None:
            p = self._patterns[fp] = _PatternStats()
        return p

    def record_submit(self, fp: str) -> None:
        with self._lock:
            if self._t_first is None:
                self._t_first = time.perf_counter()
            self._pat(fp).submitted += 1

    def record_update(self, fp: str) -> None:
        with self._lock:
            self._pat(fp).updates += 1

    def record_rejected(self, fp: str) -> None:
        with self._lock:
            self._pat(fp).rejected += 1

    def record_batch(
        self,
        fp: str,
        size: int,
        *,
        queue_waits,
        e2e,
        solve_seconds: float,
    ) -> None:
        with self._lock:
            p = self._pat(fp)
            p.completed += size
            p.batches += 1
            p.batch_hist[size] += 1
            p.queue_wait.extend(queue_waits)
            p.e2e.extend(e2e)
            self._solve.add(solve_seconds)
            self._mark_completion_locked()

    def record_grouped_batch(
        self,
        fps,
        *,
        queue_waits,
        e2e,
        solve_seconds: float,
    ) -> None:
        """One width-class grouped batch: request j came from pattern
        ``fps[j]`` (``queue_waits``/``e2e`` aligned). Completions and
        latencies are attributed per pattern; the batch itself is counted
        once, globally, as a grouped batch — attributing it to any single
        pattern would misstate that pattern's batching."""
        with self._lock:
            for fp, qw, el in zip(fps, queue_waits, e2e):
                p = self._pat(fp)
                p.completed += 1
                p.queue_wait.add(qw)
                p.e2e.add(el)
            self._grouped_batches += 1
            self._grouped_hist[len(fps)] += 1
            self._solve.add(solve_seconds)
            self._mark_completion_locked()

    def record_slot_pass(
        self,
        fps,
        *,
        queue_waits,
        slot_times,
        e2e,
        solve_seconds: float,
        occupancy: int,
        n_slots: int,
    ) -> None:
        """One continuous-mode dispatch pass over the resident slots:
        request j (pattern ``fps[j]``) rode one of the pass's
        ``occupancy`` occupied lanes (of ``n_slots``). ``queue_waits``
        is time-in-queue (submit -> lane insertion) and ``slot_times``
        time-in-slot (insertion -> completion) — the two halves of
        ``e2e``, split so an operator can see whether the tail comes
        from waiting for a lane or from the solve itself. Completions
        and latencies are attributed per pattern; the pass is counted
        once, globally, like a grouped batch."""
        with self._lock:
            for fp, qw, el in zip(fps, queue_waits, e2e):
                p = self._pat(fp)
                p.completed += 1
                p.queue_wait.add(qw)
                p.e2e.add(el)
            self._slot_time.extend(slot_times)
            self._slot_passes += 1
            self._slot_occ_hist[occupancy] += 1
            self._slot_width = n_slots
            self._solve.add(solve_seconds)
            self._mark_completion_locked()

    def record_failure(self, fp: str, size: int) -> None:
        with self._lock:
            self._pat(fp).failed += size
            self._mark_completion_locked()

    def _mark_completion_locked(self) -> None:
        """Advance the throughput window. The window is anchored on the
        FIRST recorded event — submit or completion, whichever comes
        first: a batch draining after ``reset()`` (warm-up) used to set
        ``_t_last`` while ``_t_first`` stayed None, making every later
        snapshot report 0.0 solves/s despite completions."""
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now
        self._t_last = now

    # ----------------------------------------------------------- snapshot
    def snapshot(self, *, queue_depth: int = 0, extra: dict = None) -> dict:
        """One JSON-ready dict: global aggregates + a per-pattern section.
        ``extra`` (e.g. plan-cache stats, live versions) is merged at the
        top level by the service."""
        with self._lock:
            per_pattern = {}
            tot_sub = tot_done = tot_fail = tot_rej = tot_batches = 0
            hist: Counter = Counter()
            # global percentiles pool every pattern's window uncapped —
            # funneling them through one capped reservoir would silently
            # drop the first-inserted (often hottest) patterns' samples
            all_e2e: list = []
            all_queue: list = []
            for fp, p in self._patterns.items():
                tot_sub += p.submitted
                tot_done += p.completed
                tot_fail += p.failed
                tot_rej += p.rejected
                tot_batches += p.batches
                hist.update(p.batch_hist)
                all_e2e.extend(p.e2e.samples())
                all_queue.extend(p.queue_wait.samples())
                per_pattern[fp] = {
                    "submitted": p.submitted,
                    "completed": p.completed,
                    "failed": p.failed,
                    "rejected": p.rejected,
                    "batches": p.batches,
                    "numeric_updates": p.updates,
                    "batch_size_hist": dict(sorted(p.batch_hist.items())),
                    "latency_us": p.e2e.percentiles_us(),
                    "queue_wait_us": p.queue_wait.percentiles_us(),
                }
            elapsed = (
                (self._t_last or 0.0) - (self._t_first or 0.0)
                if self._t_first is not None
                else 0.0
            )
            # width-class grouped batches and slot passes are counted
            # once, globally (the per-pattern loop above only saw their
            # per-request shares)
            tot_batches += self._grouped_batches + self._slot_passes
            hist.update(self._grouped_hist)
            occ_total = sum(
                occ * cnt for occ, cnt in self._slot_occ_hist.items()
            )
            out = {
                "submitted": tot_sub,
                "completed": tot_done,
                "failed": tot_fail,
                "rejected": tot_rej,
                "queue_depth": queue_depth,
                "batches": tot_batches,
                "grouped_batches": self._grouped_batches,
                "grouped_batch_size_hist": dict(
                    sorted(self._grouped_hist.items())
                ),
                "mean_batch_size": round(tot_done / tot_batches, 2)
                if tot_batches
                else 0.0,
                "batch_size_hist": dict(sorted(hist.items())),
                "elapsed_seconds": round(max(elapsed, 0.0), 4),
                "solves_per_sec": round(tot_done / elapsed, 1)
                if elapsed > 0
                else 0.0,
                "latency_us": _percentiles_us(np.asarray(all_e2e)),
                "queue_wait_us": _percentiles_us(np.asarray(all_queue)),
                "batch_solve_us": self._solve.percentiles_us(),
                # continuous mode: dispatch passes over the resident
                # slots (zeros when the service runs pure microbatch)
                "slots": {
                    "passes": self._slot_passes,
                    "n_slots": self._slot_width,
                    "occupancy_hist": dict(
                        sorted(self._slot_occ_hist.items())
                    ),
                    "mean_occupancy": round(
                        occ_total / self._slot_passes, 2
                    )
                    if self._slot_passes
                    else 0.0,
                    "time_in_slot_us": self._slot_time.percentiles_us(),
                },
                "per_pattern": per_pattern,
            }
        if extra:
            out.update(extra)
        return out


def pretty(snap: dict) -> str:
    """Render a ``ServeMetrics.snapshot()`` dict for terminals."""
    lines = [
        "== serve metrics ==",
        f"requests: {snap['completed']}/{snap['submitted']} completed"
        f" ({snap['failed']} failed, {snap.get('rejected', 0)} rejected, "
        f"queue depth {snap['queue_depth']})",
        f"throughput: {snap['solves_per_sec']} solves/s over "
        f"{snap['elapsed_seconds']}s in {snap['batches']} batches "
        f"(mean batch {snap['mean_batch_size']}, "
        f"{snap.get('grouped_batches', 0)} cross-pattern)",
        f"latency us: {snap['latency_us']}  "
        f"queue wait us: {snap['queue_wait_us']}",
        f"batch size hist: {snap['batch_size_hist']}",
    ]
    slots = snap.get("slots") or {}
    if slots.get("passes"):
        lines.append(
            f"slots: {slots['passes']} passes over {slots['n_slots']} "
            f"lanes (mean occupancy {slots['mean_occupancy']}), "
            f"time in slot us: {slots['time_in_slot_us']}"
        )
    if "plan_cache" in snap:
        lines.append(f"plan cache: {snap['plan_cache']}")
    for fp, p in snap.get("per_pattern", {}).items():
        lines.append(
            f"  {fp[:12]}…: {p['completed']}/{p['submitted']} done, "
            f"{p['batches']} batches, {p['numeric_updates']} updates, "
            f"p50={p['latency_us']['p50']}us p99={p['latency_us']['p99']}us"
        )
    return "\n".join(lines)
