"""Live refactorization — version-tagged plans per sparsity pattern.

The serving regime refreshes factor values *while requests are in
flight* (every Newton step of an outer solver, say). Correctness rule:
a request is pinned at admission to the plan version current at that
moment, and is always executed against exactly that version's values —
an update between admission and execution must neither corrupt nor drop
it. ``VersionedPlans`` enforces this with:

  * immutable versions — an update never mutates a live solver; it
    clones the current one with the new values
    (``TriangularSolver.clone_with_values``, structure shared, value
    tensors owned), so in-flight batches read stable tensors;
  * per-version pin counts — ``admit()`` pins a request to the current
    version, ``complete()`` unpins; a superseded version is retired (its
    solver reference dropped) only once its pin count reaches zero.

The schedule/index structure is shared across all versions (it depends
only on the pattern), so a version swap costs O(nnz) — exactly the
paper's §7.7 amortization argument carried into the serving loop.
"""
from __future__ import annotations

import threading
from typing import Dict, Tuple

import numpy as np


class VersionedPlans:
    """All live plan versions of one registered pattern."""

    def __init__(self, solver, *, lower: bool = True):
        self.fingerprint = solver.fingerprint
        self.lower = lower
        self.n = solver.n
        # structural solve-graph identity + grouping capability — shared
        # by every version (updates clone values, never the structure),
        # so they are computed once here. The serve batcher routes on
        # width_class when cross-pattern batching is enabled.
        self.width_class = getattr(solver, "width_class", None)
        self.groupable = bool(getattr(solver, "supports_grouping", False))
        # a Condition, not a bare Lock: retirements notify waiters so
        # tests (and operators) can wait for a superseded version to
        # drain without sleep-polling (wait_retired)
        self._lock = threading.Condition()
        self._versions: Dict[int, object] = {0: solver}
        self._pins: Dict[int, int] = {0: 0}
        self.current = 0

    # ------------------------------------------------------------ admission
    def admit(self) -> Tuple[int, object]:
        """Pin one request to the current version; returns
        ``(version, solver)``. The solver reference stays valid until the
        matching ``complete`` even if updates supersede it meanwhile."""
        with self._lock:
            v = self.current
            self._pins[v] += 1
            return v, self._versions[v]

    def solver_for(self, version: int):
        with self._lock:
            return self._versions[version]

    def current_solver(self):
        """The current version's solver, read atomically — reading
        ``vp.current`` and then calling ``solver_for`` without the lock
        can race a concurrent ``update`` retiring the version between
        the two reads (telemetry's KeyError hazard)."""
        with self._lock:
            return self._versions[self.current]

    def current_entry(self):
        """``(version, solver)`` read under ONE lock acquisition.
        Callers that need the pair (e.g. keying a bank lane by version)
        must not read ``current`` and ``current_solver()`` separately —
        an ``update`` between the two reads would pair the old version
        number with the new solver's values."""
        with self._lock:
            return self.current, self._versions[self.current]

    def complete(self, version: int, count: int = 1) -> None:
        """Unpin ``count`` requests from ``version``; retire superseded
        versions that have fully drained."""
        with self._lock:
            self._pins[version] -= count
            self._retire_locked()

    def _retire_locked(self) -> None:
        dead = [
            v
            for v, pins in self._pins.items()
            if v != self.current and pins <= 0
        ]
        for v in dead:
            del self._versions[v]
            del self._pins[v]
        if dead:
            self._lock.notify_all()

    def wait_retired(self, version: int, timeout: float = None) -> bool:
        """Block until ``version`` has retired (drained and superseded);
        True on retirement, False on timeout. The event-based
        alternative to sleep-polling ``live_versions`` in tests and
        drain-aware operators."""
        with self._lock:
            return self._lock.wait_for(
                lambda: version not in self._versions, timeout
            )

    # -------------------------------------------------------------- updates
    def update(self, a_or_data) -> int:
        """Install new factor values as a fresh version and make it
        current. Queued requests keep their admitted version; only
        requests admitted *after* this call see the new values."""
        with self._lock:
            base = self._versions[self.current]
            new = base.clone_with_values(a_or_data)
            v = self.current + 1
            self._versions[v] = new
            self._pins[v] = 0
            self.current = v
            self._retire_locked()
            return v

    def values_match(self, data: np.ndarray) -> bool:
        """True when ``data`` equals the *current* version's values —
        submit() uses this to decide whether a matrix resubmission is an
        implicit numeric update."""
        with self._lock:
            cur = self._versions[self.current].source_values
        return cur is not None and np.array_equal(cur, data)

    # ------------------------------------------------------------ introspection
    def live_versions(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._versions))

    def pins(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._pins)
