"""Continuous batching — persistent device-resident RHS slots, no drain
barrier between dispatches.

The paper's thesis is that SpTRSV speed comes from removing
synchronization barriers (12.07x fewer than HDagg, §7). The microbatch
serving loop still contains one: every dispatch *forms* a batch (waits
up to ``max_wait_us`` for company), solves it, and fully *drains* it
before the next batch forms — a barrier per microbatch, paid by every
request's tail latency. This module removes it, JetStream-style:

  * one ``SlotEngine`` per width class owns ``n_slots`` persistent
    device lanes: a resident rhs bank ``B[n, S]`` plus the width class's
    stacked plan bank (``repro.pipeline.GroupBank`` — restacked only on
    membership change, never per dispatch);
  * admission is *slot allocation* (``SlotState.admit``): a free lane is
    assigned and the request's rhs is written into the resident bank
    with a jitted device-side ``dynamic_update_slice``
    (``BoundSolve.insert_lane``) — no host-side batch stacking, no bank
    rebuild, no formation deadline;
  * ONE always-running dispatch loop (``SlotDispatcher``) drives every
    engine: it drains the shared admission queue, round-robins one
    solve pass per engine with pending work
    (``BoundSolve.solve_resident``; lanes allocate lowest-first, so
    each pass dispatches the smallest pow2 lane prefix covering the
    occupants — a lightly-loaded bank never pays the full-S solve);
    completion extracts the lane's column (``extract_lane``), fulfills
    the ticket, and frees the lane — newly queued requests take freed
    lanes on the very next pass, while the pass they missed is still
    what bounds their wait. There is no drain barrier: the loop never
    waits for a bank to empty or fill.

One dispatch thread, not one per engine, on purpose: passes serialize
on the device anyway, so per-class threads buy no overlap — they only
oversubscribe the host (a request mix spanning k width classes would
spawn k loops whose GIL/scheduler preemption shows up directly in the
open-loop tail, badly on small machines) — and a single mutator thread
is what makes every ``SlotState``, resident bank and bank-membership
mutation in the whole service lock-free by construction.

Slot lifecycle (see README "Continuous batching" for the diagram)::

    submit -> AdmissionQueue -> admit (free lane) -> insert_lane
           -> solve_resident pass -> extract_lane -> fulfill -> release

Bitwise contract — unchanged from the microbatch path and now holding
with neighbors churning in adjacent lanes: the banked kernel's vmap
lanes are data-independent, so a lane's bits depend only on its own
(plan, rhs) at the dispatched (width, position) = (pass width, lane).
Free lanes keep whatever stale column the previous occupant left (and a
filler plan key); by lane independence those bits never reach an
occupied lane, so the engine never wastes a write zeroing them. Each
completed ticket records ``batch_width`` (its pass width),
``batch_position = lane`` and ``served_by = GroupReplay(solver)`` —
exactly the replay reference ``direct_reference`` already verifies
grouped results against.

``SlotState`` is the pure lane-allocation state machine, kept free of
any device or threading concern so the Hypothesis property suite
(tests/test_serve_slots.py) can drive it through millions of random
admit/complete/evict sequences and audit its invariants directly.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from typing import Callable, Dict, Hashable, List, Optional

import numpy as np

from repro import obs
from repro.pipeline import GroupBank
from repro.serve.batcher import AdmissionQueue, pad_width
from repro.serve.metrics import ServeMetrics


class SlotsFull(RuntimeError):
    """Raised by ``SlotState.admit`` when every lane is occupied."""


class SlotState:
    """Pure lane-allocation state machine for ``n_slots`` device lanes.

    No device state, no locks, no clock — a deterministic object the
    property tests can drive in isolation. Invariants (audited by
    :meth:`check`):

      * a lane is either free or holds exactly one token — ``admit``
        never double-occupies, ``release``/``evict`` of a free lane
        raises;
      * a token occupies at most one lane — re-admitting a live token
        raises;
      * ``free + occupied`` is always a partition of ``range(n_slots)``.

    ``release`` (completion) and ``evict`` (failure/shutdown) are the
    same transition with different books — every admitted token leaves
    through exactly one of them, which is how the engine guarantees
    every ticket terminates exactly once.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.n_slots = n_slots
        # stack, reversed so lane 0 is allocated first — deterministic
        # lane assignment keeps replay tests and telemetry readable
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._occupant: Dict[int, Hashable] = {}  # lane -> token
        self._lane_of: Dict[Hashable, int] = {}  # token -> lane
        self.admitted = 0
        self.completed = 0
        self.evicted = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> int:
        return len(self._occupant)

    def occupants(self) -> Dict[int, Hashable]:
        """lane -> token snapshot (copy; mutating it changes nothing)."""
        return dict(self._occupant)

    def lane_of(self, token) -> Optional[int]:
        return self._lane_of.get(token)

    def admit(self, token) -> int:
        """Allocate a free lane to ``token``; returns the lane."""
        if token in self._lane_of:
            raise ValueError(
                f"token {token!r} already occupies lane "
                f"{self._lane_of[token]}"
            )
        if not self._free:
            raise SlotsFull(f"all {self.n_slots} lanes occupied")
        lane = self._free.pop()
        self._occupant[lane] = token
        self._lane_of[token] = lane
        self.admitted += 1
        return lane

    def _vacate(self, lane: int):
        if lane not in self._occupant:
            raise ValueError(
                f"lane {lane} is already free (or out of range)"
            )
        token = self._occupant.pop(lane)
        del self._lane_of[token]
        self._free.append(lane)
        return token

    def release(self, lane: int):
        """Completion: free ``lane``, returning its token."""
        token = self._vacate(lane)
        self.completed += 1
        return token

    def evict(self, lane: int):
        """Failure/shutdown path: free ``lane`` without counting a
        completion, returning its token."""
        token = self._vacate(lane)
        self.evicted += 1
        return token

    def check(self) -> None:
        """Audit every invariant; raises AssertionError on violation.
        Cheap enough for the property tests to call after every step."""
        assert len(self._free) + len(self._occupant) == self.n_slots
        assert set(self._free).isdisjoint(self._occupant.keys())
        assert set(self._free) | set(self._occupant) == set(
            range(self.n_slots)
        )
        assert sorted(self._lane_of.values()) == sorted(self._occupant)
        for lane, token in self._occupant.items():
            assert self._lane_of[token] == lane
        assert self.admitted == (
            self.completed + self.evicted + len(self._occupant)
        )


class SlotRequest:
    """One queued continuous-mode request: the ticket, its pinned
    ``(fingerprint, version)`` bank key, that version's solver, and the
    rhs."""

    __slots__ = ("ticket", "key", "solver", "b")

    def __init__(self, ticket, key, solver, b):
        self.ticket = ticket
        self.key = key
        self.solver = solver
        self.b = b


class SlotEngine:
    """One width class's continuous-batching context: persistent device
    lanes, the class's stacked plan bank, and the pass executor — driven
    by a :class:`SlotDispatcher`, never by its own thread (see module
    docstring for why the dispatch loop is shared).

    ``is_live(key) -> bool`` and ``on_complete(key, count)`` decouple
    the engine from the service's version registry: completions unpin
    the served versions through ``on_complete`` (mirroring the worker
    loops' ``VersionedPlans.complete``), and bank lanes of retired
    versions are pruned with ``is_live``. Everything that touches
    ``SlotState``, the resident bank, or the plan bank's membership runs
    on the dispatcher thread — producers only ever append to the shared
    admission queue — so the engine needs no slot-level locking.
    """

    def __init__(
        self,
        *,
        n_slots: int,
        metrics: Optional[ServeMetrics] = None,
        is_live: Optional[Callable[[Hashable], bool]] = None,
        on_complete: Optional[Callable[[Hashable, int], None]] = None,
        name: str = "slots",
    ):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        # pow2 lane count: together with the plan bank's pow2 lane
        # padding this keeps the compiled-variant count logarithmic
        self.n_slots = 1 << (int(n_slots) - 1).bit_length()
        self.name = name
        self.state = SlotState(self.n_slots)
        self.bank = GroupBank()
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self._is_live = is_live if is_live is not None else (lambda k: True)
        self._on_complete = (
            on_complete if on_complete is not None else (lambda k, c: None)
        )
        self.passes = 0  # dispatch passes actually executed
        self.occupancy_hist: Counter = Counter()  # occupancy -> passes
        # device residency, fixed by the first admitted solver
        self._cls = None  # the width class's BoundSolve subclass
        self._B = None  # resident rhs bank f[n, n_slots]
        self._dtype = None

    def _ensure_device(self, solver) -> None:
        if self._cls is None:
            self._cls = type(solver._bound)
            self._dtype = np.dtype(solver.dtype)
            self._B = self._cls.blank_rhs(
                solver.n, self.n_slots, self._dtype
            )

    def _run_pass(self, reqs: List[SlotRequest]) -> None:
        # lazy import: service.py imports this module at load time
        from repro.serve.service import GroupReplay

        admitted = []
        for r in reqs:
            try:
                self._ensure_device(r.solver)
                self.bank.add(r.key, r.solver)
                lane = self.state.admit(r.ticket)
            except Exception as e:
                r.ticket._fulfill(None, e)
                self.metrics.record_failure(r.ticket.fingerprint, 1)
                self._on_complete(r.key, 1)
                continue
            admitted.append((lane, r))
        if not admitted:
            return
        t0 = time.perf_counter()
        cls, B = self._cls, self._B
        for lane, r in admitted:
            B = cls.insert_lane(B, lane, np.asarray(r.b, self._dtype))
            r.ticket.t_admit = time.perf_counter()
        self._B = B
        occupied = {lane: r for lane, r in admitted}
        # dispatch the smallest pow2 lane prefix covering the occupants
        # (lanes allocate lowest-first, so the prefix is tight): a
        # lightly-loaded bank solves at width 2, not n_slots
        width = pad_width(max(occupied) + 1, self.n_slots)
        # free lanes inside the prefix solve their stale columns against
        # a filler plan — discarded results; lane independence keeps
        # them from ever touching an occupied lane's bits
        filler = admitted[0][1].key
        keys = [
            occupied[lane].key if lane in occupied else filler
            for lane in range(width)
        ]
        try:
            with obs.span(
                "serve.slot_pass",
                cat="serve",
                width=width,
                occupied=len(occupied),
            ):
                X = self.bank.solve_resident(keys, B)
            xs = {
                lane: np.asarray(cls.extract_lane(X, lane))
                for lane in occupied
            }
        except Exception as e:  # scatter the failure, keep serving
            for lane, r in occupied.items():
                self.state.evict(lane)
                r.ticket._fulfill(None, e)
            for fp, cnt in Counter(
                r.ticket.fingerprint for r in occupied.values()
            ).items():
                self.metrics.record_failure(fp, cnt)
            for key, cnt in Counter(
                r.key for r in occupied.values()
            ).items():
                self._on_complete(key, cnt)
            return
        t1 = time.perf_counter()
        for lane, r in occupied.items():
            t = r.ticket
            t.batch_width = width
            t.batch_position = lane
            t.served_by = GroupReplay(r.solver)
            t._fulfill(np.ascontiguousarray(xs[lane]))
            self.state.release(lane)
        self.passes += 1
        self.occupancy_hist[len(occupied)] += 1
        tickets = [r.ticket for r in occupied.values()]
        self.metrics.record_slot_pass(
            [t.fingerprint for t in tickets],
            queue_waits=[t.t_admit - t.t_submit for t in tickets],
            slot_times=[t.t_done - t.t_admit for t in tickets],
            e2e=[t.t_done - t.t_submit for t in tickets],
            solve_seconds=t1 - t0,
            occupancy=len(occupied),
            n_slots=self.n_slots,
        )
        for key, cnt in Counter(r.key for r in occupied.values()).items():
            self._on_complete(key, cnt)
        # retire bank lanes of drained, superseded versions — queried
        # per key at prune time (under the bank lock): any key with a
        # queued or in-lane request is pinned, hence still live
        self.bank.prune(self._is_live)

    # ------------------------------------------------------------- warm-up
    def warm(self, key, solver) -> None:
        """Compile every XLA variant this engine can dispatch for
        ``key``'s width class: the (n, S) insert/extract pair and the
        resident pass at each pow2 prefix width. Call BEFORE offering
        traffic (the service's ``prewarm`` does) — warming shares the
        resident device state with the dispatch thread and is only safe
        while that thread is idle."""
        self._ensure_device(solver)
        self.bank.add(key, solver)
        cls, B = self._cls, self._B
        b = np.zeros(solver.n, self._dtype)
        np.asarray(cls.extract_lane(cls.insert_lane(B, 0, b), 0))
        w = 1
        while w <= self.n_slots:
            width = pad_width(w, self.n_slots)
            np.asarray(
                cls.extract_lane(
                    self.bank.solve_resident([key] * width, B), 0
                )
            )
            if width >= self.n_slots:
                break
            w = width * 2

    # ----------------------------------------------------------- telemetry
    def describe(self) -> dict:
        return {
            "n_slots": self.n_slots,
            "passes": self.passes,
            "occupancy": self.state.occupancy,
            "occupancy_hist": dict(sorted(self.occupancy_hist.items())),
            "admitted": self.state.admitted,
            "completed": self.state.completed,
            "evicted": self.state.evicted,
            "bank": self.bank.describe(),
        }


class SlotDispatcher:
    """The single always-running dispatch loop behind every
    :class:`SlotEngine` of a service (see module docstring for why the
    loop is shared rather than per-engine).

    Producers ``submit(engine, ticket, key, solver, b)`` into one shared
    :class:`~repro.serve.batcher.AdmissionQueue`; the loop drains it,
    routes each request to its engine's pending deque, and round-robins
    ONE solve pass per engine with work — so a burst on one width class
    cannot starve the others for more than a pass, and every piece of
    slot/bank/resident state in the service is mutated by exactly this
    thread. When a class's pending backlog exceeds its free lanes the
    remainder simply stays pending and the next round picks it up —
    overflow costs extra passes, never an error.

    ``close`` stops admissions, lets the loop drain BOTH the shared
    queue and every pending deque (shutdown never strands a ticket),
    and joins the thread.
    """

    def __init__(self, name: str = "slots"):
        self._queue = AdmissionQueue()
        self._thread = threading.Thread(
            target=self._loop, name=f"slot-dispatch-{name}", daemon=True
        )
        self._thread.start()

    # ---------------------------------------------------------- admission
    def depth(self) -> int:
        """Requests accepted but not yet in a lane — the continuous
        path's share of the service's ``max_queue`` back-pressure bound
        (in-lane requests are counted by the engines' occupancy)."""
        return self._queue.depth()

    def submit(self, engine: SlotEngine, ticket, key, solver, b) -> None:
        """Queue one request for slot allocation on ``engine``. Raises
        RuntimeError once the dispatcher is closed (the service maps
        that to its own closed-state error)."""
        self._queue.put((engine, SlotRequest(ticket, key, solver, b)))

    # ------------------------------------------------------ dispatch loop
    def _loop(self) -> None:
        pending: Dict[SlotEngine, deque] = {}
        while True:
            if any(pending.values()):
                # work in hand: top up without blocking so a queued
                # burst lands in this round's passes
                items = self._queue.drain()
            else:
                items = self._queue.take(self._queue.UNBOUNDED)
                if not items:
                    return  # closed, shared queue and deques drained
            for engine, req in items:
                pending.setdefault(engine, deque()).append(req)
            self._queue.mark_pending(
                sum(len(q) for q in pending.values())
            )
            for engine, q in pending.items():
                if not q:
                    continue
                take = min(engine.state.free_count, len(q))
                if take:
                    engine._run_pass([q.popleft() for _ in range(take)])
            self._queue.mark_pending(
                sum(len(q) for q in pending.values())
            )

    # ----------------------------------------------------------- lifecycle
    def close(self, timeout: Optional[float] = None) -> bool:
        """Stop admissions, drain everything queued or pending (every
        accepted request is still served), join the loop thread.
        Returns True once the thread has exited."""
        self._queue.close()
        self._thread.join(timeout)
        return not self._thread.is_alive()

    def alive(self) -> bool:
        return self._thread.is_alive()
