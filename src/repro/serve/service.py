"""``SolveService`` — many independent solve requests in, few batched
multi-RHS solves out.

The paper amortizes one schedule across hundreds of solves (§7.7); the
service carries the same idea into a concurrent setting: client threads
``submit(a_or_fingerprint, b)`` single-RHS requests, an admission queue
routes them by sparsity-pattern fingerprint, and a worker loop coalesces
each route's backlog (up to ``max_batch`` / ``max_wait_us``) into one
``TriangularSolver.solve(B[n, m])`` against the cached plan, scattering
the columns back to per-request tickets.

Correctness contracts (enforced by tests/test_serve.py):

  * every served result is bitwise-identical to a direct multi-RHS
    ``solve`` of the same right-hand side on the pinned plan version at
    the dispatched (batch width, column position) — both recorded on the
    ticket; at a fixed width and position the executor's batched path
    never lets neighbor columns change a request's bits
    (``direct_reference``);
  * ``numeric_update`` swaps values in *between* microbatches: requests
    are pinned at admission to the then-current plan version
    (``serve.updates``), so an update never corrupts or drops queued work.

The service owns (or shares) a ``PlanCache`` and pins the plan entries it
serves, so cache-eviction pressure from pattern churn cannot evict a plan
with live traffic.

Back-pressure: ``max_queue`` bounds the admission queue. When the
backlog is at the bound, ``submit`` returns a ticket in the ``rejected``
state immediately (``result()`` raises ``QueueFullError``) instead of
letting the queue grow without bound; rejections are counted in the
metrics. Version swaps and numeric updates are never rejected — only
solve admissions are.

``mode="continuous"`` replaces microbatch formation with persistent
device-resident RHS slots (``repro.serve.slots``): admission is slot
allocation into an always-running dispatch loop — no batch-formation
deadline, no drain barrier between dispatches. Both correctness
contracts above carry over unchanged (slot tickets record
``batch_width = n_slots``, ``batch_position = lane`` and replay through
``GroupReplay``); patterns whose binding cannot group (e.g. elastic
bounds) transparently fall back to the microbatch path.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import Counter
from typing import Dict, Optional, Union

import numpy as np

from repro import obs
from repro.pipeline import GroupBank, PlanCache, TriangularSolver, grouped_solve
from repro.serve.batcher import MicroBatcher, normalize_max_batch, pad_width
from repro.serve.metrics import ServeMetrics, pretty
from repro.serve.slots import SlotDispatcher, SlotEngine
from repro.serve.updates import VersionedPlans
from repro.sparse.csr import CSRMatrix, pattern_fingerprint


class QueueFullError(RuntimeError):
    """Raised by ``SolveTicket.result()`` when the request was rejected
    at admission because the service's ``max_queue`` bound was hit."""


class SolveTicket:
    """Future for one submitted request. ``result()`` blocks until the
    microbatch containing this request has been served — or raises
    immediately if the request was ``rejected`` at admission
    (back-pressure)."""

    __slots__ = (
        "fingerprint", "version", "batch_width", "batch_position",
        "served_by", "rejected", "_event", "_result", "_error",
        "t_submit", "t_admit", "t_done",
    )

    def __init__(self, fingerprint: str, version: int):
        self.fingerprint = fingerprint
        self.version = version  # plan version pinned at admission
        self.rejected = False  # True: bounced by the admission bound
        self.batch_width: Optional[int] = None  # set at dispatch
        self.batch_position: Optional[int] = None  # column in the batch
        # the TriangularSolver that served this request — kept on the
        # ticket so verification can replay the exact solve even after
        # the version retires from the service's registry
        self.served_by: Optional[TriangularSolver] = None
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None
        self.t_submit = time.perf_counter()
        self.t_admit: Optional[float] = None  # continuous: lane insertion
        self.t_done: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError("solve request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._result

    def _fulfill(self, x, error: Optional[BaseException] = None) -> None:
        # exactly-once termination: a ticket that completed (or was
        # rejected) can never be fulfilled again — a second fulfill is
        # always a serving-loop bug (e.g. a lane double-completion), so
        # it raises instead of silently overwriting the first result
        if self._event.is_set():
            raise RuntimeError(
                f"ticket for pattern {self.fingerprint[:12]} fulfilled "
                "twice"
            )
        self._result = x
        self._error = error
        self.t_done = time.perf_counter()
        self._event.set()

    def _reject(self, depth: int, bound: int) -> None:
        self.rejected = True
        self._fulfill(
            None,
            QueueFullError(
                f"admission queue full ({depth} >= max_queue={bound}); "
                "request rejected — retry with backoff"
            ),
        )


class _Request:
    __slots__ = ("ticket", "b")

    def __init__(self, ticket: SolveTicket, b: np.ndarray):
        self.ticket = ticket
        self.b = b


class GroupReplay:
    """The bitwise reference solver for a width-class-grouped result.

    A cross-pattern grouped batch executes each column against its own
    plan through the vmapped grouped kernel, whose compiled graph differs
    from the plain multi-RHS path — so the replay for such a ticket is
    the SAME grouped kernel with the request's own solver replicated into
    every lane. Lane independence (a vmap lane's bits depend only on its
    own plan and rhs — property-tested) makes this reproduce the served
    bits exactly at the recorded (width, position). Exposes ``solve(B)``
    so ``direct_reference`` works on grouped tickets unchanged."""

    __slots__ = ("solver",)

    def __init__(self, solver: TriangularSolver):
        self.solver = solver

    def solve(self, B):
        B = np.asarray(B)
        return grouped_solve([self.solver] * B.shape[1], B)


def _width_class_label(wc) -> str:
    """Stable short handle for a width-class tuple — JSON dict keys in
    ``stats()`` (the raw tuple is neither a string nor hash-stable
    across processes)."""
    return "wc-" + hashlib.sha1(repr(wc).encode()).hexdigest()[:12]


def direct_reference(
    solver: TriangularSolver, b, width: int = 2, position: int = 0
) -> np.ndarray:
    """The bitwise reference for a served result: a direct
    ``solver.solve`` of a batch with ``b`` at column ``position`` (zeros
    elsewhere), at the dispatched width — both recorded on the ticket
    (``batch_width`` / ``batch_position``). At a fixed (width, position),
    a column's bits are independent of what the other columns hold
    (property-tested in tests/test_serve.py), so this reproduces the
    served bits exactly; across widths/positions XLA may vectorize the
    batched einsum differently, so only float-tolerance comparisons
    apply there."""
    b = np.asarray(b)
    B = np.zeros((b.shape[0], max(width, 1)), b.dtype)
    B[:, position] = b
    x = np.asarray(solver.solve(B))
    return x[:, position]


class SolveService:
    """Batching SpTRSV solve service over ``repro.pipeline``.

    Parameters mirror the two serving knobs plus the plan binding:
    ``max_batch`` / ``max_wait_us`` bound each microbatch's size and
    latency cost (``max_batch`` is normalized DOWN to a power of two —
    the log2 compiled-variant bound); ``max_queue`` bounds the admission
    backlog (None = unbounded; at the bound, submits come back
    ``rejected`` instead of growing the queue); ``n_workers`` executes
    batches concurrently (distinct routes only — one batch owns its
    whole route group); ``width_class_batching=True`` routes requests by
    structural plan identity instead of (pattern, version), so
    structurally-identical patterns coalesce into one grouped multi-RHS
    solve (scan backend; each column keeps its own pattern/values and
    its bitwise (width, position) contract via ``GroupReplay``);
    everything in ``plan_defaults`` (strategy, backend, dtype, k, mesh,
    ...) flows to ``TriangularSolver.plan`` at registration. With
    ``backend="distributed"`` the worker loop additionally rounds each
    dispatch width up to a multiple of the mesh's ``data`` axis, so
    batches shard cleanly instead of padding inside the backend.

    ``mode`` selects the serving engine: ``"microbatch"`` (default,
    everything above) or ``"continuous"`` — persistent device-resident
    RHS slots with an always-running dispatch loop per width class
    (``repro.serve.slots``; ``n_slots`` lanes each, default
    ``max_batch``, normalized UP to a power of two). Continuous mode
    requires the backend to advertise the ``"slots"`` capability;
    groupable patterns of one width class share an engine (cross-
    pattern by construction, no ``width_class_batching`` flag needed),
    while non-groupable patterns (elastic bounds, ``slack=N`` in the
    plan defaults) fall back to the microbatch path — the service-level
    ``mode`` knob is about the serving loop, not the solve graph.
    """

    def __init__(
        self,
        *,
        max_batch: int = 32,
        max_wait_us: int = 2000,
        max_queue: Optional[int] = None,
        n_workers: int = 1,
        width_class_batching: bool = False,
        mode: str = "microbatch",
        n_slots: Optional[int] = None,
        cache: Optional[PlanCache] = None,
        strategy: str = "auto",
        **plan_defaults,
    ):
        self.max_batch = normalize_max_batch(max_batch)
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        self.max_queue = max_queue
        self.width_class_batching = width_class_batching
        if mode not in ("microbatch", "continuous"):
            raise ValueError(
                f"mode must be 'microbatch' or 'continuous'; got {mode!r}"
            )
        self.mode = mode
        self.n_slots = self.max_batch if n_slots is None else int(n_slots)
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if mode == "continuous":
            from repro.backends import get_backend

            backend = plan_defaults.get("backend", "scan")
            if "slots" not in get_backend(backend).capabilities():
                raise ValueError(
                    f"mode='continuous' needs a backend with the 'slots' "
                    f"capability (resident RHS slots); backend "
                    f"{backend!r} does not advertise it"
                )
            # continuous serving lives on groupable (bankable) bindings;
            # left to itself, strategy='auto' may flip deep patterns to
            # elastic mode, whose bounds cannot join a bank — silently
            # routing a slice of traffic through the microbatch fallback
            # and re-importing the formation deadline this mode removes.
            # Pin auto selection to bulk-synchronous unless the caller
            # explicitly opts a pattern into elastic (those still serve,
            # via the fallback path).
            plan_defaults.setdefault("mode", "bsp")
        self._engines: Dict[tuple, SlotEngine] = {}  # wc -> slot engine
        # one dispatch loop drives every engine (see slots module doc)
        self._dispatcher = (
            SlotDispatcher() if mode == "continuous" else None
        )
        self.cache = cache if cache is not None else PlanCache()
        self._plan_defaults = dict(strategy=strategy, **plan_defaults)
        # mesh-sharded serving: batches shard over the mesh's 'data' axis,
        # so the worker loop aligns dispatch widths to it up front
        mesh = plan_defaults.get("mesh")
        self._mesh = mesh
        self._batch_align = (
            int(dict(mesh.shape).get("data", 1))
            if mesh is not None
            and plan_defaults.get("backend") == "distributed"
            else 1
        )
        self._patterns: Dict[str, VersionedPlans] = {}
        self._width_classes: Dict[tuple, set] = {}  # wc -> fingerprints
        self._banks: Dict[tuple, GroupBank] = {}  # wc -> device bank
        self._pinned_keys: set = set()  # released at close()
        self._pins_released = False
        self._plock = threading.Lock()
        self._batcher = MicroBatcher(
            max_batch=self.max_batch, max_wait_us=max_wait_us
        )
        self.metrics = ServeMetrics()
        self._closed = False
        self._workers = [
            threading.Thread(
                target=self._worker_loop, name=f"solve-worker-{i}",
                daemon=True,
            )
            for i in range(max(n_workers, 1))
        ]
        self.n_workers = len(self._workers)
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------ patterns
    def register(
        self, a: CSRMatrix, *, lower: bool = True, **plan_kwargs
    ) -> str:
        """Plan (or re-use) the solver for ``a``'s sparsity pattern;
        returns the pattern fingerprint — the cheap handle clients pass
        to ``submit`` to skip re-hashing. Registering an already-known
        pattern with new values is an implicit ``numeric_update``."""
        if self._closed:
            # a post-close registration would pin a cache key that no
            # close() will ever release
            raise RuntimeError("service is closed")
        fp = pattern_fingerprint(a)
        vp = self._patterns.get(fp)
        if vp is not None and vp.lower != lower:
            raise ValueError(
                f"pattern {fp[:12]}… is registered with "
                f"lower={vp.lower}; re-registering it with lower={lower} "
                "would silently change the solve orientation"
            )
        if vp is None:
            # plan outside the registry lock (the inspector can take
            # seconds); racing registrations of one pattern share plan
            # work through the PlanCache and keep the first-inserted entry
            solver = TriangularSolver.plan(
                a,
                cache=self.cache,
                lower=lower,
                **{**self._plan_defaults, **plan_kwargs},
            )
            if solver.plan_key is not None:
                self.cache.pin(solver.plan_key)
                self.cache.note_width_class(
                    solver.width_class, solver.plan_key
                )
                with self._plock:
                    # a close() that already released the pins will never
                    # run again for this key — racing past the _closed
                    # check above must not leak an eternal pin into a
                    # shared cache
                    too_late = self._pins_released
                    if not too_late:
                        self._pinned_keys.add(solver.plan_key)
                if too_late:
                    self.cache.unpin(solver.plan_key)
                    raise RuntimeError("service is closed")
            with self._plock:
                vp = self._patterns.get(fp)
                if vp is None:
                    vp = VersionedPlans(solver, lower=lower)
                    self._patterns[fp] = vp
                    if vp.width_class is not None:
                        self._width_classes.setdefault(
                            vp.width_class, set()
                        ).add(fp)
                    return fp
        if vp.lower != lower:  # racing registration with other orientation
            raise ValueError(
                f"pattern {fp[:12]}… is registered with lower={vp.lower}"
            )
        if not vp.values_match(np.asarray(a.data)):
            self.numeric_update(fp, a.data)
        return fp

    def pattern(self, fp: str) -> VersionedPlans:
        try:
            return self._patterns[fp]
        except KeyError:
            raise KeyError(
                f"unknown pattern fingerprint {fp!r}; submit the CSRMatrix "
                "itself (auto-registers) or call register(a) first"
            ) from None

    # --------------------------------------------------- continuous engines
    def _key_live(self, key) -> bool:
        """Bank-lane liveness for the slot engines' prune: a
        ``(fingerprint, version)`` key is prunable once its version has
        retired from the registry. Queried at prune time under the bank
        lock — any queued or in-lane request pins its version, so a
        live lane can never be seen as dead."""
        fp, version = key
        vp = self._patterns.get(fp)
        return vp is not None and version in vp.live_versions()

    def _key_complete(self, key, count: int) -> None:
        """Unpin ``count`` served requests from their admitted version
        (the slot engines' mirror of the worker loops'
        ``VersionedPlans.complete``)."""
        fp, version = key
        self._patterns[fp].complete(version, count)

    def _engine_for(self, wc) -> SlotEngine:
        """The width class's slot engine, created on first use (lanes
        only materialize on device for classes that actually serve)."""
        with self._plock:
            eng = self._engines.get(wc)
            if eng is None:
                eng = self._engines[wc] = SlotEngine(
                    n_slots=self.n_slots,
                    metrics=self.metrics,
                    is_live=self._key_live,
                    on_complete=self._key_complete,
                    name=_width_class_label(wc),
                )
            return eng

    def _backlog(self) -> int:
        """Total admission backlog across both serving paths — the
        quantity ``max_queue`` bounds."""
        with self._plock:
            engines = list(self._engines.values())
        depth = self._batcher.depth()
        if self._dispatcher is not None:
            depth += self._dispatcher.depth()
        return depth + sum(e.state.occupancy for e in engines)

    # ------------------------------------------------------------- serving
    def submit(
        self,
        a_or_fp: Union[CSRMatrix, str],
        b,
        *,
        lower: Optional[bool] = None,
        **plan_kwargs,
    ) -> SolveTicket:
        """Enqueue one single-RHS solve; returns a ``SolveTicket``.
        ``a_or_fp`` is either a fingerprint from ``register`` (the fast
        path — no hashing, no value comparison; orientation and plan
        binding were fixed at registration, so ``lower``/``plan_kwargs``
        only cross-check) or a ``CSRMatrix`` (auto-registers; same
        pattern with new values triggers an implicit
        ``numeric_update``)."""
        if self._closed:
            raise RuntimeError("service is closed")
        if isinstance(a_or_fp, CSRMatrix):
            fp = self.register(
                a_or_fp,
                lower=True if lower is None else lower,
                **plan_kwargs,
            )
            vp = self.pattern(fp)
        else:
            fp = a_or_fp
            vp = self.pattern(fp)
            if lower is not None and lower != vp.lower:
                raise ValueError(
                    f"pattern {fp[:12]}… was registered with "
                    f"lower={vp.lower}; it cannot serve lower={lower} "
                    "requests"
                )
        b = np.asarray(b)
        if b.ndim != 1 or b.shape[0] != vp.n:
            raise ValueError(
                f"submit takes one right-hand side f[n={vp.n}]; got "
                f"{b.shape} (batching is the service's job)"
            )
        # admission bound: bounce instead of growing the backlog. The
        # check-then-put is advisory (racing submits may briefly overshoot
        # by n_producers), which is the standard cheap admission-control
        # trade-off — the queue stays O(max_queue), never unbounded.
        if self.max_queue is not None:
            depth = self._backlog()
            if depth >= self.max_queue:
                ticket = SolveTicket(fp, -1)
                self.metrics.record_rejected(fp)
                ticket._reject(depth, self.max_queue)
                return ticket
        # continuous mode: groupable patterns go to their width class's
        # slot engine — admission is slot allocation, not group
        # formation. Non-groupable bindings (elastic bounds have no
        # banked twin) fall back to the microbatch path below.
        if self.mode == "continuous" and vp.groupable:
            version, solver = vp.admit()
            ticket = SolveTicket(fp, version)
            self.metrics.record_submit(fp)
            try:
                self._dispatcher.submit(
                    self._engine_for(vp.width_class),
                    ticket,
                    (fp, version),
                    solver,
                    b,
                )
            except RuntimeError:
                vp.complete(version)
                raise
            return ticket
        version, _ = vp.admit()
        ticket = SolveTicket(fp, version)
        self.metrics.record_submit(fp)
        # width-class routing coalesces structurally-identical plans into
        # one grouped dispatch; each request still pins (and is served
        # by) its own (pattern, version) — the route only widens WHO can
        # share a batch, never what values a column sees
        if self.width_class_batching and vp.groupable:
            route = ("wc", vp.width_class)
        else:
            route = (fp, version)
        try:
            self._batcher.put(route, _Request(ticket, b))
        except RuntimeError:
            vp.complete(version)
            raise
        return ticket

    def solve(
        self,
        a_or_fp: Union[CSRMatrix, str],
        b,
        *,
        timeout: Optional[float] = None,
        **kw,
    ) -> np.ndarray:
        """Blocking convenience: ``submit(...).result(timeout)``."""
        return self.submit(a_or_fp, b, **kw).result(timeout)

    def numeric_update(
        self, a_or_fp: Union[CSRMatrix, str], data=None
    ) -> int:
        """Install new factor values for a registered pattern; returns the
        new plan version. Requests already admitted stay pinned to their
        version — the swap is only visible to later submissions."""
        if isinstance(a_or_fp, CSRMatrix):
            fp = pattern_fingerprint(a_or_fp)
            payload = a_or_fp  # clone_with_values re-checks the pattern
        else:
            fp = a_or_fp
            if data is None:
                raise ValueError(
                    "numeric_update(fingerprint) needs the new values"
                )
            payload = np.asarray(data)
        vp = self.pattern(fp)
        v = vp.update(payload)
        self.metrics.record_update(fp)
        return v

    # -------------------------------------------------------------- worker
    def _worker_loop(self) -> None:
        while True:
            item = self._batcher.next_batch()
            if item is None:
                return
            route, reqs = item
            if route and route[0] == "wc":
                self._serve_group(route[1], reqs)
            else:
                fp, version = route
                self._serve_plain(fp, version, reqs)

    def _dispatch_width(self, m: int) -> int:
        """The batch width actually dispatched for ``m`` requests: pow2
        quantization (``pad_width``) then — mesh-sharded serving — round
        UP to a multiple of the mesh's ``data`` axis, so the distributed
        backend shards the batch instead of padding it internally. Still
        at most log2(max_batch) distinct widths."""
        w = pad_width(m, self.max_batch)
        if self._batch_align > 1:
            w = -(-w // self._batch_align) * self._batch_align
        return w

    def _serve_plain(self, fp: str, version: int, reqs) -> None:
        """One (pattern, version) microbatch — the classic multi-RHS
        path; every column shares one solver."""
        vp = self._patterns[fp]
        t0 = time.perf_counter()
        try:
            solver = vp.solver_for(version)
            m = len(reqs)
            B = np.stack([r.b for r in reqs], axis=1)
            w = self._dispatch_width(m)
            if w > m:
                B = np.concatenate(
                    [B, np.zeros((B.shape[0], w - m), B.dtype)], axis=1
                )
            with obs.span(
                "serve.microbatch", cat="serve", size=m, width=w
            ):
                X = np.asarray(solver.solve(B))
            t1 = time.perf_counter()
            for j, r in enumerate(reqs):
                r.ticket.batch_width = w
                r.ticket.batch_position = j
                r.ticket.served_by = solver
                r.ticket._fulfill(np.ascontiguousarray(X[:, j]))
            self.metrics.record_batch(
                fp,
                m,
                queue_waits=[t0 - r.ticket.t_submit for r in reqs],
                e2e=[r.ticket.t_done - r.ticket.t_submit for r in reqs],
                solve_seconds=t1 - t0,
            )
        except Exception as e:  # scatter the failure, keep serving
            for r in reqs:
                r.ticket._fulfill(None, e)
            self.metrics.record_failure(fp, len(reqs))
        finally:
            vp.complete(version, len(reqs))

    def _serve_group(self, wc, reqs) -> None:
        """One width-class microbatch: columns may come from different
        patterns and plan versions (one solver per column), executed
        through the class's device-side ``GroupBank`` — one jitted call,
        no per-dispatch tensor stacking. A group that happens to be
        homogeneous takes the plain path — same bits, same
        ``direct_reference`` contract as before."""
        req_keys = [
            (r.ticket.fingerprint, r.ticket.version) for r in reqs
        ]
        if len(set(req_keys)) == 1:
            fp, version = req_keys[0]
            self._serve_plain(fp, version, reqs)
            return
        t0 = time.perf_counter()
        try:
            solvers = [
                self._patterns[fp].solver_for(version)
                for fp, version in req_keys
            ]
            bank = self._banks.setdefault(wc, GroupBank())
            for key, solver in zip(req_keys, solvers):
                bank.add(key, solver)
            # retire bank lanes of drained, superseded versions (their
            # VersionedPlans entry is gone, so they can never dispatch).
            # Liveness is queried INSIDE the prune (under the bank lock,
            # serialized with concurrent adds) — a hoisted snapshot could
            # go stale against another worker's just-added lane and drop
            # it: any in-flight batch pins its versions, so a
            # query-at-prune-time can never see them as dead.
            fps_touched = {fp for fp, _ in req_keys}
            bank.prune(
                lambda k: k[0] not in fps_touched
                or k[1] in self._patterns[k[0]].live_versions()
            )
            m = len(reqs)
            w = self._dispatch_width(m)
            B = np.stack([r.b for r in reqs], axis=1)
            keys = list(req_keys)
            if w > m:
                B = np.concatenate(
                    [B, np.zeros((B.shape[0], w - m), B.dtype)], axis=1
                )
                keys = keys + [keys[0]] * (w - m)  # padding lanes
            with obs.span(
                "serve.grouped_batch",
                cat="serve",
                size=m,
                width=w,
                patterns=len(fps_touched),
            ):
                X = np.asarray(bank.solve(keys, B))
            t1 = time.perf_counter()
            for j, r in enumerate(reqs):
                r.ticket.batch_width = w
                r.ticket.batch_position = j
                r.ticket.served_by = GroupReplay(solvers[j])
                r.ticket._fulfill(np.ascontiguousarray(X[:, j]))
            self.metrics.record_grouped_batch(
                [r.ticket.fingerprint for r in reqs],
                queue_waits=[t0 - r.ticket.t_submit for r in reqs],
                e2e=[r.ticket.t_done - r.ticket.t_submit for r in reqs],
                solve_seconds=t1 - t0,
            )
        except Exception as e:  # scatter the failure, keep serving
            for r in reqs:
                r.ticket._fulfill(None, e)
            for fp, cnt in Counter(
                r.ticket.fingerprint for r in reqs
            ).items():
                self.metrics.record_failure(fp, cnt)
        finally:
            done = Counter(
                (r.ticket.fingerprint, r.ticket.version) for r in reqs
            )
            for (fp, version), cnt in done.items():
                self._patterns[fp].complete(version, cnt)

    # ------------------------------------------------------------- warm-up
    def prewarm(self) -> None:
        """Compile every XLA variant serving can dispatch — per pattern,
        each pow2 (data-axis-aligned) batch width; per width class with
        cross-pattern batching on, the banked grouped variant at each
        width. Benchmarks call this before measuring so steady-state
        percentiles never include compile time."""
        widths = sorted(
            {
                self._dispatch_width(m)
                for m in range(1, self.max_batch + 1)
            }
        )
        with self._plock:
            patterns = list(self._patterns.items())
            classes = {
                wc: sorted(fps)
                for wc, fps in self._width_classes.items()
            }
        for fp, vp in patterns:
            solver = vp.current_solver()
            dtype = np.dtype(solver.dtype)
            for w in widths:
                np.asarray(solver.solve(np.zeros((vp.n, w), dtype)))
        if self.mode == "continuous":
            # compile the slot engines' variants per groupable pattern:
            # the (n, S) insert/extract pair plus the resident pass at
            # every pow2 prefix width — warmed in registration order, so
            # the later patterns warm against the bank lane counts the
            # steady state will use
            for fp, vp in patterns:
                if vp.groupable:
                    version, solver = vp.current_entry()
                    self._engine_for(vp.width_class).warm(
                        (fp, version), solver
                    )
        if not self.width_class_batching:
            return
        for wc, fps in classes.items():
            groupable = [
                fp for fp in fps if self._patterns[fp].groupable
            ]
            if len(groupable) < 2:
                continue
            bank = self._banks.setdefault(wc, GroupBank())
            keys = []
            for fp in groupable:
                vp = self._patterns[fp]
                # one atomic read: (version, solver) must pair up, or a
                # racing numeric_update could register a lane keyed by
                # the old version holding the new version's values
                version, solver = vp.current_entry()
                key = (fp, version)
                bank.add(key, solver)
                keys.append(key)
            n = self._patterns[groupable[0]].n
            dtype = np.dtype(
                self._patterns[groupable[0]].current_solver().dtype
            )
            for w in widths:
                lanes = [keys[j % len(keys)] for j in range(w)]
                np.asarray(bank.solve(lanes, np.zeros((n, w), dtype)))

    # ------------------------------------------------------------ lifecycle
    def close(self, timeout: Optional[float] = None) -> dict:
        """Stop admissions, drain the queue, join the workers; release
        the plan-cache eviction pins only once every worker has actually
        exited. A worker still alive after ``timeout`` may hold an
        in-flight batch against a pinned plan — unpinning then would let
        LRU eviction race the batch — so the pins are RETAINED and
        reported instead; call ``close()`` again (it is idempotent and
        retries the join) once the stall clears.

        Returns a report dict: ``workers_alive`` (names of workers that
        missed the timeout), ``pins_released``, ``pins_retained``."""
        self._closed = True
        self._batcher.close()
        deadline = (
            None if timeout is None else time.perf_counter() + timeout
        )
        stuck = []
        for w in self._workers:
            if deadline is None:
                w.join()
            else:
                w.join(max(0.0, deadline - time.perf_counter()))
            if w.is_alive():
                stuck.append(w.name)
        # the slot dispatcher drains its queue and every engine's pending
        # work before exiting — shutdown never strands a continuous-mode
        # ticket
        if self._dispatcher is not None:
            joined = self._dispatcher.close(
                None
                if deadline is None
                else max(0.0, deadline - time.perf_counter())
            )
            if not joined:
                stuck.append("slot-dispatch")
        if stuck:
            with self._plock:
                retained = len(self._pinned_keys)
            return {
                "workers_alive": stuck,
                "pins_released": 0,
                "pins_retained": retained,
            }
        # release the eviction pins — a shared PlanCache outliving this
        # service must regain its normal LRU behavior
        with self._plock:
            keys, self._pinned_keys = self._pinned_keys, set()
            self._pins_released = True
        for key in keys:
            self.cache.unpin(key)
        return {
            "workers_alive": [],
            "pins_released": len(keys),
            "pins_retained": 0,
        }

    def __enter__(self) -> "SolveService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """JSON-ready snapshot: serving telemetry + plan-cache stats +
        live plan versions per pattern."""
        cs = self.cache.stats
        looked_up = cs.hits + cs.misses
        # snapshot under the registry lock: submit(CSRMatrix) auto-registers
        # concurrently, and iterating the live dict while it grows would
        # crash the telemetry thread
        with self._plock:
            patterns = list(self._patterns.items())
            width_classes = {
                wc: sorted(fps) for wc, fps in self._width_classes.items()
            }
            engines = dict(self._engines)
        wc_labels = {wc: _width_class_label(wc) for wc in width_classes}
        return self.metrics.snapshot(
            queue_depth=self._backlog(),
            extra={
                "serving": {
                    "mode": self.mode,
                    "n_workers": self.n_workers,
                    "workers_alive": sum(
                        w.is_alive() for w in self._workers
                    ),
                    "max_batch": self.max_batch,
                    "n_slots": self.n_slots,
                    "batch_align": self._batch_align,
                    "width_class_batching": self.width_class_batching,
                    "mesh": dict(self._mesh.shape)
                    if self._mesh is not None
                    else None,
                },
                "plan_cache": {
                    **cs.as_dict(),
                    "hit_rate": round(cs.hits / looked_up, 3)
                    if looked_up
                    else 0.0,
                },
                # classes with >1 pattern are live cross-pattern batching
                # opportunities (the width mix's whole premise)
                "width_classes": {
                    wc_labels[wc]: {
                        "n_patterns": len(fps),
                        "patterns": fps,
                        # bank telemetry: live device lanes + restacks
                        "bank": self._banks[wc].describe()
                        if wc in self._banks
                        else None,
                        # continuous mode: the class's slot engine
                        "slots": engines[wc].describe()
                        if wc in engines
                        else None,
                    }
                    for wc, fps in width_classes.items()
                },
                "patterns": {
                    fp: {
                        "versions_alive": vp.live_versions(),
                        "current_version": vp.current,
                        "width_class": wc_labels.get(vp.width_class),
                        # the backend BoundSolve's own telemetry (shapes,
                        # device bytes, compiled variants) — registry
                        # backends all speak describe(); current_solver()
                        # reads atomically so a racing update cannot
                        # retire the version mid-lookup
                        "binding": vp.current_solver().bound.describe(),
                    }
                    for fp, vp in patterns
                },
                # repro.obs cross-layer tracing aggregate — one merged
                # telemetry document per service: serve metrics above,
                # span/counter rollup here ({"enabled": False} when
                # tracing is off)
                "obs": obs.summary(),
            },
        )

    def print_stats(self) -> None:
        print(pretty(self.stats()), flush=True)
