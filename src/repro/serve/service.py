"""``SolveService`` — many independent solve requests in, few batched
multi-RHS solves out.

The paper amortizes one schedule across hundreds of solves (§7.7); the
service carries the same idea into a concurrent setting: client threads
``submit(a_or_fingerprint, b)`` single-RHS requests, an admission queue
routes them by sparsity-pattern fingerprint, and a worker loop coalesces
each route's backlog (up to ``max_batch`` / ``max_wait_us``) into one
``TriangularSolver.solve(B[n, m])`` against the cached plan, scattering
the columns back to per-request tickets.

Correctness contracts (enforced by tests/test_serve.py):

  * every served result is bitwise-identical to a direct multi-RHS
    ``solve`` of the same right-hand side on the pinned plan version at
    the dispatched (batch width, column position) — both recorded on the
    ticket; at a fixed width and position the executor's batched path
    never lets neighbor columns change a request's bits
    (``direct_reference``);
  * ``numeric_update`` swaps values in *between* microbatches: requests
    are pinned at admission to the then-current plan version
    (``serve.updates``), so an update never corrupts or drops queued work.

The service owns (or shares) a ``PlanCache`` and pins the plan entries it
serves, so cache-eviction pressure from pattern churn cannot evict a plan
with live traffic.

Back-pressure: ``max_queue`` bounds the admission queue. When the
backlog is at the bound, ``submit`` returns a ticket in the ``rejected``
state immediately (``result()`` raises ``QueueFullError``) instead of
letting the queue grow without bound; rejections are counted in the
metrics. Version swaps and numeric updates are never rejected — only
solve admissions are.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Union

import numpy as np

from repro.pipeline import PlanCache, TriangularSolver
from repro.serve.batcher import MicroBatcher, pad_width
from repro.serve.metrics import ServeMetrics, pretty
from repro.serve.updates import VersionedPlans
from repro.sparse.csr import CSRMatrix, pattern_fingerprint


class QueueFullError(RuntimeError):
    """Raised by ``SolveTicket.result()`` when the request was rejected
    at admission because the service's ``max_queue`` bound was hit."""


class SolveTicket:
    """Future for one submitted request. ``result()`` blocks until the
    microbatch containing this request has been served — or raises
    immediately if the request was ``rejected`` at admission
    (back-pressure)."""

    __slots__ = (
        "fingerprint", "version", "batch_width", "batch_position",
        "served_by", "rejected", "_event", "_result", "_error",
        "t_submit", "t_done",
    )

    def __init__(self, fingerprint: str, version: int):
        self.fingerprint = fingerprint
        self.version = version  # plan version pinned at admission
        self.rejected = False  # True: bounced by the admission bound
        self.batch_width: Optional[int] = None  # set at dispatch
        self.batch_position: Optional[int] = None  # column in the batch
        # the TriangularSolver that served this request — kept on the
        # ticket so verification can replay the exact solve even after
        # the version retires from the service's registry
        self.served_by: Optional[TriangularSolver] = None
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError("solve request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._result

    def _fulfill(self, x, error: Optional[BaseException] = None) -> None:
        self._result = x
        self._error = error
        self.t_done = time.perf_counter()
        self._event.set()

    def _reject(self, depth: int, bound: int) -> None:
        self.rejected = True
        self._fulfill(
            None,
            QueueFullError(
                f"admission queue full ({depth} >= max_queue={bound}); "
                "request rejected — retry with backoff"
            ),
        )


class _Request:
    __slots__ = ("ticket", "b")

    def __init__(self, ticket: SolveTicket, b: np.ndarray):
        self.ticket = ticket
        self.b = b


def direct_reference(
    solver: TriangularSolver, b, width: int = 2, position: int = 0
) -> np.ndarray:
    """The bitwise reference for a served result: a direct
    ``solver.solve`` of a batch with ``b`` at column ``position`` (zeros
    elsewhere), at the dispatched width — both recorded on the ticket
    (``batch_width`` / ``batch_position``). At a fixed (width, position),
    a column's bits are independent of what the other columns hold
    (property-tested in tests/test_serve.py), so this reproduces the
    served bits exactly; across widths/positions XLA may vectorize the
    batched einsum differently, so only float-tolerance comparisons
    apply there."""
    b = np.asarray(b)
    B = np.zeros((b.shape[0], max(width, 1)), b.dtype)
    B[:, position] = b
    x = np.asarray(solver.solve(B))
    return x[:, position]


class SolveService:
    """Batching SpTRSV solve service over ``repro.pipeline``.

    Parameters mirror the two serving knobs plus the plan binding:
    ``max_batch`` / ``max_wait_us`` bound each microbatch's size and
    latency cost; ``max_queue`` bounds the admission backlog (None =
    unbounded; at the bound, submits come back ``rejected`` instead of
    growing the queue); ``n_workers`` executes batches concurrently
    (distinct routes only — one batch owns its whole route group);
    everything in ``plan_defaults`` (strategy, backend, dtype, k, ...)
    flows to ``TriangularSolver.plan`` at registration.
    """

    def __init__(
        self,
        *,
        max_batch: int = 32,
        max_wait_us: int = 2000,
        max_queue: Optional[int] = None,
        n_workers: int = 1,
        cache: Optional[PlanCache] = None,
        strategy: str = "auto",
        **plan_defaults,
    ):
        self.max_batch = max_batch
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        self.max_queue = max_queue
        self.cache = cache if cache is not None else PlanCache()
        self._plan_defaults = dict(strategy=strategy, **plan_defaults)
        self._patterns: Dict[str, VersionedPlans] = {}
        self._pinned_keys: set = set()  # released at close()
        self._plock = threading.Lock()
        self._batcher = MicroBatcher(
            max_batch=max_batch, max_wait_us=max_wait_us
        )
        self.metrics = ServeMetrics()
        self._closed = False
        self._workers = [
            threading.Thread(
                target=self._worker_loop, name=f"solve-worker-{i}",
                daemon=True,
            )
            for i in range(max(n_workers, 1))
        ]
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------ patterns
    def register(
        self, a: CSRMatrix, *, lower: bool = True, **plan_kwargs
    ) -> str:
        """Plan (or re-use) the solver for ``a``'s sparsity pattern;
        returns the pattern fingerprint — the cheap handle clients pass
        to ``submit`` to skip re-hashing. Registering an already-known
        pattern with new values is an implicit ``numeric_update``."""
        if self._closed:
            # a post-close registration would pin a cache key that no
            # close() will ever release
            raise RuntimeError("service is closed")
        fp = pattern_fingerprint(a)
        vp = self._patterns.get(fp)
        if vp is not None and vp.lower != lower:
            raise ValueError(
                f"pattern {fp[:12]}… is registered with "
                f"lower={vp.lower}; re-registering it with lower={lower} "
                "would silently change the solve orientation"
            )
        if vp is None:
            # plan outside the registry lock (the inspector can take
            # seconds); racing registrations of one pattern share plan
            # work through the PlanCache and keep the first-inserted entry
            solver = TriangularSolver.plan(
                a,
                cache=self.cache,
                lower=lower,
                **{**self._plan_defaults, **plan_kwargs},
            )
            if solver.plan_key is not None:
                self.cache.pin(solver.plan_key)
                with self._plock:
                    self._pinned_keys.add(solver.plan_key)
            with self._plock:
                vp = self._patterns.get(fp)
                if vp is None:
                    self._patterns[fp] = VersionedPlans(solver, lower=lower)
                    return fp
        if vp.lower != lower:  # racing registration with other orientation
            raise ValueError(
                f"pattern {fp[:12]}… is registered with lower={vp.lower}"
            )
        if not vp.values_match(np.asarray(a.data)):
            self.numeric_update(fp, a.data)
        return fp

    def pattern(self, fp: str) -> VersionedPlans:
        try:
            return self._patterns[fp]
        except KeyError:
            raise KeyError(
                f"unknown pattern fingerprint {fp!r}; submit the CSRMatrix "
                "itself (auto-registers) or call register(a) first"
            ) from None

    # ------------------------------------------------------------- serving
    def submit(
        self,
        a_or_fp: Union[CSRMatrix, str],
        b,
        *,
        lower: Optional[bool] = None,
        **plan_kwargs,
    ) -> SolveTicket:
        """Enqueue one single-RHS solve; returns a ``SolveTicket``.
        ``a_or_fp`` is either a fingerprint from ``register`` (the fast
        path — no hashing, no value comparison; orientation and plan
        binding were fixed at registration, so ``lower``/``plan_kwargs``
        only cross-check) or a ``CSRMatrix`` (auto-registers; same
        pattern with new values triggers an implicit
        ``numeric_update``)."""
        if self._closed:
            raise RuntimeError("service is closed")
        if isinstance(a_or_fp, CSRMatrix):
            fp = self.register(
                a_or_fp,
                lower=True if lower is None else lower,
                **plan_kwargs,
            )
            vp = self.pattern(fp)
        else:
            fp = a_or_fp
            vp = self.pattern(fp)
            if lower is not None and lower != vp.lower:
                raise ValueError(
                    f"pattern {fp[:12]}… was registered with "
                    f"lower={vp.lower}; it cannot serve lower={lower} "
                    "requests"
                )
        b = np.asarray(b)
        if b.ndim != 1 or b.shape[0] != vp.n:
            raise ValueError(
                f"submit takes one right-hand side f[n={vp.n}]; got "
                f"{b.shape} (batching is the service's job)"
            )
        # admission bound: bounce instead of growing the backlog. The
        # check-then-put is advisory (racing submits may briefly overshoot
        # by n_producers), which is the standard cheap admission-control
        # trade-off — the queue stays O(max_queue), never unbounded.
        if (
            self.max_queue is not None
            and self._batcher.depth() >= self.max_queue
        ):
            ticket = SolveTicket(fp, -1)
            self.metrics.record_rejected(fp)
            ticket._reject(self._batcher.depth(), self.max_queue)
            return ticket
        version, _ = vp.admit()
        ticket = SolveTicket(fp, version)
        self.metrics.record_submit(fp)
        try:
            self._batcher.put((fp, version), _Request(ticket, b))
        except RuntimeError:
            vp.complete(version)
            raise
        return ticket

    def solve(
        self,
        a_or_fp: Union[CSRMatrix, str],
        b,
        *,
        timeout: Optional[float] = None,
        **kw,
    ) -> np.ndarray:
        """Blocking convenience: ``submit(...).result(timeout)``."""
        return self.submit(a_or_fp, b, **kw).result(timeout)

    def numeric_update(
        self, a_or_fp: Union[CSRMatrix, str], data=None
    ) -> int:
        """Install new factor values for a registered pattern; returns the
        new plan version. Requests already admitted stay pinned to their
        version — the swap is only visible to later submissions."""
        if isinstance(a_or_fp, CSRMatrix):
            fp = pattern_fingerprint(a_or_fp)
            payload = a_or_fp  # clone_with_values re-checks the pattern
        else:
            fp = a_or_fp
            if data is None:
                raise ValueError(
                    "numeric_update(fingerprint) needs the new values"
                )
            payload = np.asarray(data)
        vp = self.pattern(fp)
        v = vp.update(payload)
        self.metrics.record_update(fp)
        return v

    # -------------------------------------------------------------- worker
    def _worker_loop(self) -> None:
        while True:
            item = self._batcher.next_batch()
            if item is None:
                return
            (fp, version), reqs = item
            vp = self._patterns[fp]
            t0 = time.perf_counter()
            try:
                solver = vp.solver_for(version)
                m = len(reqs)
                B = np.stack([r.b for r in reqs], axis=1)
                w = pad_width(m, self.max_batch)
                if w > m:
                    B = np.concatenate(
                        [B, np.zeros((B.shape[0], w - m), B.dtype)], axis=1
                    )
                X = np.asarray(solver.solve(B))
                t1 = time.perf_counter()
                for j, r in enumerate(reqs):
                    r.ticket.batch_width = w
                    r.ticket.batch_position = j
                    r.ticket.served_by = solver
                    r.ticket._fulfill(np.ascontiguousarray(X[:, j]))
                self.metrics.record_batch(
                    fp,
                    m,
                    queue_waits=[t0 - r.ticket.t_submit for r in reqs],
                    e2e=[r.ticket.t_done - r.ticket.t_submit for r in reqs],
                    solve_seconds=t1 - t0,
                )
            except Exception as e:  # scatter the failure, keep serving
                for r in reqs:
                    r.ticket._fulfill(None, e)
                self.metrics.record_failure(fp, len(reqs))
            finally:
                vp.complete(version, len(reqs))

    # ------------------------------------------------------------ lifecycle
    def close(self, timeout: Optional[float] = None) -> None:
        """Stop admissions, drain the queue, join the workers."""
        if self._closed:
            return
        self._closed = True
        self._batcher.close()
        for w in self._workers:
            w.join(timeout)
        # release the eviction pins — a shared PlanCache outliving this
        # service must regain its normal LRU behavior
        for key in self._pinned_keys:
            self.cache.unpin(key)
        self._pinned_keys.clear()

    def __enter__(self) -> "SolveService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """JSON-ready snapshot: serving telemetry + plan-cache stats +
        live plan versions per pattern."""
        cs = self.cache.stats
        looked_up = cs.hits + cs.misses
        # snapshot under the registry lock: submit(CSRMatrix) auto-registers
        # concurrently, and iterating the live dict while it grows would
        # crash the telemetry thread
        with self._plock:
            patterns = list(self._patterns.items())
        return self.metrics.snapshot(
            queue_depth=self._batcher.depth(),
            extra={
                "plan_cache": {
                    **cs.as_dict(),
                    "hit_rate": round(cs.hits / looked_up, 3)
                    if looked_up
                    else 0.0,
                },
                "patterns": {
                    fp: {
                        "versions_alive": vp.live_versions(),
                        "current_version": vp.current,
                        # the backend BoundSolve's own telemetry (shapes,
                        # device bytes, compiled variants) — registry
                        # backends all speak describe(); current_solver()
                        # reads atomically so a racing update cannot
                        # retire the version mid-lookup
                        "binding": vp.current_solver().bound.describe(),
                    }
                    for fp, vp in patterns
                },
            },
        )

    def print_stats(self) -> None:
        print(pretty(self.stats()), flush=True)
