"""Load generation for ``SolveService`` — request mixes + drivers.

Three canonical mixes over a set of registered patterns:

  * ``hot``         — skewed routing (geometric weights): one pattern
                      dominates, the regime where pattern-routed
                      microbatching should shine;
  * ``uniform``     — equal weight per pattern (batching still helps,
                      diluted across routes);
  * ``adversarial`` — every pattern equally cold across many distinct
                      patterns: the worst case for both the plan cache
                      and the batcher (nothing coalesces).

Two drivers:

  * ``run_closed_loop`` — ``n_clients`` threads, each submits and *waits*
    (classic closed loop: offered load adapts to service latency);
  * ``run_open_loop``   — a paced submitter that does not wait (offered
    load fixed at ``rate_hz``; queue depth reveals saturation).

Both return a JSON-ready report: throughput, p50/p95/p99/p99.9 latency,
error count, and the service's full metrics snapshot. The open-loop
driver additionally reports ``client_latency_us`` — percentiles over
EVERY ticket's submit-to-result time (the service reservoir keeps only
the most recent 4096 samples; a p99.9 acceptance gate needs the full
population). With ``validate=True`` every result is checked *bitwise*
against ``direct_reference`` on the version-pinned solver — the same
contract tests/test_serve.py enforces.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.metrics import _percentiles_us
from repro.serve.service import (
    QueueFullError,
    SolveService,
    SolveTicket,
    direct_reference,
)
from repro.sparse.generators import erdos_renyi_lower, shifted_coupling_lower

MIXES = ("hot", "uniform", "adversarial", "width")


def corpus_patterns(
    service: SolveService, **plan_kwargs
) -> List[Tuple[str, int]]:
    """Register the 9-matrix autotune scenario corpus; returns
    ``[(fingerprint, n), ...]`` in corpus order."""
    from repro.autotune import corpus_entries

    out = []
    for e in corpus_entries():
        m = e.matrix()
        out.append((service.register(m, **plan_kwargs), m.n_rows))
    return out


def adversarial_patterns(
    service: SolveService,
    n_patterns: int = 16,
    *,
    n: int = 160,
    density: float = 0.02,
    seed: int = 0,
    **plan_kwargs,
) -> List[Tuple[str, int]]:
    """``n_patterns`` structurally distinct matrices (distinct ER seeds →
    distinct fingerprints): every request routes to its own plan, so the
    batcher can only coalesce same-pattern repeats."""
    out = []
    for i in range(n_patterns):
        m = erdos_renyi_lower(n, density, seed=seed + 1000 + i)
        out.append((service.register(m, **plan_kwargs), m.n_rows))
    return out


def width_class_patterns(
    service: SolveService,
    n_patterns: int = 6,
    *,
    n: int = 96,
    stride: int = 8,
    seed: int = 0,
    **plan_kwargs,
) -> List[Tuple[str, int]]:
    """``n_patterns`` structurally DISTINCT matrices that land in ONE
    width class (``sparse.generators.shifted_coupling_lower`` — same
    ``ExecPlan`` shapes under a level scheduler): the regime where
    cross-pattern batching coalesces requests that classic
    per-fingerprint routing cannot. Asserts the class actually formed —
    a scheduler whose plan shapes depend on the shift values would
    silently degrade the mix into ``adversarial``."""
    if n_patterns > stride - 1:
        raise ValueError(
            f"at most stride-1={stride - 1} distinct shifts exist"
        )
    out = []
    for j in range(n_patterns):
        m = shifted_coupling_lower(n, j, stride=stride, seed=seed + j)
        out.append((service.register(m, **plan_kwargs), m.n_rows))
    classes = {service.pattern(fp).width_class for fp, _ in out}
    if len(classes) != 1:
        raise AssertionError(
            f"width-class family split into {len(classes)} classes — "
            "plan with a level scheduler (strategy='wavefront') so the "
            "plan shapes stay shift-invariant"
        )
    return out


def patterns_for_mix(
    service: SolveService,
    mix: str,
    *,
    n_adversarial: int = 16,
    seed: int = 0,
    **plan_kwargs,
):
    """One-stop setup for a named mix: registers the right pattern set
    (corpus for hot/uniform, distinct ER matrices for adversarial, one
    width-class family for width) and returns ``(patterns, sampler)``.
    Shared by ``benchmarks.serve_load`` and the
    ``repro.launch.solver_serve`` CLI so the two can never diverge on
    what a mix means."""
    if mix == "adversarial":
        patterns = adversarial_patterns(
            service, n_adversarial, seed=seed, **plan_kwargs
        )
        kind = "uniform"  # adversity is the pattern count, not the skew
    elif mix == "width":
        # the family needs shift-invariant plan shapes: pin a level
        # scheduler unless the caller chose one explicitly
        patterns = width_class_patterns(
            service, seed=seed, **{"strategy": "wavefront", **plan_kwargs}
        )
        kind = "uniform"  # structure is shared; traffic is spread
    else:
        patterns = corpus_patterns(service, **plan_kwargs)
        kind = mix
    return patterns, make_sampler(patterns, kind, seed=seed)


def mix_weights(kind: str, n_patterns: int) -> np.ndarray:
    """Routing distribution over patterns for a named mix."""
    if kind == "uniform" or kind == "adversarial":
        w = np.ones(n_patterns)
    elif kind == "hot":
        # geometric skew: pattern 0 takes ~half the traffic
        w = 0.5 ** np.arange(n_patterns, dtype=np.float64)
    else:
        raise ValueError(f"unknown mix {kind!r}; expected one of {MIXES}")
    return w / w.sum()


def make_sampler(
    patterns: Sequence[Tuple[str, int]],
    kind: str = "hot",
    *,
    seed: int = 0,
) -> Callable[[], Tuple[str, np.ndarray]]:
    """Thread-safe request sampler: () -> (fingerprint, b). Each call
    draws a pattern from the mix distribution and a fresh Gaussian
    right-hand side."""
    weights = mix_weights(kind, len(patterns))
    lock = threading.Lock()
    rng = np.random.default_rng(seed)

    def sample() -> Tuple[str, np.ndarray]:
        with lock:
            i = int(rng.choice(len(patterns), p=weights))
            fp, n = patterns[i]
            b = rng.standard_normal(n).astype(np.float32)
        return fp, b

    return sample


def _validate_tickets(
    served: List[Tuple[SolveTicket, np.ndarray, np.ndarray]],
) -> int:
    """Bitwise-check served results against the version-pinned solver
    (``ticket.served_by`` — kept on the ticket so the check works even
    after the version retires from the service); returns the mismatch
    count (0 is the contract)."""
    bad = 0
    for ticket, b, x in served:
        ref = direct_reference(
            ticket.served_by, b, ticket.batch_width, ticket.batch_position
        )
        if not np.array_equal(x, ref):
            bad += 1
    return bad


def _report(
    service: SolveService,
    *,
    mode: str,
    n_requests: int,
    elapsed: float,
    errors: int,
    mismatches: Optional[int],
    rejected: int = 0,
    client_latency_us: Optional[dict] = None,
) -> dict:
    snap = service.stats()
    # rejected requests are back-pressure working as designed, not
    # failures — reported separately and excluded from throughput
    completed = n_requests - errors - rejected
    out = {
        "mode": mode,
        "requests": n_requests,
        "completed": completed,
        "elapsed_seconds": round(elapsed, 4),
        "solves_per_sec": round(completed / elapsed, 1) if elapsed else 0.0,
        "errors": errors,
        "rejected": rejected,
        "bitwise_mismatches": mismatches,
        "latency_us": snap["latency_us"],
        "queue_wait_us": snap["queue_wait_us"],
        "mean_batch_size": snap["mean_batch_size"],
        "metrics": snap,
    }
    if client_latency_us is not None:
        out["client_latency_us"] = client_latency_us
    return out


def run_closed_loop(
    service: SolveService,
    sampler: Callable[[], Tuple[str, np.ndarray]],
    *,
    n_clients: int = 8,
    requests_per_client: int = 50,
    validate: bool = False,
    timeout: float = 120.0,
) -> dict:
    """``n_clients`` threads, each submitting ``requests_per_client``
    requests back-to-back (waiting for each result)."""
    errors = [0] * n_clients
    rejected = [0] * n_clients
    kept: List[List] = [[] for _ in range(n_clients)]

    def client(ci: int) -> None:
        for _ in range(requests_per_client):
            fp, b = sampler()
            ticket = service.submit(fp, b)
            try:
                x = ticket.result(timeout)
                if validate:
                    kept[ci].append((ticket, b, x))
            except QueueFullError:
                rejected[ci] += 1
            except Exception:
                errors[ci] += 1

    threads = [
        threading.Thread(target=client, args=(i,), daemon=True)
        for i in range(n_clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    mism = (
        _validate_tickets([s for c in kept for s in c])
        if validate
        else None
    )
    return _report(
        service,
        mode="closed",
        n_requests=n_clients * requests_per_client,
        elapsed=elapsed,
        errors=sum(errors),
        mismatches=mism,
        rejected=sum(rejected),
    )


def run_open_loop(
    service: SolveService,
    sampler: Callable[[], Tuple[str, np.ndarray]],
    *,
    rate_hz: float = 500.0,
    n_requests: int = 200,
    validate: bool = False,
    timeout: float = 120.0,
) -> dict:
    """Paced submitter: one request every ``1/rate_hz`` seconds regardless
    of completions, then wait for all tickets. Reports
    ``client_latency_us`` percentiles (incl. p99/p99.9) over every
    completed ticket's submit-to-completion time — the open-loop tail
    the continuous engine is built for."""
    interval = 1.0 / rate_hz
    inflight: List[Tuple[SolveTicket, np.ndarray]] = []
    t0 = time.perf_counter()
    next_t = t0
    for _ in range(n_requests):
        now = time.perf_counter()
        if now < next_t:
            time.sleep(next_t - now)
        fp, b = sampler()
        inflight.append((service.submit(fp, b), b))
        next_t += interval
    errors = 0
    rejected = 0
    served = []
    latencies = []
    for ticket, b in inflight:
        try:
            x = ticket.result(timeout)
        except QueueFullError:
            rejected += 1
            continue
        except Exception:
            errors += 1
            continue
        # t_submit/t_done are stamped on the ticket itself, so the
        # sequential result() collection here does not skew the sample
        latencies.append(ticket.t_done - ticket.t_submit)
        if validate:
            served.append((ticket, b, x))
    elapsed = time.perf_counter() - t0
    mism = _validate_tickets(served) if validate else None
    return _report(
        service,
        mode=f"open@{rate_hz:g}Hz",
        n_requests=n_requests,
        elapsed=elapsed,
        errors=errors,
        mismatches=mism,
        rejected=rejected,
        client_latency_us=_percentiles_us(np.asarray(latencies)),
    )
