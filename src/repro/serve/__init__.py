"""``repro.serve`` — a batching SpTRSV solve service on top of
``repro.pipeline``.

Turn a stream of independent solve requests into few large batched
solves: requests sharing a sparsity pattern are coalesced (pattern-routed
microbatching) into one multi-RHS ``solve(B[n, m])`` against the cached
plan, and factor values can be swapped live between microbatches without
corrupting queued work (version-pinned plans). With
``width_class_batching=True`` the coalescing widens to *structurally
identical* patterns (one ``TriangularSolver.width_class``): columns from
different patterns/versions ride one grouped vmapped dispatch, each
solved against its own plan tensors. ``backend="distributed"`` +
``mesh=...`` serves through the mesh-sharded executor with batches
aligned to the mesh's ``data`` axis; ``n_workers>1`` executes distinct
routes concurrently.

    from repro.serve import SolveService

    with SolveService(max_batch=32, max_wait_us=2000) as svc:
        fp = svc.register(L)            # plan once; cheap handle back
        x = svc.solve(fp, b)            # or submit(fp, b) -> SolveTicket
        svc.numeric_update(fp, new_vals)  # live refactorization
        svc.print_stats()

``mode="continuous"`` swaps microbatch formation for persistent
device-resident RHS slots (``repro.serve.slots``): admission allocates a
free lane, an always-running dispatch loop solves the resident bank
back-to-back, and there is no drain barrier between dispatches — the
open-loop tail-latency regime.

Module map:

  * ``service`` — ``SolveService`` / ``SolveTicket`` (admission, workers)
  * ``batcher`` — pattern-routed microbatching queue (``MicroBatcher``)
    + the continuous engine's ``AdmissionQueue``
  * ``slots``   — continuous batching: ``SlotState`` / ``SlotEngine``
  * ``updates`` — version-tagged plans for live refactorization
  * ``metrics`` — per-pattern + global telemetry (``ServeMetrics``)
  * ``loadgen`` — request-mix load generator (hot / uniform / adversarial)
"""
from repro.serve.batcher import (
    AdmissionQueue,
    MicroBatcher,
    normalize_max_batch,
    pad_width,
)
from repro.serve.loadgen import (
    MIXES,
    adversarial_patterns,
    corpus_patterns,
    make_sampler,
    mix_weights,
    patterns_for_mix,
    run_closed_loop,
    run_open_loop,
    width_class_patterns,
)
from repro.serve.metrics import LatencyReservoir, ServeMetrics, pretty
from repro.serve.service import (
    GroupReplay,
    QueueFullError,
    SolveService,
    SolveTicket,
    direct_reference,
)
from repro.serve.slots import (
    SlotDispatcher,
    SlotEngine,
    SlotRequest,
    SlotsFull,
    SlotState,
)
from repro.serve.updates import VersionedPlans

__all__ = [
    "AdmissionQueue",
    "MicroBatcher",
    "normalize_max_batch",
    "pad_width",
    "MIXES",
    "adversarial_patterns",
    "corpus_patterns",
    "make_sampler",
    "mix_weights",
    "patterns_for_mix",
    "run_closed_loop",
    "run_open_loop",
    "width_class_patterns",
    "LatencyReservoir",
    "ServeMetrics",
    "pretty",
    "GroupReplay",
    "QueueFullError",
    "SolveService",
    "SolveTicket",
    "direct_reference",
    "SlotDispatcher",
    "SlotEngine",
    "SlotRequest",
    "SlotsFull",
    "SlotState",
    "VersionedPlans",
]
