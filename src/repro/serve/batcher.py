"""Pattern-routed microbatching — the heart of the solve service.

Requests are keyed by *route* — ``(pattern fingerprint, plan version)`` —
because only requests that share both the sparsity pattern and the factor
values can legally ride one multi-RHS ``solve(B[n, m])``. A route's group
is dispatched when it reaches ``max_batch`` or when its oldest request has
waited ``max_wait_us`` (the classic throughput/latency knob pair of
serving systems), whichever comes first. ``close()`` flushes every
remaining group immediately, so shutdown never strands a request.

Bitwise contract: at a fixed batch width and column position, the
executor's multi-RHS path never lets neighbor columns change a column's
bits (each output column's FP op sequence reads only its own column —
property-tested in tests/test_serve.py), so coalescing never changes a
request's bits relative to a direct solve of a batch with the same shape
and placement. Across widths and positions XLA may vectorize the batched
einsum differently; ``pad_width`` therefore quantizes every dispatch to
a power-of-two width — pinning down the (width, position) a request was
served at (recorded on its ticket) and capping each plan shape at
log2(max_batch) compiled XLA variants.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from typing import Hashable, List, Optional, Tuple


def normalize_max_batch(max_batch: int) -> int:
    """The effective batch cap: ``max_batch`` rounded DOWN to a power of
    two (24 -> 16). The serving contract promises at most
    ``log2(max_batch)`` compiled XLA variants per plan shape — a non-pow2
    cap would dispatch a non-pow2 width the moment a group fills,
    breaking that bound, so the cap is quantized once at construction
    (``SolveService`` / ``MicroBatcher``) and everything downstream sees
    only the normalized value."""
    if max_batch < 1:
        raise ValueError("max_batch must be >= 1")
    return 1 << (int(max_batch).bit_length() - 1)


def pad_width(m: int, max_batch: int) -> int:
    """Batch width actually dispatched for ``m`` queued requests: the next
    power of two >= max(m, 2), capped at ``normalize_max_batch(max_batch)``
    — every dispatched width is a power of two, keeping the
    log2(max_batch) compiled-variant bound exact. ``max_batch=1`` (the
    no-batching baseline) is the one width-1 escape hatch."""
    cap = normalize_max_batch(max_batch)
    if cap <= 1:
        return 1
    w = 2
    while w < m:
        w *= 2
    return min(w, cap)


class MicroBatcher:
    """Thread-safe grouping queue: ``put(route, item)`` from any number of
    producers, ``next_batch()`` from worker threads. FIFO within a route.
    Across routes the dispatch order is: any FULL group first (the first
    one found, in route-insertion order — not the fullest), otherwise the
    group whose oldest item's ``max_wait_us`` deadline expires first.
    ``max_batch`` is normalized to a power of two at construction
    (``normalize_max_batch``), so dispatched group sizes always respect
    the pow2 width quantization."""

    def __init__(self, *, max_batch: int = 32, max_wait_us: int = 2000):
        self.max_batch = normalize_max_batch(max_batch)
        self.max_wait = max_wait_us / 1e6
        self._cond = threading.Condition()
        self._groups: "OrderedDict[Hashable, List]" = OrderedDict()
        self._arrival: dict = {}  # route -> perf_counter of oldest item
        self._closed = False

    def depth(self) -> int:
        with self._cond:
            return sum(len(g) for g in self._groups.values())

    def put(self, route: Hashable, item) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("batcher is closed")
            group = self._groups.get(route)
            if group is None:
                group = self._groups[route] = []
                self._arrival[route] = time.perf_counter()
            group.append(item)
            self._cond.notify()

    def _pop(self, route) -> Tuple[Hashable, List]:
        """Take up to ``max_batch`` items; a longer group keeps its place
        (and its arrival time, so the remainder dispatches next)."""
        group = self._groups[route]
        if len(group) <= self.max_batch:
            del self._groups[route]
            del self._arrival[route]
            return route, group
        self._groups[route] = group[self.max_batch:]
        return route, group[: self.max_batch]

    def next_batch(self) -> Optional[Tuple[Hashable, List]]:
        """Block until a group is dispatchable; None once closed AND
        drained (the worker-loop exit signal)."""
        with self._cond:
            while True:
                if self._groups:
                    # any full group dispatches immediately
                    for route, group in self._groups.items():
                        if len(group) >= self.max_batch:
                            return self._pop(route)
                    if self._closed:  # flush: deadlines no longer apply
                        return self._pop(next(iter(self._groups)))
                    oldest = min(self._arrival, key=self._arrival.get)
                    deadline = self._arrival[oldest] + self.max_wait
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        return self._pop(oldest)
                    self._cond.wait(remaining)
                else:
                    if self._closed:
                        return None
                    self._cond.wait()

    def close(self) -> None:
        """Stop admissions and wake every worker; queued groups still
        drain (flushed immediately) before ``next_batch`` returns None."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()


class AdmissionQueue:
    """Slot-allocation admission for the continuous engine
    (``repro.serve.slots``).

    The microbatcher above implements *group formation*: it deliberately
    holds a route's backlog for up to ``max_wait_us`` hoping more
    requests arrive to share the dispatch — a batch-formation deadline
    that is itself a small synchronization barrier. Continuous mode has
    no such barrier: requests go into persistent device lanes, so there
    is nothing to form. This queue is therefore a plain FIFO — ``take``
    blocks only while the queue is EMPTY, and hands the dispatch loop
    everything queued the moment it comes back for work. The only wait
    a request ever experiences here is for the loop, never for company.

    The dispatcher routes taken items into per-engine pending deques
    before dispatching them; ``mark_pending`` lets it report that
    in-hand count so ``depth`` (the service's back-pressure signal)
    keeps covering requests that are accepted but not yet in a lane.
    """

    UNBOUNDED = 1 << 30  # take(k) cap meaning "everything queued"

    def __init__(self):
        self._cond = threading.Condition()
        self._items: deque = deque()
        self._pending = 0  # items the consumer took but hasn't dispatched
        self._closed = False

    def depth(self) -> int:
        with self._cond:
            return len(self._items) + self._pending

    def mark_pending(self, n: int) -> None:
        """Report the consumer's in-hand (taken, undispatched) count."""
        with self._cond:
            self._pending = n

    def put(self, item) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("admission queue is closed")
            self._items.append(item)
            self._cond.notify()

    def take(self, k: int) -> List:
        """Up to ``k`` queued items, FIFO. Blocks while empty; an empty
        list means closed AND drained — the dispatch-loop exit signal
        (mirrors ``MicroBatcher.next_batch`` returning None)."""
        with self._cond:
            while not self._items:
                if self._closed:
                    return []
                self._cond.wait()
            take = min(k, len(self._items))
            return [self._items.popleft() for _ in range(take)]

    def drain(self) -> List:
        """Everything queued right now, without blocking — the
        dispatcher's top-up path while it still has pending work in
        hand (blocking would stall those)."""
        with self._cond:
            items = list(self._items)
            self._items.clear()
            return items

    def close(self) -> None:
        """Stop admissions and wake the dispatch loop; queued requests
        still drain before ``take`` returns empty."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
