"""``repro.obs`` — cross-cutting observability: tracing, counters,
exportable telemetry.

The paper's headline numbers are *accounting* claims (12.07x fewer
barriers, balanced per-step work); ``ExecPlan.stats()`` reports them
statically. This package measures where wall-clock actually goes at
runtime, across every layer of the stack:

    inspector   compile_plan phases, DAG build, schedule, reorder
    autotune    feature extraction, candidate scoring, measured trials
    cache       PlanCache hit/miss/evict/pin counters + lookup spans
    backend     bind / update_values per backend
    executor    per-solve dispatch; per-superstep (bulk) and
                per-macro-step (elastic) device timings on a
                ``timed=True`` plan
    serve       microbatches, grouped batches, slot passes

Usage::

    from repro import obs

    obs.enable()                      # or: with obs.tracing(): ...
    solver = TriangularSolver.plan(L, strategy="auto", cache=cache)
    x = solver.solve(b)
    obs.export_chrome_trace("trace.json")   # chrome://tracing / Perfetto
    print(obs.summary())                    # per-span aggregate + counters

Tracing is OFF by default and costs one flag check per instrumentation
site when off (no allocation — ``span()`` returns a process-wide
singleton; bounded ~0.5% on the corpus hot path, enforced by
``benchmarks/obs_overhead.py``). Enabled tracing stays on the host side
of the JAX async dispatch boundary, bounded <= 3% median solve latency
on the same bench. ``jax.named_scope`` annotations inside the executors
additionally tag the XLA HLO, so a ``jax.profiler`` trace carries
plan-step names at zero runtime cost.
"""
from repro.obs.export import (
    TRACE_SCHEMA,
    chrome_trace_events,
    chrome_trace_payload,
    export_chrome_trace,
    load_chrome_trace,
    metrics_rows,
    validate_chrome_trace,
)
from repro.obs.trace import (
    COUNTER_WRAP,
    DEFAULT_CAP,
    NULL_SPAN,
    Span,
    SpanRecord,
    TraceBuffer,
    active_buffer,
    counter_add,
    disable,
    enable,
    get_buffer,
    is_enabled,
    span,
    tracing,
)


def summary(buffer=None) -> dict:
    """JSON-ready aggregate of the active (or given) buffer — the dict
    ``SolveService.stats()["obs"]`` embeds."""
    buf = buffer if buffer is not None else active_buffer()
    if buf is None:
        return {"enabled": False}
    return {"enabled": is_enabled(), **buf.summary()}


__all__ = [
    "COUNTER_WRAP",
    "DEFAULT_CAP",
    "NULL_SPAN",
    "Span",
    "SpanRecord",
    "TRACE_SCHEMA",
    "TraceBuffer",
    "active_buffer",
    "chrome_trace_events",
    "chrome_trace_payload",
    "counter_add",
    "disable",
    "enable",
    "export_chrome_trace",
    "get_buffer",
    "is_enabled",
    "load_chrome_trace",
    "metrics_rows",
    "span",
    "summary",
    "tracing",
    "validate_chrome_trace",
]
