"""Tracing core — spans, counters, and the in-process ``TraceBuffer``.

Design constraints (the whole point of this module, enforced by
``tests/test_obs.py`` and ``benchmarks/obs_overhead.py``):

  * **zero overhead when off** — ``span(...)`` on the disabled path is a
    single module-flag check returning one process-wide ``_NullSpan``
    singleton: no allocation, no lock, no buffer growth. The flag is
    re-read per call, so enabling tracing mid-process takes effect
    immediately everywhere.
  * **thread-safe when on** — spans finish by appending one immutable
    record under the buffer lock (a leaf lock: nothing is called while
    holding it, so it can never participate in a lock cycle with the
    plan-cache / serve / bank locks the instrumented code holds).
  * **bounded** — the buffer keeps at most ``cap`` spans and counts
    drops instead of growing without bound under a long serving run.

Spans nest lexically (context managers), so per-thread begin/end pairs
are properly bracketed by construction — exactly what the Chrome
``trace_event`` exporter (``repro.obs.export``) needs to emit matching
B/E pairs.

Counters are monotonic ``int``s that wrap at ``COUNTER_WRAP`` (2**63 —
documented two's-complement semantics so exported values stay exact in
JSON/float64 consumers); ``reset_counters`` zeroes them.
"""
from __future__ import annotations

import os
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, NamedTuple, Optional

# counters wrap modulo 2**63: large enough to be unreachable in practice,
# small enough that every value survives a float64/JSON round-trip exactly
COUNTER_WRAP = 1 << 63

# spans kept per buffer before drops start (each record is ~200 bytes; the
# default bounds a runaway traced serving loop at ~200 MB)
DEFAULT_CAP = 1_000_000


class SpanRecord(NamedTuple):
    """One finished span. Times are ``time.perf_counter_ns`` (monotonic,
    process-relative — NOT wall-clock epoch)."""

    name: str
    cat: str
    tid: int
    thread_name: str
    t0_ns: int
    t1_ns: int
    args: dict


class TraceBuffer:
    """Thread-safe bounded span + counter sink (see module docstring)."""

    def __init__(self, name: str = "default", cap: int = DEFAULT_CAP):
        self.name = name
        self.cap = int(cap)
        self._lock = threading.Lock()
        self._spans: List[SpanRecord] = []
        self._counters: Dict[str, int] = {}
        self.dropped = 0  # spans discarded once cap was reached

    # ------------------------------------------------------------ record
    def add_span(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._spans) >= self.cap:
                self.dropped += 1
                return
            self._spans.append(rec)

    def counter_add(self, name: str, value: int = 1) -> int:
        """Add ``value`` (may be negative) to counter ``name``; returns
        the new value. Wraps modulo ``COUNTER_WRAP``."""
        with self._lock:
            v = (self._counters.get(name, 0) + int(value)) % COUNTER_WRAP
            self._counters[name] = v
            return v

    # ---------------------------------------------------------- snapshot
    def spans(self) -> List[SpanRecord]:
        """A consistent copy of the finished spans (insertion order =
        per-thread completion order)."""
        with self._lock:
            return list(self._spans)

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def clear(self) -> None:
        """Drop all spans and the drop count; counters survive (use
        ``reset_counters`` for those — benchmarks clear the span buffer
        between phases without losing lifetime counts)."""
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    def reset_counters(self) -> None:
        with self._lock:
            self._counters.clear()

    def summary(self) -> dict:
        """JSON-ready aggregate: span counts + total/mean duration per
        span name, the counters, and buffer health. The per-name table is
        what ``SolveService.stats()["obs"]`` and the ``--trace`` metrics
        dump surface."""
        with self._lock:
            spans = list(self._spans)
            counters = dict(self._counters)
            dropped = self.dropped
        agg: Dict[str, list] = {}
        for s in spans:
            a = agg.get(s.name)
            if a is None:
                agg[s.name] = [1, s.t1_ns - s.t0_ns, s.cat]
            else:
                a[0] += 1
                a[1] += s.t1_ns - s.t0_ns
        return {
            "buffer": self.name,
            "n_spans": len(spans),
            "dropped": dropped,
            "cap": self.cap,
            "spans": {
                name: {
                    "cat": cat,
                    "count": cnt,
                    "total_us": round(tot / 1e3, 1),
                    "mean_us": round(tot / cnt / 1e3, 2),
                }
                for name, (cnt, tot, cat) in sorted(agg.items())
            },
            "counters": dict(sorted(counters.items())),
        }


# ------------------------------------------------------------- registry
_REG_LOCK = threading.Lock()
_BUFFERS: Dict[str, TraceBuffer] = {}


def get_buffer(name: str = "default") -> TraceBuffer:
    """The process-global buffer registry: one ``TraceBuffer`` per name,
    created on first use. The ``"default"`` buffer is the one ``enable()``
    activates and every instrumentation site records into."""
    with _REG_LOCK:
        buf = _BUFFERS.get(name)
        if buf is None:
            buf = _BUFFERS[name] = TraceBuffer(name)
        return buf


# --------------------------------------------------------- on/off switch
# The fast path reads these two module globals and nothing else. They are
# only ever written under _REG_LOCK; readers tolerate the (benign) race of
# seeing the flag flip mid-call — a span started just before disable()
# still lands in its buffer, which is the useful behavior.
_ENABLED = False
_ACTIVE: Optional[TraceBuffer] = None


def enable(buffer: Optional[TraceBuffer] = None) -> TraceBuffer:
    """Turn tracing on, recording into ``buffer`` (default: the global
    ``"default"`` buffer). Returns the active buffer."""
    global _ENABLED, _ACTIVE
    buf = buffer if buffer is not None else get_buffer("default")
    with _REG_LOCK:
        _ACTIVE = buf
        _ENABLED = True
    return buf


def disable() -> None:
    global _ENABLED
    with _REG_LOCK:
        _ENABLED = False


def is_enabled() -> bool:
    return _ENABLED


def active_buffer() -> Optional[TraceBuffer]:
    """The buffer currently receiving spans (None while disabled)."""
    return _ACTIVE if _ENABLED else None


@contextmanager
def tracing(buffer: Optional[TraceBuffer] = None):
    """Scoped enable: ``with obs.tracing() as buf: ...`` — restores the
    previous on/off state (and active buffer) on exit, so tests and
    benchmarks can trace one region without leaking global state."""
    global _ENABLED, _ACTIVE
    with _REG_LOCK:
        prev = (_ENABLED, _ACTIVE)
    buf = enable(buffer)
    try:
        yield buf
    finally:
        with _REG_LOCK:
            _ENABLED, _ACTIVE = prev


# ----------------------------------------------------------------- spans
class _NullSpan:
    """The disabled-path span: one process-wide singleton, every method a
    no-op. ``span()`` must return THIS object (identity-tested) whenever
    tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """A live span: created by ``span()`` on the enabled path, recorded
    into its buffer on ``__exit__``. ``set(key=value)`` attaches args
    discovered mid-span (e.g. a cache hit flag known only at the end)."""

    __slots__ = ("name", "cat", "args", "_buf", "_t0")

    def __init__(self, name: str, cat: str, args: dict, buf: TraceBuffer):
        self.name = name
        self.cat = cat
        self.args = args
        self._buf = buf
        self._t0 = 0

    def set(self, **args) -> "Span":
        self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter_ns()
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        cur = threading.current_thread()
        self._buf.add_span(
            SpanRecord(
                name=self.name,
                cat=self.cat,
                tid=cur.ident or 0,
                thread_name=cur.name,
                t0_ns=self._t0,
                t1_ns=t1,
                args=self.args,
            )
        )
        return False


def span(name: str, cat: str = "", **args):
    """Open a traced region::

        with obs.span("inspector.compile_plan", cat="inspector", n=n):
            ...

    Disabled path: one flag check, returns the shared ``NULL_SPAN``
    singleton — no allocation, no lock (see module docstring). ``cat``
    groups spans into layers (inspector / autotune / cache / backend /
    executor / serve) for the exporters; it defaults to the text before
    the first ``.`` of ``name``."""
    if not _ENABLED:
        return NULL_SPAN
    buf = _ACTIVE
    if buf is None:  # disable() raced us; drop silently
        return NULL_SPAN
    return Span(name, cat or name.split(".", 1)[0], args, buf)


def counter_add(name: str, value: int = 1) -> None:
    """Bump monotonic counter ``name`` in the active buffer; a no-op
    (one flag check) while tracing is off."""
    if not _ENABLED:
        return
    buf = _ACTIVE
    if buf is not None:
        buf.counter_add(name, value)


def pid() -> int:
    return os.getpid()
