"""Exporters — Chrome/Perfetto ``trace_event`` JSON and flat metric rows.

``export_chrome_trace(path)`` writes the active (or given) buffer as the
Chrome Trace Event Format consumed by ``chrome://tracing``, Perfetto
(https://ui.perfetto.dev) and ``speedscope``: a ``traceEvents`` list of
``B``/``E`` (duration begin/end) events with microsecond timestamps,
grouped by thread. Span nesting is lexical (context managers), so the
per-thread event stream is properly bracketed; ties at one timestamp are
ordered E-before-B (and inner-before-outer among E's) so a stack-based
consumer never underflows — ``tests/test_obs.py`` round-trips this.

``metrics_rows()`` flattens the same buffer into the
``repro-bench-rows/v1`` row shape (``name, us_per_call, derived``) used
by every benchmark JSON in this repo, so a ``--trace`` run can feed the
BENCH trajectory tooling unchanged.
"""
from __future__ import annotations

import json
from typing import List, Optional, Tuple

from repro.obs.trace import TraceBuffer, active_buffer, get_buffer, pid

TRACE_SCHEMA = "repro-obs-trace/v1"


def _resolve(buffer: Optional[TraceBuffer]) -> TraceBuffer:
    buf = buffer if buffer is not None else active_buffer()
    return buf if buf is not None else get_buffer("default")


def chrome_trace_events(buffer: Optional[TraceBuffer] = None) -> List[dict]:
    """The buffer's spans as Chrome ``trace_event`` B/E dicts, sorted by
    timestamp (microseconds, monotonic origin). Tie-break at equal ts:
    E events sort before B events, and among simultaneous E's the
    later-started (inner) span closes first — preserving proper nesting
    for stack-based consumers."""
    buf = _resolve(buffer)
    p = pid()
    events = []
    for s in buf.spans():
        common = {
            "name": s.name,
            "cat": s.cat or "obs",
            "pid": p,
            "tid": s.tid,
        }
        # sort keys: (ts_ns, phase_rank, nesting_rank). E=0 < B=1 puts a
        # closing span before the next one opens at the same instant;
        # within simultaneous B's the longer (outer) span opens first,
        # within simultaneous E's the shorter (inner) span closes first.
        dur = s.t1_ns - s.t0_ns
        b = dict(common, ph="B", ts=s.t0_ns / 1e3)
        e = dict(common, ph="E", ts=s.t1_ns / 1e3)
        if s.args:
            b["args"] = s.args
        events.append(((s.t0_ns, 1, -dur), b))
        events.append(((s.t1_ns, 0, dur), e))
    events.sort(key=lambda kv: kv[0])
    return [ev for _, ev in events]


def chrome_trace_payload(buffer: Optional[TraceBuffer] = None) -> dict:
    """The full JSON document ``export_chrome_trace`` writes: the event
    list plus thread-name metadata, the counters, and the buffer summary
    (Perfetto ignores the extra top-level keys)."""
    buf = _resolve(buffer)
    p = pid()
    tids = {}
    for s in buf.spans():
        tids.setdefault(s.tid, s.thread_name)
    meta = [
        {
            "name": "thread_name",
            "ph": "M",
            "pid": p,
            "tid": tid,
            "args": {"name": tname},
        }
        for tid, tname in sorted(tids.items())
    ]
    return {
        "schema": TRACE_SCHEMA,
        "displayTimeUnit": "ms",
        "traceEvents": meta + chrome_trace_events(buf),
        "counters": buf.counters(),
        "summary": buf.summary(),
    }


def export_chrome_trace(
    path: str, buffer: Optional[TraceBuffer] = None
) -> dict:
    """Write the buffer as Chrome/Perfetto trace JSON; returns the
    payload that was written (handy for asserting on it in tests)."""
    payload = chrome_trace_payload(buffer)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
        fh.write("\n")
    return payload


def load_chrome_trace(path: str) -> dict:
    """Re-parse an exported trace (the smoke's round-trip check)."""
    with open(path) as fh:
        return json.load(fh)


def validate_chrome_trace(payload: dict) -> dict:
    """Structural validation of a (re-parsed) trace payload: timestamps
    monotonic, every B matched by an E on its own thread with proper
    nesting (per-tid stack never underflows and names match), no event
    left open. Returns {"n_events", "n_pairs", "cats"}; raises
    ``ValueError`` on any violation. Shared by the unit tests and the
    ``obs_overhead --smoke`` acceptance check."""
    events = [
        ev for ev in payload["traceEvents"] if ev.get("ph") in ("B", "E")
    ]
    last_ts = None
    for ev in events:
        if last_ts is not None and ev["ts"] < last_ts:
            raise ValueError(
                f"timestamps not monotonic: {ev['ts']} after {last_ts}"
            )
        last_ts = ev["ts"]
    stacks: dict = {}
    pairs = 0
    cats = set()
    for ev in events:
        stack = stacks.setdefault(ev["tid"], [])
        if ev["ph"] == "B":
            stack.append(ev)
            cats.add(ev.get("cat", ""))
        else:
            if not stack:
                raise ValueError(
                    f"E without matching B: {ev['name']} tid={ev['tid']}"
                )
            b = stack.pop()
            if b["name"] != ev["name"]:
                raise ValueError(
                    f"mismatched B/E pair: B={b['name']} E={ev['name']}"
                )
            pairs += 1
    open_spans = [b["name"] for st in stacks.values() for b in st]
    if open_spans:
        raise ValueError(f"unclosed spans at end of trace: {open_spans}")
    return {"n_events": len(events), "n_pairs": pairs, "cats": sorted(cats)}


def metrics_rows(
    buffer: Optional[TraceBuffer] = None,
) -> List[Tuple[str, float, str]]:
    """The buffer flattened to ``repro-bench-rows/v1`` rows: one
    ``(obs.<span name>, mean_us_per_call, "count=N total_us=T")`` row per
    span name plus one ``(obs.counter.<name>, value, "counter")`` row per
    counter — directly consumable by ``benchmarks.common.write_json_rows``.
    """
    summary = _resolve(buffer).summary()
    rows: List[Tuple[str, float, str]] = []
    for name, agg in summary["spans"].items():
        rows.append(
            (
                f"obs.{name}",
                agg["mean_us"],
                f"count={agg['count']} total_us={agg['total_us']}",
            )
        )
    for name, value in summary["counters"].items():
        rows.append((f"obs.counter.{name}", float(value), "counter"))
    return rows
