"""Trip-count-aware cost analysis over optimized HLO text.

XLA's HloCostAnalysis (exposed as ``compiled.cost_analysis()``) counts a
while-loop body ONCE — with scan-over-layers, microbatch accumulation and
flash-attention chunk scans, that undercounts FLOPs/bytes by the product of
all trip counts (e.g. 40 layers x 2 microbatches x 32 chunks). This module
re-analyzes the optimized HLO text and weights every op by the product of
``known_trip_count`` values of the while loops enclosing it.

What is counted:
  * flops            — dot ops: 2 * prod(output_shape) * prod(contracted lhs
                       dims). (Elementwise flops are <1% for these models and
                       are ignored; convolutions do not appear.)
  * hbm bytes        — for every top-level op in an *execution* computation
                       (entry, while bodies/conds, called computations):
                       operand bytes + output bytes. Fusion-internal ops are
                       excluded (a fusion reads its operands and writes its
                       outputs once).
  * collective bytes — output-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       weighted by trip count; per-op counts kept.

This is a first-order HBM model (perfect fusion locality, no spills); §Perf
uses *relative* deltas of these terms, where modeling bias largely cancels.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(%[\w\.\-]+|ROOT\s+%?[\w\.\-]+)\s*=")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` normalized to a flat dict.

    jax <= 0.4.30 returns ``{...}``; newer versions return ``[{...}]`` (one
    entry per executable). Every consumer in this repo wants the flat
    dict — normalize in exactly one place.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)

_OPNAME_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)\s+([a-z][a-z0-9\-]*)\("
)


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, float]
    collective_byte_detail: Dict[str, float]
    n_whiles: int


class _Op:
    __slots__ = ("name", "kind", "out_shapes", "operands", "line")

    def __init__(self, name, kind, out_shapes, operands, line):
        self.name = name
        self.kind = kind
        self.out_shapes = out_shapes
        self.operands = operands
        self.line = line


def _parse(hlo: str):
    """-> (comps: name -> [ops], sym: comp -> {opname: shapes})"""
    comps: Dict[str, List[_Op]] = {}
    sym: Dict[str, Dict[str, List]] = defaultdict(dict)
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if (
            not line.startswith(" ")
            and line.endswith("{")
            and "->" in line
            and _COMP_HDR_RE.match(line)
        ):
            m = _COMP_HDR_RE.match(line)
            cur = m.group(2)
            comps.setdefault(cur, [])
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name = m.group(1).replace("ROOT", "").strip().lstrip("%")
        rhs = line.split("=", 1)[1]
        # output shapes: everything before the op name token
        om = _OPNAME_RE.search(line)
        kind = om.group(1) if om else "unknown"
        paren = rhs.find("(")
        out_shapes = _shapes_in(rhs[: rhs.find(kind) if kind in rhs else paren])
        # operand names: inside the top-level parens of the op call
        call_start = rhs.find(kind + "(") if kind != "unknown" else -1
        operands = []
        if call_start >= 0:
            depth = 0
            seg = []
            for ch in rhs[call_start + len(kind):]:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                if depth >= 1:
                    seg.append(ch)
            operands = [
                t.lstrip("%") for t in _OPERAND_RE.findall("".join(seg))
            ]
        op = _Op(name, kind, out_shapes, operands, line)
        comps[cur].append(op)
        sym[cur][name] = out_shapes
    return comps, sym


def analyze_hlo(hlo: str) -> HloCost:
    comps, sym = _parse(hlo)

    # ---- multipliers via while nesting --------------------------------
    mult: Dict[str, float] = defaultdict(float)
    entry = None
    for name in comps:
        if re.search(r"^main|\bentry\b", name) or name.startswith("main"):
            entry = name
            break
    if entry is None:  # fall back: computation that nobody calls
        called = set()
        for ops in comps.values():
            for op in ops:
                for rx in (_BODY_RE, _COND_RE, _CALLS_RE, _TO_APPLY_RE):
                    m = rx.search(op.line)
                    if m:
                        called.add(m.group(1))
        candidates = [c for c in comps if c not in called]
        entry = candidates[-1] if candidates else list(comps)[-1]
    mult[entry] = 1.0

    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    execution = {entry}
    for _ in range(64):
        changed = False
        for cname, ops in comps.items():
            m_c = mult.get(cname, 0.0)
            if m_c == 0.0:
                continue
            for op in ops:
                if op.kind == "while":
                    trip_m = _TRIP_RE.search(op.line)
                    trip = float(trip_m.group(1)) if trip_m else 1.0
                    for rx, f in ((_BODY_RE, trip), (_COND_RE, trip + 1)):
                        mm = rx.search(op.line)
                        if mm:
                            tgt = mm.group(1)
                            val = m_c * f
                            if mult.get(tgt, 0.0) < val:
                                mult[tgt] = val
                                changed = True
                            execution.add(tgt)
                elif op.kind in ("call", "conditional", "async-start"):
                    for mm in _TO_APPLY_RE.finditer(op.line):
                        tgt = mm.group(1)
                        if mult.get(tgt, 0.0) < m_c:
                            mult[tgt] = m_c
                            changed = True
                        execution.add(tgt)
                elif op.kind == "fusion":
                    mm = _CALLS_RE.search(op.line)
                    if mm:
                        tgt = mm.group(1)
                        if mult.get(tgt, 0.0) < m_c:
                            mult[tgt] = m_c
                            changed = True
                        # fusions are NOT execution comps (internals fused)
        if not changed:
            break

    def _lookup(cname: str, o: str):
        shapes = sym[cname].get(o)
        if shapes is None:
            for s in sym.values():
                if o in s:
                    return s[o]
        return shapes

    def _operand_bytes(cname: str, op: _Op) -> int:
        total = 0
        for o in op.operands:
            shapes = _lookup(cname, o)
            if shapes:
                total += _bytes_of(shapes)
        return total

    # Effective read bytes of fusion parameters: a fusion that only
    # dynamic-slices / gathers a big stacked operand reads the slice, not
    # the whole tensor (the scan-over-layers weight access pattern).
    fusion_param_reads: Dict[str, List[Optional[int]]] = {}

    def _fusion_reads(fcomp: str) -> List[Optional[int]]:
        if fcomp in fusion_param_reads:
            return fusion_param_reads[fcomp]
        reads: Dict[int, int] = {}
        params: Dict[str, int] = {}
        full: Dict[int, int] = {}
        for op in comps.get(fcomp, []):
            if op.kind == "parameter":
                mm = re.search(r"parameter\((\d+)\)", op.line)
                if mm:
                    idx = int(mm.group(1))
                    params[op.name] = idx
                    full[idx] = _bytes_of(op.out_shapes)
        for op in comps.get(fcomp, []):
            for o in op.operands:
                if o in params:
                    idx = params[o]
                    if op.kind in ("dynamic-slice", "gather", "slice"):
                        reads[idx] = reads.get(idx, 0) + _bytes_of(op.out_shapes)
                    else:
                        reads[idx] = reads.get(idx, 0) + full[idx]
        out: List[Optional[int]] = []
        for idx in range(len(full)):
            eff = min(full.get(idx, 0), reads.get(idx, full.get(idx, 0)))
            out.append(eff)
        fusion_param_reads[fcomp] = out
        return out

    flops = 0.0
    hbm = 0.0
    coll_bytes = 0.0
    coll_counts: Dict[str, float] = defaultdict(float)
    coll_detail: Dict[str, float] = defaultdict(float)
    n_whiles = 0

    # flops: dots can live in ANY computation (incl. fusions)
    for cname, ops in comps.items():
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0:
            continue
        for op in ops:
            if op.kind == "while":
                n_whiles += 1
            if op.kind == "dot":
                out_elems = 1
                for dt, dims in op.out_shapes[:1]:
                    for d in dims:
                        out_elems *= d
                lhs_shapes = None
                if op.operands:
                    lhs_shapes = sym[cname].get(op.operands[0])
                contract = 1
                mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
                if mm and lhs_shapes:
                    dims = lhs_shapes[0][1]
                    for idx in mm.group(1).split(","):
                        if idx:
                            contract *= dims[int(idx)]
                flops += m_c * 2.0 * out_elems * contract

    # hbm bytes + collectives: only top-level ops of execution computations
    for cname in execution:
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0 or cname not in comps:
            continue
        for op in comps[cname]:
            if op.kind in ("parameter", "constant", "tuple", "get-tuple-element",
                           "bitcast", "while", "call", "conditional"):
                continue
            is_coll = any(op.kind.startswith(c) for c in _COLLECTIVES)
            ob = _bytes_of(op.out_shapes)
            if is_coll:
                base = op.kind.replace("-start", "")
                coll_bytes += m_c * ob
                coll_counts[base] += m_c
                coll_detail[base] += m_c * ob
                hbm += m_c * ob  # collectives also touch HBM once
                continue
            if op.kind.endswith("-done"):
                continue
            # per-op traffic semantics (first-order HBM model)
            if op.kind in ("dynamic-slice", "slice", "gather"):
                traffic = 2 * ob  # read the slice, write the slice
            elif op.kind in ("dynamic-update-slice", "scatter"):
                upd = 0
                if len(op.operands) > 1:
                    shapes = _lookup(cname, op.operands[1])
                    upd = _bytes_of(shapes) if shapes else 0
                traffic = 2 * upd  # read update, write region (in-place base)
            elif op.kind in ("broadcast", "iota"):
                traffic = ob
            elif op.kind == "fusion":
                mm = _CALLS_RE.search(op.line)
                traffic = ob
                if mm:
                    reads = _fusion_reads(mm.group(1))
                    for i, o in enumerate(op.operands):
                        if i < len(reads) and reads[i] is not None:
                            traffic += reads[i]
                        else:
                            shapes = _lookup(cname, o)
                            traffic += _bytes_of(shapes) if shapes else 0
                else:
                    traffic += _operand_bytes(cname, op)
            else:
                traffic = ob + _operand_bytes(cname, op)
            hbm += m_c * traffic

    return HloCost(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll_bytes,
        collective_counts=dict(coll_counts),
        collective_byte_detail=dict(coll_detail),
        n_whiles=n_whiles,
    )
