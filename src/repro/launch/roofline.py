"""Roofline-term extraction from compiled dry-run artifacts.

Three terms, per (arch × shape × mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs            / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes_accessed   / (chips * 819e9  B/s HBM)
  collective = collective_bytes     / (chips * 50e9   B/s per ICI link)

HLO_FLOPs / bytes come from compiled.cost_analysis(). collective_bytes is
not in cost_analysis: we parse the optimized HLO text and sum operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops. MODEL_FLOPS (6·N·D dense, 6·N_active·D MoE) is computed from configs
for the usefulness ratio.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch.hlo_analysis import xla_cost_analysis

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,4096]' -> bytes. Tuple shapes handled by the caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    Lines look like:
      %ag = bf16[16,512,128] all-gather(%x), replica_groups=...
    The LHS shape is the op's output — a good proxy for the wire bytes
    (all-gather output = full gathered tensor, all-reduce output = tensor
    reduced, etc.). Fusions never contain collectives, so a line scan
    suffices on optimized HLO."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            # match the op name as `= <shape> op-name(` — avoids matching
            # metadata or variable names, and skips `-start/-done` pairs
            # being double counted (we count only `-start` when present).
            if f" {coll}(" in stripped or f" {coll}-start(" in stripped:
                lhs = stripped.split("=", 1)
                if len(lhs) != 2:
                    continue
                # output shape(s) appear between '=' and the op name
                rhs = lhs[1]
                op_pos = rhs.find(coll)
                shape_part = rhs[:op_pos]
                out[coll] += _shape_bytes(shape_part)
                counts[coll] += 1
                break
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    collective_detail: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def analyze(compiled, hlo_text: str, chips: int) -> RooflineTerms:
    cost = xla_cost_analysis(compiled)
    colls = collective_bytes_from_hlo(hlo_text)
    counts = colls.pop("_counts")
    return RooflineTerms(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=float(sum(colls.values())),
        chips=chips,
        collective_detail={"bytes": colls, "counts": counts},
    )


def essential_bytes(cfg, shape, n_params: int, chips: int, microbatches: int = 1,
                    tp: int = 16) -> float:
    """Analytic LOWER BOUND on per-chip HBM traffic (bytes): weight reads,
    optimizer state r/w, saved residual w+r, logits, decode-cache traffic.
    The HLO-derived number is the matching UPPER bound (it inherits the CPU
    backend's finer fusion granularity); real TPU traffic lies between."""
    P = float(n_params)
    D, V = cfg.d_model, cfg.padded_vocab
    B, S = shape.global_batch, shape.seq_len
    dp = max(chips // tp, 1)
    w_bf16 = 2 * P / tp  # per-chip bytes of one full weight sweep (TP shard)
    if shape.kind == "train":
        M = microbatches
        weights = 3.0 * M * w_bf16  # fwd + remat-fwd + bwd
        opt = (4 * 2 + 4 * 2 + 2 + 4) * P / chips  # m,v r/w + param w + grad
        resid = 2.0 * (cfg.n_layers * M * (B / M) * S * D * 2 / dp)
        logits = 2.0 * (B * S * V * 2 / chips)
        return weights + opt + resid + logits
    if shape.kind == "prefill":
        weights = w_bf16
        resid = 2.0 * cfg.n_layers * B * S * D * 2 / dp
        cache_w = 2.0 * B * S * cfg.n_kv_heads * cfg.hd * 2 * cfg.n_layers / dp
        return weights + resid + cache_w
    # decode: weights once + cache read/write
    weights = w_bf16
    C = min(S, cfg.window) if cfg.window else S
    if cfg.family == "rwkv6":
        cache = B * cfg.n_layers * (cfg.d_model * (cfg.d_model // cfg.n_heads)) * 4
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // 3
        cache = B * (n_super * cfg.local_window * cfg.n_kv_heads * cfg.hd * 2 * 2
                     + cfg.n_layers * (cfg.d_rnn or D) * 4)
    else:
        cache = 2 * B * C * cfg.n_kv_heads * cfg.hd * 2 * cfg.n_layers
    return weights + 2.0 * cache / chips


def model_flops(cfg, shape, n_params: int, n_active_params: Optional[int] = None):
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for training;
    2·N·D for inference forward passes."""
    n = n_active_params if n_active_params is not None else n_params
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * 1 * shape.global_batch  # decode: one token
