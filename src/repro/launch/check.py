"""Static-verification sweep: run ``repro.analysis`` over the whole
scenario corpus and print a findings table.

One row per (matrix, strategy, orientation, mode, shards) cell — the
same grid the conformance suite executes on device, verified here
host-side only (``partition_plan`` is pure NumPy, so the 4-shard cells
need no mesh).  Exit is nonzero iff any cell yields an error finding,
which makes this the CI gate for the inspector pipeline.

``--mutate`` additionally runs the mutation harness
(``repro.analysis.mutate``): every seeded corruption must be caught,
every pristine artifact set must stay clean — the verifier's
false-negative test, in the same sweep binary.

Usage:
  PYTHONPATH=src python -m repro.launch.check                 # full sweep
  PYTHONPATH=src python -m repro.launch.check --smoke         # CI-sized
  PYTHONPATH=src python -m repro.launch.check --mutate
  PYTHONPATH=src python -m repro.launch.check --json out.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional

import numpy as np

from repro import obs
from repro.analysis import verify_artifacts
from repro.analysis.lint import lint_paths
from repro.analysis.mutate import MUTATIONS, build_artifacts, run_harness
from repro.autotune.corpus import corpus_entry, corpus_names
from repro.pipeline.registry import available_strategies
from repro.sparse.csr import transpose_csr

SMOKE_MATRICES = ("er_dense", "band_narrow", "chain")
SMOKE_STRATEGIES = ("growlocal", "wavefront")


def _upper_of(a):
    """The upper-triangular transpose — what ``plan(lower=False)`` sees
    before mirroring back to lower form."""
    return transpose_csr(a)


def sweep_cells(
    *,
    matrices,
    strategies,
    orientations=("lower", "upper"),
    modes=("bsp", "elastic"),
    shard_counts=(1, 4),
    slack: int = 4,
    level: str = "full",
) -> List[dict]:
    """Verify every grid cell; one record per cell with codes/timing."""
    rows: List[dict] = []
    for name in matrices:
        a = corpus_entry(name).matrix()
        for strategy in strategies:
            for orient in orientations:
                lower = orient == "lower"
                mat = a if lower else _upper_of(a)
                for mode in modes:
                    for ns in shard_counts:
                        t0 = time.perf_counter()
                        try:
                            art = build_artifacts(
                                mat, strategy=strategy, k=8, lower=lower,
                                slack=slack if mode == "elastic" else 0,
                                n_shards=ns,
                            )
                            rep = verify_artifacts(art, level=level)
                            ok, codes = rep.ok, list(rep.codes())
                            err = None
                        except Exception as e:  # a crash is a failure too
                            ok, codes, err = False, [], repr(e)
                        rows.append({
                            "matrix": name,
                            "strategy": strategy,
                            "orientation": orient,
                            "mode": mode,
                            "n_shards": ns,
                            "ok": ok,
                            "codes": codes,
                            "error": err,
                            "seconds": round(time.perf_counter() - t0, 4),
                        })
    return rows


def mutation_cells(*, smoke: bool = False) -> List[dict]:
    """The harness's artifact grid: families spread so every operator
    has at least one applicable site (wavefront/bsp for multi-round
    exchanges, narrow width for accum chains)."""
    grid = [
        ("er_dense/growlocal/el4", "er_dense", "growlocal",
         dict(slack=4, n_shards=4)),
        ("band_narrow/growlocal/el4w2", "band_narrow", "growlocal",
         dict(slack=4, n_shards=4, width=2)),
        ("er_dense/wavefront/bsp4", "er_dense", "wavefront",
         dict(slack=0, n_shards=4)),
        ("chain/growlocal/el2", "chain", "growlocal",
         dict(slack=2, n_shards=2)),
    ]
    if smoke:
        grid = grid[:2] + grid[2:3]
    sets = []
    for label, name, strategy, kw in grid:
        a = corpus_entry(name).matrix()
        sets.append((
            label, build_artifacts(a, strategy=strategy, k=8, **kw)
        ))
    return run_harness(sets)


def summarize_mutations(rows: List[dict]) -> dict:
    """Per-operator verdicts: every operator must be applicable
    somewhere and caught everywhere it applies."""
    ops = {}
    for r in rows:
        d = ops.setdefault(r["mutation"], {
            "family": r["family"], "applicable": 0, "caught": 0,
        })
        if r["caught"] is not None:
            d["applicable"] += 1
            d["caught"] += int(r["caught"])
    missed = sorted(
        m for m, d in ops.items()
        if d["applicable"] == 0 or d["caught"] != d["applicable"]
    )
    return {
        "operators": len(ops),
        "families": len({d["family"] for d in ops.values()}),
        "missed": missed,
        "per_operator": ops,
    }


def _print_table(rows: List[dict]) -> None:
    hdr = f"{'matrix':<18}{'strategy':<12}{'orient':<7}{'mode':<9}" \
          f"{'shards':>6}  {'verdict':<8}{'findings'}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        what = ", ".join(r["codes"]) if r["codes"] else (
            r["error"] or "-"
        )
        print(
            f"{r['matrix']:<18}{r['strategy']:<12}{r['orientation']:<7}"
            f"{r['mode']:<9}{r['n_shards']:>6}  "
            f"{'ok' if r['ok'] else 'FAIL':<8}{what}"
        )


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="repro.launch.check",
        description="static verification sweep over the scenario corpus",
    )
    p.add_argument(
        "--smoke", action="store_true",
        help="CI-sized subset (3 matrices x 2 strategies, fast level)",
    )
    p.add_argument(
        "--mutate", action="store_true",
        help="also run the mutation harness (verifier false-negative test)",
    )
    p.add_argument(
        "--no-lint", action="store_true",
        help="skip the determinism lint pass",
    )
    p.add_argument("--level", choices=("fast", "full"), default=None)
    p.add_argument("--json", metavar="PATH", default=None)
    args = p.parse_args(argv)

    matrices = SMOKE_MATRICES if args.smoke else corpus_names()
    strategies = (
        SMOKE_STRATEGIES if args.smoke
        else tuple(s for s in available_strategies() if s != "auto")
    )
    level = args.level or ("fast" if args.smoke else "full")

    t0 = time.perf_counter()
    rows = sweep_cells(
        matrices=matrices, strategies=strategies, level=level,
    )
    _print_table(rows)
    n_fail = sum(not r["ok"] for r in rows)
    print(
        f"\nsweep: {len(rows)} cells, {n_fail} failing, level={level}, "
        f"{time.perf_counter() - t0:.1f}s"
    )
    failed = n_fail > 0

    lint_found = []
    if not args.no_lint:
        lint_found = lint_paths()
        print(f"determinism lint: {len(lint_found)} finding(s)")
        for f in lint_found:
            print(f"  {f.code}  {f.message}")
        failed = failed or bool(lint_found)

    mut_summary = None
    if args.mutate:
        t1 = time.perf_counter()
        mrows = mutation_cells(smoke=args.smoke)
        mut_summary = summarize_mutations(mrows)
        print(
            f"mutation harness: {mut_summary['operators']} operators / "
            f"{mut_summary['families']} families, "
            f"missed={mut_summary['missed'] or 'none'}, "
            f"{time.perf_counter() - t1:.1f}s"
        )
        failed = failed or bool(mut_summary["missed"])

    if args.json:
        buf = obs.active_buffer()
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump({
                "cells": rows,
                "lint": [f.message for f in lint_found],
                "mutation": mut_summary,
                "counters": buf.counters() if buf is not None else {},
            }, fh, indent=2)
        print(f"wrote {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
