"""Production meshes.

make_production_mesh() is a FUNCTION (never a module-level constant) so that
importing this module does not touch jax device state — the 512-placeholder
device trick in dryrun.py depends on being able to set XLA_FLAGS before the
first jax initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_local_mesh(model: int = 1, data: int = 1):
    """Small mesh for tests/examples on host devices."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
