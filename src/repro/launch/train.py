"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --steps 100 --batch 8 --seq 256 [--reduced] [--ckpt-dir DIR] \
        [--compress-grads] [--resume]

On the container this drives the reduced configs on CPU; on a real cluster
the same file runs the full configs over make_production_mesh() (the mesh is
picked from the visible device count). Wires together: config registry,
data pipeline, train loop, async checkpointing, fault-tolerant resume.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, restore_checkpoint
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data.pipeline import SyntheticLMData
from repro.distributed.compression import (
    compressed_grad_transform,
    init_error_buffers,
)
from repro.launch.inputs import token_split
from repro.models import init_params, param_specs
from repro.train import AdamWConfig, make_train_step
from repro.train.train_loop import init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"devices={len(jax.devices())}")

    params = init_params(param_specs(cfg), jax.random.PRNGKey(0),
                         dtype=jnp.float32)
    state = init_train_state(cfg, params)
    start_step = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest():
        state, meta = restore_checkpoint(ckpt.latest(), template=state)
        start_step = meta["step"]
        print(f"[train] resumed from {ckpt.latest()} at step {start_step}")

    p_fe, _ = token_split(cfg, args.seq)
    data = SyntheticLMData(
        vocab=cfg.vocab_size, batch=args.batch, seq=args.seq, seed=0,
        frontend_positions=p_fe, d_model=cfg.d_model,
    )
    grad_transform = None
    if args.compress_grads:
        err = {"e": init_error_buffers(state.params)}
        grad_transform = compressed_grad_transform(err)

    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      microbatches=args.microbatches,
                                      grad_transform=grad_transform))
    t0 = time.time()
    for step in range(start_step, args.steps):
        state, metrics = step_fn(state, data.batch_at(step))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0):.1f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, step=step + 1)
    if ckpt:
        ckpt.save(state, step=args.steps)
        ckpt.wait()
        print(f"[train] final checkpoint: {ckpt.latest()}")


if __name__ == "__main__":
    main()
