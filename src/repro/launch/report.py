"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--mp]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def fmt_s(x):
    return f"{x:.3e}" if x is not None else "-"


def load(mp: bool):
    rows = []
    for f in sorted(OUT_DIR.glob("*.json")):
        is_mp = f.stem.endswith(".mp")
        if is_mp != mp:
            continue
        rows.append(json.loads(f.read_text()))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mp", action="store_true")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load(args.mp)
    hdr = (
        "| cell | status | t_comp (s) | t_mem (s) | t_mem_lb (s) | "
        "t_coll (s) | dominant | useful-FLOP ratio | bytes/chip (temp) | "
        "roofline frac |"
    )
    print(hdr)
    print("|" + "---|" * 10)
    for r in rows:
        if r["status"] == "SKIP":
            print(f"| {r['cell']} | SKIP ({r['reason'][:40]}…) |" + " - |" * 8)
            continue
        if r["status"] != "OK":
            print(f"| {r['cell']} | ERROR |" + " - |" * 8)
            continue
        t = r["roofline"]
        dom = t["dominant"]
        dom_t = t[f"t_{dom}_s" if dom != "memory" else "t_memory_s"]
        # roofline fraction: compute term / dominant term — how close the
        # cell is to being compute-bound at peak
        frac = t["t_compute_s"] / max(dom_t, 1e-30)
        temp = r.get("memory_analysis", {}).get("temp_size_in_bytes")
        print(
            f"| {r['cell']} | OK | {fmt_s(t['t_compute_s'])} | "
            f"{fmt_s(t['t_memory_s'])} | {fmt_s(t.get('t_memory_lb_s'))} | "
            f"{fmt_s(t['t_collective_s'])} | {dom} | "
            f"{(r.get('useful_flops_ratio') or 0):.2f} | "
            f"{(temp or 0)/1e9:.1f} GB | {frac:.3f} |"
        )


if __name__ == "__main__":
    main()
