"""CLI for the batching solve service — drive a load mix, print metrics.

Replays a request mix over the autotune scenario corpus (or a set of
adversarial all-distinct patterns, or one width-class family) through
``repro.serve.SolveService`` and prints the telemetry snapshot;
optionally dumps the full report as JSON (same shape as
``repro.serve.loadgen`` reports).

  PYTHONPATH=src python -m repro.launch.solver_serve --mix hot
  PYTHONPATH=src python -m repro.launch.solver_serve \\
      --mix uniform --clients 16 --requests 50 --max-batch 32 --workers 2
  PYTHONPATH=src python -m repro.launch.solver_serve \\
      --mix width --width-class --strategy wavefront
  PYTHONPATH=src python -m repro.launch.solver_serve \\
      --mix hot --open-loop 400 --n-requests 800 --json report.json
  PYTHONPATH=src python -m repro.launch.solver_serve \\
      --mix hot --mode continuous --slots 32 --open-loop 150

Mesh-sharded serving (the distributed backend needs >1 device; on a CPU
host force a device count before jax initializes):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.solver_serve \\
      --backend distributed --mesh 2x4 --mix hot
"""
from __future__ import annotations

import argparse
import json

from repro.serve import (
    MIXES,
    SolveService,
    patterns_for_mix,
    pretty,
    run_closed_loop,
    run_open_loop,
)


def _make_mesh(spec: str):
    """``"DATAxMODEL"`` -> a jax Mesh over ("data", "model")."""
    import jax

    try:
        data_ax, model_ax = (int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh expects DATAxMODEL (e.g. 2x4); got {spec!r}")
    have = len(jax.devices())
    if data_ax * model_ax > have:
        raise SystemExit(
            f"--mesh {spec} needs {data_ax * model_ax} devices but jax "
            f"sees {have}; set XLA_FLAGS="
            "--xla_force_host_platform_device_count=N (before jax "
            "initializes) or shrink the mesh"
        )
    return jax.make_mesh((data_ax, model_ax), ("data", "model"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mix", choices=MIXES, default="hot")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument(
        "--requests", type=int, default=25, help="requests per client"
    )
    ap.add_argument(
        "--open-loop", type=float, metavar="RATE_HZ", default=None,
        help="open-loop mode at RATE_HZ (default: closed loop)",
    )
    ap.add_argument(
        "--n-requests", type=int, default=200,
        help="total requests in open-loop mode",
    )
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-us", type=int, default=2000)
    ap.add_argument(
        "--workers", type=int, default=1,
        help="worker threads executing microbatches concurrently",
    )
    ap.add_argument(
        "--width-class", action="store_true",
        help="coalesce structurally-identical patterns into grouped "
        "multi-RHS solves (cross-pattern batching)",
    )
    ap.add_argument(
        "--mode", choices=("microbatch", "continuous"),
        default="microbatch",
        help="continuous: persistent resident-slot serving, no batch "
        "formation deadline and no drain barrier (repro.serve.slots)",
    )
    ap.add_argument(
        "--slots", type=int, default=None,
        help="resident device lanes per width class in continuous mode "
        "(default: max_batch, rounded up to a power of two)",
    )
    ap.add_argument("--strategy", default="auto")
    ap.add_argument(
        "--backend", choices=("scan", "pallas", "distributed"),
        default="scan",
    )
    ap.add_argument(
        "--mesh", metavar="DATAxMODEL", default="2x4",
        help="mesh shape for --backend distributed (default 2x4)",
    )
    ap.add_argument(
        "--adversarial-patterns", type=int, default=16,
        help="distinct patterns for --mix adversarial",
    )
    ap.add_argument(
        "--prewarm", action="store_true",
        help="compile every dispatch variant before offering load "
        "(recommended with --mode continuous: resident-slot serving "
        "compiles one pass per pow2 prefix width)",
    )
    ap.add_argument(
        "--validate", action="store_true",
        help="bitwise-check every served result against the direct solver",
    )
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args(argv)

    plan_kw = {}
    if args.backend == "pallas":
        plan_kw["interpret"] = True  # CPU containers have no TPU
    if args.backend == "distributed":
        mesh = _make_mesh(args.mesh)
        plan_kw["mesh"] = mesh
        # one schedule core per model-axis device: the distributed
        # executor rejects plans with more cores than devices, and the
        # auto selector respects an explicitly fixed k
        plan_kw["k"] = int(dict(mesh.shape)["model"])
    svc = SolveService(
        max_batch=args.max_batch,
        max_wait_us=args.max_wait_us,
        n_workers=args.workers,
        width_class_batching=args.width_class,
        mode=args.mode,
        n_slots=args.slots,
        strategy=args.strategy,
        backend=args.backend,
        **plan_kw,
    )
    try:
        patterns, sampler = patterns_for_mix(
            svc, args.mix, n_adversarial=args.adversarial_patterns
        )
        print(
            f"registered {len(patterns)} patterns "
            f"(mix={args.mix}, backend={args.backend}, "
            f"strategy={args.strategy}, mode={svc.mode}, "
            f"workers={svc.n_workers}, "
            f"width_class_batching={svc.width_class_batching})",
            flush=True,
        )
        if args.prewarm:
            svc.prewarm()
            svc.metrics.reset()  # steady-state telemetry only
        if args.open_loop is not None:
            report = run_open_loop(
                svc,
                sampler,
                rate_hz=args.open_loop,
                n_requests=args.n_requests,
                validate=args.validate,
            )
        else:
            report = run_closed_loop(
                svc,
                sampler,
                n_clients=args.clients,
                requests_per_client=args.requests,
                validate=args.validate,
            )
        print(
            f"\n{report['mode']} loop: {report['requests']} requests in "
            f"{report['elapsed_seconds']}s -> "
            f"{report['solves_per_sec']} solves/s, "
            f"errors={report['errors']}, "
            f"bitwise_mismatches={report['bitwise_mismatches']}"
        )
        print(pretty(report["metrics"]))
    finally:
        close_report = svc.close(timeout=60.0)
        if close_report["pins_retained"]:
            print(
                f"[close: {len(close_report['workers_alive'])} worker(s) "
                f"still alive after timeout, "
                f"{close_report['pins_retained']} plan pins retained]"
            )
    if args.validate and (report["bitwise_mismatches"] or report["errors"]):
        raise SystemExit("validation failed")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"[json written to {args.json}]")


if __name__ == "__main__":
    main()
