"""CLI for the batching solve service — drive a load mix, print metrics.

Replays a request mix over the autotune scenario corpus (or a set of
adversarial all-distinct patterns) through ``repro.serve.SolveService``
and prints the telemetry snapshot; optionally dumps the full report as
JSON (same shape as ``repro.serve.loadgen`` reports).

  PYTHONPATH=src python -m repro.launch.solver_serve --mix hot
  PYTHONPATH=src python -m repro.launch.solver_serve \\
      --mix uniform --clients 16 --requests 50 --max-batch 32
  PYTHONPATH=src python -m repro.launch.solver_serve \\
      --mix hot --open-loop 400 --n-requests 800 --json report.json
"""
from __future__ import annotations

import argparse
import json

from repro.serve import (
    MIXES,
    SolveService,
    patterns_for_mix,
    pretty,
    run_closed_loop,
    run_open_loop,
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mix", choices=MIXES, default="hot")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument(
        "--requests", type=int, default=25, help="requests per client"
    )
    ap.add_argument(
        "--open-loop", type=float, metavar="RATE_HZ", default=None,
        help="open-loop mode at RATE_HZ (default: closed loop)",
    )
    ap.add_argument(
        "--n-requests", type=int, default=200,
        help="total requests in open-loop mode",
    )
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-us", type=int, default=2000)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--backend", choices=("scan", "pallas"), default="scan")
    ap.add_argument(
        "--adversarial-patterns", type=int, default=16,
        help="distinct patterns for --mix adversarial",
    )
    ap.add_argument(
        "--validate", action="store_true",
        help="bitwise-check every served result against the direct solver",
    )
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args(argv)

    plan_kw = {}
    if args.backend == "pallas":
        plan_kw["interpret"] = True  # CPU containers have no TPU
    with SolveService(
        max_batch=args.max_batch,
        max_wait_us=args.max_wait_us,
        n_workers=args.workers,
        strategy=args.strategy,
        backend=args.backend,
        **plan_kw,
    ) as svc:
        patterns, sampler = patterns_for_mix(
            svc, args.mix, n_adversarial=args.adversarial_patterns
        )
        print(
            f"registered {len(patterns)} patterns "
            f"(mix={args.mix}, backend={args.backend}, "
            f"strategy={args.strategy})",
            flush=True,
        )
        if args.open_loop is not None:
            report = run_open_loop(
                svc,
                sampler,
                rate_hz=args.open_loop,
                n_requests=args.n_requests,
                validate=args.validate,
            )
        else:
            report = run_closed_loop(
                svc,
                sampler,
                n_clients=args.clients,
                requests_per_client=args.requests,
                validate=args.validate,
            )
        print(
            f"\n{report['mode']} loop: {report['requests']} requests in "
            f"{report['elapsed_seconds']}s -> "
            f"{report['solves_per_sec']} solves/s, "
            f"errors={report['errors']}, "
            f"bitwise_mismatches={report['bitwise_mismatches']}"
        )
        print(pretty(report["metrics"]))
    if args.validate and (report["bitwise_mismatches"] or report["errors"]):
        raise SystemExit("validation failed")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"[json written to {args.json}]")


if __name__ == "__main__":
    main()
