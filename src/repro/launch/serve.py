"""Serving launcher: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        [--reduced] [--batch 4] [--prompt-len 64] [--new-tokens 32]

On the container this drives reduced configs on CPU; the same entry point
drives full configs over make_production_mesh() on a real cluster (the
decode_32k / long_500k dry-run cells lower exactly this step function).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.inputs import make_train_batch
from repro.models import decode_step, init_params, param_specs, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"[serve] arch={cfg.name} family={cfg.family} "
          f"batch={args.batch} prompt={args.prompt_len}")
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0),
                         dtype=jnp.float32)
    batch = make_train_batch(cfg, batch=args.batch, seq_len=args.prompt_len,
                             seed=0)
    max_len = args.prompt_len + args.new_tokens

    prefill_fn = jax.jit(lambda p, b: prefill(cfg, p, b, max_len=max_len))
    decode_fn = jax.jit(lambda p, c, pos, t: decode_step(cfg, p, c, pos, t))

    t0 = time.time()
    logits, cache, pos = prefill_fn(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    token = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    out = [np.asarray(token)]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, cache = decode_fn(params, cache,
                                  jnp.asarray(pos + i, jnp.int32), token)
        token = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        out.append(np.asarray(token))
    token.block_until_ready()
    t_tok = (time.time() - t0) / max(args.new_tokens - 1, 1)
    seqs = np.stack(out, axis=1)
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms, decode "
          f"{t_tok*1e3:.1f} ms/token")
    for b in range(min(args.batch, 2)):
        print(f"  request {b}: {seqs[b][:12].tolist()}")


if __name__ == "__main__":
    main()
