import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, print memory_analysis / cost_analysis, and emit the
roofline terms consumed by EXPERIMENTS.md.

The two lines above MUST stay the first statements of this module: jax locks
the host device count at first initialization, and this module is the only
place that may see 512 placeholder devices (tests and benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch sptrsv --shape solve_nb
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Each cell writes experiments/dryrun/<cell>[.mp].json.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.distributed.meshes import resolve_spec, batch_axes
from repro.launch.inputs import (
    batch_logical,
    cache_logical,
    decode_state_shapes,
    resolve_kv_logical,
    token_split,
    train_batch_shapes,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, essential_bytes, model_flops
from repro.models import abstract_params, logical_specs, param_specs
from repro.models.decode import decode_step, prefill
from repro.models.lm import ModelConfig
from repro.train import AdamWConfig, make_train_step
from repro.train.train_loop import TrainState, train_state_specs

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=jax.sharding.NamedSharding(mesh, spec)
    )


def _abstract_with_sharding(cfg: ModelConfig, mesh, dtype=jnp.bfloat16):
    """Params as ShapeDtypeStructs with resolved shardings attached."""
    specs = param_specs(cfg)
    logical = logical_specs(specs)
    abstract = abstract_params(specs, dtype=dtype)
    return jax.tree_util.tree_map(
        lambda log, a: _sds(a.shape, a.dtype, mesh,
                            resolve_spec(mesh, log, a.shape)),
        logical,
        abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) or e is None for e in x
        ),
    )


def _abstract_state(cfg: ModelConfig, mesh) -> TrainState:
    p = _abstract_with_sharding(cfg, mesh)
    f32 = lambda a: _sds(a.shape, jnp.float32, mesh, a.sharding.spec)  # noqa: E731
    return TrainState(
        params=p,
        opt_state={
            "mu": jax.tree_util.tree_map(f32, p),
            "nu": jax.tree_util.tree_map(f32, p),
            "step": _sds((), jnp.int32, mesh, jax.sharding.PartitionSpec()),
        },
    )


def _batch_sds(cfg: ModelConfig, shape, mesh):
    shapes = train_batch_shapes(cfg, shape)
    logical = batch_logical(cfg, shape.kind)
    out = {}
    for name, (shp, dt) in shapes.items():
        spec = resolve_spec(mesh, logical[name], shp)
        out[name] = _sds(shp, dt, mesh, spec)
    return out


def _microbatches_for(cfg: ModelConfig, shape) -> int:
    """Gradient-accumulation depth per cell: keep per-microbatch activation
    memory bounded. Scales with parameter width (the §Perf memory lever)."""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 12000:
        return 16
    if cfg.d_model >= 5000:
        return 8
    if cfg.d_model >= 4000:
        return 4
    return 2


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False):
    """Returns (lowered, compiled, meta) for one cell."""
    import dataclasses as _dc

    cfg = get_config(arch)
    wkv_chunk = int(os.environ.get("REPRO_WKV_CHUNK", "0"))
    if wkv_chunk and cfg.family == "rwkv6":
        cfg = _dc.replace(cfg, wkv_chunk=wkv_chunk)
    if os.environ.get("REPRO_NO_REMAT"):
        cfg = _dc.replace(cfg, remat=False)
    shape = SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        return None, None, {"skipped": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    from repro.distributed.sharding_ctx import activation_sharding

    seq_sharded = bool(int(os.environ.get("REPRO_SEQ_SHARD", "0")))
    if shape.kind == "train":
        state = _abstract_state(cfg, mesh)
        batch = _batch_sds(cfg, shape, mesh)
        mb = _microbatches_for(cfg, shape)
        step = make_train_step(
            cfg,
            AdamWConfig(),
            microbatches=mb,
        )
        with mesh, activation_sharding(mesh, seq_sharded=seq_sharded):
            lowered = jax.jit(step).lower(state, batch)
    elif shape.kind == "prefill":
        params = _abstract_with_sharding(cfg, mesh)
        batch = _batch_sds(cfg, shape, mesh)
        fn = lambda p, b: prefill(cfg, p, b, max_len=shape.seq_len)  # noqa: E731
        with mesh, activation_sharding(mesh, seq_sharded=seq_sharded):
            lowered = jax.jit(fn).lower(params, batch)
    else:  # decode
        params = _abstract_with_sharding(cfg, mesh)
        cache_shapes, (tok_shape, tok_dt) = decode_state_shapes(cfg, shape)
        clog = cache_logical(cfg)
        cache = jax.tree_util.tree_map(
            lambda log, a: _sds(
                a.shape, a.dtype, mesh, resolve_kv_logical(mesh, log, a.shape)
            ),
            clog,
            cache_shapes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, str) or e is None for e in x
            ),
        )
        tok = _sds(tok_shape, tok_dt, mesh,
                   resolve_spec(mesh, ("batch",), tok_shape))
        pos = _sds((), jnp.int32, mesh, jax.sharding.PartitionSpec())
        fn = lambda p, c, ps, t: decode_step(cfg, p, c, ps, t)  # noqa: E731
        with mesh, activation_sharding(mesh):
            lowered = jax.jit(fn).lower(params, cache, pos, tok)

    compiled = lowered.compile()
    return lowered, compiled, {"chips": chips, "mesh": dict(mesh.shape)}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True) -> dict:
    t0 = time.time()
    tag = f"{arch}.{shape_name}" + (".mp" if multi_pod else "")
    try:
        lowered, compiled, meta = lower_cell(
            arch, shape_name, multi_pod=multi_pod
        )
    except Exception as e:  # noqa: BLE001
        result = {
            "cell": tag, "status": "ERROR",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
        _save(result, tag, save)
        return result

    if lowered is None:
        result = {"cell": tag, "status": "SKIP", "reason": meta["skipped"]}
        _save(result, tag, save)
        return result

    hlo = compiled.as_text()
    terms = roofline_terms(compiled, hlo, meta["chips"])
    mem_d = _memory_dict(compiled)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_params, n_active = _param_counts(cfg)
    mf = model_flops(cfg, shape, n_params, n_active)
    hlo_total_flops = terms["flops"] * meta["chips"]
    ess = essential_bytes(
        cfg, shape, n_params, meta["chips"],
        microbatches=_microbatches_for(cfg, shape),
        tp=meta["mesh"].get("model", 1),
    )
    terms["essential_bytes"] = ess
    terms["t_memory_lb_s"] = ess / HBM_BW

    result = {
        "cell": tag,
        "status": "OK",
        "mesh": meta["mesh"],
        "chips": meta["chips"],
        "compile_s": round(time.time() - t0, 1),
        "roofline": terms,
        "memory_analysis": mem_d,
        "model_flops": mf,
        "n_params": n_params,
        "n_active_params": n_active,
        "useful_flops_ratio": (mf / hlo_total_flops) if hlo_total_flops else None,
    }
    _save(result, tag, save)
    return result


def _memory_dict(compiled) -> dict:
    mem = compiled.memory_analysis()
    mem_d = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        try:
            mem_d[attr] = int(getattr(mem, attr))
        except Exception:  # noqa: BLE001
            pass
    return mem_d


def roofline_terms(compiled, hlo_text: str, chips: int) -> dict:
    """Trip-count-aware roofline terms (per device) + raw XLA numbers."""
    from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
    from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    cost = analyze_hlo(hlo_text)
    xla = xla_cost_analysis(compiled)
    t_comp = cost.flops / PEAK_FLOPS
    t_mem = cost.hbm_bytes / HBM_BW
    t_coll = cost.collective_bytes / ICI_BW
    terms = {
        "flops": cost.flops,
        "bytes_accessed": cost.hbm_bytes,
        "collective_bytes": cost.collective_bytes,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": max(
            [("compute", t_comp), ("memory", t_mem), ("collective", t_coll)],
            key=lambda kv: kv[1],
        )[0],
        "collective_counts": cost.collective_counts,
        "collective_byte_detail": cost.collective_byte_detail,
        "xla_raw_flops": float(xla.get("flops", 0.0)),
        "xla_raw_bytes": float(xla.get("bytes accessed", 0.0)),
    }
    return terms


def _param_counts(cfg: ModelConfig):
    """(total, active) parameter counts from the abstract tree."""
    ab = abstract_params(param_specs(cfg))
    total = sum(int(np.prod(a.shape)) for a in jax.tree_util.tree_leaves(ab))
    active = total
    if cfg.moe is not None:
        # routed experts contribute top_k/n_experts of their params per token
        moe_leaves = 0
        specs = param_specs(cfg)
        for name in ("w_gate", "w_up", "w_down"):
            leaf = specs["layers"]["moe"][name]
            moe_leaves += int(np.prod(leaf.shape))
        active = total - moe_leaves + int(
            moe_leaves * cfg.moe.top_k / cfg.moe.n_experts
        )
    return total, active


def _save(result: dict, tag: str, save: bool):
    line = (
        f"[{result['status']}] {tag}"
        + (f" compile={result.get('compile_s')}s" if "compile_s" in result else "")
    )
    print(line, flush=True)
    if result["status"] == "OK":
        r = result["roofline"]
        print(
            f"    t_comp={r['t_compute_s']:.3e}s t_mem={r['t_memory_s']:.3e}s "
            f"t_coll={r['t_collective_s']:.3e}s dominant={r['dominant']}",
            flush=True,
        )
        print(f"    memory/device: {result['memory_analysis']}", flush=True)
    elif result["status"] == "ERROR":
        print("    " + result["error"], flush=True)
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        (OUT_DIR / f"{tag}.json").write_text(json.dumps(result, indent=1))


# ---------------------------------------------------------------------------
# the paper's own workload as dry-run cells
# ---------------------------------------------------------------------------
SPTRSV_SHAPES = {
    # (n, kind, params, batch): batch = #RHS sharded over 'data';
    # shard = mesh decomposition ("model" all_gather | "rows" halo ring)
    "solve_er100k": dict(n=100_000, kind="er", p=5e-5, batch=16),
    "solve_nb100k": dict(n=100_000, kind="nb", p=0.14, band=10.0, batch=16),
    "solve_nb100k_rows": dict(
        n=100_000, kind="nb", p=0.14, band=10.0, batch=16, shard="rows"
    ),
}


def run_sptrsv_cell(shape_name: str, *, multi_pod: bool = False,
                    save: bool = True) -> dict:
    from repro.pipeline import TriangularSolver
    from repro.solver.distributed import dist_plan_spec, lower_distributed_solve
    from repro.sparse import erdos_renyi_lower, narrow_band_lower

    t0 = time.time()
    tag = f"sptrsv.{shape_name}" + (".mp" if multi_pod else "")
    spec = SPTRSV_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    k = mesh.shape["model"]

    if spec["kind"] == "er":
        L = erdos_renyi_lower(spec["n"], spec["p"], seed=1)
    else:
        L = narrow_band_lower(spec["n"], spec["p"], spec["band"], seed=1)
    # plan through the real distributed backend so the reported numbers
    # come from the binding that production would execute —
    # BoundSolve.describe() (device bytes, padded plan geometry, mesh)
    # rather than ad-hoc locals recomputed here
    shard = spec.get("shard", "model")
    solver = TriangularSolver.plan(
        L, strategy="growlocal", k=k, backend="distributed", mesh=mesh,
        shard=shard,
    )
    try:
        with mesh:
            if shard == "rows":
                from repro.core import partition_plan
                from repro.solver.rowsharded import lower_rowsharded_solve

                rsp = partition_plan(solver.exec_plan, k)
                lowered = lower_rowsharded_solve(
                    rsp, mesh, batch=spec["batch"]
                )
            else:
                dspec = dist_plan_spec(
                    solver.exec_plan, batch=spec["batch"]
                )
                lowered = lower_distributed_solve(dspec, mesh)
            compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001
        result = {"cell": tag, "status": "ERROR",
                  "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
        _save(result, tag, save)
        return result
    hlo = compiled.as_text()
    terms = roofline_terms(compiled, hlo, chips)
    mem_d = _memory_dict(compiled)
    info = solver.info()
    binding = info["binding"]
    # comm fields are .get-guarded: only distributed bindings publish an
    # exchange dict, and only shard="rows" carries the halo keys
    ex = binding.get("exchange") or {}
    result = {
        "cell": tag, "status": "OK", "mesh": dict(mesh.shape), "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "roofline": terms,
        "memory_analysis": mem_d,
        "supersteps": solver.n_supersteps,
        "shard": binding.get("shard", "model"),
        "comm": {
            "mode": ex.get("mode"),
            "exchange_rounds": ex.get("rounds"),
            "comm_values_per_solve": ex.get("comm_values_per_solve"),
            "comm_bytes_per_solve": ex.get("comm_bytes_per_solve"),
            "halo_bytes_per_solve": ex.get("halo_bytes_per_solve"),
            "allgather_bytes": ex.get("allgather_bytes"),
            "halo_ratio": ex.get("halo_ratio"),
        },
        "plan": info["plan"],
        "binding": binding,
        "nnz": L.nnz,
        # useful flops: 2 per off-diagonal nnz + 1 divide per row
        "model_flops": float(2 * (L.nnz - L.n_rows) + L.n_rows) * spec["batch"],
    }
    if ex:
        print(
            f"    comm[{binding.get('shard', 'model')}]: "
            f"mode={ex.get('mode')} rounds={ex.get('rounds')} "
            f"bytes/solve={ex.get('comm_bytes_per_solve')}"
            + (
                f" halo_ratio={ex['halo_ratio']:.4f}"
                if "halo_ratio" in ex
                else ""
            ),
            flush=True,
        )
    _save(result, tag, save)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument(
        "--trace", metavar="PATH", default=None,
        help="trace the dry-run with repro.obs and write a Chrome "
             "trace_event JSON to PATH (sptrsv cells span the "
             "inspector/backend layers; lowering itself is untraced)",
    )
    args = ap.parse_args()

    trace_buf = None
    if args.trace:
        from repro import obs

        trace_buf = obs.enable()
    try:
        _dispatch(args)
    finally:
        if trace_buf is not None:
            from repro import obs

            obs.disable()
            obs.export_chrome_trace(args.trace, trace_buf)
            print(f"[trace: {len(trace_buf)} spans -> {args.trace}]",
                  flush=True)


def _dispatch(args):
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                run_cell(arch, shape, multi_pod=args.multi_pod)
        for shape in SPTRSV_SHAPES:
            run_sptrsv_cell(shape, multi_pod=args.multi_pod)
        return
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    if args.arch == "sptrsv":
        run_sptrsv_cell(args.shape, multi_pod=args.multi_pod)
    else:
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
