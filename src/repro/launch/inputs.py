"""Input construction for every (arch × shape) cell — both as
ShapeDtypeStructs (dry-run; no allocation) and as real arrays (smoke tests,
examples).

Frontend stubs (by assignment): [audio] gets precomputed frame embeddings
(T_frames = seq_len / 4 — a conv subsampler's output rate), [vlm] gets
anyres patch embeddings (2880 patches) that occupy the first positions of
the sequence; text tokens fill the rest.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig
from repro.models.decode import init_decode_cache
from repro.models.lm import ModelConfig

Pytree = Any

VLM_PATCHES = 2880  # anyres: 4 tiles + base thumbnail, 576 each
AUDIO_SUBSAMPLE = 4


def token_split(cfg: ModelConfig, seq_len: int) -> Tuple[int, int]:
    """(frontend positions, text positions) summing to seq_len."""
    if cfg.frontend == "vision":
        p = min(VLM_PATCHES, seq_len // 2)
        return p, seq_len - p
    if cfg.family == "encdec":
        return seq_len // AUDIO_SUBSAMPLE, seq_len
    return 0, seq_len


def train_batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    B, S = shape.global_batch, shape.seq_len
    p, t = token_split(cfg, S)
    out = {"tokens": ((B, t), np.int32), "labels": ((B, t), np.int32)}
    if p:
        out["frontend_embeds"] = ((B, p, cfg.d_model), np.float32)
    return out


def make_train_batch(
    cfg: ModelConfig, *, batch: int, seq_len: int, seed: int = 0
) -> Dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    p, t = token_split(cfg, seq_len)
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, t)), jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, t)), jnp.int32
        ),
    }
    if p:
        out["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((batch, p, cfg.d_model)), jnp.float32
        )
    return out


def decode_state_shapes(cfg: ModelConfig, shape: ShapeConfig):
    """(cache shapes via eval_shape, token/pos shapes) for decode cells."""
    B, S = shape.global_batch, shape.seq_len
    enc_len = S // AUDIO_SUBSAMPLE if cfg.family == "encdec" else 0
    cache = jax.eval_shape(
        lambda: init_decode_cache(cfg, B, S, enc_len=enc_len)
    )
    return cache, ((B,), np.int32)


# ---------------------------------------------------------------------------
# logical shardings for inputs/caches
# ---------------------------------------------------------------------------
def batch_logical(cfg: ModelConfig, shape_kind: str) -> Dict[str, tuple]:
    out = {
        "tokens": ("batch", None),
        "labels": ("batch", None),
    }
    if cfg.frontend == "vision" or cfg.family == "encdec":
        out["frontend_embeds"] = ("batch", None, None)
    return out


def cache_logical(cfg: ModelConfig) -> Pytree:
    """Logical axes for every cache leaf (structure mirrors
    init_decode_cache).

    KV caches shard on the SEQUENCE dim: attention contracts over Dh and
    softmaxes over S, and with S sharded both einsums stay local (only the
    flash-style softmax stats cross the wire). Head/Dh sharding was tried
    first and refuted — XLA resolved the Dh-sharded contraction by
    all-gathering the whole cache every layer (EXPERIMENTS.md §Perf,
    granite decode iterations)."""
    kv = (None, "batch", "tp", None, None)  # shard the sequence/slots dim

    def kv_spec():
        return kv

    if cfg.family in ("dense", "moe"):
        return {"k": kv_spec(), "v": kv_spec()}
    if cfg.family == "rwkv6":
        return {
            "shift_tm": (None, "batch", None, "tp"),
            "wkv": (None, "batch", "tp", None, None),
            "shift_cm": (None, "batch", None, "tp"),
        }
    if cfg.family == "hybrid":
        rec = {"conv": (None, "batch", None, "tp"), "h": (None, "batch", "tp")}
        out = {
            "super": {
                "rec1": dict(rec),
                "rec2": dict(rec),
                "attn": {"k": kv_spec(), "v": kv_spec()},
            }
        }
        if cfg.n_layers % 3:
            out["tail"] = dict(rec)
        return out
    if cfg.family == "encdec":
        return {"k": kv_spec(), "v": kv_spec(), "xk": kv_spec(), "xv": kv_spec()}
    raise ValueError(cfg.family)


def resolve_kv_logical(mesh, logical, shape):
    """Special-case 'tp2': place 'model' on the kv-head dim when divisible,
    otherwise on head_dim ('tp2' slot)."""
    from repro.distributed.meshes import resolve_spec

    if "tp2" not in logical:
        return resolve_spec(mesh, logical, shape)
    heads_dim = logical.index("tp")
    hd_dim = logical.index("tp2")
    model_size = mesh.shape.get("model", 1)
    use_heads = shape[heads_dim] % model_size == 0
    fixed = tuple(
        (
            "tp"
            if (i == heads_dim and use_heads) or (i == hd_dim and not use_heads)
            else (None if i in (heads_dim, hd_dim) else ax)
        )
        for i, ax in enumerate(logical)
    )
    return resolve_spec(mesh, fixed, shape)
