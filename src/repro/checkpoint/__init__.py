from repro.checkpoint.checkpoint import (
    AsyncCheckpointer,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "AsyncCheckpointer"]
