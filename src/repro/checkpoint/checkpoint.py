"""Fault-tolerant checkpointing (no orbax in the container — self-contained).

Design for the 1000-node posture:
  * every leaf saved as its own ``.npy`` under a manifest with tree structure,
    dtypes and a content checksum — single-writer per shard in a real
    deployment, atomic rename on completion (a crashed save never produces a
    loadable checkpoint: the manifest is written LAST);
  * restore is *resharding*: arrays are loaded host-side and re-placed with
    whatever sharding the (possibly different-size) restart mesh dictates —
    elastic restarts after node loss (distributed/fault_tolerance.py drives
    this);
  * AsyncCheckpointer overlaps serialization with training (snapshot on the
    host, background thread writes).
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(path: str | Path, tree: Pytree, *, step: int = 0,
                    extra: Optional[dict] = None) -> None:
    """Atomic checkpoint save (tmp dir + rename; manifest written last)."""
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    paths, leaves, treedef = _flatten_with_paths(tree)
    entries = []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        entries.append({
            "path": p,
            "file": fname,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    manifest = {
        "step": step,
        "paths": [e["path"] for e in entries],
        "entries": entries,
        "extra": extra or {},
    }
    (tmp / _MANIFEST).write_text(json.dumps(manifest))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)


def restore_checkpoint(
    path: str | Path,
    *,
    template: Optional[Pytree] = None,
    shardings: Optional[Pytree] = None,
) -> Tuple[Pytree, dict]:
    """Load a checkpoint. With ``template`` the tree structure comes from it
    (and arrays are checked against it); with ``shardings`` every leaf is
    device_put with the given (new-mesh) sharding — the elastic-restart
    path. Returns (tree, meta)."""
    path = Path(path)
    manifest = json.loads((path / _MANIFEST).read_text())
    arrays = []
    for e in manifest["entries"]:
        arr = np.load(path / e["file"])
        crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        if crc != e["crc32"]:
            raise IOError(
                f"checkpoint corruption in {e['path']}: crc {crc} != {e['crc32']}"
            )
        arrays.append(arr)

    if template is not None:
        t_paths, t_leaves, treedef = _flatten_with_paths(template)
        by_path = dict(zip(manifest["paths"], arrays))
        ordered = []
        for p, t in zip(t_paths, t_leaves):
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p}")
            a = by_path[p]
            if tuple(a.shape) != tuple(np.shape(t)):
                raise ValueError(
                    f"shape mismatch for {p}: ckpt {a.shape} vs template "
                    f"{np.shape(t)}"
                )
            ordered.append(a)
        arrays = ordered
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
    else:
        # rebuild a nested dict from paths
        tree = {}
        for p, a in zip(manifest["paths"], arrays):
            node = tree
            parts = [s for s in p.replace("[", ".").replace("]", "")
                     .replace("'", "").split(".") if s]
            for key in parts[:-1]:
                node = node.setdefault(key, {})
            node[parts[-1]] = a

    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    meta = {"step": manifest["step"], **manifest.get("extra", {})}
    return tree, meta


@dataclasses.dataclass
class AsyncCheckpointer:
    """Overlap checkpoint IO with compute: snapshot to host RAM
    synchronously (cheap), write in a daemon thread."""

    directory: str
    keep: int = 3

    def __post_init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, tree: Pytree, *, step: int) -> None:
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def _write():
            try:
                save_checkpoint(
                    Path(self.directory) / f"step_{step:08d}", host_tree,
                    step=step,
                )
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest(self) -> Optional[Path]:
        d = Path(self.directory)
        if not d.exists():
            return None
        cands = sorted(p for p in d.iterdir()
                       if p.name.startswith("step_") and (p / _MANIFEST).exists())
        return cands[-1] if cands else None

    def _gc(self):
        d = Path(self.directory)
        cands = sorted(p for p in d.iterdir()
                       if p.name.startswith("step_") and (p / _MANIFEST).exists())
        for old in cands[: -self.keep]:
            shutil.rmtree(old)
