"""Matrix-Market IO (coordinate real/integer/pattern, general/symmetric),
dependency-light.

Lets users drop in actual SuiteSparse ``.mtx`` / ``.mtx.gz`` files when
they have them; the offline container uses the generators instead.
Reading and writing round-trip each other for every supported
(field, symmetry) combination — tests/test_io.py exercises the full
grid, gzip included.
"""
from __future__ import annotations

import gzip
import io
from pathlib import Path

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo

_FIELDS = ("real", "integer", "pattern")
_SYMMETRIES = ("general", "symmetric")


def _parse_header(header: str) -> tuple:
    """-> (field, symmetry); raises on anything we cannot faithfully
    represent (complex values, skew/hermitian symmetry, array format)."""
    tokens = header.split()
    # %%MatrixMarket object format field symmetry
    if len(tokens) < 5 or tokens[0] != "%%matrixmarket":
        raise ValueError(f"unsupported MatrixMarket header: {header}")
    _, obj, fmt, field, symmetry = tokens[:5]
    if obj != "matrix" or fmt != "coordinate":
        raise ValueError(
            f"only 'matrix coordinate' files are supported, got "
            f"{obj!r} {fmt!r}"
        )
    if field not in _FIELDS:
        raise ValueError(
            f"unsupported field {field!r}; supported: {_FIELDS}"
        )
    if symmetry not in _SYMMETRIES:
        raise ValueError(
            f"unsupported symmetry {symmetry!r}; supported: {_SYMMETRIES}"
        )
    return field, symmetry


def _opener(path: Path):
    return gzip.open if path.suffix == ".gz" else open


def read_matrix_market(path: str | Path) -> CSRMatrix:
    path = Path(path)
    with _opener(path)(path, "rt") as fh:
        header = fh.readline().strip().lower()
        field, symmetry = _parse_header(header)
        pattern = field == "pattern"
        symmetric = symmetry == "symmetric"
        line = fh.readline()
        while line.startswith("%"):
            line = fh.readline()
        n_rows, n_cols, nnz = (int(t) for t in line.split())
        data = np.loadtxt(io.StringIO(fh.read()), ndmin=2)
    if data.shape[0] != nnz:
        raise ValueError(
            f"entry count mismatch: header says {nnz}, file has "
            f"{data.shape[0]}"
        )
    rows = data[:, 0].astype(np.int64) - 1
    cols = data[:, 1].astype(np.int64) - 1
    vals = np.ones(len(rows)) if pattern else data[:, 2].astype(np.float64)
    if symmetric:
        if not bool(np.all(rows >= cols)):
            raise ValueError(
                "symmetric MatrixMarket files must store the lower triangle"
            )
        off = rows != cols
        rows_all = np.concatenate([rows, cols[off]])
        cols_all = np.concatenate([cols, rows[off]])
        vals = np.concatenate([vals, vals[off]])
        rows, cols = rows_all, cols_all
    assert len(rows) >= nnz  # symmetric expansion can only grow
    return csr_from_coo(n_rows, n_cols, rows, cols, vals)


def write_matrix_market(
    path: str | Path,
    m: CSRMatrix,
    *,
    field: str = "real",
    symmetry: str = "general",
) -> None:
    """Write ``m`` as ``coordinate <field> <symmetry>``; gzip-compressed
    when ``path`` ends in ``.gz``.

    * ``field="integer"`` requires integral values (formatted as ints);
      ``field="pattern"`` stores structure only (values read back as 1.0).
    * ``symmetry="symmetric"`` requires a structurally and numerically
      symmetric ``m`` and stores only its lower triangle (the standard
      MatrixMarket convention ``read_matrix_market`` expands).
    """
    if field not in _FIELDS:
        raise ValueError(f"field must be one of {_FIELDS}, got {field!r}")
    if symmetry not in _SYMMETRIES:
        raise ValueError(
            f"symmetry must be one of {_SYMMETRIES}, got {symmetry!r}"
        )
    path = Path(path)
    rows = m.row_of_entry()
    cols = m.indices
    vals = m.data
    if field == "integer" and not np.all(vals == np.round(vals)):
        raise ValueError("field='integer' requires integral values")
    if symmetry == "symmetric":
        from repro.sparse.csr import transpose_csr

        t = transpose_csr(m)
        # pattern files never store values, so only structural symmetry
        # is required for a faithful round-trip
        if (
            m.n_rows != m.n_cols
            or not np.array_equal(m.indptr, t.indptr)
            or not np.array_equal(m.indices, t.indices)
            or (field != "pattern" and not np.array_equal(m.data, t.data))
        ):
            raise ValueError(
                "symmetry='symmetric' requires a symmetric matrix"
            )
        keep = rows >= cols
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
    with _opener(path)(path, "wt") as fh:
        fh.write(f"%%MatrixMarket matrix coordinate {field} {symmetry}\n")
        fh.write(f"{m.n_rows} {m.n_cols} {len(rows)}\n")
        if field == "pattern":
            for r, c in zip(rows, cols):
                fh.write(f"{r + 1} {c + 1}\n")
        elif field == "integer":
            for r, c, v in zip(rows, cols, vals):
                fh.write(f"{r + 1} {c + 1} {int(round(v))}\n")
        else:
            for r, c, v in zip(rows, cols, vals):
                fh.write(f"{r + 1} {c + 1} {v:.17g}\n")
