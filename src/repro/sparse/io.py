"""Matrix-Market IO (coordinate real general/symmetric), dependency-light.

Lets users drop in actual SuiteSparse ``.mtx`` files when they have them;
the offline container uses the generators instead.
"""
from __future__ import annotations

import gzip
import io
from pathlib import Path

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo


def read_matrix_market(path: str | Path) -> CSRMatrix:
    path = Path(path)
    opener = gzip.open if path.suffix == ".gz" else open
    with opener(path, "rt") as fh:
        header = fh.readline().strip().lower()
        if not header.startswith("%%matrixmarket matrix coordinate"):
            raise ValueError(f"unsupported MatrixMarket header: {header}")
        symmetric = "symmetric" in header
        pattern = "pattern" in header
        line = fh.readline()
        while line.startswith("%"):
            line = fh.readline()
        n_rows, n_cols, nnz = (int(t) for t in line.split())
        data = np.loadtxt(io.StringIO(fh.read()), ndmin=2)
    rows = data[:, 0].astype(np.int64) - 1
    cols = data[:, 1].astype(np.int64) - 1
    vals = np.ones(len(rows)) if pattern else data[:, 2].astype(np.float64)
    if symmetric:
        off = rows != cols
        rows = np.concatenate([rows, cols[off]])
        cols_all = np.concatenate([cols, data[:, 0].astype(np.int64)[off] - 1])
        vals = np.concatenate([vals, vals[off]])
        cols = cols_all
    assert len(rows) >= nnz  # symmetric expansion can only grow
    return csr_from_coo(n_rows, n_cols, rows, cols, vals)


def write_matrix_market(path: str | Path, m: CSRMatrix) -> None:
    path = Path(path)
    rows = m.row_of_entry()
    with open(path, "wt") as fh:
        fh.write("%%MatrixMarket matrix coordinate real general\n")
        fh.write(f"{m.n_rows} {m.n_cols} {m.nnz}\n")
        for r, c, v in zip(rows, m.indices, m.data):
            fh.write(f"{r + 1} {c + 1} {v:.17g}\n")
