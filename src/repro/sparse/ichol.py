"""Zero-fill incomplete Cholesky — IC(0).

Produces the iChol data set of the paper (§6.2.3) from SPD matrices and the
preconditioner for the PCG example driver. Standard up-looking IC(0) on the
lower-triangular pattern of A; the inspector runs once per sparsity pattern,
so the per-row python loop is acceptable at benchmark sizes.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSRMatrix, lower_triangle_of


def ichol0(a: CSRMatrix, *, shift: float = 0.0) -> CSRMatrix:
    """IC(0) factor L with A ≈ L Lᵀ, L restricted to tril(A)'s pattern.

    ``shift`` scales the diagonal by (1 + shift) before factorization
    (standard remedy when a pivot goes non-positive; we retry internally
    with growing shift)."""
    tril = lower_triangle_of(a)
    base_diag = tril.diagonal().copy()

    attempt_shift = shift
    for _ in range(12):
        ok, L = _ichol0_once(tril, base_diag, attempt_shift)
        if ok:
            return L
        attempt_shift = max(attempt_shift * 2.0, 1e-3)
    raise np.linalg.LinAlgError("IC(0) failed even with diagonal shift")


def _ichol0_once(tril: CSRMatrix, base_diag: np.ndarray, shift: float):
    n = tril.n_rows
    indptr, indices = tril.indptr, tril.indices
    vals = tril.data.copy()
    rows = tril.row_of_entry()
    diag_mask = indices == rows
    if shift:
        vals[diag_mask] = base_diag * (1.0 + shift)

    diag_pos = np.nonzero(diag_mask)[0]
    assert len(diag_pos) == n, "IC(0) requires a structurally full diagonal"

    for i in range(n):
        lo = int(indptr[i])
        ti = int(diag_pos[i])
        for t in range(lo, ti):
            j = int(indices[t])
            # L[i,j] = (A[i,j] - sum_{k<j} L[i,k] L[j,k]) / L[j,j]
            s = vals[t]
            pi, pj = lo, int(indptr[j])
            tj = int(diag_pos[j])
            while pi < t and pj < tj:
                ci, cj = indices[pi], indices[pj]
                if ci == cj:
                    s -= vals[pi] * vals[pj]
                    pi += 1
                    pj += 1
                elif ci < cj:
                    pi += 1
                else:
                    pj += 1
            vals[t] = s / vals[tj]
        # diagonal: L[i,i] = sqrt(A[i,i] - sum_k L[i,k]^2)
        s = vals[ti] - float(np.sum(vals[lo:ti] ** 2))
        if s <= 0.0:
            return False, None
        vals[ti] = np.sqrt(s)
    L = CSRMatrix(n, tril.n_cols, indptr.copy(), indices.copy(), vals)
    return True, L
