"""The SpTRSV dependency DAG (paper §2.2, Fig. 1.1).

Vertex i = row i of the lower-triangular matrix L. Edge (j, i) iff L[i, j] != 0
for j < i. Vertex weight ω(i) = nnz of row i (paper §2.2: "the weight ω(v) of
each vertex ... is simply defined as the number of non-zero entries in the
corresponding row").

The DAG is stored as two CSR adjacency structures (parents = the strictly-lower
CSR of L itself; children = its transpose), which is what every scheduler here
consumes. Pure numpy; sizes up to |E| ~ 10^8 are fine.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo, transpose_csr


@dataclasses.dataclass(frozen=True)
class SolveDAG:
    """DAG G=(V,E,ω) of a forward-substitution solve."""

    n: int
    # parents[i] = {j : (j,i) in E}: CSR over rows (strictly-lower structure)
    parent_ptr: np.ndarray  # int64[n+1]
    parent_idx: np.ndarray  # int64[|E|]
    # children[j] = {i : (j,i) in E}
    child_ptr: np.ndarray  # int64[n+1]
    child_idx: np.ndarray  # int64[|E|]
    weights: np.ndarray  # int64[n] — ω(v) = row nnz (incl. diagonal)

    @property
    def n_edges(self) -> int:
        return len(self.parent_idx)

    def parents(self, v: int) -> np.ndarray:
        return self.parent_idx[self.parent_ptr[v] : self.parent_ptr[v + 1]]

    def children(self, v: int) -> np.ndarray:
        return self.child_idx[self.child_ptr[v] : self.child_ptr[v + 1]]

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.parent_ptr)

    def out_degrees(self) -> np.ndarray:
        return np.diff(self.child_ptr)

    def total_work(self) -> int:
        return int(self.weights.sum())


def dag_from_lower_csr(L: CSRMatrix) -> SolveDAG:
    """Build the solve DAG from a lower-triangular CSR matrix."""
    rows = L.row_of_entry()
    strict = L.indices < rows  # drop the diagonal: it is not a dependency
    erow = rows[strict]
    ecol = L.indices[strict]
    n = L.n_rows
    # parents CSR: row i -> its parents (the strictly-lower column ids)
    pmat = csr_from_coo(n, n, erow, ecol, np.ones(len(erow)))
    cmat = transpose_csr(pmat)
    weights = L.row_nnz().astype(np.int64)
    # Guard: weight must be >= 1 even for structurally-empty rows.
    weights = np.maximum(weights, 1)
    return SolveDAG(
        n=n,
        parent_ptr=pmat.indptr,
        parent_idx=pmat.indices,
        child_ptr=cmat.indptr,
        child_idx=cmat.indices,
        weights=weights,
    )


def dag_from_edges(n: int, edges: np.ndarray, weights: np.ndarray | None = None) -> SolveDAG:
    """Build a SolveDAG from an explicit (u, v) edge list (u -> v). Used by
    tests, the coarsener (quotient DAGs) and the pipeline-schedule generator."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    pmat = csr_from_coo(n, n, edges[:, 1], edges[:, 0], np.ones(len(edges)))
    cmat = transpose_csr(pmat)
    if weights is None:
        weights = np.ones(n, dtype=np.int64)
    return SolveDAG(
        n=n,
        parent_ptr=pmat.indptr,
        parent_idx=pmat.indices,
        child_ptr=cmat.indptr,
        child_idx=cmat.indices,
        weights=np.asarray(weights, dtype=np.int64),
    )


def gather_ranges(ptr: np.ndarray, idx: np.ndarray, verts: np.ndarray):
    """Return (flat_targets, src_repeat) where flat_targets concatenates
    ``idx[ptr[v]:ptr[v+1]]`` for every v in ``verts`` and ``src_repeat``
    repeats each v by its range length. Fully vectorized adjacency gather —
    the workhorse of every wavefront-style sweep here."""
    verts = np.asarray(verts, dtype=np.int64)
    starts = ptr[verts]
    counts = ptr[verts + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    rep_starts = np.repeat(starts, counts)
    cum_before = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(total, dtype=np.int64) - np.repeat(cum_before, counts)
    return idx[rep_starts + within], np.repeat(verts, counts)


def topological_levels(dag: SolveDAG) -> np.ndarray:
    """level[v] = length of the longest path ending at v (0 for sources).

    Vectorized Kahn sweep (one numpy pass per wavefront); works for any DAG,
    not just triangular-matrix DAGs."""
    level = np.zeros(dag.n, dtype=np.int64)
    indeg = dag.in_degrees().copy()
    frontier = np.nonzero(indeg == 0)[0]
    processed = 0
    while len(frontier):
        processed += len(frontier)
        kids, srcs = gather_ranges(dag.child_ptr, dag.child_idx, frontier)
        if len(kids) == 0:
            break
        np.maximum.at(level, kids, level[srcs] + 1)
        np.subtract.at(indeg, kids, 1)
        frontier = np.unique(kids[indeg[kids] == 0])
    if processed != dag.n:
        raise ValueError("graph has a cycle: not a DAG")
    return level


def wavefronts(dag: SolveDAG) -> List[np.ndarray]:
    """The wavefronts of the DAG (Fig. 1.1b): vertices grouped by level."""
    level = topological_levels(dag)
    n_levels = int(level.max()) + 1 if dag.n else 0
    order = np.argsort(level, kind="stable")
    sorted_levels = level[order]
    bounds = np.searchsorted(sorted_levels, np.arange(n_levels + 1))
    return [order[bounds[i] : bounds[i + 1]] for i in range(n_levels)]


def longest_path_length(dag: SolveDAG) -> int:
    """Number of vertices on the longest path (= #wavefronts)."""
    if dag.n == 0:
        return 0
    return int(topological_levels(dag).max()) + 1


def average_wavefront_size(dag: SolveDAG) -> float:
    """Paper §6.2: n / longest-path-length — the parallelizability proxy."""
    lp = longest_path_length(dag)
    return dag.n / lp if lp else 0.0


def is_topological_order(dag: SolveDAG, order: np.ndarray) -> bool:
    pos = np.empty(dag.n, dtype=np.int64)
    pos[order] = np.arange(dag.n)
    # every edge (parent -> child) must go forward
    for v in range(dag.n):
        ps = dag.parents(v)
        if len(ps) and (pos[ps] >= pos[v]).any():
            return False
    return True
