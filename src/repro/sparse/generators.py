"""Matrix generators reproducing the paper's synthetic data sets (§6.2.4,
§6.2.5) plus FEM-style substitutes for SuiteSparse (§6.2.1, see DESIGN.md §8.5:
SuiteSparse is not downloadable in the offline container, so we generate
Poisson FEM matrices whose solve-DAG statistics sit in the same regime).

Entry-value distributions follow the paper exactly:
  * off-diagonal non-zeros ~ U[-2, 2] i.i.d.,
  * |diagonal| ~ LogUniform[2^-1, 2], sign ± uniform (footnote 5: avoids
    divisions by ~0).
"""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo


def _paper_values(rng: np.random.Generator, n_off: int, n_diag: int):
    off = rng.uniform(-2.0, 2.0, size=n_off)
    mag = np.exp(rng.uniform(np.log(0.5), np.log(2.0), size=n_diag))
    sign = rng.choice([-1.0, 1.0], size=n_diag)
    return off, mag * sign


def erdos_renyi_lower(
    n: int, p: float, *, seed: int = 0
) -> CSRMatrix:
    """§6.2.4: lower-triangular ER matrix — entry (i, j), i > j, non-zero with
    probability p; full non-zero diagonal with the paper's value distributions."""
    rng = np.random.default_rng(seed)
    # Sample the number of non-zeros per row i from Binomial(i, p), then choose
    # columns without replacement. Vectorized in expectation-sized batches.
    rows_list = []
    cols_list = []
    counts = rng.binomial(np.arange(n), p)
    total = int(counts.sum())
    # Sample columns via sorting a uniform draw per entry: for row i we need
    # `counts[i]` distinct columns in [0, i). Use floyd-like sampling per row
    # only for tiny counts; otherwise random choice with dedup via unique.
    for i in np.nonzero(counts)[0]:
        c = rng.choice(i, size=counts[i], replace=False)
        rows_list.append(np.full(len(c), i, dtype=np.int64))
        cols_list.append(c.astype(np.int64))
    if rows_list:
        rows = np.concatenate(rows_list)
        cols = np.concatenate(cols_list)
    else:
        rows = np.empty(0, dtype=np.int64)
        cols = np.empty(0, dtype=np.int64)
    off, diag = _paper_values(rng, len(rows), n)
    all_rows = np.concatenate([rows, np.arange(n, dtype=np.int64)])
    all_cols = np.concatenate([cols, np.arange(n, dtype=np.int64)])
    all_vals = np.concatenate([off, diag])
    del total
    return csr_from_coo(n, n, all_rows, all_cols, all_vals)


def narrow_band_lower(
    n: int, p: float, band: float, *, seed: int = 0, max_width_sigma: float = 12.0
) -> CSRMatrix:
    """§6.2.5: entry (i, j), i > j, non-zero with probability
    ``p * exp((1 + j - i) / B)`` — mass concentrated near the diagonal.
    Hard to parallelize by design, but good locality.

    We truncate the band at width ``max_width_sigma * B`` where the inclusion
    probability has decayed below p * e^-12 ~ 6e-6 p: negligible mass,
    keeps generation O(n * B)."""
    rng = np.random.default_rng(seed)
    width = int(min(n - 1, np.ceil(band * max_width_sigma)))
    offsets = np.arange(1, width + 1)  # i - j
    probs = p * np.exp((1 - offsets) / band)
    probs = np.clip(probs, 0.0, 1.0)
    rows_list, cols_list = [], []
    for off_k, pk in zip(offsets, probs):
        if pk <= 0:
            continue
        i = np.arange(off_k, n, dtype=np.int64)
        mask = rng.random(len(i)) < pk
        ii = i[mask]
        rows_list.append(ii)
        cols_list.append(ii - off_k)
    rows = np.concatenate(rows_list) if rows_list else np.empty(0, dtype=np.int64)
    cols = np.concatenate(cols_list) if cols_list else np.empty(0, dtype=np.int64)
    off, diag = _paper_values(rng, len(rows), n)
    all_rows = np.concatenate([rows, np.arange(n, dtype=np.int64)])
    all_cols = np.concatenate([cols, np.arange(n, dtype=np.int64)])
    all_vals = np.concatenate([off, diag])
    return csr_from_coo(n, n, all_rows, all_cols, all_vals)


def poisson2d_matrix(nx: int, ny: int | None = None) -> CSRMatrix:
    """SPD 5-point Laplacian on an nx × ny grid — the canonical FEM-ish
    SuiteSparse stand-in (apache2/ecology2/thermal2 are of this flavor)."""
    ny = ny or nx
    n = nx * ny
    idx = np.arange(n, dtype=np.int64).reshape(nx, ny)
    rows, cols, vals = [idx.ravel()], [idx.ravel()], [np.full(n, 4.0)]
    # left/right/up/down couplings
    for (a, b) in [
        (idx[:, 1:].ravel(), idx[:, :-1].ravel()),
        (idx[1:, :].ravel(), idx[:-1, :].ravel()),
    ]:
        rows.extend([a, b])
        cols.extend([b, a])
        vals.extend([np.full(len(a), -1.0)] * 2)
    return csr_from_coo(
        n, n, np.concatenate(rows), np.concatenate(cols), np.concatenate(vals)
    )


def poisson3d_matrix(nx: int, ny: int | None = None, nz: int | None = None) -> CSRMatrix:
    """SPD 7-point Laplacian on an nx × ny × nz grid (audikw_1/bone010-flavor
    connectivity after ordering)."""
    ny = ny or nx
    nz = nz or nx
    n = nx * ny * nz
    idx = np.arange(n, dtype=np.int64).reshape(nx, ny, nz)
    rows, cols, vals = [idx.ravel()], [idx.ravel()], [np.full(n, 6.0)]
    for (a, b) in [
        (idx[:, :, 1:].ravel(), idx[:, :, :-1].ravel()),
        (idx[:, 1:, :].ravel(), idx[:, :-1, :].ravel()),
        (idx[1:, :, :].ravel(), idx[:-1, :, :].ravel()),
    ]:
        rows.extend([a, b])
        cols.extend([b, a])
        vals.extend([np.full(len(a), -1.0)] * 2)
    return csr_from_coo(
        n, n, np.concatenate(rows), np.concatenate(cols), np.concatenate(vals)
    )


def shifted_coupling_lower(
    n: int, shift: int, *, stride: int = 8, seed: int = 0
) -> CSRMatrix:
    """A family of structurally DISTINCT lower-triangular matrices that
    compile to identically-shaped ``ExecPlan`` tensors — one *width
    class* (``TriangularSolver.width_class``), the serve layer's
    cross-pattern batching unit.

    Full non-zero diagonal plus one off-diagonal entry per ``stride``-th
    row ``i``, at column ``i - 1 - shift``. Varying ``shift`` in
    ``[0, stride - 2]`` moves every coupling to a different column
    (distinct sparsity fingerprints) while preserving the DAG's level
    profile exactly: couplings never target another coupled row, so
    every variant is "n - n/stride roots, n/stride depth-1 rows" with
    the same row-nnz histogram — level schedulers (``wavefront``,
    ``hdagg``) and the plan compiler see the same shapes for all shifts.
    Values follow the paper's distributions (off ~ U[-2,2],
    |diag| ~ LogU[1/2, 2])."""
    if not 0 <= shift <= stride - 2:
        raise ValueError(
            f"shift must be in [0, {stride - 2}] so couplings stay "
            "clear of the coupled rows (shift == stride - 1 would chain "
            "them, changing the DAG depth and thus the width class)"
        )
    rng = np.random.default_rng(seed)
    rr = np.arange(stride, n, stride, dtype=np.int64)
    cc = rr - 1 - shift
    off, diag = _paper_values(rng, len(rr), n)
    all_rows = np.concatenate([rr, np.arange(n, dtype=np.int64)])
    all_cols = np.concatenate([cc, np.arange(n, dtype=np.int64)])
    all_vals = np.concatenate([off, diag])
    return csr_from_coo(n, n, all_rows, all_cols, all_vals)


def random_spd_band(n: int, bandwidth: int, density: float, *, seed: int = 0) -> CSRMatrix:
    """Random symmetric positive-definite banded matrix (diagonally dominant),
    used by the IC(0) data-set generator."""
    rng = np.random.default_rng(seed)
    rows_list, cols_list, vals_list = [], [], []
    for off in range(1, bandwidth + 1):
        i = np.arange(off, n, dtype=np.int64)
        mask = rng.random(len(i)) < density
        ii = i[mask]
        v = rng.uniform(-1.0, 1.0, size=len(ii))
        rows_list.extend([ii, ii - off])
        cols_list.extend([ii - off, ii])
        vals_list.extend([v, v])
    rows = np.concatenate(rows_list) if rows_list else np.empty(0, dtype=np.int64)
    cols = np.concatenate(cols_list) if cols_list else np.empty(0, dtype=np.int64)
    vals = np.concatenate(vals_list) if vals_list else np.empty(0, dtype=np.float64)
    # diagonal dominance => SPD
    abssum = np.zeros(n)
    np.add.at(abssum, rows, np.abs(vals))
    diag = abssum + 1.0
    rows = np.concatenate([rows, np.arange(n, dtype=np.int64)])
    cols = np.concatenate([cols, np.arange(n, dtype=np.int64)])
    vals = np.concatenate([vals, diag])
    return csr_from_coo(n, n, rows, cols, vals)
