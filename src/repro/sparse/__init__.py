"""Sparse-matrix substrate: CSR structures, generators, IC(0), DAG utilities.

All of this is "inspector side": pure numpy, runs on the host, amortized over
many solves (cf. paper §7.7). Executor-side (JAX/Pallas) code lives in
``repro.solver`` and ``repro.kernels``.
"""
from repro.sparse.csr import (
    CSRMatrix,
    csr_from_coo,
    csr_from_dense,
    csr_to_dense,
    lower_triangle_of,
    pattern_fingerprint,
    permute_symmetric,
    transpose_csr,
)
from repro.sparse.dag import (
    SolveDAG,
    dag_from_lower_csr,
    wavefronts,
    longest_path_length,
    average_wavefront_size,
)
from repro.sparse.generators import (
    erdos_renyi_lower,
    narrow_band_lower,
    poisson2d_matrix,
    poisson3d_matrix,
    random_spd_band,
    shifted_coupling_lower,
)
from repro.sparse.ichol import ichol0

__all__ = [
    "CSRMatrix",
    "csr_from_coo",
    "csr_from_dense",
    "csr_to_dense",
    "lower_triangle_of",
    "pattern_fingerprint",
    "permute_symmetric",
    "transpose_csr",
    "SolveDAG",
    "dag_from_lower_csr",
    "wavefronts",
    "longest_path_length",
    "average_wavefront_size",
    "erdos_renyi_lower",
    "narrow_band_lower",
    "poisson2d_matrix",
    "poisson3d_matrix",
    "random_spd_band",
    "shifted_coupling_lower",
    "ichol0",
]
