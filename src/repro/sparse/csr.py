"""Compressed-sparse-row matrices (host/inspector side, numpy only).

The paper stores the triangular matrix in CSR (§6.1, [TW67]); every scheduler
and the plan compiler consume this representation. We keep an explicit,
dependency-light CSR rather than scipy.sparse so the inspector is trivially
portable; conversion helpers to/from scipy exist for testing. All operations
here are vectorized — they run on matrices with 10^5..10^6 rows inside the
benchmark harness.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """A CSR matrix. ``indptr`` has length n+1, ``indices``/``data`` length nnz."""

    n_rows: int
    n_cols: int
    indptr: np.ndarray  # int64[n_rows+1]
    indices: np.ndarray  # int64[nnz]
    data: np.ndarray  # float64[nnz]

    def __post_init__(self):
        assert self.indptr.shape == (self.n_rows + 1,)
        assert self.indices.shape == self.data.shape
        assert int(self.indptr[-1]) == len(self.indices)

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def row_of_entry(self) -> np.ndarray:
        """int64[nnz]: the row index of every stored entry."""
        return np.repeat(np.arange(self.n_rows, dtype=np.int64), self.row_nnz())

    def is_lower_triangular(self) -> bool:
        return bool(np.all(self.indices <= self.row_of_entry()))

    def diagonal(self) -> np.ndarray:
        n = min(self.n_rows, self.n_cols)
        d = np.zeros(n, dtype=self.data.dtype)
        rows = self.row_of_entry()
        mask = (self.indices == rows) & (rows < n)
        d[rows[mask]] = self.data[mask]
        return d

    def has_full_diagonal(self) -> bool:
        n = min(self.n_rows, self.n_cols)
        rows = self.row_of_entry()
        mask = (self.indices == rows) & (rows < n)
        present = np.zeros(n, dtype=bool)
        present[rows[mask]] = self.data[mask] != 0.0
        return bool(present.all())

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csr_matrix(
            (self.data, self.indices, self.indptr), shape=(self.n_rows, self.n_cols)
        )

    @staticmethod
    def from_scipy(m) -> "CSRMatrix":
        m = m.tocsr()
        m.sum_duplicates()
        m.sort_indices()
        return CSRMatrix(
            n_rows=m.shape[0],
            n_cols=m.shape[1],
            indptr=np.asarray(m.indptr, dtype=np.int64),
            indices=np.asarray(m.indices, dtype=np.int64),
            data=np.asarray(m.data, dtype=np.float64),
        )


def pattern_fingerprint(m: CSRMatrix) -> str:
    """Stable hash of the *sparsity pattern* (shape + indptr + indices).

    Deliberately ignores ``data``: two factors with identical structure but
    different values share schedules and plan tensors' shapes, which is what
    the pipeline plan cache keys on (values refresh via ``numeric_update``).
    """
    import hashlib

    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64([m.n_rows, m.n_cols]).tobytes())
    h.update(np.ascontiguousarray(m.indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(m.indices, dtype=np.int64).tobytes())
    return h.hexdigest()


def csr_from_coo(
    n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray
) -> CSRMatrix:
    """Build CSR from COO triplets; duplicate entries are summed. Vectorized."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if len(rows):
        # Merge duplicate (row, col) runs with a segmented sum.
        new_run = np.ones(len(rows), dtype=bool)
        new_run[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
        run_id = np.cumsum(new_run) - 1
        n_runs = int(run_id[-1]) + 1
        merged = np.zeros(n_runs, dtype=np.float64)
        np.add.at(merged, run_id, vals)
        rows, cols, vals = rows[new_run], cols[new_run], merged
    indptr = np.zeros(n_rows + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRMatrix(n_rows, n_cols, indptr, cols, vals)


def csr_from_dense(a: np.ndarray) -> CSRMatrix:
    rows, cols = np.nonzero(a)
    return csr_from_coo(a.shape[0], a.shape[1], rows, cols, a[rows, cols])


def csr_to_dense(m: CSRMatrix) -> np.ndarray:
    out = np.zeros((m.n_rows, m.n_cols), dtype=np.float64)
    out[m.row_of_entry(), m.indices] = m.data
    return out


def lower_triangle_of(m: CSRMatrix, *, unit_diagonal_fill: bool = False) -> CSRMatrix:
    """Extract the lower triangle (incl. diagonal). Optionally force a unit
    diagonal where the diagonal entry is missing (keeps the solve well-posed)."""
    rows = m.row_of_entry()
    keep = m.indices <= rows
    rows, cols, vals = rows[keep], m.indices[keep], m.data[keep]
    if unit_diagonal_fill:
        has_diag = np.zeros(m.n_rows, dtype=bool)
        has_diag[rows[cols == rows]] = True
        missing = np.nonzero(~has_diag)[0]
        rows = np.concatenate([rows, missing])
        cols = np.concatenate([cols, missing])
        vals = np.concatenate([vals, np.ones(len(missing))])
    return csr_from_coo(m.n_rows, m.n_cols, rows, cols, vals)


def transpose_csr(m: CSRMatrix) -> CSRMatrix:
    return csr_from_coo(m.n_cols, m.n_rows, m.indices, m.row_of_entry(), m.data)


def permute_symmetric(m: CSRMatrix, perm: np.ndarray) -> CSRMatrix:
    """Symmetric permutation: ``B = P A P^T`` where ``perm[new] = old``.

    Row ``perm[i]`` of A becomes row ``i`` of B; columns are relabeled the same
    way. This is the §5 reordering primitive: if ``perm`` lists vertices in
    (superstep, core, original-id) order — a topological order — B is still
    lower triangular.
    """
    perm = np.asarray(perm, dtype=np.int64)
    assert perm.shape == (m.n_rows,)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(m.n_rows, dtype=np.int64)
    return csr_from_coo(
        m.n_rows, m.n_cols, inv[m.row_of_entry()], inv[m.indices], m.data
    )
