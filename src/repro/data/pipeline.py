"""Deterministic, shard-aware synthetic LM data pipeline.

Fault-tolerance property that matters at 1000 nodes: the pipeline is a pure
function of (seed, step, shard) — a restarted job resumes mid-epoch with NO
pipeline state in the checkpoint, and every data shard produces its slice
independently (no coordinator). A background prefetch thread keeps one batch
ahead (the CPU-container stand-in for the host-side input pipeline).

The synthetic stream is a structured Markov-ish token process rather than
uniform noise, so cross-entropy has learnable signal (examples/train_lm.py
asserts the loss actually decreases).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    shard: int = 0  # data-parallel shard id
    n_shards: int = 1
    frontend_positions: int = 0  # for [audio]/[vlm] stubs
    d_model: int = 0

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """Pure function of (seed, step, shard)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard])
        )
        # structured stream: tokens follow t_{i+1} = (a*t_i + b + noise) % V
        a = 1 + 4 * (1 + self.shard)
        b = rng.integers(1, self.vocab, size=(self.batch, 1))
        t0 = rng.integers(0, self.vocab, size=(self.batch, 1))
        toks = [t0]
        for _ in range(self.seq):
            nxt = (a * toks[-1] + b) % self.vocab
            flip = rng.random((self.batch, 1)) < 0.1
            rand = rng.integers(0, self.vocab, size=(self.batch, 1))
            toks.append(np.where(flip, rand, nxt))
        stream = np.concatenate(toks, axis=1)
        out = {
            "tokens": jnp.asarray(stream[:, : self.seq], jnp.int32),
            "labels": jnp.asarray(stream[:, 1 : self.seq + 1], jnp.int32),
        }
        if self.frontend_positions:
            out["frontend_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (self.batch, self.frontend_positions, self.d_model)
                ),
                jnp.float32,
            )
        return out

    # -- prefetching iterator ------------------------------------------
    def next_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        return self.batch_at(step)

    def prefetching(self, start_step: int = 0, depth: int = 2) -> Iterator:
        q: "queue.Queue" = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch_at(s)))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
