from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_loop import TrainState, make_train_step, train_state_specs

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "TrainState",
    "make_train_step",
    "train_state_specs",
]
