"""train_step / serve_step factories.

``make_train_step`` builds the jittable (state, batch) -> (state, metrics)
with:
  * microbatch gradient accumulation (lax.scan over the leading microbatch
    axis — the memory lever for the 123B train_4k cell),
  * optional error-feedback gradient compression on the cross-pod hop
    (distributed/compression.py),
  * sequence-parallel residual sharding constraints
    (distributed/meshes.py supplies the specs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import ModelConfig, loss_fn
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

Pytree = Any


@dataclasses.dataclass
class TrainState:
    params: Pytree
    opt_state: Pytree

    def tree_flatten(self):
        return (self.params, self.opt_state), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state), None),
    lambda aux, ch: TrainState(*ch),
)


def init_train_state(cfg: ModelConfig, params: Pytree) -> TrainState:
    return TrainState(params=params, opt_state=adamw_init(params))


def train_state_specs(param_logical: Pytree) -> Pytree:
    """Logical specs for the whole TrainState (moments shard like params)."""
    return TrainState(
        params=param_logical,
        opt_state={
            "mu": param_logical,
            "nu": param_logical,
            "step": (),
        },
    )


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    grad_transform: Optional[Callable[[Pytree], Pytree]] = None,
    activation_constraint: Optional[Callable[[jax.Array], jax.Array]] = None,
):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch``: {"tokens": int32[B, S], "labels": int32[B, S], ...}. When
    ``microbatches`` = M > 1 the batch is reshaped to [M, B/M, S] and grads
    are accumulated with a scan (activations for only one microbatch live at
    a time). ``grad_transform`` hooks gradient compression."""

    def single_loss(params, mb):
        loss, parts = loss_fn(cfg, params, mb, train=True)
        return loss, parts

    def train_step(state: TrainState, batch):
        from repro.distributed.sharding_ctx import constrain

        def reshape(x):
            x = x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])
            return constrain(x, "microbatch_tokens")

        mbs = jax.tree_util.tree_map(reshape, batch)
        grad_fn = jax.value_and_grad(single_loss, has_aux=True)

        def accum(carry, mb):
            gsum, lsum = carry
            (loss, _), g = grad_fn(state.params, mb)
            gsum = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g
            )
            return (gsum, lsum + loss), None

        gzero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        (gsum, lsum), _ = jax.lax.scan(accum, (gzero, 0.0), mbs)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt_state
        )
        metrics = {"loss": lsum / microbatches, **opt_metrics}
        return TrainState(params=params, opt_state=opt_state), metrics

    return train_step
