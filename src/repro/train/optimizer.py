"""AdamW with decoupled weight decay, global-norm clipping and a linear
warmup + cosine decay schedule. Optimizer moments are f32 and shard exactly
like their parameters (ZeRO posture: with params FSDP-sharded over 'data',
moments are too — no replicated optimizer state anywhere)."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Pytree) -> Pytree:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "mu": jax.tree_util.tree_map(zeros32, params),
        "nu": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    progress = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    progress = jnp.clip(progress, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in
              jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    cfg: AdamWConfig, params: Pytree, grads: Pytree, opt_state: Pytree
) -> Tuple[Pytree, Pytree, dict]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    lr = lr_at(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_v = jax.tree_util.tree_leaves(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return (
        new_p,
        {"mu": new_m, "nu": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
