"""Pallas TPU kernel: padded-ELL SpMV — the off-diagonal-block operator of
the paper's block decomposition (§1.1.4: splitting the triangular matrix
into diagonal SpTRSV blocks + off-diagonal SpMV blocks; the SpMV part is
embarrassingly parallel and feeds the next diagonal block's b-vector).

Format: rows padded to W entries (cols self-padded to a scratch slot, vals
0-padded) — the same convention as the SpTRSV plan. Grid tiles the rows;
x stays resident in VMEM; each grid step streams an (R, W) tile of indices
and values and writes an (R,) tile of y. Rows are independent, so the grid
is parallel ("arbitrary" is not required).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

from repro.sparse.csr import CSRMatrix


def _spmv_kernel(col_ref, val_ref, x_ref, y_ref):
    cols = col_ref[...]  # [R, W]
    vals = val_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
    # repro: blessed-reduction — per-row W-axis dot; SpMV feeds CG's
    # iterative loop, which is outside the solve's bitwise contract
    y_ref[...] = jnp.sum(vals * gathered, axis=-1)


@functools.partial(jax.jit, static_argnames=("rows_per_tile", "interpret"))
def spmv_pallas(col_idx, vals, x_pad, *, rows_per_tile: int = 256,
                interpret: bool = False):
    """y = A x for padded-ELL A. col_idx int32[R, W]; vals f[R, W];
    x_pad f[n+1] (last slot scratch). Returns y f[R]."""
    R, W = col_idx.shape
    assert R % rows_per_tile == 0, "pad rows to a multiple of rows_per_tile"
    grid = (R // rows_per_tile,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, W), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, W), lambda i: (i, 0)),
            pl.BlockSpec(x_pad.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), vals.dtype),
        interpret=interpret,
    )(col_idx, vals, x_pad)


def ell_from_csr(m: CSRMatrix, *, width: int | None = None, dtype=np.float32):
    """(col_idx int32[R, W], vals f[R, W]) with self-padding to slot n.
    Wide rows are split into accumulating virtual rows? No — SpMV has no
    ordering constraint, so wide rows SPLIT into multiple ELL rows and the
    caller segment-sums (``row_map`` gives the target row of each ELL row)."""
    W = width or max(int(np.percentile(m.row_nnz(), 95)), 1)
    col_rows, val_rows, row_map = [], [], []
    for i in range(m.n_rows):
        cols, vals = m.row(i)
        for g in range(0, max(len(cols), 1), W):
            c = cols[g : g + W]
            v = vals[g : g + W]
            cc = np.full(W, m.n_cols, dtype=np.int32)
            vv = np.zeros(W, dtype=dtype)
            cc[: len(c)] = c
            vv[: len(v)] = v
            col_rows.append(cc)
            val_rows.append(vv)
            row_map.append(i)
    return (
        np.stack(col_rows).astype(np.int32),
        np.stack(val_rows),
        np.asarray(row_map, dtype=np.int32),
    )


def spmv(m: CSRMatrix, x, *, rows_per_tile: int = 256, interpret: bool | None = None,
         dtype=jnp.float32):
    """Full SpMV via the kernel: ELL conversion + segment-sum of split rows."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    col_idx, vals, row_map = ell_from_csr(m, dtype=np.dtype(dtype))
    R = col_idx.shape[0]
    pad = (-R) % rows_per_tile
    if pad:
        col_idx = np.concatenate(
            [col_idx, np.full((pad, col_idx.shape[1]), m.n_cols, np.int32)]
        )
        vals = np.concatenate([vals, np.zeros((pad, vals.shape[1]), vals.dtype)])
        row_map = np.concatenate([row_map, np.full(pad, m.n_rows, np.int32)])
    x_pad = jnp.concatenate([jnp.asarray(x, dtype), jnp.zeros(1, dtype)])
    y_ell = spmv_pallas(
        jnp.asarray(col_idx), jnp.asarray(vals), x_pad,
        rows_per_tile=rows_per_tile, interpret=interpret,
    )
    return jax.ops.segment_sum(
        y_ell, jnp.asarray(row_map), num_segments=m.n_rows + 1
    )[: m.n_rows]
