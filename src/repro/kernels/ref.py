"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (interpret=True on
CPU, real lowering on TPU): numerically identical algorithms written with
plain jnp ops, no pallas primitives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sptrsv_ref(row_ids, col_idx, vals, diag, accum, b_pad):
    """Oracle for the superstep SpTRSV kernel.

    Shapes: row_ids int32[T,k]; col_idx int32[T,k,W]; vals f[T,k,W];
    diag f[T,k]; accum bool[T,k]; b_pad f[n+1]. Returns x f[n+1] (the last
    slot is scratch). Sequential over T, vectorized over k — the same
    dataflow the kernel implements with its grid.
    """
    n1 = b_pad.shape[0]
    x0 = jnp.zeros(n1, dtype=b_pad.dtype)
    acc0 = jnp.zeros(row_ids.shape[1], dtype=b_pad.dtype)

    def step(carry, inp):
        x, acc = carry
        rows, cols, v, d, a = inp
        # fixed left-to-right lane reduction, matching the scan executor's
        # _step_single exactly — elementwise IEEE ops per lane keep the
        # oracle bitwise shape-independent (see solver/executor.py)
        for w in range(v.shape[1]):
            acc = acc + v[:, w] * x[cols[:, w]]
        xv = (b_pad[rows] - acc) / d
        x = x.at[rows].set(jnp.where(a, x[rows], xv))
        acc = jnp.where(a, acc, 0.0)
        return (x, acc), None

    (x, _), _ = jax.lax.scan(step, (x0, acc0), (row_ids, col_idx, vals, diag, accum))
    return x


def spmv_block_ref(x_block, idx, vals):
    """Oracle for the gather-SpMV kernel: y[r] = sum_w vals[r,w]*x[idx[r,w]].
    x_block f[m]; idx int32[R,W]; vals f[R,W] -> y f[R]."""
    # repro: blessed-reduction — SpMV oracle, outside the solve's
    # bitwise contract (the solve oracle above folds in fixed order)
    return jnp.einsum("rw,rw->r", vals, x_block[idx])
