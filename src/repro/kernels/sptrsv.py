"""Pallas TPU kernel for the scheduled SpTRSV executor.

TPU-native design (DESIGN.md §3): the solution vector x lives in VMEM for
the *entire* solve (input_output_aliasing on a (n+1,)-shaped buffer — 4 MB
for n = 10^6 in f32, comfortably inside the 16 MB VMEM of a v5e core), while
the plan tensors (row ids, column indices, values, diagonals) stream
HBM -> VMEM one lock-step tile at a time via BlockSpecs. One grid step =
``steps_per_tile`` sequential lock-step rows x k lanes. The grid dimension is
sequential ("arbitrary"), which *is* the superstep chain: within a chip no
barrier instruction exists or is needed between grid steps — exactly the
L ~ 0 regime discussed in the paper's footnote 1.

The k axis is sized to the VPU lane count (128) by the plan compiler for
best utilization; W is the streamed gather width per row.

Gather: x is addressed with per-lane dynamic indices. We express it as
``jnp.take(x, cols)`` — Mosaic lowers int32 VMEM gathers natively on
TPU >= v4 (dynamic-gather); correctness here is validated in interpret mode
(this container is CPU-only).

Per-row recurrence inside a tile (sequential over the tile's rows):
    acc   += sum_w vals[t, l, w] * x[col[t, l, w]]
    x[row] = (b[row] - acc) / diag        (only on non-accum rows)
The accumulator lives in a VMEM scratch buffer so it survives across grid
steps (rows wider than W span tiles).

``sptrsv_pallas_elastic`` is the ``mode="elastic"`` variant: instead of
one ``fori_loop`` iteration per lock-step row (a level barrier inside
the tile), it iterates the tile's *readiness waves* — runs of mutually
independent steps certified by ``core.elastic.elastic_transform`` — with
per-row readiness masks, so tiles whose rows are mostly independent
finish in a handful of iterations. Bitwise-identical to the bulk kernel
(the per-row accumulation order is untouched; see the kernel docstring).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only namespace; absent on CPU builds is fine for interpret mode
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _sptrsv_kernel(
    row_ref,  # int32[S, k]        (tile: S = steps_per_tile)
    col_ref,  # int32[S, k, W]
    val_ref,  # f[S, k, W]
    diag_ref,  # f[S, k]
    accum_ref,  # f[S, k]  (0.0 / 1.0 mask; bool blocks are awkward on TPU)
    b_ref,  # f[n+1]  (resident)
    x_in_ref,  # f[n+1]  (the donated zero buffer; same memory as x_ref)
    x_ref,  # f[n+1]  (aliased in/out, resident)
    acc_ref,  # f[k] scratch — carries partial sums across tiles
    *,
    steps_per_tile: int,
):
    del x_in_ref  # aliased with x_ref; all access goes through the output ref
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # x starts as zeros; the aliased input is pre-zeroed by the wrapper.

    def body(t, _):
        rows = row_ref[t]  # int32[k]
        cols = col_ref[t]  # int32[k, W]
        v = val_ref[t]  # f[k, W]
        d = diag_ref[t]
        a = accum_ref[t]
        x = x_ref[...]
        gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
        # repro: blessed-reduction — W-axis dot within one lane: the
        # operand set is fixed per (row, lane) regardless of k/shard, so
        # reassociation cannot cross lanes (bitwise-checked vs the scan
        # oracle in tests/test_kernels.py)
        acc = acc_ref[...] + jnp.sum(v * gathered, axis=-1)
        b_rows = jnp.take(b_ref[...], rows, axis=0)
        xv = (b_rows - acc) / d
        keep = a > 0.5  # still accumulating
        old = jnp.take(x, rows, axis=0)
        write = jnp.where(keep, old, xv)
        x_ref[...] = x.at[rows].set(write)
        acc_ref[...] = jnp.where(keep, acc, 0.0)
        return ()

    jax.lax.fori_loop(0, steps_per_tile, body, ())


def _sptrsv_mrhs_kernel(
    row_ref,  # int32[S, k]
    col_ref,  # int32[S, k, W]
    val_ref,  # f[S, k, W]
    diag_ref,  # f[S, k]
    accum_ref,  # f[S, k]
    b_ref,  # f[n+1, m]  (resident; m RHS lane-major)
    x_in_ref,  # f[n+1, m]
    x_ref,  # f[n+1, m]  (aliased in/out, resident)
    acc_ref,  # f[k, m] scratch — per-lane, per-RHS partial sums
    *,
    steps_per_tile: int,
):
    """Multi-RHS variant: identical control flow to ``_sptrsv_kernel``, but
    every x slot is a length-m vector (RHS index = minor/lane axis, so the
    m solves share one gather of indices and widen only the value lanes)."""
    del x_in_ref
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(t, _):
        rows = row_ref[t]  # int32[k]
        cols = col_ref[t]  # int32[k, W]
        v = val_ref[t]  # f[k, W]
        d = diag_ref[t]
        a = accum_ref[t]
        x = x_ref[...]  # f[n+1, m]
        gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(*cols.shape, -1)
        # repro: blessed-reduction — W-axis dot within one lane: the
        # operand set is fixed per (row, lane) regardless of k/shard, so
        # reassociation cannot cross lanes (bitwise-checked vs the scan
        # oracle in tests/test_kernels.py)
        acc = acc_ref[...] + jnp.sum(v[..., None] * gathered, axis=1)
        b_rows = jnp.take(b_ref[...], rows, axis=0)  # f[k, m]
        xv = (b_rows - acc) / d[:, None]
        keep = (a > 0.5)[:, None]  # still accumulating
        old = jnp.take(x, rows, axis=0)
        write = jnp.where(keep, old, xv)
        x_ref[...] = x.at[rows].set(write)
        acc_ref[...] = jnp.where(keep, acc, 0.0)
        return ()

    jax.lax.fori_loop(0, steps_per_tile, body, ())


def _sptrsv_elastic_kernel(
    wave_ref,  # int32[S]  readiness wave of each in-tile step
    nw_ref,  # int32[1]  number of waves in this tile
    row_ref,  # int32[S, k]
    col_ref,  # int32[S, k, W]
    val_ref,  # f[S, k, W]
    diag_ref,  # f[S, k]
    accum_ref,  # f[S, k]  (0/1 mask)
    b_ref,  # f[n+1]  (resident)
    x_in_ref,  # f[n+1]  (donated zero buffer, aliased with x_ref)
    x_ref,  # f[n+1]  (aliased in/out, resident)
    acc_ref,  # f[k] scratch — selected accumulator entering the tile
    tot_ref,  # f[S, k] scratch — per-step running totals within the tile
    *,
    steps_per_tile: int,
):
    """Elastic tile body: per-row readiness waves instead of one
    ``fori_loop`` iteration per lock-step row.

    The elastic transform (core.elastic) certifies that within a tile,
    consecutive steps sharing a ``wave_id`` are mutually independent —
    their gather columns were all written before the wave starts and no
    accumulator chain crosses into them. The loop therefore iterates
    ``n_waves <= steps_per_tile`` times (the traced bound lowers to a
    while loop), each iteration processing a whole wave of rows at once
    under a readiness mask — on wide-wave tiles this replaces the level
    barrier (one iteration per step) with far fewer iterations.

    Bitwise equality with the bulk kernel: each step's partial sum is
    still ``sum_w v * x[col]`` reduced in the same lane order, and the
    accumulator entering step s is *selected*, never re-summed — step
    s reads ``tot_ref[s-1]`` iff step s-1 accumulates (same-lane chain,
    forced into an earlier wave), else the zero the bulk kernel would
    also hold. Stale ``tot_ref`` rows are never selected: a same-wave
    predecessor cannot carry ``accum`` by the wave-break rule.
    """
    del x_in_ref
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
    # tot_ref needs no init: rows are only read behind an accum flag,
    # which certifies the row was written in an earlier wave of THIS tile

    rows = row_ref[...]  # int32[S, k]
    aflag = accum_ref[...] > 0.5  # bool[S, k]
    waves = wave_ref[...]  # int32[S]
    n_slot = x_ref.shape[0] - 1

    def wave(r, _):
        x = x_ref[...]
        sel = waves == r  # bool[S]
        cols = col_ref[...]
        gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
        # repro: blessed-reduction — W-axis dot within one lane: the
        # operand set is fixed per (row, lane) regardless of k/shard, so
        # reassociation cannot cross lanes (bitwise-checked vs the scan
        # oracle in tests/test_kernels.py)
        ps = jnp.sum(val_ref[...] * gathered, axis=-1)  # f[S, k]
        tot_prev = tot_ref[...]
        # accumulator entering step s: the tile carry for s = 0, else
        # step s-1's total iff s-1 is an accum step (same-lane chain)
        sel_acc = jnp.concatenate(
            [acc_ref[...][None], jnp.where(aflag[:-1], tot_prev[:-1], 0.0)],
            axis=0,
        )
        tot = sel_acc + ps
        b_rows = jnp.take(b_ref[...], rows.reshape(-1), axis=0).reshape(rows.shape)
        xv = (b_rows - tot) / diag_ref[...]
        live = sel[:, None] & ~aflag  # rows finalized by this wave
        safe = jnp.where(live, rows, n_slot)  # off-wave lanes hit scratch
        x_ref[...] = x.at[safe.reshape(-1)].set(
            jnp.where(live, xv, 0.0).reshape(-1)
        )
        tot_ref[...] = jnp.where(sel[:, None], tot, tot_prev)
        return ()

    jax.lax.fori_loop(0, nw_ref[0], wave, ())
    # tile carry: the last step's total iff it accumulates into the next
    # tile (virtual-row chains are same-lane consecutive steps)
    acc_ref[...] = jnp.where(
        aflag[steps_per_tile - 1], tot_ref[steps_per_tile - 1], 0.0
    )


def _sptrsv_elastic_mrhs_kernel(
    wave_ref,  # int32[S]
    nw_ref,  # int32[1]
    row_ref,  # int32[S, k]
    col_ref,  # int32[S, k, W]
    val_ref,  # f[S, k, W]
    diag_ref,  # f[S, k]
    accum_ref,  # f[S, k]
    b_ref,  # f[n+1, m]  (resident)
    x_in_ref,  # f[n+1, m]
    x_ref,  # f[n+1, m]  (aliased in/out, resident)
    acc_ref,  # f[k, m] scratch
    tot_ref,  # f[S, k, m] scratch
    *,
    steps_per_tile: int,
):
    """Multi-RHS twin of ``_sptrsv_elastic_kernel`` (x slots widen to m)."""
    del x_in_ref
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = row_ref[...]
    aflag = accum_ref[...] > 0.5
    waves = wave_ref[...]
    n_slot = x_ref.shape[0] - 1

    def wave(r, _):
        x = x_ref[...]  # f[n+1, m]
        sel = waves == r
        cols = col_ref[...]
        gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(*cols.shape, -1)
        # repro: blessed-reduction — W-axis dot within one lane: the
        # operand set is fixed per (row, lane) regardless of k/shard, so
        # reassociation cannot cross lanes (bitwise-checked vs the scan
        # oracle in tests/test_kernels.py)
        ps = jnp.sum(val_ref[...][..., None] * gathered, axis=2)  # f[S, k, m]
        tot_prev = tot_ref[...]
        sel_acc = jnp.concatenate(
            [
                acc_ref[...][None],
                jnp.where(aflag[:-1, :, None], tot_prev[:-1], 0.0),
            ],
            axis=0,
        )
        tot = sel_acc + ps
        b_rows = jnp.take(b_ref[...], rows.reshape(-1), axis=0).reshape(
            *rows.shape, -1
        )
        xv = (b_rows - tot) / diag_ref[...][..., None]
        live = sel[:, None] & ~aflag
        safe = jnp.where(live, rows, n_slot)
        x_ref[...] = x.at[safe.reshape(-1)].set(
            jnp.where(live[..., None], xv, 0.0).reshape(-1, xv.shape[-1])
        )
        tot_ref[...] = jnp.where(sel[:, None, None], tot, tot_prev)
        return ()

    jax.lax.fori_loop(0, nw_ref[0], wave, ())
    acc_ref[...] = jnp.where(
        aflag[steps_per_tile - 1][:, None], tot_ref[steps_per_tile - 1], 0.0
    )


@functools.partial(
    jax.jit,
    static_argnames=("steps_per_tile", "interpret"),
)
def sptrsv_pallas(
    row_ids,  # int32[T, k]
    col_idx,  # int32[T, k, W]
    vals,  # f[T, k, W]
    diag,  # f[T, k]
    accum_mask,  # f[T, k] (0/1)
    b_pad,  # f[n+1] or f[n+1, m] (multi-RHS)
    *,
    steps_per_tile: int = 8,
    interpret: bool = False,
):
    """Run the full scheduled solve; returns x shaped like ``b_pad`` (last
    row is scratch). A 2-D ``b_pad`` solves all m RHS in one pass."""
    T, k = row_ids.shape
    W = col_idx.shape[-1]
    assert T % steps_per_tile == 0, "pad T to a multiple of steps_per_tile"
    n_tiles = T // steps_per_tile
    multi_rhs = b_pad.ndim == 2
    x0 = jnp.zeros_like(b_pad)

    grid = (n_tiles,)
    tile = lambda *tail: pl.BlockSpec(  # noqa: E731
        (steps_per_tile, *tail), lambda i: (i, *([0] * len(tail)))
    )
    resident = pl.BlockSpec(b_pad.shape, lambda i: (0,) * b_pad.ndim)

    if multi_rhs:
        kernel = functools.partial(
            _sptrsv_mrhs_kernel, steps_per_tile=steps_per_tile
        )
        acc_shape = (k, b_pad.shape[1])
    else:
        kernel = functools.partial(_sptrsv_kernel, steps_per_tile=steps_per_tile)
        acc_shape = (k,)
    # pltpu.VMEM scratch persists across (sequential) grid steps — the
    # accumulator for rows split over multiple tiles. Interpret mode honours
    # it on CPU.
    assert _VMEM is not None, "pltpu namespace unavailable"
    scratch_shapes = [_VMEM(acc_shape, vals.dtype)]

    compiler_params = None
    if not interpret:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),  # sequential grid = chain
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            tile(k),  # row_ids
            tile(k, W),  # col_idx
            tile(k, W),  # vals
            tile(k),  # diag
            tile(k),  # accum mask
            resident,  # b
            resident,  # x0 (aliased with the output)
        ],
        out_specs=resident,  # x
        out_shape=jax.ShapeDtypeStruct(b_pad.shape, vals.dtype),
        input_output_aliases={6: 0},  # x0 (7th arg) <-> output
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=compiler_params,
    )(row_ids, col_idx, vals, diag, accum_mask, b_pad, x0)


@functools.partial(
    jax.jit,
    static_argnames=("steps_per_tile", "interpret"),
)
def sptrsv_pallas_elastic(
    wave_id,  # int32[T]  readiness wave of each step within its tile
    n_waves,  # int32[n_tiles]  waves per tile
    row_ids,  # int32[T, k]
    col_idx,  # int32[T, k, W]
    vals,  # f[T, k, W]
    diag,  # f[T, k]
    accum_mask,  # f[T, k] (0/1)
    b_pad,  # f[n+1] or f[n+1, m]
    *,
    steps_per_tile: int = 8,
    interpret: bool = False,
):
    """Elastic scheduled solve: per-row readiness waves replace the level
    barrier inside each tile (see ``_sptrsv_elastic_kernel``). The tile
    size must equal the elastic transform's slack window — ``wave_id`` /
    ``n_waves`` come from ``core.elastic.elastic_transform(plan, slack)``
    with ``slack == steps_per_tile``. Returns x shaped like ``b_pad``."""
    T, k = row_ids.shape
    W = col_idx.shape[-1]
    assert T % steps_per_tile == 0, "pad T to a multiple of steps_per_tile"
    n_tiles = T // steps_per_tile
    multi_rhs = b_pad.ndim == 2
    x0 = jnp.zeros_like(b_pad)

    grid = (n_tiles,)
    tile = lambda *tail: pl.BlockSpec(  # noqa: E731
        (steps_per_tile, *tail), lambda i: (i, *([0] * len(tail)))
    )
    resident = pl.BlockSpec(b_pad.shape, lambda i: (0,) * b_pad.ndim)

    if multi_rhs:
        kernel = functools.partial(
            _sptrsv_elastic_mrhs_kernel, steps_per_tile=steps_per_tile
        )
        acc_shape = (k, b_pad.shape[1])
        tot_shape = (steps_per_tile, k, b_pad.shape[1])
    else:
        kernel = functools.partial(
            _sptrsv_elastic_kernel, steps_per_tile=steps_per_tile
        )
        acc_shape = (k,)
        tot_shape = (steps_per_tile, k)
    assert _VMEM is not None, "pltpu namespace unavailable"
    scratch_shapes = [_VMEM(acc_shape, vals.dtype), _VMEM(tot_shape, vals.dtype)]

    compiler_params = None
    if not interpret:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),  # sequential grid = chain
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((steps_per_tile,), lambda i: (i,)),  # wave_id
            pl.BlockSpec((1,), lambda i: (i,)),  # n_waves
            tile(k),  # row_ids
            tile(k, W),  # col_idx
            tile(k, W),  # vals
            tile(k),  # diag
            tile(k),  # accum mask
            resident,  # b
            resident,  # x0 (aliased with the output)
        ],
        out_specs=resident,  # x
        out_shape=jax.ShapeDtypeStruct(b_pad.shape, vals.dtype),
        input_output_aliases={8: 0},  # x0 (9th arg) <-> output
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=compiler_params,
    )(wave_id, n_waves, row_ids, col_idx, vals, diag, accum_mask, b_pad, x0)
