"""jit'd public wrappers around the Pallas kernels.

``sptrsv_kernel_solve(plan, b)`` is the drop-in replacement for
``solver.executor.solve_with_plan`` backed by the Pallas kernel; on this
CPU-only container it runs in interpret mode (the kernel body executes in
Python), on TPU it lowers through Mosaic.

This module is the device half of the ``pallas`` entry in
``repro.backends`` — bind through the registry
(``get_backend("pallas").bind(plan)``) unless you need the raw pieces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecPlan
from repro.kernels.sptrsv import sptrsv_pallas, sptrsv_pallas_elastic


def _pad_steps(a: np.ndarray, mult: int, fill):
    T = a.shape[0]
    pad = (-T) % mult
    if pad == 0:
        return a
    padding = np.full((pad, *a.shape[1:]), fill, dtype=a.dtype)
    return np.concatenate([a, padding], axis=0)


def kernel_plan_arrays(plan: ExecPlan, *, steps_per_tile: int = 8, dtype=jnp.float32):
    """Plan tensors padded to a multiple of the kernel tile, as jax arrays."""
    row_ids = _pad_steps(plan.row_ids, steps_per_tile, plan.n)
    col_idx = _pad_steps(plan.col_idx, steps_per_tile, plan.n)
    vals = _pad_steps(plan.vals.astype(np.dtype(dtype)), steps_per_tile, 0)
    diag = _pad_steps(plan.diag.astype(np.dtype(dtype)), steps_per_tile, 1)
    accum = _pad_steps(plan.accum.astype(np.dtype(dtype)), steps_per_tile, 0)
    return (
        jnp.asarray(row_ids, jnp.int32),
        jnp.asarray(col_idx, jnp.int32),
        jnp.asarray(vals),
        jnp.asarray(diag),
        jnp.asarray(accum),
    )


def solve_with_kernel_arrays(
    arrays, b, *, n: int, steps_per_tile: int, interpret: bool, dtype
):
    """The kernel-calling convention in one place: cast ``b``, append the
    scratch row, run ``sptrsv_pallas`` over pre-built (tile-padded) plan
    ``arrays``, drop the scratch row. Shared by ``bind_kernel_solver``
    and the ``pallas`` entry of ``repro.backends``."""
    b = jnp.asarray(b, dtype=dtype)
    pad = jnp.zeros((1, *b.shape[1:]), dtype=dtype)
    x = sptrsv_pallas(
        *arrays,
        jnp.concatenate([b, pad]),
        steps_per_tile=steps_per_tile,
        interpret=interpret,
    )
    return x[:n]


def elastic_kernel_arrays(plan: ExecPlan, *, dtype=jnp.float32):
    """Plan + wave tensors for the elastic kernel. The tile size IS the
    elastic slack window, so the certificate attached to the plan
    (``plan.elastic``, from ``core.elastic.elastic_transform``) supplies
    ``wave_id``/``n_waves`` directly and the step padding matches the
    ``[M, slack]`` macro grid."""
    ep = plan.elastic
    assert ep is not None, "plan has no elastic certificate attached"
    slack = ep.slack
    return (
        jnp.asarray(ep.wave_id.reshape(-1), jnp.int32),
        jnp.asarray(ep.n_waves, jnp.int32),
        *kernel_plan_arrays(plan, steps_per_tile=slack, dtype=dtype),
    )


def solve_with_elastic_kernel_arrays(
    arrays, b, *, n: int, steps_per_tile: int, interpret: bool, dtype
):
    """Elastic twin of ``solve_with_kernel_arrays`` — same calling
    convention over ``elastic_kernel_arrays`` output."""
    b = jnp.asarray(b, dtype=dtype)
    pad = jnp.zeros((1, *b.shape[1:]), dtype=dtype)
    x = sptrsv_pallas_elastic(
        *arrays,
        jnp.concatenate([b, pad]),
        steps_per_tile=steps_per_tile,
        interpret=interpret,
    )
    return x[:n]


def bind_kernel_solver(
    plan: ExecPlan,
    *,
    steps_per_tile: int = 8,
    dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Bind the plan tensors once; returns ``solve(b) -> x`` where ``b`` is
    f[n] or f[n, m] (batched multi-RHS)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    arrays = kernel_plan_arrays(plan, steps_per_tile=steps_per_tile, dtype=dtype)
    n = plan.n

    def solve(b):
        return solve_with_kernel_arrays(
            arrays, b, n=n, steps_per_tile=steps_per_tile,
            interpret=interpret, dtype=dtype,
        )

    return solve


def sptrsv_kernel_solve(
    plan: ExecPlan,
    b,
    *,
    steps_per_tile: int = 8,
    dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Solve L x = b with the Pallas kernel. ``b``: f[n] (returns x f[n]) or
    f[n, m] for a batched multi-RHS solve (returns x f[n, m])."""
    solve = bind_kernel_solver(
        plan, steps_per_tile=steps_per_tile, dtype=dtype, interpret=interpret
    )
    return solve(b)
