"""Distributed SpTRSV executor — the BSP model on a device mesh.

Here the paper's abstract machine becomes literal hardware: the k schedule
cores are k devices along the ``model`` mesh axis; a superstep is a local
sequential scan over each device's chain; the synchronization barrier is an
``all_gather`` of the x-fragments produced in the superstep (the paper's
L = barrier cost becomes the ICI all-gather latency — see DESIGN.md §3).

The jitted graph contains exactly ``n_supersteps`` all-gathers: GrowLocal's
barrier reduction is visible directly in the lowered HLO (the §Roofline
collective term counts these). Multi-RHS (SpTRSM) batches shard over the
``data`` axis, giving the full production mesh a workload.

``distributed_input_specs`` / ``lower_distributed_solve`` are consumed by
``launch/dryrun.py`` for the paper-workload dry-run cells.

This module is the device half of the ``distributed`` entry in
``repro.backends`` — bind through the registry
(``get_backend("distributed").bind(plan, mesh=mesh)``) unless you need
the raw pieces.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.plan import ExecPlan


@dataclasses.dataclass
class DistPlanSpec:
    """Static description of a distributed solve (shapes only)."""

    n: int
    k: int  # devices on the model axis == schedule cores
    W: int
    T: int
    step_bounds: tuple  # len S+1
    batch: int  # number of RHS (SpTRSM); sharded over 'data'
    dtype: np.dtype = np.dtype(np.float32)
    # plan-step indices of the barriers actually executed (len F+1,
    # subset of step_bounds). None -> one barrier per superstep. Set
    # from the elastic fused-run certificate (core.elastic) to fuse
    # greedy superstep runs into single all-gather rounds: a fused run
    # has no cross-core reads of values written inside it, so deferring
    # the exchange to the run boundary is exactly as correct as the
    # per-superstep barrier (tests/test_rowshard_distributed.py).
    exchange_steps: tuple = None


def dist_plan_spec(
    plan: ExecPlan, batch: int = 1, dtype=np.float32, exchange_steps=None
) -> DistPlanSpec:
    return DistPlanSpec(
        n=plan.n,
        k=plan.k,
        W=plan.W,
        T=plan.n_steps,
        step_bounds=tuple(int(t) for t in plan.step_bounds),
        batch=batch,
        dtype=np.dtype(dtype),
        exchange_steps=(
            None
            if exchange_steps is None
            else tuple(int(t) for t in exchange_steps)
        ),
    )


def _local_solve(spec: DistPlanSpec, rows_full, col_idx, vals, diag,
                 accum_full, b_pad):
    """Per-device body (inside shard_map). Shapes (local):
    rows_full int32[T, k] (REPLICATED — static plan metadata);
    col_idx int32[T, 1, W]; vals f[T, 1, W]; diag f[T, 1];
    accum_full f[T, k] (replicated); b_pad f[B_local, n+1].
    Returns x f[B_local, n+1]."""
    Bl = b_pad.shape[0]
    x = jnp.zeros((Bl, spec.n + 1), dtype=b_pad.dtype)
    core = jax.lax.axis_index("model")
    row_ids = jax.lax.dynamic_slice_in_dim(rows_full, core, 1, axis=1)
    accum = jax.lax.dynamic_slice_in_dim(accum_full, core, 1, axis=1)

    def superstep(x, lo, hi):
        def step(carry, inp):
            x, acc = carry
            rows, cols, v, d, a = inp  # (1,), (1,W), (1,W), (1,), (1,)
            gathered = x[:, cols[0]]  # [Bl, W]
            acc = acc + gathered @ v[0]  # [Bl]
            xv = (b_pad[:, rows[0]] - acc) / d[0]
            keep = a[0] > 0.5
            old = x[:, rows[0]]
            write = jnp.where(keep, old, xv)
            x = x.at[:, rows[0]].set(write)
            acc = jnp.where(keep, acc, jnp.zeros_like(acc))
            return (x, acc), xv

        acc0 = jnp.zeros((Bl,), dtype=b_pad.dtype)
        (x, _), xv_steps = jax.lax.scan(
            step,
            (x, acc0),
            (
                row_ids[lo:hi],
                col_idx[lo:hi],
                vals[lo:hi],
                diag[lo:hi],
                accum[lo:hi],
            ),
        )
        return x, xv_steps  # xv_steps: [hi-lo, Bl]

    # Perf note (EXPERIMENTS.md §Perf, sptrsv cell): row ids and accum
    # flags are STATIC plan data — every device already holds the full
    # [T, k] arrays (replicated in_specs) — so the barrier exchanges ONLY
    # the solved values: one all-gather per superstep instead of three.
    # With exchange_steps set, runs of supersteps certified by the
    # elastic fusion bound share a single barrier.
    bounds = (
        spec.exchange_steps
        if spec.exchange_steps is not None
        else spec.step_bounds
    )
    for s in range(len(bounds) - 1):
        lo, hi = bounds[s], bounds[s + 1]
        if hi == lo:
            continue
        x, xv_steps = superstep(x, lo, hi)
        # --- BARRIER: exchange the fragment produced in this superstep ----
        xv_all = jax.lax.all_gather(xv_steps, "model")  # [k, hi-lo, Bl]
        flat_vals = xv_all.reshape(-1, Bl).T  # [Bl, k*(hi-lo)]
        # static metadata: all cores' rows/accum flags, transposed to the
        # same (core, step) order as the gathered values
        rows_all = rows_full[lo:hi].T.reshape(-1)  # [k*(hi-lo)]
        acc_all = accum_full[lo:hi].T.reshape(-1)
        safe_rows = jnp.where(acc_all > 0.5, spec.n, rows_all)
        x = x.at[:, safe_rows].set(
            jnp.where(acc_all > 0.5, x[:, safe_rows], flat_vals)
        )
    return x


def build_distributed_solver(spec: DistPlanSpec, mesh: Mesh):
    """Returns a jittable ``solve(plan_tensors..., b_pad) -> x`` shard-mapped
    over (data: RHS batch, model: schedule cores)."""
    plan_spec_in = (
        P(None, None),  # rows_full [T, k] — replicated plan metadata
        P(None, "model", None),  # col_idx
        P(None, "model", None),  # vals
        P(None, "model"),  # diag
        P(None, None),  # accum_full [T, k] — replicated
        P("data", None),  # b_pad [B, n+1]
    )

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=plan_spec_in,
        out_specs=P("data", None),
        check_rep=False,
    )
    def solve(row_ids, col_idx, vals, diag, accum, b_pad):
        return _local_solve(spec, row_ids, col_idx, vals, diag, accum, b_pad)

    return solve


def distributed_input_specs(spec: DistPlanSpec, mesh: Mesh):
    """ShapeDtypeStructs (+ shardings) for lowering without allocation."""
    f = spec.dtype
    shapes = [
        ((spec.T, spec.k), np.int32, P(None, None)),
        ((spec.T, spec.k, spec.W), np.int32, P(None, "model", None)),
        ((spec.T, spec.k, spec.W), f, P(None, "model", None)),
        ((spec.T, spec.k), f, P(None, "model")),
        ((spec.T, spec.k), f, P(None, None)),
        ((spec.batch, spec.n + 1), f, P("data", None)),
    ]
    return [
        jax.ShapeDtypeStruct(s, d, sharding=NamedSharding(mesh, p))
        for (s, d, p) in shapes
    ]


def lower_distributed_solve(spec: DistPlanSpec, mesh: Mesh):
    """.lower() the distributed solve on the given mesh (dry-run path)."""
    solve = build_distributed_solver(spec, mesh)
    args = distributed_input_specs(spec, mesh)
    return jax.jit(solve).lower(*args)


def run_distributed_solve(plan: ExecPlan, b: np.ndarray, mesh: Mesh, dtype=jnp.float32):
    """Execute on a real (or host-count-forced) mesh; b: [B, n]."""
    spec = dist_plan_spec(plan, batch=b.shape[0], dtype=np.dtype(dtype))
    solve = build_distributed_solver(spec, mesh)
    b_pad = np.concatenate(
        [np.asarray(b, dtype=dtype), np.zeros((b.shape[0], 1), dtype=dtype)], axis=1
    )
    args = (
        jnp.asarray(plan.row_ids, jnp.int32),
        jnp.asarray(plan.col_idx, jnp.int32),
        jnp.asarray(plan.vals, dtype),
        jnp.asarray(plan.diag, dtype),
        jnp.asarray(plan.accum.astype(np.dtype(dtype))),
        jnp.asarray(b_pad),
    )
    with mesh:
        x = jax.jit(solve)(*args)
    return np.asarray(x)[:, : plan.n]
