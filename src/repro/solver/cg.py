"""(Preconditioned) conjugate gradient — the end-to-end consumer of SpTRSV.

This is the application the paper motivates (§1: iterative methods reuse one
sparsity pattern across many solves — IC(0)-preconditioned CG does two
triangular solves per iteration). ``pcg_ichol`` wires the whole pipeline:
IC(0) -> GrowLocal schedule -> reorder -> ExecPlan for L and L^T -> CG loop
in JAX, with the triangular solves executed by the scheduled executor.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_reordering, compile_plan, grow_local
from repro.solver.executor import make_solver
from repro.sparse.csr import CSRMatrix, transpose_csr
from repro.sparse.dag import dag_from_lower_csr
from repro.sparse.ichol import ichol0


def _csr_matvec_fn(a: CSRMatrix, dtype=jnp.float32):
    indptr = jnp.asarray(a.indptr, jnp.int32)
    indices = jnp.asarray(a.indices, jnp.int32)
    data = jnp.asarray(a.data, dtype)
    row = jnp.asarray(a.row_of_entry(), jnp.int32)

    def matvec(x):
        contrib = data * x[indices]
        return jax.ops.segment_sum(contrib, row, num_segments=a.n_rows)

    del indptr
    return matvec


def cg_solve(
    a: CSRMatrix,
    b: np.ndarray,
    *,
    precond: Optional[Callable] = None,
    tol: float = 1e-6,
    maxiter: int = 1000,
    dtype=jnp.float32,
):
    """CG on SPD ``a``; ``precond(r) -> z`` approximates A^-1 r.
    Returns (x, n_iters, final residual norm)."""
    matvec = _csr_matvec_fn(a, dtype)
    b_j = jnp.asarray(b, dtype)
    bnorm = jnp.linalg.norm(b_j) + 1e-30

    M = precond if precond is not None else (lambda r: r)

    def cond(state):
        _, r, _, _, it = state
        return jnp.logical_and(jnp.linalg.norm(r) / bnorm > tol, it < maxiter)

    def body(state):
        x, r, z, p, it = state
        ap = matvec(p)
        rz = jnp.vdot(r, z)
        alpha = rz / (jnp.vdot(p, ap) + 1e-30)
        x = x + alpha * p
        r2 = r - alpha * ap
        z2 = M(r2)
        beta = jnp.vdot(r2, z2) / (rz + 1e-30)
        p = z2 + beta * p
        return (x, r2, z2, p, it + 1)

    x0 = jnp.zeros_like(b_j)
    z0 = M(b_j)
    state = (x0, b_j, z0, z0, jnp.zeros((), jnp.int32))
    x, r, _, _, it = jax.lax.while_loop(cond, body, state)
    return np.asarray(x), int(it), float(jnp.linalg.norm(r) / bnorm)


def pcg_ichol(
    a: CSRMatrix,
    b: np.ndarray,
    *,
    k: int = 8,
    tol: float = 1e-6,
    maxiter: int = 1000,
    dtype=jnp.float32,
):
    """End-to-end driver: IC(0) + GrowLocal-scheduled triangular solves as
    the CG preconditioner. Returns (x, iters, relres, info-dict)."""
    Lf = ichol0(a)
    dag = dag_from_lower_csr(Lf)
    sched = grow_local(dag, k)
    L2, s2, _, r = apply_reordering(Lf, sched)
    fwd_plan = compile_plan(L2, s2, dtype=np.dtype(dtype))
    solve_fwd = make_solver(fwd_plan, dtype=dtype)

    # backward solve: L^T x = y  <=>  forward solve on reversed ordering.
    # (L^T reversed symmetrically is lower triangular again.)
    U = transpose_csr(L2)
    rev = np.arange(L2.n_rows)[::-1].copy()
    from repro.sparse.csr import permute_symmetric

    U_rev = permute_symmetric(U, rev)
    dag_u = dag_from_lower_csr(U_rev)
    sched_u = grow_local(dag_u, k)
    U2, su2, _, ru = apply_reordering(U_rev, sched_u)
    bwd_plan = compile_plan(U2, su2, dtype=np.dtype(dtype))
    solve_bwd = make_solver(bwd_plan, dtype=dtype)

    perm = jnp.asarray(r.perm)  # fine ids: new -> old
    inv = jnp.asarray(r.inv)
    rev_j = jnp.asarray(rev)
    perm_u = jnp.asarray(ru.perm)
    inv_u = jnp.asarray(ru.inv)

    def precond(res):
        # z = (L L^T)^{-1} res, all in the reordered bases
        y = solve_fwd(res[perm])  # L2 y = P res
        yr = y[rev_j][perm_u]  # into U2's basis
        z2 = solve_bwd(yr)
        # back out: undo U2 reordering, undo reversal, undo L2 reordering
        z = z2[inv_u][rev_j][inv]
        return z

    x, iters, relres = cg_solve(
        a, b, precond=precond, tol=tol, maxiter=maxiter, dtype=dtype
    )
    info = {
        "fwd_supersteps": s2.n_supersteps,
        "bwd_supersteps": su2.n_supersteps,
        "fwd_plan": fwd_plan.stats(),
        "bwd_plan": bwd_plan.stats(),
    }
    return x, iters, relres, info
