"""(Preconditioned) conjugate gradient — the end-to-end consumer of SpTRSV.

This is the application the paper motivates (§1: iterative methods reuse one
sparsity pattern across many solves — IC(0)-preconditioned CG does two
triangular solves per iteration). ``pcg_ichol`` is now a thin client of the
``repro.pipeline`` front door: IC(0), then ``factor_pair`` plans the
scheduled (L, L^T) solver pair — all permutation plumbing lives inside
``TriangularSolver``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.pipeline import PlanCache, factor_pair
from repro.sparse.csr import CSRMatrix
from repro.sparse.ichol import ichol0


def _csr_matvec_fn(a: CSRMatrix, dtype=jnp.float32):
    indptr = jnp.asarray(a.indptr, jnp.int32)
    indices = jnp.asarray(a.indices, jnp.int32)
    data = jnp.asarray(a.data, dtype)
    row = jnp.asarray(a.row_of_entry(), jnp.int32)

    def matvec(x):
        contrib = data * x[indices]
        return jax.ops.segment_sum(contrib, row, num_segments=a.n_rows)

    del indptr
    return matvec


def cg_solve(
    a: CSRMatrix,
    b: np.ndarray,
    *,
    precond: Optional[Callable] = None,
    tol: float = 1e-6,
    maxiter: int = 1000,
    dtype=jnp.float32,
):
    """CG on SPD ``a``; ``precond(r) -> z`` approximates A^-1 r.
    Returns (x, n_iters, final residual norm)."""
    matvec = _csr_matvec_fn(a, dtype)
    b_j = jnp.asarray(b, dtype)
    bnorm = jnp.linalg.norm(b_j) + 1e-30

    M = precond if precond is not None else (lambda r: r)

    def cond(state):
        _, r, _, _, it = state
        return jnp.logical_and(jnp.linalg.norm(r) / bnorm > tol, it < maxiter)

    def body(state):
        x, r, z, p, it = state
        ap = matvec(p)
        # repro: blessed-reduction — CG inner products: the iteration is
        # convergence-bounded, not bitwise-specified (only the triangular
        # solves inside the preconditioner carry the bitwise contract)
        rz = jnp.vdot(r, z)
        alpha = rz / (jnp.vdot(p, ap) + 1e-30)  # repro: blessed-reduction
        x = x + alpha * p
        r2 = r - alpha * ap
        z2 = M(r2)
        beta = jnp.vdot(r2, z2) / (rz + 1e-30)  # repro: blessed-reduction
        p = z2 + beta * p
        return (x, r2, z2, p, it + 1)

    x0 = jnp.zeros_like(b_j)
    z0 = M(b_j)
    state = (x0, b_j, z0, z0, jnp.zeros((), jnp.int32))
    x, r, _, _, it = jax.lax.while_loop(cond, body, state)
    return np.asarray(x), int(it), float(jnp.linalg.norm(r) / bnorm)


def pcg_ichol(
    a: CSRMatrix,
    b: np.ndarray,
    *,
    k: int = 8,
    strategy: str = "auto",
    tol: float = 1e-6,
    maxiter: int = 1000,
    dtype=jnp.float32,
    cache: Optional[PlanCache] = None,
):
    """End-to-end driver: IC(0) + scheduled triangular solves as the CG
    preconditioner. Returns (x, iters, relres, info-dict). Pass a
    ``PlanCache`` to reuse plans across calls on one sparsity pattern.
    The default ``strategy="auto"`` lets the autotuner pick per factor
    (``fwd`` and ``bwd`` solve mirror-image DAGs and are selected
    independently); pass a registry name to pin it."""
    Lf = ichol0(a)
    fwd, bwd = factor_pair(Lf, strategy=strategy, k=k, dtype=dtype, cache=cache)

    def precond(res):  # z = (L L^T)^{-1} res
        return bwd(fwd(res))

    x, iters, relres = cg_solve(
        a, b, precond=precond, tol=tol, maxiter=maxiter, dtype=dtype
    )
    info = {
        "fwd_supersteps": fwd.n_supersteps,
        "bwd_supersteps": bwd.n_supersteps,
        "fwd_strategy": fwd.strategy,
        "bwd_strategy": bwd.strategy,
        "fwd_plan": fwd.exec_plan.stats(),
        "bwd_plan": bwd.exec_plan.stats(),
    }
    if cache is not None:
        info["cache"] = cache.stats.as_dict()
    return x, iters, relres, info
