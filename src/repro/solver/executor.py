"""Single-chip JAX executor: a `lax.scan` over the ExecPlan.

This module is the device half of the ``scan`` entry in
``repro.backends`` — bind through the registry
(``get_backend("scan").bind(plan)``) unless you need the raw pieces.

Each scan step processes one lock-step row per core (k rows in parallel on
the VPU): gather x at the row's column indices, fused multiply-accumulate,
divide by the diagonal, scatter into x. Same-core sequential chains flow
through the scan carry; superstep barriers are free on one chip (DESIGN.md
§3), so the scan ignores `step_bounds` — they matter for the distributed
executor and the Pallas kernel grid.

Padding protocol (see core.plan): row id n = scratch row, gather index n =
scratch slot, so padded lanes are harmless. `accum` rows carry partial sums
for rows wider than W.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecPlan


class PlanArrays(NamedTuple):
    """Device-resident plan tensors (see ExecPlan for shapes)."""

    row_ids: jax.Array  # int32[T, k]
    col_idx: jax.Array  # int32[T, k, W]
    vals: jax.Array  # f[T, k, W]
    diag: jax.Array  # f[T, k]
    accum: jax.Array  # bool[T, k]
    n: int
    step_bounds: np.ndarray  # host-side; used by distributed executor


def plan_arrays(plan: ExecPlan, dtype=jnp.float32) -> PlanArrays:
    return PlanArrays(
        row_ids=jnp.asarray(plan.row_ids, dtype=jnp.int32),
        col_idx=jnp.asarray(plan.col_idx, dtype=jnp.int32),
        vals=jnp.asarray(plan.vals, dtype=dtype),
        diag=jnp.asarray(plan.diag, dtype=dtype),
        accum=jnp.asarray(plan.accum),
        n=plan.n,
        step_bounds=np.asarray(plan.step_bounds),
    )


@partial(jax.jit, static_argnames=("n",))
def _solve_scan(row_ids, col_idx, vals, diag, accum, b_pad, n):
    x0 = jnp.zeros(n + 1, dtype=b_pad.dtype)
    acc0 = jnp.zeros(row_ids.shape[1], dtype=b_pad.dtype)

    def step(carry, inp):
        x, acc = carry
        rows, cols, v, d, a = inp
        partial_sum = jnp.einsum("kw,kw->k", v, x[cols])
        acc = acc + partial_sum
        xv = (b_pad[rows] - acc) / d
        # finishing lanes write x and reset their accumulator
        write = jnp.where(a, x[rows], xv)
        # NOTE: padded lanes share the scratch row id n -> indices are not
        # unique; plain scatter keeps them well-defined (they all write junk
        # to the scratch slot).
        x = x.at[rows].set(write)
        acc = jnp.where(a, acc, 0.0)
        return (x, acc), None

    (x, _), _ = jax.lax.scan(
        step, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


@partial(jax.jit, static_argnames=("n",))
def _solve_scan_mrhs(row_ids, col_idx, vals, diag, accum, b_pad, n):
    """Batched SpTRSM: ``b_pad`` f[n+1, m], carry ``x`` f[n+1, m]. One plan
    traversal solves all m right-hand sides (the gather/scatter indices are
    shared; only the value lanes widen)."""
    m = b_pad.shape[1]
    x0 = jnp.zeros((n + 1, m), dtype=b_pad.dtype)
    acc0 = jnp.zeros((row_ids.shape[1], m), dtype=b_pad.dtype)

    def step(carry, inp):
        x, acc = carry
        rows, cols, v, d, a = inp
        acc = acc + jnp.einsum("kw,kwm->km", v, x[cols])
        xv = (b_pad[rows] - acc) / d[:, None]
        write = jnp.where(a[:, None], x[rows], xv)
        x = x.at[rows].set(write)
        acc = jnp.where(a[:, None], acc, 0.0)
        return (x, acc), None

    (x, _), _ = jax.lax.scan(
        step, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


def solve_with_plan(pa: PlanArrays, b: jax.Array) -> jax.Array:
    """Solve L x = b using the compiled plan. ``b``: f[n] or f[n, m]
    (multi-RHS — solved in one batched traversal)."""
    b = b.astype(pa.vals.dtype)
    pad = jnp.zeros((1, *b.shape[1:]), pa.vals.dtype)
    b_pad = jnp.concatenate([b, pad])
    solver = _solve_scan if b.ndim == 1 else _solve_scan_mrhs
    return solver(pa.row_ids, pa.col_idx, pa.vals, pa.diag, pa.accum, b_pad, pa.n)


def make_solver(plan: ExecPlan, dtype=jnp.float32):
    """Bind a plan; returns ``solve(b) -> x`` (jit-compiled on first call).
    ``b`` may be f[n] or f[n, m] for a batched multi-RHS solve."""
    pa = plan_arrays(plan, dtype=dtype)

    def solve(b):
        return solve_with_plan(pa, jnp.asarray(b, dtype=dtype))

    return solve
