"""Single-chip JAX executor: a `lax.scan` over the ExecPlan.

This module is the device half of the ``scan`` entry in
``repro.backends`` — bind through the registry
(``get_backend("scan").bind(plan)``) unless you need the raw pieces.

Each scan step processes one lock-step row per core (k rows in parallel on
the VPU): gather x at the row's column indices, fused multiply-accumulate,
divide by the diagonal, scatter into x. Same-core sequential chains flow
through the scan carry; superstep barriers are free on one chip (DESIGN.md
§3), so the scan ignores `step_bounds` — they matter for the distributed
executor and the Pallas kernel grid.

Padding protocol (see core.plan): row id n = scratch row, gather index n =
scratch slot, so padded lanes are harmless. `accum` rows carry partial sums
for rows wider than W.

The elastic section at the bottom (``ElasticArrays`` /
``solve_with_elastic``) is the ``mode="elastic"`` variant: the same step
bodies, but scanned over ``ceil(T/slack)`` fused macro-steps with the
slack window unrolled inside each one (certificate in ``core.elastic``;
bound via ``get_backend("scan").bind(plan, slack=s)``). Results are
bitwise-identical to the bulk scan — the unrolled bodies replay the
exact same op sequence.
"""
from __future__ import annotations

import time
from functools import partial
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.plan import ExecPlan


class PlanArrays(NamedTuple):
    """Device-resident plan tensors (see ExecPlan for shapes)."""

    row_ids: jax.Array  # int32[T, k]
    col_idx: jax.Array  # int32[T, k, W]
    vals: jax.Array  # f[T, k, W]
    diag: jax.Array  # f[T, k]
    accum: jax.Array  # bool[T, k]
    n: int
    step_bounds: np.ndarray  # host-side; used by distributed executor


def plan_arrays(plan: ExecPlan, dtype=jnp.float32) -> PlanArrays:
    return PlanArrays(
        row_ids=jnp.asarray(plan.row_ids, dtype=jnp.int32),
        col_idx=jnp.asarray(plan.col_idx, dtype=jnp.int32),
        vals=jnp.asarray(plan.vals, dtype=dtype),
        diag=jnp.asarray(plan.diag, dtype=dtype),
        accum=jnp.asarray(plan.accum),
        n=plan.n,
        step_bounds=np.asarray(plan.step_bounds),
    )


def _step_single(x, acc, rows, cols, v, d, a, b_pad):
    """One plan step: gather, fused multiply-accumulate, divide, scatter.

    Shared verbatim by the bulk-synchronous scan, the elastic macro-step
    executor AND the row-sharded distributed executor
    (``solver.rowsharded``) so every path emits the exact same op
    sequence per step — the foundation of the bitwise elastic == bulk
    and sharded == single-chip guarantees (tests/test_elastic.py,
    tests/test_rowshard_distributed.py).

    The W-reduction is an explicit fixed-order loop of ELEMENTWISE
    multiply/adds rather than an einsum dot: elementwise IEEE ops are
    exact per element, so a lane's bits are independent of the step's
    tensor SHAPES. An einsum's reduction order is XLA's choice and was
    observed to differ between k and k_local < k operands (1-ulp FMA
    drift), which would break bitwise parity between a shard's local
    scan and the full-width scan.
    """
    # named_scope tags the emitted HLO (zero runtime cost), so a
    # jax.profiler device trace carries plan-step names
    with jax.named_scope("sptrsv_step"):
        for w in range(v.shape[1]):
            acc = acc + v[:, w] * x[cols[:, w]]
        xv = (b_pad[rows] - acc) / d
        # finishing lanes write x and reset their accumulator
        write = jnp.where(a, x[rows], xv)
        # NOTE: padded lanes share the scratch row id n -> indices are not
        # unique; plain scatter keeps them well-defined (they all write
        # junk to the scratch slot).
        x = x.at[rows].set(write)
        acc = jnp.where(a, acc, 0.0)
    return x, acc


def _scan_single(row_ids, col_idx, vals, diag, accum, b_pad, n):
    x0 = jnp.zeros(n + 1, dtype=b_pad.dtype)
    acc0 = jnp.zeros(row_ids.shape[1], dtype=b_pad.dtype)

    def step(carry, inp):
        return _step_single(*carry, *inp, b_pad), None

    (x, _), _ = jax.lax.scan(
        step, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


# the single-RHS entry keeps its jitted name; the raw body stays callable
# so the grouped executor can vmap it without nesting jits
_solve_scan = partial(jax.jit, static_argnames=("n",))(_scan_single)


@partial(jax.jit, static_argnames=("n",))
def _solve_scan_grouped(row_ids, col_idx, vals, diag, accum, b_pad, n):
    """Width-class grouped solve: every tensor carries a leading group
    axis g — lane g runs the single-RHS scan on ITS OWN plan tensors
    (``row_ids[g], col_idx[g], ...``) and rhs ``b_pad[g]``. The compiled
    graph depends only on the stacked shapes ``(g, T, k, W, n)``, so one
    XLA variant serves every combination of structurally-identical plans
    (the serve layer's cross-pattern microbatching). Lanes are
    data-independent: vmap batches the same op sequence per lane, so a
    lane's bits never depend on what its neighbors hold (property-tested
    in tests/test_serve_scaleout.py)."""
    return jax.vmap(partial(_scan_single, n=n))(
        row_ids, col_idx, vals, diag, accum, b_pad
    )


def solve_with_plan_group(pas, b_cols: jax.Array) -> jax.Array:
    """Solve lane j of ``b_cols`` f[g, n] (already in plan row order)
    against ``pas[j]`` — one vmapped traversal over the whole group. All
    plans must share the same tensor shapes (one width class); returns
    x f[g, n].

    Stacks the plan tensors per call — fine for replay/verification; the
    serving hot path amortizes the stacking through a ``BankTensors``
    bank + ``_solve_scan_banked`` instead (bitwise-identical output,
    asserted in tests/test_serve_scaleout.py)."""
    dtype = pas[0].vals.dtype
    b = jnp.asarray(b_cols, dtype)
    b_pad = jnp.concatenate([b, jnp.zeros((b.shape[0], 1), dtype)], axis=1)
    stacked = [
        jnp.stack([getattr(pa, f) for pa in pas])
        for f in ("row_ids", "col_idx", "vals", "diag", "accum")
    ]
    return _solve_scan_grouped(*stacked, b_pad, pas[0].n)


class BankTensors(NamedTuple):
    """A width class's plan tensors stacked ONCE on device (lane axis P
    first) plus per-lane row permutations — the serving fast path for
    cross-pattern grouped batches. Dispatches index lanes inside the jit
    (``_solve_scan_banked``), so a microbatch costs one compiled call
    with no per-dispatch stacking; the bank is only restacked when the
    class membership changes (new pattern or plan version)."""

    row_ids: jax.Array  # int32[P, T, k]
    col_idx: jax.Array  # int32[P, T, k, W]
    vals: jax.Array  # f[P, T, k, W]
    diag: jax.Array  # f[P, T, k]
    accum: jax.Array  # bool[P, T, k]
    perm: jax.Array  # int32[P, n]  caller order -> plan row order
    inv: jax.Array  # int32[P, n]  plan row order -> caller order


def stack_plan_bank(pas, perms, invs) -> BankTensors:
    """Stack one width class's plans into a ``BankTensors``. The lane
    axis is padded UP to a power of two (repeating lane 0) so the jitted
    banked solve compiles at most log2 bank-size variants as classes
    grow and shrink with plan-version churn."""
    P = len(pas)
    pad = (1 << max(P - 1, 0).bit_length()) - P if P > 1 else 0
    idx = list(range(P)) + [0] * pad
    return BankTensors(
        *(
            jnp.stack([getattr(pas[i], f) for i in idx])
            for f in ("row_ids", "col_idx", "vals", "diag", "accum")
        ),
        perm=jnp.stack([perms[i] for i in idx]),
        inv=jnp.stack([invs[i] for i in idx]),
    )


@partial(jax.jit, static_argnames=("n",))
def _solve_scan_banked(
    row_ids, col_idx, vals, diag, accum, perm, inv, lane_idx, B, n
):
    """The banked grouped solve: request j reads bank lane
    ``lane_idx[j]`` — plan tensors AND its row permutation — solves, and
    un-permutes, all inside one compiled call. ``B`` is f[n, m] in
    caller row order; returns x f[n, m]. Bitwise-identical to
    ``_solve_scan_grouped`` on the same lanes: the lane gathers and
    permutations move bits unchanged, and the scan body is the same
    vmapped ``_scan_single``."""
    r = row_ids[lane_idx]
    c = col_idx[lane_idx]
    v = vals[lane_idx]
    d = diag[lane_idx]
    a = accum[lane_idx]
    b = jnp.take_along_axis(B.T.astype(v.dtype), perm[lane_idx], axis=1)
    b_pad = jnp.concatenate(
        [b, jnp.zeros((b.shape[0], 1), b.dtype)], axis=1
    )
    x = jax.vmap(partial(_scan_single, n=n))(r, c, v, d, a, b_pad)
    return jnp.take_along_axis(x, inv[lane_idx], axis=1).T


def solve_with_bank(bank: BankTensors, lane_idx, B) -> jax.Array:
    """Solve column j of ``B`` f[n, m] (caller order) against bank lane
    ``lane_idx[j]``; returns x f[n, m] (caller order)."""
    n = int(bank.perm.shape[1])
    return _solve_scan_banked(
        *bank, jnp.asarray(lane_idx, jnp.int32), jnp.asarray(B), n
    )


# ------------------------------------------------- resident RHS slots
# The continuous-batching serve engine (repro.serve.slots) keeps one
# device-resident rhs bank B f[n, S] per width class: admission INSERTS a
# request's b into a free slot (dynamic_update_slice — no host restack of
# the whole batch), every dispatch-loop pass solves a pow2 lane prefix
# of the bank through the same jitted banked kernel, and completion
# EXTRACTS the finished slot's column. The slot index is a traced scalar,
# so insert/extract compile exactly once per (n, S) shape and the pass
# at most log2(S) times (one per pow2 prefix width).

@jax.jit
def _insert_lane(B, lane, b):
    return jax.lax.dynamic_update_slice(B, b[:, None], (0, lane))


@jax.jit
def _extract_lane(X, lane):
    return jax.lax.dynamic_slice_in_dim(X, lane, 1, axis=1)[:, 0]


def blank_rhs(n: int, slots: int, dtype) -> jax.Array:
    """A zeroed device-resident rhs bank f[n, slots]."""
    return jnp.zeros((n, slots), dtype)


def insert_lane(B_res: jax.Array, lane: int, b) -> jax.Array:
    """New resident bank with column ``lane`` replaced by ``b`` f[n] —
    bits of every other column are untouched (``dynamic_update_slice``
    moves bits unchanged; slot-neighbor independence is property-tested
    in tests/test_serve_slots.py). Pure: the input bank is not mutated,
    so a dispatch pass holding the old reference keeps solving the
    snapshot it captured."""
    return _insert_lane(
        B_res, jnp.int32(lane), jnp.asarray(b, B_res.dtype)
    )


def extract_lane(X: jax.Array, lane: int) -> jax.Array:
    """Column ``lane`` of ``X`` f[n, S] as f[n] (bits unchanged)."""
    return _extract_lane(X, jnp.int32(lane))


def solve_resident(bank: BankTensors, lane_idx, B_res) -> jax.Array:
    """The continuous-mode solve pass: identical to ``solve_with_bank``
    (same jitted kernel, bitwise-identical bits per (width, column)),
    except ``B_res`` is already device-resident — nothing re-uploads.
    The pass width is ``len(lane_idx)``: the engine allocates lanes
    lowest-first and dispatches the smallest pow2 lane prefix covering
    the occupied slots, so a lightly-loaded bank never pays the full-S
    solve (``lax.slice_in_dim`` moves bits unchanged, so the result is
    still bitwise-identical to solving a freshly-stacked width-w batch
    of the same columns). Free slots inside the prefix carry stale
    columns whose results are simply never extracted (lane independence
    makes them harmless to live neighbors)."""
    w = len(lane_idx)
    if w != B_res.shape[1]:
        B_res = jax.lax.slice_in_dim(B_res, 0, w, axis=1)
    return solve_with_bank(bank, lane_idx, B_res)


def _step_mrhs(x, acc, rows, cols, v, d, a, b_pad):
    """Multi-RHS twin of ``_step_single`` (value lanes widen to m);
    shared by the bulk scan, the elastic macro-step body and the
    row-sharded executor. Same fixed-order elementwise W-reduction as
    ``_step_single`` — a column's bits are independent of both the lane
    count k and the batch width m."""
    with jax.named_scope("sptrsv_step_mrhs"):
        for w in range(v.shape[1]):
            acc = acc + v[:, w, None] * x[cols[:, w]]
        xv = (b_pad[rows] - acc) / d[:, None]
        write = jnp.where(a[:, None], x[rows], xv)
        x = x.at[rows].set(write)
        acc = jnp.where(a[:, None], acc, 0.0)
    return x, acc


@partial(jax.jit, static_argnames=("n",))
def _solve_scan_mrhs(row_ids, col_idx, vals, diag, accum, b_pad, n):
    """Batched SpTRSM: ``b_pad`` f[n+1, m], carry ``x`` f[n+1, m]. One plan
    traversal solves all m right-hand sides (the gather/scatter indices are
    shared; only the value lanes widen)."""
    m = b_pad.shape[1]
    x0 = jnp.zeros((n + 1, m), dtype=b_pad.dtype)
    acc0 = jnp.zeros((row_ids.shape[1], m), dtype=b_pad.dtype)

    def step(carry, inp):
        return _step_mrhs(*carry, *inp, b_pad), None

    (x, _), _ = jax.lax.scan(
        step, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


def solve_with_plan(pa: PlanArrays, b: jax.Array) -> jax.Array:
    """Solve L x = b using the compiled plan. ``b``: f[n] or f[n, m]
    (multi-RHS — solved in one batched traversal)."""
    b = b.astype(pa.vals.dtype)
    pad = jnp.zeros((1, *b.shape[1:]), pa.vals.dtype)
    b_pad = jnp.concatenate([b, pad])
    solver = _solve_scan if b.ndim == 1 else _solve_scan_mrhs
    return solver(pa.row_ids, pa.col_idx, pa.vals, pa.diag, pa.accum, b_pad, pa.n)


# --------------------------------------------------------------- elastic
class ElasticArrays(NamedTuple):
    """Device-resident plan tensors in macro-step layout: the T plan
    steps, padded up to ``M * slack`` with scratch steps, reshaped to a
    leading [M, slack] grid. ``lax.scan`` runs over the M macro-steps;
    the slack axis is unrolled inside the step body (see
    ``_elastic_single``)."""

    row_ids: jax.Array  # int32[M, S, k]
    col_idx: jax.Array  # int32[M, S, k, W]
    vals: jax.Array  # f[M, S, k, W]
    diag: jax.Array  # f[M, S, k]
    accum: jax.Array  # bool[M, S, k]
    n: int
    slack: int
    n_steps: int  # original (pre-padding) plan step count T


def _pad_to_window(a: np.ndarray, pad: int, fill) -> np.ndarray:
    if pad == 0:
        return a
    tail = np.full((pad, *a.shape[1:]), fill, dtype=a.dtype)
    return np.concatenate([a, tail], axis=0)


def elastic_plan_arrays(
    plan: ExecPlan, *, slack: int, dtype=jnp.float32
) -> ElasticArrays:
    """Lay the plan out for the elastic executor. Padding steps are the
    usual scratch protocol (row n, gather n, val 0, diag 1, no accum):
    they cost a few junk scratch writes inside the last macro-step and
    cannot perturb x[:n]. The accumulator provably enters the padding
    region as zero — a plan's last real step never carries ``accum``
    (every virtual-row chain ends with its finishing row)."""
    T = plan.n_steps
    M = max(1, -(-T // slack))
    pad = M * slack - T
    n, k, W = plan.n, plan.k, plan.W
    return ElasticArrays(
        row_ids=jnp.asarray(
            _pad_to_window(plan.row_ids, pad, n).reshape(M, slack, k),
            dtype=jnp.int32,
        ),
        col_idx=jnp.asarray(
            _pad_to_window(plan.col_idx, pad, n).reshape(M, slack, k, W),
            dtype=jnp.int32,
        ),
        vals=jnp.asarray(
            _pad_to_window(plan.vals, pad, 0).reshape(M, slack, k, W),
            dtype=dtype,
        ),
        diag=jnp.asarray(
            _pad_to_window(plan.diag, pad, 1).reshape(M, slack, k),
            dtype=dtype,
        ),
        accum=jnp.asarray(
            _pad_to_window(plan.accum, pad, False).reshape(M, slack, k)
        ),
        n=n,
        slack=int(slack),
        n_steps=T,
    )


def _elastic_single(row_ids, col_idx, vals, diag, accum, b_pad, n):
    """Elastic scan: ``ceil(T / slack)`` fused macro-steps. Each scan
    step replays its window's ``slack`` plan steps in order through the
    statically-unrolled ``_step_single`` body — intra-window
    dependencies resolve by local substitution on the live x carry, so
    every row still accumulates in exactly the plan order and the result
    is bitwise-identical to ``_scan_single``; only the scan trip count
    (and with it per-step dispatch overhead) shrinks."""
    S = row_ids.shape[1]
    x0 = jnp.zeros(n + 1, dtype=b_pad.dtype)
    acc0 = jnp.zeros(row_ids.shape[2], dtype=b_pad.dtype)

    def macro(carry, inp):
        x, acc = carry
        rows, cols, v, d, a = inp
        for j in range(S):
            x, acc = _step_single(x, acc, rows[j], cols[j], v[j], d[j], a[j], b_pad)
        return (x, acc), None

    (x, _), _ = jax.lax.scan(
        macro, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


_solve_elastic = partial(jax.jit, static_argnames=("n",))(_elastic_single)


@partial(jax.jit, static_argnames=("n",))
def _solve_elastic_mrhs(row_ids, col_idx, vals, diag, accum, b_pad, n):
    """Multi-RHS elastic scan (macro-step twin of ``_solve_scan_mrhs``)."""
    S = row_ids.shape[1]
    m = b_pad.shape[1]
    x0 = jnp.zeros((n + 1, m), dtype=b_pad.dtype)
    acc0 = jnp.zeros((row_ids.shape[2], m), dtype=b_pad.dtype)

    def macro(carry, inp):
        x, acc = carry
        rows, cols, v, d, a = inp
        for j in range(S):
            x, acc = _step_mrhs(x, acc, rows[j], cols[j], v[j], d[j], a[j], b_pad)
        return (x, acc), None

    (x, _), _ = jax.lax.scan(
        macro, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


def solve_with_elastic(ea: ElasticArrays, b: jax.Array) -> jax.Array:
    """Solve L x = b through the elastic macro-step scan. ``b``: f[n] or
    f[n, m]; bitwise-identical to ``solve_with_plan`` on the same plan."""
    b = b.astype(ea.vals.dtype)
    pad = jnp.zeros((1, *b.shape[1:]), ea.vals.dtype)
    b_pad = jnp.concatenate([b, pad])
    solver = _solve_elastic if b.ndim == 1 else _solve_elastic_mrhs
    return solver(ea.row_ids, ea.col_idx, ea.vals, ea.diag, ea.accum, b_pad, ea.n)


# ---------------------------------------------------------- timed solves
# Opt-in per-step device timing (``TriangularSolver.plan(..., timed=True)``
# / ``BoundSolve.solve_timed``): the plan traversal is broken at its
# natural boundaries — superstep bounds for the bulk scan, macro-step
# windows for elastic — and each segment runs as its own jitted call,
# host-timed around ``block_until_ready``. Results stay numerically
# identical to the fused scans (the segment carry replays the same step
# bodies in the same order); only dispatch granularity changes, which is
# exactly what makes the per-segment wall-clock observable. Compiled
# variants are bounded: one per distinct superstep length (bulk) and ONE
# total for elastic (every window is [slack, ...]-shaped).

@jax.jit
def _solve_segment(rows, cols, v, d, a, b_pad, x, acc):
    """Run one contiguous run of plan steps on an existing (x, acc)
    carry. Serves both timed paths: a bulk superstep slice (rows
    int32[t, k]) and one elastic macro window (rows int32[slack, k]).
    Single- vs multi-RHS is resolved statically from the carry rank."""
    body = _step_single if x.ndim == 1 else _step_mrhs

    def step(carry, inp):
        return body(*carry, *inp, b_pad), None

    (x, acc), _ = jax.lax.scan(step, (x, acc), (rows, cols, v, d, a))
    return x, acc


def _timed_carry(b, vals_dtype, n, k):
    """Shared setup for the timed paths: padded rhs + zero carry."""
    b = jnp.asarray(b).astype(vals_dtype)
    pad = jnp.zeros((1, *b.shape[1:]), vals_dtype)
    b_pad = jnp.concatenate([b, pad])
    if b.ndim == 1:
        x = jnp.zeros(n + 1, b_pad.dtype)
        acc = jnp.zeros(k, b_pad.dtype)
    else:
        m = b.shape[1]
        x = jnp.zeros((n + 1, m), b_pad.dtype)
        acc = jnp.zeros((k, m), b_pad.dtype)
    return b_pad, x, acc


def solve_with_plan_timed(
    pa: PlanArrays, b: jax.Array
) -> Tuple[jax.Array, List[dict]]:
    """``solve_with_plan`` with per-superstep device timing: one jitted
    segment per superstep, synchronized and host-timed. Returns
    ``(x, steps)`` where each entry is
    ``{"superstep", "n_steps", "us"}``; an ``executor.superstep`` span
    lands in the active trace buffer per segment when tracing is on."""
    k = int(pa.row_ids.shape[1])
    b_pad, x, acc = _timed_carry(b, pa.vals.dtype, pa.n, k)
    bounds = pa.step_bounds
    steps: List[dict] = []
    for s in range(len(bounds) - 1):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        if hi == lo:
            continue
        with obs.span(
            "executor.superstep", cat="executor", superstep=s, steps=hi - lo
        ):
            t0 = time.perf_counter_ns()
            x, acc = _solve_segment(
                pa.row_ids[lo:hi],
                pa.col_idx[lo:hi],
                pa.vals[lo:hi],
                pa.diag[lo:hi],
                pa.accum[lo:hi],
                b_pad,
                x,
                acc,
            )
            x.block_until_ready()
            dur = time.perf_counter_ns() - t0
        steps.append(
            {"superstep": s, "n_steps": hi - lo, "us": round(dur / 1e3, 2)}
        )
    return x[:pa.n], steps


def solve_with_elastic_timed(
    ea: ElasticArrays, b: jax.Array
) -> Tuple[jax.Array, List[dict]]:
    """``solve_with_elastic`` with per-macro-step device timing. Every
    window shares the [slack, ...] shape, so the whole loop compiles ONE
    ``_solve_segment`` variant. Returns ``(x, steps)`` with one
    ``{"macro_step", "n_steps", "us"}`` entry (and one
    ``executor.macro_step`` span when tracing) per executed macro-step —
    the runtime side of the elastic barrier-fusion certificate."""
    k = int(ea.row_ids.shape[2])
    b_pad, x, acc = _timed_carry(b, ea.vals.dtype, ea.n, k)
    M = int(ea.row_ids.shape[0])
    steps: List[dict] = []
    for m in range(M):
        with obs.span(
            "executor.macro_step", cat="executor", macro=m, slack=ea.slack
        ):
            t0 = time.perf_counter_ns()
            x, acc = _solve_segment(
                ea.row_ids[m],
                ea.col_idx[m],
                ea.vals[m],
                ea.diag[m],
                ea.accum[m],
                b_pad,
                x,
                acc,
            )
            x.block_until_ready()
            dur = time.perf_counter_ns() - t0
        steps.append(
            {"macro_step": m, "n_steps": ea.slack, "us": round(dur / 1e3, 2)}
        )
    return x[:ea.n], steps


def make_solver(plan: ExecPlan, dtype=jnp.float32):
    """Bind a plan; returns ``solve(b) -> x`` (jit-compiled on first call).
    ``b`` may be f[n] or f[n, m] for a batched multi-RHS solve."""
    pa = plan_arrays(plan, dtype=dtype)

    def solve(b):
        return solve_with_plan(pa, jnp.asarray(b, dtype=dtype))

    return solve
