"""Single-chip JAX executor: a `lax.scan` over the ExecPlan.

This module is the device half of the ``scan`` entry in
``repro.backends`` — bind through the registry
(``get_backend("scan").bind(plan)``) unless you need the raw pieces.

Each scan step processes one lock-step row per core (k rows in parallel on
the VPU): gather x at the row's column indices, fused multiply-accumulate,
divide by the diagonal, scatter into x. Same-core sequential chains flow
through the scan carry; superstep barriers are free on one chip (DESIGN.md
§3), so the scan ignores `step_bounds` — they matter for the distributed
executor and the Pallas kernel grid.

Padding protocol (see core.plan): row id n = scratch row, gather index n =
scratch slot, so padded lanes are harmless. `accum` rows carry partial sums
for rows wider than W.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecPlan


class PlanArrays(NamedTuple):
    """Device-resident plan tensors (see ExecPlan for shapes)."""

    row_ids: jax.Array  # int32[T, k]
    col_idx: jax.Array  # int32[T, k, W]
    vals: jax.Array  # f[T, k, W]
    diag: jax.Array  # f[T, k]
    accum: jax.Array  # bool[T, k]
    n: int
    step_bounds: np.ndarray  # host-side; used by distributed executor


def plan_arrays(plan: ExecPlan, dtype=jnp.float32) -> PlanArrays:
    return PlanArrays(
        row_ids=jnp.asarray(plan.row_ids, dtype=jnp.int32),
        col_idx=jnp.asarray(plan.col_idx, dtype=jnp.int32),
        vals=jnp.asarray(plan.vals, dtype=dtype),
        diag=jnp.asarray(plan.diag, dtype=dtype),
        accum=jnp.asarray(plan.accum),
        n=plan.n,
        step_bounds=np.asarray(plan.step_bounds),
    )


def _scan_single(row_ids, col_idx, vals, diag, accum, b_pad, n):
    x0 = jnp.zeros(n + 1, dtype=b_pad.dtype)
    acc0 = jnp.zeros(row_ids.shape[1], dtype=b_pad.dtype)

    def step(carry, inp):
        x, acc = carry
        rows, cols, v, d, a = inp
        partial_sum = jnp.einsum("kw,kw->k", v, x[cols])
        acc = acc + partial_sum
        xv = (b_pad[rows] - acc) / d
        # finishing lanes write x and reset their accumulator
        write = jnp.where(a, x[rows], xv)
        # NOTE: padded lanes share the scratch row id n -> indices are not
        # unique; plain scatter keeps them well-defined (they all write junk
        # to the scratch slot).
        x = x.at[rows].set(write)
        acc = jnp.where(a, acc, 0.0)
        return (x, acc), None

    (x, _), _ = jax.lax.scan(
        step, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


# the single-RHS entry keeps its jitted name; the raw body stays callable
# so the grouped executor can vmap it without nesting jits
_solve_scan = partial(jax.jit, static_argnames=("n",))(_scan_single)


@partial(jax.jit, static_argnames=("n",))
def _solve_scan_grouped(row_ids, col_idx, vals, diag, accum, b_pad, n):
    """Width-class grouped solve: every tensor carries a leading group
    axis g — lane g runs the single-RHS scan on ITS OWN plan tensors
    (``row_ids[g], col_idx[g], ...``) and rhs ``b_pad[g]``. The compiled
    graph depends only on the stacked shapes ``(g, T, k, W, n)``, so one
    XLA variant serves every combination of structurally-identical plans
    (the serve layer's cross-pattern microbatching). Lanes are
    data-independent: vmap batches the same op sequence per lane, so a
    lane's bits never depend on what its neighbors hold (property-tested
    in tests/test_serve_scaleout.py)."""
    return jax.vmap(partial(_scan_single, n=n))(
        row_ids, col_idx, vals, diag, accum, b_pad
    )


def solve_with_plan_group(pas, b_cols: jax.Array) -> jax.Array:
    """Solve lane j of ``b_cols`` f[g, n] (already in plan row order)
    against ``pas[j]`` — one vmapped traversal over the whole group. All
    plans must share the same tensor shapes (one width class); returns
    x f[g, n].

    Stacks the plan tensors per call — fine for replay/verification; the
    serving hot path amortizes the stacking through a ``BankTensors``
    bank + ``_solve_scan_banked`` instead (bitwise-identical output,
    asserted in tests/test_serve_scaleout.py)."""
    dtype = pas[0].vals.dtype
    b = jnp.asarray(b_cols, dtype)
    b_pad = jnp.concatenate([b, jnp.zeros((b.shape[0], 1), dtype)], axis=1)
    stacked = [
        jnp.stack([getattr(pa, f) for pa in pas])
        for f in ("row_ids", "col_idx", "vals", "diag", "accum")
    ]
    return _solve_scan_grouped(*stacked, b_pad, pas[0].n)


class BankTensors(NamedTuple):
    """A width class's plan tensors stacked ONCE on device (lane axis P
    first) plus per-lane row permutations — the serving fast path for
    cross-pattern grouped batches. Dispatches index lanes inside the jit
    (``_solve_scan_banked``), so a microbatch costs one compiled call
    with no per-dispatch stacking; the bank is only restacked when the
    class membership changes (new pattern or plan version)."""

    row_ids: jax.Array  # int32[P, T, k]
    col_idx: jax.Array  # int32[P, T, k, W]
    vals: jax.Array  # f[P, T, k, W]
    diag: jax.Array  # f[P, T, k]
    accum: jax.Array  # bool[P, T, k]
    perm: jax.Array  # int32[P, n]  caller order -> plan row order
    inv: jax.Array  # int32[P, n]  plan row order -> caller order


def stack_plan_bank(pas, perms, invs) -> BankTensors:
    """Stack one width class's plans into a ``BankTensors``. The lane
    axis is padded UP to a power of two (repeating lane 0) so the jitted
    banked solve compiles at most log2 bank-size variants as classes
    grow and shrink with plan-version churn."""
    P = len(pas)
    pad = (1 << max(P - 1, 0).bit_length()) - P if P > 1 else 0
    idx = list(range(P)) + [0] * pad
    return BankTensors(
        *(
            jnp.stack([getattr(pas[i], f) for i in idx])
            for f in ("row_ids", "col_idx", "vals", "diag", "accum")
        ),
        perm=jnp.stack([perms[i] for i in idx]),
        inv=jnp.stack([invs[i] for i in idx]),
    )


@partial(jax.jit, static_argnames=("n",))
def _solve_scan_banked(
    row_ids, col_idx, vals, diag, accum, perm, inv, lane_idx, B, n
):
    """The banked grouped solve: request j reads bank lane
    ``lane_idx[j]`` — plan tensors AND its row permutation — solves, and
    un-permutes, all inside one compiled call. ``B`` is f[n, m] in
    caller row order; returns x f[n, m]. Bitwise-identical to
    ``_solve_scan_grouped`` on the same lanes: the lane gathers and
    permutations move bits unchanged, and the scan body is the same
    vmapped ``_scan_single``."""
    r = row_ids[lane_idx]
    c = col_idx[lane_idx]
    v = vals[lane_idx]
    d = diag[lane_idx]
    a = accum[lane_idx]
    b = jnp.take_along_axis(B.T.astype(v.dtype), perm[lane_idx], axis=1)
    b_pad = jnp.concatenate(
        [b, jnp.zeros((b.shape[0], 1), b.dtype)], axis=1
    )
    x = jax.vmap(partial(_scan_single, n=n))(r, c, v, d, a, b_pad)
    return jnp.take_along_axis(x, inv[lane_idx], axis=1).T


def solve_with_bank(bank: BankTensors, lane_idx, B) -> jax.Array:
    """Solve column j of ``B`` f[n, m] (caller order) against bank lane
    ``lane_idx[j]``; returns x f[n, m] (caller order)."""
    n = int(bank.perm.shape[1])
    return _solve_scan_banked(
        *bank, jnp.asarray(lane_idx, jnp.int32), jnp.asarray(B), n
    )


@partial(jax.jit, static_argnames=("n",))
def _solve_scan_mrhs(row_ids, col_idx, vals, diag, accum, b_pad, n):
    """Batched SpTRSM: ``b_pad`` f[n+1, m], carry ``x`` f[n+1, m]. One plan
    traversal solves all m right-hand sides (the gather/scatter indices are
    shared; only the value lanes widen)."""
    m = b_pad.shape[1]
    x0 = jnp.zeros((n + 1, m), dtype=b_pad.dtype)
    acc0 = jnp.zeros((row_ids.shape[1], m), dtype=b_pad.dtype)

    def step(carry, inp):
        x, acc = carry
        rows, cols, v, d, a = inp
        acc = acc + jnp.einsum("kw,kwm->km", v, x[cols])
        xv = (b_pad[rows] - acc) / d[:, None]
        write = jnp.where(a[:, None], x[rows], xv)
        x = x.at[rows].set(write)
        acc = jnp.where(a[:, None], acc, 0.0)
        return (x, acc), None

    (x, _), _ = jax.lax.scan(
        step, (x0, acc0), (row_ids, col_idx, vals, diag, accum)
    )
    return x[:n]


def solve_with_plan(pa: PlanArrays, b: jax.Array) -> jax.Array:
    """Solve L x = b using the compiled plan. ``b``: f[n] or f[n, m]
    (multi-RHS — solved in one batched traversal)."""
    b = b.astype(pa.vals.dtype)
    pad = jnp.zeros((1, *b.shape[1:]), pa.vals.dtype)
    b_pad = jnp.concatenate([b, pad])
    solver = _solve_scan if b.ndim == 1 else _solve_scan_mrhs
    return solver(pa.row_ids, pa.col_idx, pa.vals, pa.diag, pa.accum, b_pad, pa.n)


def make_solver(plan: ExecPlan, dtype=jnp.float32):
    """Bind a plan; returns ``solve(b) -> x`` (jit-compiled on first call).
    ``b`` may be f[n] or f[n, m] for a batched multi-RHS solve."""
    pa = plan_arrays(plan, dtype=dtype)

    def solve(b):
        return solve_with_plan(pa, jnp.asarray(b, dtype=dtype))

    return solve
