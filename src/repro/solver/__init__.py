"""SpTRSV executors (the 'executor' half of the inspector–executor split).

  * ``reference``   — serial numpy forward/backward substitution (oracle)
  * ``executor``    — jnp scan over an ExecPlan (single-chip view)
  * ``distributed`` — shard_map executor: cores = mesh devices, barrier =
                      all-gather (the BSP model on ICI)
  * ``cg``          — (preconditioned) conjugate gradient driver
"""
from repro.solver.reference import forward_substitution, solve_lower_scipy
from repro.solver.executor import plan_arrays, solve_with_plan, make_solver
from repro.solver.cg import cg_solve, pcg_ichol

__all__ = [
    "forward_substitution",
    "solve_lower_scipy",
    "plan_arrays",
    "solve_with_plan",
    "make_solver",
    "cg_solve",
    "pcg_ichol",
]
