"""Serial reference solvers — the oracle every executor is tested against."""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSRMatrix


def forward_substitution(L: CSRMatrix, b: np.ndarray) -> np.ndarray:
    """Eq. (2.1): x_i = (b_i - sum_{j<i} A_ij x_j) / A_ii. Serial CSR sweep —
    the 'Serial' baseline of the paper's tables."""
    n = L.n_rows
    x = np.zeros(n, dtype=np.float64)
    indptr, indices, data = L.indptr, L.indices, L.data
    for i in range(n):
        acc = 0.0
        diag = None
        for t in range(int(indptr[i]), int(indptr[i + 1])):
            j = int(indices[t])
            if j == i:
                diag = data[t]
            else:
                acc += data[t] * x[j]
        x[i] = (b[i] - acc) / diag
    return x


def solve_lower_scipy(L: CSRMatrix, b: np.ndarray) -> np.ndarray:
    from scipy.sparse.linalg import spsolve_triangular

    return spsolve_triangular(L.to_scipy().tocsr(), b, lower=True)
