"""Row-sharded SpTRSV executor — local supersteps + halo exchange.

Device half of the ``distributed`` backend's ``shard="rows"`` binding
mode (partitioning in ``repro.core.rowshard``; bind through
``get_backend("distributed").bind(plan, mesh=mesh, shard="rows")``).

Each ``model``-axis device owns one shard: a contiguous block of
``k_local`` schedule cores and their rows. Its x-buffer is *resident* —
``[owned | halo | scratch]`` local slots — and a solve is the ordinary
scan over the shard's local ``ExecPlan`` (the exact ``_step_single`` /
``_step_mrhs`` bodies from ``solver.executor``, so per-row arithmetic is
bitwise-identical to the single-chip scan), punctuated by one halo
exchange per barrier round. Unlike the model-axis executor
(``solver.distributed``), which ``all_gather``s every core's xv at every
superstep, the exchange moves ONLY the boundary values some other shard
actually reads — static index tensors computed at partition time.

Two lowerings of the same exchange plan:

  * ``mode="ring"`` (default): one ``ppermute`` per occupied hop
    distance per round. Values move bits unchanged — this is the
    bitwise-safe path the conformance tests pin.
  * ``mode="psum"``: scatter-add into a shared sparse boundary buffer,
    one ``psum`` per round, gather into halo slots. Fewest collectives,
    but ``-0.0 + 0.0 == +0.0`` makes it not bitwise-safe; bench/opt-in.

Because each device simulates its ``k_local`` cores with the full-width
einsum step (not one lane per device), ``shard="rows"`` also lifts the
model-axis mode's ``k <= mesh devices`` restriction — a k=256 schedule
runs on 8 devices as 8 shards of 32 lanes.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.rowshard import RowShardPlan
from repro.solver.executor import _step_mrhs, _step_single


@dataclasses.dataclass(frozen=True)
class RowShardSpec:
    """Static (hashable) description of a row-sharded solve: everything
    the traced graph's structure depends on. Per-round exchange-table
    shapes are static too — they ride in ``rounds_static``
    (``rowshard_round_static``) for cache keys; table *contents* travel
    as operands."""

    n: int
    n_shards: int
    k_local: int
    W: int
    T: int
    n_loc: int
    n_halo: int
    step_bounds: Tuple[int, ...]
    exchange_bounds: Tuple[int, ...]
    rounds_static: Tuple  # see rowshard_round_static
    mode: str = "ring"  # "ring" | "psum"
    batch: int = 0  # 0 = single RHS; else padded multi-RHS width

    @property
    def slots(self) -> int:
        return self.n_loc + self.n_halo + 1

    @property
    def n_rounds(self) -> int:
        return len(self.exchange_bounds) - 1


def rowshard_round_static(rsp: RowShardPlan, mode="ring"):
    """The exchange schedule's static shape: ring -> one
    ``(hop, width)`` pair per occupied hop per round; psum ->
    ``(send_w, recv_w, buf_size)`` per round."""
    if mode == "ring":
        return tuple(
            tuple((int(h), int(ss.shape[1])) for h, ss, _ in r.hops)
            for r in rsp.rounds
        )
    return tuple(
        (int(r.send_slot.shape[1]), int(r.recv_pos.shape[1]), int(r.buf_size))
        for r in rsp.rounds
    )


def rowshard_spec(rsp: RowShardPlan, *, mode="ring", batch=0) -> RowShardSpec:
    if mode not in ("ring", "psum"):
        raise ValueError(f"exchange mode must be 'ring' or 'psum': {mode!r}")
    return RowShardSpec(
        n=rsp.n,
        n_shards=rsp.n_shards,
        k_local=rsp.k_local,
        W=rsp.W,
        T=rsp.T,
        n_loc=rsp.n_loc,
        n_halo=rsp.n_halo,
        step_bounds=tuple(rsp.step_bounds),
        exchange_bounds=tuple(rsp.exchange_bounds),
        rounds_static=rowshard_round_static(rsp, mode),
        mode=mode,
        batch=batch,
    )


def rowshard_plan_args(rsp: RowShardPlan, dtype=jnp.float32):
    """Stack the per-shard plans into device operands
    [n_shards, T, k_local, ...] (sharded over ``model`` by shard_map)."""
    return (
        jnp.asarray(np.stack([s.row_ids for s in rsp.shards]), jnp.int32),
        jnp.asarray(np.stack([s.col_idx for s in rsp.shards]), jnp.int32),
        jnp.asarray(np.stack([s.vals for s in rsp.shards]), dtype),
        jnp.asarray(np.stack([s.diag for s in rsp.shards]), dtype),
        jnp.asarray(np.stack([s.accum for s in rsp.shards])),
    )


def rowshard_halo_args(rsp: RowShardPlan, mode="ring"):
    """The exchange plan as a FLAT tuple of int32[n_shards, H] operands
    (shard_map slices each along ``model``). Ring: per round, per hop,
    ``send_slot`` then ``recv_slot`` — order matches
    ``rowshard_round_static``; psum: per round ``send_slot, send_pos,
    recv_pos, recv_slot``."""
    flat = []
    for r in rsp.rounds:
        if mode == "ring":
            for _, ss, rt in r.hops:
                flat.append(jnp.asarray(ss, jnp.int32))
                flat.append(jnp.asarray(rt, jnp.int32))
        else:
            flat.append(jnp.asarray(r.send_slot, jnp.int32))
            flat.append(jnp.asarray(r.send_pos, jnp.int32))
            flat.append(jnp.asarray(r.recv_pos, jnp.int32))
            flat.append(jnp.asarray(r.recv_slot, jnp.int32))
    return tuple(flat)


PLAN_SPECS = (
    P("model", None, None),  # row_ids [n_shards, T, k_local]
    P("model", None, None, None),  # col_idx
    P("model", None, None, None),  # vals
    P("model", None, None),  # diag
    P("model", None, None),  # accum
)


def _exchange_ring(x, tables, hops_static, n_shards):
    """One ring round on the local x ([slots] or [slots, m]): per hop h,
    every shard i sends its boundary values finalized this round to
    shard (i + h) % n_shards in a single ``ppermute``. Sender/receiver
    tables are positionally aligned by construction (sorted by global
    row id within each src->dst pair; dst = src + h is a bijection per
    hop), so the position IS the routing. Padded positions send the
    scratch slot — provably +0.0 (padding-lane induction, see
    ``solver.executor``) — and land on the receiver's scratch slot:
    ragged per-shard halo counts stay bitwise harmless."""
    for (h, _), (ss, rt) in zip(hops_static, tables):
        perm = [(i, (i + h) % n_shards) for i in range(n_shards)]
        got = jax.lax.ppermute(x[ss[0]], "model", perm=perm)
        x = x.at[rt[0]].set(got)
    return x


def _exchange_psum(x, tables, buf_size):
    """One sparse-psum round: owners scatter-add fresh boundary values
    into a shared [buf_size + 1] buffer (position buf_size is the
    padding trash slot), one ``psum`` reduces it, consumers gather their
    positions into halo slots. Each position is written by exactly one
    owner, so the reduction is value + zeros — numerically exact but NOT
    bitwise-safe when the value is -0.0 (-0.0 + 0.0 == +0.0)."""
    ss, sp, rp, rt = tables
    tail = x.shape[1:]
    buf = jnp.zeros((buf_size + 1, *tail), x.dtype)
    buf = buf.at[sp[0]].add(x[ss[0]])
    # repro: blessed-reduction — value + zeros per position (exactly one
    # owner writes each); numerically exact, -0.0 hazard documented
    # above, and the executor defaults to the bitwise-safe ring form
    buf = jax.lax.psum(buf, "model")
    return x.at[rt[0]].set(buf[rp[0]])


def _group_tables(spec: RowShardSpec, flat):
    """Regroup the flat halo operands by round (inverse of
    ``rowshard_halo_args``), using the static shape schedule."""
    rounds, i = [], 0
    for rs in spec.rounds_static:
        if spec.mode == "ring":
            tabs = tuple(
                (flat[i + 2 * j], flat[i + 2 * j + 1])
                for j in range(len(rs))
            )
            i += 2 * len(rs)
        else:
            tabs = tuple(flat[i: i + 4])
            i += 4
        rounds.append(tabs)
    return rounds


def _run_round(spec, step, x, acc, rows, cols, vals, diag, accum, b_pad, r):
    """Scan the plan steps of exchange round ``r`` on the carry."""
    sb, eb = spec.step_bounds, spec.exchange_bounds
    lo, hi = sb[eb[r]], sb[eb[r + 1]]
    if hi == lo:
        return x, acc

    def scan_step(carry, inp):
        return step(*carry, *inp, b_pad), None

    (x, acc), _ = jax.lax.scan(
        scan_step,
        (x, acc),
        (rows[lo:hi], cols[lo:hi], vals[lo:hi], diag[lo:hi], accum[lo:hi]),
    )
    return x, acc


def build_rowsharded_solver(spec: RowShardSpec, mesh: Mesh):
    """Returns a jittable
    ``solve(rows, cols, vals, diag, accum, *halo, b_loc) -> x_owned``
    shard-mapped over (model: shards, data: RHS batch).

    ``b_loc`` is the rhs pre-scattered into local slots
    (``RowShardPlan.b_scatter``): f[n_shards, slots] single-RHS or
    f[n_shards, slots, batch] multi-RHS (batch sharded over ``data``).
    Returns the stacked owned regions f[n_shards, n_loc(, batch)] —
    recover global order with ``RowShardPlan.x_gather``."""
    mrhs = spec.batch > 0
    n_halo_args = sum(
        (2 * len(rs) if spec.mode == "ring" else 4)
        for rs in spec.rounds_static
    )
    halo_specs = (P("model", None),) * n_halo_args
    b_spec = P("model", None, "data") if mrhs else P("model", None)
    out_spec = P("model", None, "data") if mrhs else P("model", None)

    def body(rows, cols, vals, diag, accum, *rest):
        halo = _group_tables(spec, rest[:-1])
        # strip the size-1 shard axis shard_map leaves on every operand
        rows, cols, vals = rows[0], cols[0], vals[0]
        diag, accum, b_pad = diag[0], accum[0], rest[-1][0]
        step = _step_mrhs if mrhs else _step_single
        if mrhs:
            m = b_pad.shape[1]
            x = jnp.zeros((spec.slots, m), b_pad.dtype)
            acc = jnp.zeros((spec.k_local, m), b_pad.dtype)
        else:
            x = jnp.zeros(spec.slots, b_pad.dtype)
            acc = jnp.zeros(spec.k_local, b_pad.dtype)
        for r in range(spec.n_rounds):
            x, acc = _run_round(
                spec, step, x, acc, rows, cols, vals, diag, accum, b_pad, r
            )
            if r < spec.n_rounds - 1:
                if spec.mode == "ring":
                    x = _exchange_ring(
                        x, halo[r], spec.rounds_static[r], spec.n_shards
                    )
                else:
                    x = _exchange_psum(x, halo[r], spec.rounds_static[r][2])
        return x[: spec.n_loc][None]

    return shard_map(
        body,
        mesh=mesh,
        in_specs=PLAN_SPECS + halo_specs + (b_spec,),
        out_specs=out_spec,
        check_rep=False,
    )


def build_rowsharded_round(spec: RowShardSpec, mesh: Mesh, r: int):
    """One exchange round as its own shard-mapped call, for the timed
    path: ``round(rows, ..., *round_halo, b_loc, x_global) -> x_global``
    where ``x_global`` f[n_shards, slots(, batch)] carries the resident
    shards between calls. The per-round accumulator starts at zero —
    valid because virtual-row chains never span a superstep boundary
    (the plan's accumulator is provably zero at every barrier), so the
    segmented replay emits the same op sequence as the fused graph."""
    mrhs = spec.batch > 0
    rs = spec.rounds_static[r] if r < len(spec.rounds_static) else ()
    do_exchange = r < spec.n_rounds - 1
    n_halo_args = (2 * len(rs) if spec.mode == "ring" else 4) if do_exchange else 0
    halo_specs = (P("model", None),) * n_halo_args
    xb_spec = P("model", None, "data") if mrhs else P("model", None)

    def body(rows, cols, vals, diag, accum, *rest):
        halo = rest[:n_halo_args]
        b_pad, x = rest[-2][0], rest[-1][0]
        rows, cols, vals = rows[0], cols[0], vals[0]
        diag, accum = diag[0], accum[0]
        step = _step_mrhs if mrhs else _step_single
        if mrhs:
            acc = jnp.zeros((spec.k_local, b_pad.shape[1]), b_pad.dtype)
        else:
            acc = jnp.zeros(spec.k_local, b_pad.dtype)
        x, acc = _run_round(
            spec, step, x, acc, rows, cols, vals, diag, accum, b_pad, r
        )
        if do_exchange:
            if spec.mode == "ring":
                tabs = tuple(
                    (halo[2 * j], halo[2 * j + 1]) for j in range(len(rs))
                )
                x = _exchange_ring(x, tabs, rs, spec.n_shards)
            else:
                x = _exchange_psum(x, tuple(halo), rs[2])
        return x[None]

    return shard_map(
        body,
        mesh=mesh,
        in_specs=PLAN_SPECS + halo_specs + (xb_spec, xb_spec),
        out_specs=xb_spec,
        check_rep=False,
    )


def halo_args_for_round(rsp: RowShardPlan, r: int, mode="ring"):
    """The flat halo operands for round ``r`` only (timed path)."""
    hr = rsp.rounds[r]
    if mode == "ring":
        out = []
        for _, ss, rt in hr.hops:
            out.append(jnp.asarray(ss, jnp.int32))
            out.append(jnp.asarray(rt, jnp.int32))
        return tuple(out)
    return (
        jnp.asarray(hr.send_slot, jnp.int32),
        jnp.asarray(hr.send_pos, jnp.int32),
        jnp.asarray(hr.recv_pos, jnp.int32),
        jnp.asarray(hr.recv_slot, jnp.int32),
    )


def lower_rowsharded_solve(
    rsp: RowShardPlan, mesh: Mesh, *, batch=0, dtype=np.float32, mode="ring"
):
    """.lower() the sharded solve on the given mesh (dry-run path): real
    partition tensors, jit + shard_map, no execution."""
    spec = rowshard_spec(rsp, mode=mode, batch=batch)
    solve = build_rowsharded_solver(spec, mesh)
    args = rowshard_plan_args(rsp, dtype=jnp.dtype(np.dtype(dtype).name))
    halo = rowshard_halo_args(rsp, mode)
    shape = (
        (rsp.n_shards, spec.slots)
        if batch == 0
        else (rsp.n_shards, spec.slots, batch)
    )
    b_loc = jnp.zeros(shape, np.dtype(dtype))
    with mesh:
        return jax.jit(solve).lower(*args, *halo, b_loc)
