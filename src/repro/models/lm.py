"""The composable LM: one ModelConfig covers all ten assigned architectures.

Families (DESIGN.md §5):
  dense   — decoder-only transformer, GQA (+ optional SWA window, qk-norm)
  moe     — dense attention + MoE FFN (mixtral, deepseek-moe)
  rwkv6   — attention-free time-mix/channel-mix stack
  hybrid  — RecurrentGemma: (rec, rec, local-attn) superblocks + MLP
  encdec  — seamless: bidirectional encoder + causal decoder w/ cross-attn

All stacks scan over layers (compile time O(1) in depth), remat inside the
scan for training, and carry stacked per-layer decode state. The modality
frontends ([audio]/[vlm]) are stubs by assignment: ``input_specs`` provides
precomputed frame/patch embeddings that are concatenated ahead of the token
embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (
    AttnConfig,
    attn_param_specs,
    decode_attention,
    init_kv_cache,
    multi_head_attention,
)
from repro.models.common import (
    ParamSpec,
    cross_entropy_loss,
    rms_norm,
    swiglu,
)
from repro.models.moe import MoEConfig, moe_ffn, moe_param_specs
from repro.models.rglru import RGLRUConfig, rglru_block, rglru_param_specs
from repro.models.rwkv6 import (
    RWKVConfig,
    channel_mix,
    rwkv_param_specs,
    time_mix,
)
from repro.distributed.sharding_ctx import constrain

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv6 | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    window: Optional[int] = None  # sliding-window attention (mixtral)
    local_window: Optional[int] = None  # hybrid local attention window
    moe: Optional[MoEConfig] = None
    n_dec_layers: Optional[int] = None  # encdec decoder depth
    frontend: Optional[str] = None  # None | "audio" | "vision" (stub)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    remat: bool = True
    vocab_pad_to: int = 2048
    d_rnn: Optional[int] = None  # hybrid recurrent width
    attn_kv_chunk: int = 1024
    wkv_chunk: Optional[int] = None  # chunked-WKV block (rwkv6 §Perf lever)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def sub_quadratic(self) -> bool:
        """Can this config decode at 500k context? (constant/windowed state)"""
        if self.family in ("rwkv6", "hybrid"):
            return True
        return self.window is not None

    def attn_cfg(self, *, causal=True, window=None) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.hd,
            qk_norm=self.qk_norm,
            window=window if window is not None else self.window,
            causal=causal,
            rope_theta=self.rope_theta,
        )

    def rwkv_cfg(self) -> RWKVConfig:
        return RWKVConfig(
            d_model=self.d_model, n_heads=self.n_heads, d_ff=self.d_ff
        )

    def rglru_cfg(self) -> RGLRUConfig:
        return RGLRUConfig(d_model=self.d_model, d_rnn=self.d_rnn or self.d_model)

    # ----- parameter count (for 6·N·D roofline bookkeeping) ---------------
    def param_count(self, params: Pytree) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
def _mlp_specs(cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((D, F), ("fsdp", "tp")),
        "w_up": ParamSpec((D, F), ("fsdp", "tp")),
        "w_down": ParamSpec((F, D), ("tp", "fsdp")),
    }


def _norm(cfg: ModelConfig):
    return ParamSpec((cfg.d_model,), (None,), init="ones")


def _dense_layer_specs(cfg: ModelConfig):
    return {
        "attn": attn_param_specs(cfg.attn_cfg()),
        "mlp": _mlp_specs(cfg),
        "ln1": _norm(cfg),
        "ln2": _norm(cfg),
    }


def _moe_layer_specs(cfg: ModelConfig):
    return {
        "attn": attn_param_specs(cfg.attn_cfg()),
        "moe": moe_param_specs(cfg.moe),
        "ln1": _norm(cfg),
        "ln2": _norm(cfg),
    }


def _rwkv_layer_specs(cfg: ModelConfig):
    specs = rwkv_param_specs(cfg.rwkv_cfg())
    specs["ln1"] = _norm(cfg)
    specs["ln2"] = _norm(cfg)
    return specs


def _hybrid_superblock_specs(cfg: ModelConfig):
    """One (rec, rec, local-attn) superblock, each with its own MLP."""
    local = cfg.attn_cfg(window=cfg.local_window)
    blk = lambda temporal: {  # noqa: E731
        "temporal": temporal,
        "mlp": _mlp_specs(cfg),
        "ln1": _norm(cfg),
        "ln2": _norm(cfg),
    }
    return {
        "rec1": blk(rglru_param_specs(cfg.rglru_cfg())),
        "rec2": blk(rglru_param_specs(cfg.rglru_cfg())),
        "attn": blk(attn_param_specs(local)),
    }


def _stack(specs: Pytree, n: int) -> Pytree:
    """Prepend a scanned layer dimension to every ParamSpec."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n, *s.shape), (None, *s.logical), s.init, s.scale),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_specs(cfg: ModelConfig) -> Pytree:
    V, D = cfg.padded_vocab, cfg.d_model
    specs: Dict[str, Any] = {
        "embed": ParamSpec((V, D), ("tp", "fsdp"), init="embed", scale=1.0),
        "unembed": ParamSpec((D, V), ("fsdp", "tp")),
        "out_norm": _norm(cfg),
    }
    if cfg.family == "dense":
        specs["layers"] = _stack(_dense_layer_specs(cfg), cfg.n_layers)
    elif cfg.family == "moe":
        specs["layers"] = _stack(_moe_layer_specs(cfg), cfg.n_layers)
    elif cfg.family == "rwkv6":
        specs["layers"] = _stack(_rwkv_layer_specs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        n_super, n_tail = divmod(cfg.n_layers, 3)
        specs["superblocks"] = _stack(_hybrid_superblock_specs(cfg), n_super)
        if n_tail:
            # tail layers are recurrent blocks (Griffin starts each triple
            # with recurrence; 26 = 8*3 + 2 leaves two rec blocks)
            tail_blk = _hybrid_superblock_specs(cfg)["rec1"]
            specs["tail"] = _stack(tail_blk, n_tail)
    elif cfg.family == "encdec":
        enc_layer = {
            "attn": attn_param_specs(cfg.attn_cfg(causal=False)),
            "mlp": _mlp_specs(cfg),
            "ln1": _norm(cfg),
            "ln2": _norm(cfg),
        }
        dec_layer = {
            "self_attn": attn_param_specs(cfg.attn_cfg()),
            "cross_attn": attn_param_specs(cfg.attn_cfg(causal=False)),
            "mlp": _mlp_specs(cfg),
            "ln1": _norm(cfg),
            "ln2": _norm(cfg),
            "ln3": _norm(cfg),
        }
        specs["enc_layers"] = _stack(enc_layer, cfg.n_layers)
        specs["dec_layers"] = _stack(dec_layer, cfg.n_dec_layers or cfg.n_layers)
        specs["enc_norm"] = _norm(cfg)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    if cfg.frontend:
        # stub projection for precomputed frame/patch embeddings
        specs["frontend_proj"] = ParamSpec((D, D), ("fsdp", "tp"))
    return specs


# ---------------------------------------------------------------------------
# forward passes (training / prefill)
# ---------------------------------------------------------------------------
def _rope_tables(cfg: ModelConfig, positions: jax.Array):
    half = cfg.hd // 2
    inv = 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    freqs = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(freqs), jnp.sin(freqs)


def _dense_block(cfg: ModelConfig, params, h, cos, sin, is_moe: bool):
    a = multi_head_attention(
        params["attn"],
        cfg.attn_cfg(),
        rms_norm(h, params["ln1"], cfg.norm_eps),
        rope_cos=cos,
        rope_sin=sin,
        kv_chunk=cfg.attn_kv_chunk,
    )
    h = h + a
    ff_in = rms_norm(h, params["ln2"], cfg.norm_eps)
    if is_moe:
        ff, aux = moe_ffn(params["moe"], cfg.moe, ff_in)
    else:
        ff = swiglu(ff_in, params["mlp"]["w_gate"], params["mlp"]["w_up"],
                    params["mlp"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    return h + ff, aux


def _rwkv_block(cfg: ModelConfig, params, h):
    y, _ = time_mix(
        params["time_mix"], cfg.rwkv_cfg(),
        rms_norm(h, params["ln1"], cfg.norm_eps), chunk=cfg.wkv_chunk,
    )
    h = h + y
    y, _ = channel_mix(
        params["channel_mix"], cfg.rwkv_cfg(), rms_norm(h, params["ln2"], cfg.norm_eps)
    )
    return h + y


def _hybrid_block(cfg: ModelConfig, params, h, cos, sin, kind: str):
    x = rms_norm(h, params["ln1"], cfg.norm_eps)
    if kind == "rec":
        y, _ = rglru_block(params["temporal"], cfg.rglru_cfg(), x)
    else:
        y = multi_head_attention(
            params["temporal"],
            cfg.attn_cfg(window=cfg.local_window),
            x,
            rope_cos=cos,
            rope_sin=sin,
            kv_chunk=cfg.attn_kv_chunk,
        )
    h = h + y
    ff_in = rms_norm(h, params["ln2"], cfg.norm_eps)
    ff = swiglu(ff_in, params["mlp"]["w_gate"], params["mlp"]["w_up"],
                params["mlp"]["w_down"])
    return h + ff


@jax.custom_vjp
def _grad_safe_barrier(h):
    return jax.lax.optimization_barrier(h)


def _grad_safe_barrier_fwd(h):
    return jax.lax.optimization_barrier(h), None


def _grad_safe_barrier_bwd(_, g):
    return (g,)


_grad_safe_barrier.defvjp(_grad_safe_barrier_fwd, _grad_safe_barrier_bwd)


def _maybe_remat(f, cfg: ModelConfig, train: bool):
    if train and cfg.remat:
        def barriered(h, lp):
            # Pin the carry slice to the loop iteration: without this
            # barrier XLA rewrites slice(convert(saved_stack)) as
            # convert(slice(...)) and hoists the bf16->f32 convert of the
            # WHOLE saved residual stack out of the backward loop,
            # materializing an [L, B, S, D] f32 copy of every layer input
            # at once (2x the remat budget). The barrier must sit INSIDE
            # the rematted region so the recompute path starts from it —
            # found via the §Perf granite/mistral train iterations.
            # optimization_barrier has no differentiation rule, so it is
            # wrapped in a custom_vjp that barriers the primal and passes
            # cotangents straight through.
            h = _grad_safe_barrier(h)
            return f(h, lp)

        return jax.checkpoint(
            barriered, policy=jax.checkpoint_policies.nothing_saveable
        )
    return f


def _embed_tokens(cfg, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    return h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)


def _embed_inputs(cfg: ModelConfig, params, batch):
    """Token embeddings, with frontend embeddings prepended when present."""
    h = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.frontend and "frontend_embeds" in batch:
        fe = jnp.einsum(
            "bpd,de->bpe", batch["frontend_embeds"].astype(h.dtype),
            params["frontend_proj"],
        )
        h = jnp.concatenate([fe, h], axis=1)
    return constrain(h, "residual")


def forward(cfg: ModelConfig, params, batch, *, train: bool) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits [B,S,Vpad], aux_loss)."""
    if cfg.family == "encdec":
        return _forward_encdec(cfg, params, batch, train=train)
    h = _embed_inputs(cfg, params, batch)
    S = h.shape[1]
    cos, sin = _rope_tables(cfg, jnp.arange(S))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        is_moe = cfg.family == "moe"

        def layer(h, lp):
            h2, aux = _dense_block(cfg, lp, h, cos, sin, is_moe)
            return constrain(h2, "residual"), aux

        h, auxes = jax.lax.scan(_maybe_remat(layer, cfg, train), h, params["layers"])
        aux_total = auxes.sum()
    elif cfg.family == "rwkv6":

        def layer(h, lp):
            return constrain(_rwkv_block(cfg, lp, h), "residual"), jnp.zeros((), jnp.float32)

        h, _ = jax.lax.scan(_maybe_remat(layer, cfg, train), h, params["layers"])
    elif cfg.family == "hybrid":

        def superblock(h, lp):
            h = _hybrid_block(cfg, lp["rec1"], h, cos, sin, "rec")
            h = _hybrid_block(cfg, lp["rec2"], h, cos, sin, "rec")
            h = _hybrid_block(cfg, lp["attn"], h, cos, sin, "attn")
            return constrain(h, "residual"), jnp.zeros((), jnp.float32)

        h, _ = jax.lax.scan(
            _maybe_remat(superblock, cfg, train), h, params["superblocks"]
        )
        if "tail" in params:

            def tail_layer(h, lp):
                return constrain(_hybrid_block(cfg, lp, h, cos, sin, "rec"),
                                 "residual"), None

            h, _ = jax.lax.scan(_maybe_remat(tail_layer, cfg, train), h, params["tail"])
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = constrain(
        jnp.einsum("bsd,dv->bsv", h, params["unembed"]), "logits"
    )
    return logits, aux_total


def _forward_encdec(cfg: ModelConfig, params, batch, *, train: bool):
    # encoder over frontend embeddings (audio frames — stub provides them)
    enc_h = jnp.einsum(
        "bpd,de->bpe",
        batch["frontend_embeds"].astype(params["embed"].dtype),
        params["frontend_proj"],
    )

    enc_cos, enc_sin = _rope_tables(cfg, jnp.arange(enc_h.shape[1]))

    def enc_layer(h, lp):
        a = multi_head_attention(
            lp["attn"], cfg.attn_cfg(causal=False),
            rms_norm(h, lp["ln1"], cfg.norm_eps),
            rope_cos=enc_cos, rope_sin=enc_sin, kv_chunk=cfg.attn_kv_chunk,
        )
        h = h + a
        ff = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps), lp["mlp"]["w_gate"],
                    lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return constrain(h + ff, "residual"), None

    enc_h, _ = jax.lax.scan(
        _maybe_remat(enc_layer, cfg, train), enc_h, params["enc_layers"]
    )
    enc_h = rms_norm(enc_h, params["enc_norm"], cfg.norm_eps)

    h = _embed_tokens(cfg, params, batch["tokens"])
    S = h.shape[1]
    cos, sin = _rope_tables(cfg, jnp.arange(S))

    def dec_layer(h, lp):
        a = multi_head_attention(
            lp["self_attn"], cfg.attn_cfg(),
            rms_norm(h, lp["ln1"], cfg.norm_eps),
            rope_cos=cos, rope_sin=sin, kv_chunk=cfg.attn_kv_chunk,
        )
        h = h + a
        c = multi_head_attention(
            lp["cross_attn"], cfg.attn_cfg(causal=False),
            rms_norm(h, lp["ln2"], cfg.norm_eps),
            kv_source=enc_h, kv_chunk=cfg.attn_kv_chunk,
        )
        h = h + c
        ff = swiglu(rms_norm(h, lp["ln3"], cfg.norm_eps), lp["mlp"]["w_gate"],
                    lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return constrain(h + ff, "residual"), None

    h, _ = jax.lax.scan(
        _maybe_remat(dec_layer, cfg, train), h, params["dec_layers"]
    )
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = constrain(
        jnp.einsum("bsd,dv->bsv", h, params["unembed"]), "logits"
    )
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params, batch, *, train: bool = True):
    logits, aux = forward(cfg, params, batch, train=train)
    labels = batch["labels"]
    # frontend positions carry no labels — only score the token tail
    S_lab = labels.shape[1]
    logits = logits[:, -S_lab:]
    # mask out vocab padding columns
    V = cfg.vocab_size
    if cfg.padded_vocab != V:
        pad_mask = jnp.arange(cfg.padded_vocab) >= V
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    ce = cross_entropy_loss(logits, labels)
    return ce + aux, {"ce": ce, "aux": aux}
