"""Mixture-of-Experts FFN: shared + routed experts, top-k routing with
GShard-style grouped capacity dispatch (the TPU-native MoE: dispatch and
combine are einsums that shard cleanly and turn into all-to-alls under SPMD).

Tokens are grouped by sequence (group g = one sequence), each group has a
local expert capacity C = ceil(cap_factor * S * k / E); overflowing
assignments are dropped (standard Switch/GShard behaviour). The dispatch
tensor is [G, S, E, C] in bf16, sharded over batch (g) and experts (e), so
its per-device footprint stays modest; with remat it is transient.

Expert sharding modes (config ``moe_shard``):
  * "ep" — experts sharded over the 'tp' mesh axis (deepseek-moe: 64 experts
    over 16 devices). Dispatch/combine einsums become all-to-alls.
  * "tp" — every expert's hidden dim sharded over 'tp' (mixtral: 8 experts
    cannot split over 16 devices; shard F=14336 instead).

Covers both assigned MoE archs:
  * mixtral-8x7b       — 8 experts, top-2, no shared experts, mode "tp"
  * deepseek-moe-16b   — 64 routed top-6 + 2 shared experts, mode "ep"
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int  # FFN hidden dim of each routed expert
    n_experts: int
    top_k: int
    n_shared: int = 0  # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    shard_mode: str = "ep"  # "ep" | "tp"


def moe_param_specs(cfg: MoEConfig):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_expert
    if cfg.shard_mode == "ep":
        e_axes = ("tp", "fsdp", None)
        e_axes_out = ("tp", None, "fsdp")
    else:  # tp-inside-expert
        e_axes = (None, "fsdp", "tp")
        e_axes_out = (None, "tp", "fsdp")
    specs = {
        "router": ParamSpec((D, E), ("fsdp", None), scale=0.1),
        "w_gate": ParamSpec((E, D, F), e_axes),
        "w_up": ParamSpec((E, D, F), e_axes),
        "w_down": ParamSpec((E, F, D), e_axes_out),
    }
    if cfg.n_shared:
        specs["shared_gate"] = ParamSpec((cfg.n_shared, D, F), (None, "fsdp", "tp"))
        specs["shared_up"] = ParamSpec((cfg.n_shared, D, F), (None, "fsdp", "tp"))
        specs["shared_down"] = ParamSpec((cfg.n_shared, F, D), (None, "tp", "fsdp"))
    return specs


def moe_ffn(params, cfg: MoEConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [G, S, D] -> (y [G, S, D], aux_loss). Groups are dispatch-local:
    callers pass [batch, seq, D] for training/prefill and [1, batch, D] for
    decode."""
    G, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(int(cfg.capacity_factor * S * K / E), 4)

    logits = jnp.einsum("gsd,de->gse", x, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # [G, S, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # [G, S, K, E]
    # queue position of each assignment in its expert, choice-major order
    # (all k=0 choices first — Switch prioritization)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * S, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, K, S, E).transpose(0, 2, 1, 3)
    in_cap = (pos < C).astype(jnp.float32) * onehot  # [G, S, K, E]
    slot = jnp.einsum("gske,gske->gsk", pos, onehot).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(slot, C, dtype=jnp.float32)  # [G, S, K, C]

    dispatch = jnp.einsum("gske,gskc->gsec", in_cap, slot_oh).astype(x.dtype)
    combine = jnp.einsum(
        "gsk,gske,gskc->gsec", top_p.astype(jnp.float32), in_cap, slot_oh
    ).astype(x.dtype)

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, x)  # [E, G, C, D]
    he = _swiglu_experts(xe.reshape(E, G * C, D), params).reshape(E, G, C, D)
    y = jnp.einsum("gsec,egcd->gsd", combine, he)

    if cfg.n_shared:
        for i in range(cfg.n_shared):
            y = y + swiglu(
                x,
                params["shared_gate"][i],
                params["shared_up"][i],
                params["shared_down"][i],
            )

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    f_e = jnp.mean(onehot[..., 0, :], axis=(0, 1))  # top-1 routing fraction
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(f_e * p_e)
    return y, aux.astype(jnp.float32)


def _swiglu_experts(xe: jax.Array, params) -> jax.Array:
    """Per-expert SwiGLU: xe [E, T, D] with stacked weights [E, D, F]."""
    g = jax.nn.silu(jnp.einsum("etd,edf->etf", xe, params["w_gate"]))
    u = jnp.einsum("etd,edf->etf", xe, params["w_up"])
    return jnp.einsum("etf,efd->etd", g * u, params["w_down"])
