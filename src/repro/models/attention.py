"""Attention: GQA / MHA, sliding-window, local, qk-norm, cross-attention,
and the KV-cache decode path.

Layout conventions: activations [B, S, D]; per-head tensors [B, S, H, Dh];
caches [B, S_max, Hkv, Dh]. Heads are the TP axis; the batch is the DP axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, apply_rope, rms_norm

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    window: Optional[int] = None  # sliding-window size (None = full)
    causal: bool = True
    rope: bool = True
    rope_theta: float = 10000.0


def attn_param_specs(cfg: AttnConfig):
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((D, H, Dh), ("fsdp", "tp", None)),
        "wk": ParamSpec((D, Hkv, Dh), ("fsdp", "tp", None)),
        "wv": ParamSpec((D, Hkv, Dh), ("fsdp", "tp", None)),
        "wo": ParamSpec((H, Dh, D), ("tp", None, "fsdp")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((Dh,), (None,), init="ones")
        specs["k_norm"] = ParamSpec((Dh,), (None,), init="ones")
    return specs


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B, S, Hkv, Dh] -> [B, S, H, Dh] by repeating each kv head."""
    hkv = k.shape[-2]
    if hkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // hkv, axis=-2)


def _causal_window_mask(
    q_len: int, kv_len: int, window: Optional[int], causal: bool, q_offset: int = 0
):
    """bool[q_len, kv_len]: True = attendable."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def _chunked_attention(
    q: jax.Array,  # [B, S, H, Dh]
    k: jax.Array,  # [B, T, H, Dh]
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    kv_chunk: int,
) -> jax.Array:
    """Flash-style attention: scan over KV chunks with running max/sum in
    f32. Memory O(B*H*S*kv_chunk) instead of O(B*H*S*T) — this is what makes
    the 32k-prefill cells feasible (DESIGN.md; §Perf memory-term lever)."""
    B, S, H, Dh = q.shape
    T = k.shape[1]
    assert T % kv_chunk == 0, "pad kv length to a multiple of kv_chunk"
    n_chunks = T // kv_chunk
    scale = Dh ** -0.5
    q32 = (q * scale).astype(jnp.float32)

    kc = k.reshape(B, n_chunks, kv_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)[:, None]

    def step(carry, inp):
        m, l, acc = carry  # [B,H,S], [B,H,S], [B,S,H,Dh]
        kb, vb, idx = inp  # [B,C,H,Dh], [B,C,H,Dh], scalar chunk index
        logits = jnp.einsum("bshk,bthk->bhst", q32, kb.astype(jnp.float32))
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = jnp.ones((S, kv_chunk), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        correction = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * correction + p.sum(axis=-1)
        acc = acc * correction.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhst,bthk->bshk", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    acc0 = jnp.zeros((B, S, H, Dh), jnp.float32)
    # remat the chunk step: the flash backward recomputes the [B,H,S,C]
    # probabilities per chunk instead of stacking them across chunks
    # (without this, scan-of-bwd saves n_chunks x B*H*S*C floats).
    step_r = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(
        step_r, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def multi_head_attention(
    params,
    cfg: AttnConfig,
    x: jax.Array,
    *,
    rope_cos: Optional[jax.Array] = None,
    rope_sin: Optional[jax.Array] = None,
    kv_source: Optional[jax.Array] = None,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Full-sequence attention (training / prefill), flash-style chunked.
    ``kv_source`` switches to cross-attention (encoder outputs)."""
    B, S, _ = x.shape
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", src, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", src, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.rope and kv_source is None:
        q = apply_rope(q, rope_cos, rope_sin)
        k = apply_rope(k, rope_cos, rope_sin)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    causal = cfg.causal and kv_source is None
    window = cfg.window if kv_source is None else None
    chunk = min(kv_chunk, src.shape[1])
    out = _chunked_attention(
        q, k, v, causal=causal, window=window, kv_chunk=chunk
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(
    params,
    cfg: AttnConfig,
    x: jax.Array,  # [B, 1, D]
    cache,  # {"k","v": [B, S_max, Hkv, Dh]}
    pos: jax.Array,  # scalar int32 — current position
    *,
    rope_cos: Optional[jax.Array] = None,  # [1, Dh/2] at pos
    rope_sin: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """One-token decode with cache update. For sliding-window configs the
    cache is a ring buffer of size window (cache length == window)."""
    B = x.shape[0]
    S_max = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.rope:
        q = apply_rope(q, rope_cos, rope_sin)
        k = apply_rope(k, rope_cos, rope_sin)
    slot = pos % S_max if cfg.window is not None else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    # Grouped GQA einsums: q reshaped to [B,1,Hkv,G,Dh] contracts against
    # the cache directly — never materialize the H-expanded KV. (Expanding
    # repeats the kv-head dim 8->32, which breaks the cache's sharded
    # layout and forced a full-cache all-gather per layer: the dominant
    # collective of the decode cells before this change — EXPERIMENTS.md
    # §Perf, granite decode iteration.)
    Hkv = cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    qg = q.reshape(B, 1, Hkv, G, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bshgk,bthk->bhgst", qg, ck) * scale  # [B,Hkv,G,1,S]
    t_pos = jnp.arange(S_max)
    if cfg.window is not None:
        # ring buffer of size == window: before wrap-around only slots
        # <= pos hold tokens; after wrap-around every slot is live.
        valid = (t_pos <= pos) | (pos >= S_max)
    else:
        valid = t_pos <= pos
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgst,bthk->bshgk", probs, cv)
    y = jnp.einsum(
        "bshk,hkd->bsd", out.reshape(B, 1, cfg.n_heads, cfg.head_dim),
        params["wo"],
    )
    return y, {"k": ck, "v": cv}
