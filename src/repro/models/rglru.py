"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t)                 (recurrence gate)
    i_t = sigmoid(W_x x_t)                 (input gate)
    a_t = a^(c * r_t)      a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in Griffin's recurrent block: two linear branches (width d_rnn),
one gated by GeLU, the other passed through a short conv1d (width 4) and the
RG-LRU; merged multiplicatively and projected out. Diagonal recurrence =>
O(S) time scan and O(d_rnn) state — the ``long_500k`` cell runs on this.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec

_C = 8.0
_LOG_A_INIT = -8.0  # softplus-param of Lambda; a ~ sigmoid(8) ~ 0.9997


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int  # recurrent width (RecurrentGemma: lru_width = d_model)
    conv_width: int = 4


def rglru_param_specs(cfg: RGLRUConfig):
    D, R = cfg.d_model, cfg.d_rnn
    return {
        "w_in_gate": ParamSpec((D, R), ("fsdp", "tp")),
        "w_in_rnn": ParamSpec((D, R), ("fsdp", "tp")),
        "conv_w": ParamSpec((cfg.conv_width, R), (None, "tp"), scale=0.5),
        "conv_b": ParamSpec((R,), ("tp",), init="zeros"),
        "gate_a": ParamSpec((R, R), ("tp", None), scale=0.5),
        "gate_x": ParamSpec((R, R), ("tp", None), scale=0.5),
        "lambda_p": ParamSpec((R,), ("tp",), init="ones", scale=1.0),
        "w_out": ParamSpec((R, D), ("tp", "fsdp")),
    }


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array | None):
    """Causal depthwise conv, width K. x [B,S,R]; prev [B,K-1,R] carries
    decode state."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)  # [B, S+K-1, R]
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return out + b, xp[:, -(K - 1) :]


def rglru_block(params, cfg: RGLRUConfig, x, *, conv_prev=None, h_prev=None):
    """x: [B, S, D] -> (y, (conv_state, h_state))."""
    B, S, _ = x.shape
    gate_branch = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, params["w_in_gate"]))
    rnn_in = jnp.einsum("bsd,dr->bsr", x, params["w_in_rnn"])
    rnn_in, conv_state = _conv1d(rnn_in, params["conv_w"], params["conv_b"], conv_prev)

    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", rnn_in, params["gate_a"]))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", rnn_in, params["gate_x"]))
    log_a = -_C * r * jax.nn.softplus(_LOG_A_INIT * params["lambda_p"]).astype(
        r.dtype
    )
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = (i * rnn_in).astype(jnp.float32)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))

    if h_prev is None:
        h_prev = jnp.zeros((B, cfg.d_rnn), jnp.float32)

    def step(h, inp):
        a_t, u_t = inp
        h = a_t * h + u_t
        return h, h

    seq_first = lambda t: jnp.moveaxis(t, 1, 0)  # noqa: E731
    h_last, hs = jax.lax.scan(step, h_prev, (seq_first(a), seq_first(mult * gated)))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # [B, S, R]
    y = jnp.einsum("bsr,rd->bsd", hs * gate_branch, params["w_out"])
    return y, (conv_state, h_last)
