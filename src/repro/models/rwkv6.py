"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay
[arXiv:2404.05892], plus the squared-ReLU channel-mix.

Per head (head_dim Dh), the wkv recurrence over time t:

    S_t   = diag(w_t) S_{t-1} + k_t v_t^T          (state: [Dh, Dh])
    o_t   = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

with r, k, v, g projections of token-shift mixes of x, and the decay
w_t = exp(-exp(dd_t)) *data-dependent* via a low-rank MLP on the shifted
input (the Finch novelty vs RWKV5's static decay). Output gated by silu(g)
and group-normed per head.

Training uses a time scan (linear in S — this is what makes the 500k-token
cell feasible, DESIGN.md §5); decode carries (shift, S) state per layer.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    n_heads: int  # head_dim = d_model // n_heads
    d_ff: int
    decay_lora: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def rwkv_param_specs(cfg: RWKVConfig):
    D, F, R = cfg.d_model, cfg.d_ff, cfg.decay_lora
    return {
        "time_mix": {
            # token-shift interpolation factors for r/k/v/g/w
            "mu": ParamSpec((5, D), (None, "fsdp"), init="ones", scale=0.5),
            "wr": ParamSpec((D, D), ("fsdp", "tp")),
            "wk": ParamSpec((D, D), ("fsdp", "tp")),
            "wv": ParamSpec((D, D), ("fsdp", "tp")),
            "wg": ParamSpec((D, D), ("fsdp", "tp")),
            "wo": ParamSpec((D, D), ("tp", "fsdp")),
            # data-dependent decay: low-rank MLP
            "decay_a": ParamSpec((D, R), ("fsdp", None), scale=0.1),
            "decay_b": ParamSpec((R, D), (None, "tp"), scale=0.1),
            "decay_bias": ParamSpec((D,), ("tp",), init="zeros"),
            "bonus_u": ParamSpec((D,), ("tp",), init="zeros"),
            "ln_g": ParamSpec((D,), (None,), init="ones"),
        },
        "channel_mix": {
            "mu": ParamSpec((2, D), (None, "fsdp"), init="ones", scale=0.5),
            "wk": ParamSpec((D, F), ("fsdp", "tp")),
            "wv": ParamSpec((F, D), ("tp", "fsdp")),
            "wr": ParamSpec((D, D), ("fsdp", "tp")),
        },
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None):
    """Shift sequence right by one; ``prev`` [B, 1, D] seeds decode."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def time_mix(params, cfg: RWKVConfig, x, *, shift_prev=None, state=None,
             chunk: int | None = None):
    """x: [B, S, D]. Returns (y, (last_x, new_state)).
    state: [B, H, Dh, Dh] wkv state (decode carries it; training starts 0).

    ``chunk``: block size of the chunked-WKV path (None = the per-token
    scan). Chunking is the §Perf lever for the rwkv train/prefill cells:
    the recurrence's state traffic drops by the chunk factor and the
    per-chunk contractions are MXU matmuls instead of VPU outer products.
    Both paths are numerically cross-checked in tests/test_rwkv_chunked.py."""
    B, S, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    xs = _token_shift(x, shift_prev)
    mu = params["mu"]

    def mix(i):
        return x + (xs - x) * mu[i][None, None, :]

    r = jnp.einsum("bsd,de->bse", mix(0), params["wr"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", mix(1), params["wk"]).reshape(B, S, H, Dh)
    v = jnp.einsum("bsd,de->bse", mix(2), params["wv"]).reshape(B, S, H, Dh)
    g = jnp.einsum("bsd,de->bse", mix(3), params["wg"])
    dd = (
        jnp.einsum("bsd,dr,re->bse", mix(4), params["decay_a"], params["decay_b"])
        + params["decay_bias"]
    )
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32))).reshape(B, S, H, Dh)
    u = params["bonus_u"].reshape(H, Dh)

    if state is None:
        state = jnp.zeros((B, H, Dh, Dh), jnp.float32)

    log_w = -jnp.exp(dd.astype(jnp.float32)).reshape(B, S, H, Dh)
    if chunk and S % chunk == 0 and S > 1:
        state, o = _wkv_chunked(r, k, v, log_w, u, state, chunk)
    else:
        state, o = _wkv_sequential(r, k, v, w, u, state)
    o = o.reshape(B, S, D).astype(x.dtype)
    o = rms_norm(o, params["ln_g"])  # group-norm stand-in (per-channel)
    y = jnp.einsum("bsd,de->bse", o * jax.nn.silu(g), params["wo"])
    return y, (x[:, -1:], state)


def _wkv_sequential(r, k, v, w, u, state):
    """Per-token scan (paper-faithful dataflow). Shapes [B,S,H,Dh]."""

    def step(S_prev, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,Dh] each
        kv = jnp.einsum(
            "bhk,bhv->bhkv", k_t.astype(jnp.float32), v_t.astype(jnp.float32)
        )
        o = jnp.einsum(
            "bhk,bhkv->bhv", r_t.astype(jnp.float32),
            S_prev + u[None, :, :, None] * kv,
        )
        S_new = w_t.astype(jnp.float32)[..., None] * S_prev + kv
        return S_new, o

    seq_first = lambda a: jnp.moveaxis(a, 1, 0)  # noqa: E731
    state, o = jax.lax.scan(
        step, state, (seq_first(r), seq_first(k), seq_first(v), seq_first(w))
    )
    return state, jnp.moveaxis(o, 0, 1)


def _wkv_chunked(r, k, v, log_w, u, state, chunk: int):
    """Chunked WKV — mathematically identical to the scan:

      o_t = (r_t * exp(A_{t-1})) @ S_0
          + sum_{i<t} (r_t . (k_i * exp(A_{t-1} - A_i))) v_i
          + (r_t * u) . k_t * v_t                       (bonus diagonal)
      S_C = exp(A_C) * S_0 + sum_i (k_i * exp(A_C - A_i)) v_i^T

    with A = within-chunk cumulative log-decay (<= 0, per key channel).
    All exponents used are differences A_x - A_i with x >= i, hence <= 0 —
    computed EXACTLY via an explicit per-channel pairwise decay tensor
    [C, C, Dh] (a separable exp(A)·exp(-A) matmul factorization was tried
    first and refuted: clamping exp(-A_i) flushes non-negligible
    nearby-step contributions in strong-decay channels — see the §Perf
    iteration log). State traffic drops by the chunk factor; the state/
    inter-chunk terms are true matmuls.
    """
    B, S, H, Dh = r.shape
    n_chunks = S // chunk
    cf = lambda a: a.astype(jnp.float32).reshape(  # noqa: E731
        B, n_chunks, chunk, H, Dh
    ).transpose(1, 0, 2, 3, 4)  # [N, B, C, H, Dh]
    rc, kc, vc, lw = cf(r), cf(k), cf(v), cf(log_w)
    # strict causal mask over (t, i)
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool), k=-1)

    def chunk_step(S0, inp):
        rb, kb, vb, lwb = inp  # [B, C, H, Dh]
        A = jnp.cumsum(lwb, axis=1)  # inclusive
        A_prev = A - lwb  # exclusive
        # pairwise per-channel decay exp(A_{t-1} - A_i), i < t  (exact)
        diff = A_prev[:, :, None] - A[:, None]  # [B, t, i, H, Dh]
        factor = jnp.where(mask[None, :, :, None, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bthd,bihd,btihd->bhti", rb, kb, factor)
        diag = jnp.einsum("bthd,bthd->bth", rb, kb * u[None, None])
        o = (
            jnp.einsum("bhti,bihd->bthd", scores, vb)
            + diag[..., None] * vb
            + jnp.einsum("bthd,bhdv->bthv", rb * jnp.exp(A_prev), S0)
        )
        A_C = A[:, -1:]  # [B,1,H,Dh]
        k_tail = kb * jnp.exp(A_C - A)  # exponents <= 0: safe
        S_new = (
            jnp.exp(A_C[:, 0])[..., None] * S0
            + jnp.einsum("bihd,bihv->bhdv", k_tail, vb)
        )
        return S_new, o

    state, o = jax.lax.scan(chunk_step, state, (rc, kc, vc, lw))
    # [N, B, C, H, Dh] -> [B, S, H, Dh]
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)
    return state, o


def channel_mix(params, cfg: RWKVConfig, x, *, shift_prev=None):
    xs = _token_shift(x, shift_prev)
    mu = params["mu"]
    xk = x + (xs - x) * mu[0][None, None, :]
    xr = x + (xs - x) * mu[1][None, None, :]
    kk = jnp.einsum("bsd,df->bsf", xk, params["wk"])
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, params["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["wr"]))
    return rr * vv, x[:, -1:]
