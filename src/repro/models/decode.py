"""Prefill + single-token decode for every model family.

Cache layouts (stacked over the scanned layer dimension):
  dense/moe : {"k","v": [L, B, C, Hkv, Dh]}   C = min(max_len, window)
  rwkv6     : {"shift_tm": [L,B,1,D], "wkv": [L,B,H,Dh,Dh], "shift_cm": [L,B,1,D]}
  hybrid    : per-superblock {rec1/rec2: conv [Sb,B,3,R] + h [Sb,B,R],
              attn: ring k/v [Sb,B,W,Hkv,Dh]} (+ tail states)
  encdec    : decoder self k/v [L,B,C,...] + per-layer cross k/v
              [L,B,T_enc,...] precomputed from the encoder output.

Sliding-window caches are ring buffers (slot = pos % window) — constant
memory, which is what lets mixtral / recurrentgemma / rwkv6 run the
``long_500k`` cell (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    AttnConfig,
    apply_rope,
    decode_attention,
    multi_head_attention,
    _expand_kv,
    _chunked_attention,
)
from repro.models.common import rms_norm, swiglu
from repro.models.lm import ModelConfig, _embed_inputs, _embed_tokens, _rope_tables
from repro.models.moe import moe_ffn
from repro.models.rglru import rglru_block
from repro.models.rwkv6 import channel_mix, time_mix

Pytree = Any


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _ring_fill(k: jax.Array, cache_len: int) -> jax.Array:
    """Place the last ``cache_len`` tokens of k [B,S,...] into ring slots
    (slot = absolute_pos % cache_len)."""
    S = k.shape[1]
    if S <= cache_len:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, cache_len - S)
        return jnp.pad(k, pad)
    tail = k[:, -cache_len:]
    slots = (jnp.arange(S - cache_len, S)) % cache_len
    out = jnp.zeros((k.shape[0], cache_len, *k.shape[2:]), k.dtype)
    return out.at[:, slots].set(tail)


def _attn_prefill(
    params, acfg: AttnConfig, x, cos, sin, cache_len: int, kv_chunk: int
):
    """Attention over the full prompt; returns (out, k_cache, v_cache)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if acfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if acfg.rope:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    ke = _expand_kv(k, acfg.n_heads)
    ve = _expand_kv(v, acfg.n_heads)
    out = _chunked_attention(
        q, ke, ve, causal=acfg.causal, window=acfg.window,
        kv_chunk=min(kv_chunk, x.shape[1]),
    )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, _ring_fill(k, cache_len), _ring_fill(v, cache_len)


def _cache_len(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.window) if cfg.window else max_len


# ---------------------------------------------------------------------------
# dense / moe
# ---------------------------------------------------------------------------
def _dense_prefill(cfg: ModelConfig, params, batch, max_len: int):
    h = _embed_inputs(cfg, params, batch)
    B, S, _ = h.shape
    cos, sin = _rope_tables(cfg, jnp.arange(S))
    C = _cache_len(cfg, max_len)
    is_moe = cfg.family == "moe"

    def layer(h, lp):
        a, kc, vc = _attn_prefill(
            lp["attn"], cfg.attn_cfg(), rms_norm(h, lp["ln1"], cfg.norm_eps),
            cos, sin, C, cfg.attn_kv_chunk,
        )
        h = h + a
        ff_in = rms_norm(h, lp["ln2"], cfg.norm_eps)
        if is_moe:
            ff, _ = moe_ffn(lp["moe"], cfg.moe, ff_in)
        else:
            ff = swiglu(ff_in, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                        lp["mlp"]["w_down"])
        return h + ff, {"k": kc, "v": vc}

    h, cache = jax.lax.scan(layer, h, params["layers"])
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["unembed"])
    return logits, cache, S


def _dense_decode(cfg: ModelConfig, params, cache, pos, token):
    h = _embed_tokens(cfg, params, token[:, None])  # [B,1,D]
    cos, sin = _rope_tables(cfg, pos[None])
    is_moe = cfg.family == "moe"

    def layer(h, inp):
        lp, kv = inp
        a, kv2 = decode_attention(
            lp["attn"], cfg.attn_cfg(), rms_norm(h, lp["ln1"], cfg.norm_eps),
            kv, pos, rope_cos=cos, rope_sin=sin,
        )
        h = h + a
        ff_in = rms_norm(h, lp["ln2"], cfg.norm_eps)
        if is_moe:
            # single group of B tokens for decode dispatch
            ff, _ = moe_ffn(lp["moe"], cfg.moe, ff_in.transpose(1, 0, 2))
            ff = ff.transpose(1, 0, 2)
        else:
            ff = swiglu(ff_in, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                        lp["mlp"]["w_down"])
        return h + ff, kv2

    h, cache = jax.lax.scan(layer, h, (params["layers"], cache))
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], params["unembed"])
    return logits, cache


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------
def _rwkv_prefill(cfg: ModelConfig, params, batch, max_len: int):
    del max_len  # constant-size state
    h = _embed_inputs(cfg, params, batch)
    rc = cfg.rwkv_cfg()

    def layer(h, lp):
        y, (sx_tm, wkv) = time_mix(
            lp["time_mix"], rc, rms_norm(h, lp["ln1"], cfg.norm_eps),
            chunk=cfg.wkv_chunk,
        )
        h = h + y
        y, sx_cm = channel_mix(
            lp["channel_mix"], rc, rms_norm(h, lp["ln2"], cfg.norm_eps)
        )
        return h + y, {"shift_tm": sx_tm, "wkv": wkv, "shift_cm": sx_cm}

    h, cache = jax.lax.scan(layer, h, params["layers"])
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["unembed"])
    return logits, cache, h.shape[1]


def _rwkv_decode(cfg: ModelConfig, params, cache, pos, token):
    del pos
    h = _embed_tokens(cfg, params, token[:, None])
    rc = cfg.rwkv_cfg()

    def layer(h, inp):
        lp, st = inp
        y, (sx_tm, wkv) = time_mix(
            lp["time_mix"], rc, rms_norm(h, lp["ln1"], cfg.norm_eps),
            shift_prev=st["shift_tm"], state=st["wkv"],
        )
        h = h + y
        y, sx_cm = channel_mix(
            lp["channel_mix"], rc, rms_norm(h, lp["ln2"], cfg.norm_eps),
            shift_prev=st["shift_cm"],
        )
        return h + y, {"shift_tm": sx_tm, "wkv": wkv, "shift_cm": sx_cm}

    h, cache = jax.lax.scan(layer, h, (params["layers"], cache))
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], params["unembed"])
    return logits, cache


# ---------------------------------------------------------------------------
# hybrid (RecurrentGemma)
# ---------------------------------------------------------------------------
def _hybrid_rec_prefill(cfg, lp, h):
    x = rms_norm(h, lp["ln1"], cfg.norm_eps)
    y, (conv, hstate) = rglru_block(lp["temporal"], cfg.rglru_cfg(), x)
    h = h + y
    ff = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps), lp["mlp"]["w_gate"],
                lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return h + ff, {"conv": conv, "h": hstate}


def _hybrid_attn_prefill(cfg, lp, h, cos, sin):
    W = cfg.local_window
    a, kc, vc = _attn_prefill(
        lp["temporal"], cfg.attn_cfg(window=W),
        rms_norm(h, lp["ln1"], cfg.norm_eps), cos, sin, W, cfg.attn_kv_chunk,
    )
    h = h + a
    ff = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps), lp["mlp"]["w_gate"],
                lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return h + ff, {"k": kc, "v": vc}


def _hybrid_prefill(cfg: ModelConfig, params, batch, max_len: int):
    del max_len
    h = _embed_inputs(cfg, params, batch)
    S = h.shape[1]
    cos, sin = _rope_tables(cfg, jnp.arange(S))

    def superblock(h, lp):
        h, st1 = _hybrid_rec_prefill(cfg, lp["rec1"], h)
        h, st2 = _hybrid_rec_prefill(cfg, lp["rec2"], h)
        h, sta = _hybrid_attn_prefill(cfg, lp["attn"], h, cos, sin)
        return h, {"rec1": st1, "rec2": st2, "attn": sta}

    h, cache = jax.lax.scan(superblock, h, params["superblocks"])
    if "tail" in params:

        def tail_layer(h, lp):
            return _hybrid_rec_prefill(cfg, lp, h)

        h, tail_cache = jax.lax.scan(tail_layer, h, params["tail"])
        cache = {"super": cache, "tail": tail_cache}
    else:
        cache = {"super": cache}
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["unembed"])
    return logits, cache, S


def _hybrid_rec_decode(cfg, lp, h, st):
    x = rms_norm(h, lp["ln1"], cfg.norm_eps)
    y, (conv, hstate) = rglru_block(
        lp["temporal"], cfg.rglru_cfg(), x, conv_prev=st["conv"], h_prev=st["h"]
    )
    h = h + y
    ff = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps), lp["mlp"]["w_gate"],
                lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return h + ff, {"conv": conv, "h": hstate}


def _hybrid_attn_decode(cfg, lp, h, st, pos, cos, sin):
    a, kv = decode_attention(
        lp["temporal"], cfg.attn_cfg(window=cfg.local_window),
        rms_norm(h, lp["ln1"], cfg.norm_eps), st, pos, rope_cos=cos, rope_sin=sin,
    )
    h = h + a
    ff = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps), lp["mlp"]["w_gate"],
                lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return h + ff, kv


def _hybrid_decode(cfg: ModelConfig, params, cache, pos, token):
    h = _embed_tokens(cfg, params, token[:, None])
    cos, sin = _rope_tables(cfg, pos[None])

    def superblock(h, inp):
        lp, st = inp
        h, st1 = _hybrid_rec_decode(cfg, lp["rec1"], h, st["rec1"])
        h, st2 = _hybrid_rec_decode(cfg, lp["rec2"], h, st["rec2"])
        h, sta = _hybrid_attn_decode(cfg, lp["attn"], h, st["attn"], pos, cos, sin)
        return h, {"rec1": st1, "rec2": st2, "attn": sta}

    h, new_super = jax.lax.scan(
        superblock, h, (params["superblocks"], cache["super"])
    )
    new_cache = {"super": new_super}
    if "tail" in params:

        def tail_layer(h, inp):
            lp, st = inp
            return _hybrid_rec_decode(cfg, lp, h, st)

        h, new_tail = jax.lax.scan(tail_layer, h, (params["tail"], cache["tail"]))
        new_cache["tail"] = new_tail
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], params["unembed"])
    return logits, new_cache


# ---------------------------------------------------------------------------
# encdec (seamless)
# ---------------------------------------------------------------------------
def _encode(cfg: ModelConfig, params, frontend_embeds):
    enc_h = jnp.einsum(
        "bpd,de->bpe", frontend_embeds.astype(params["embed"].dtype),
        params["frontend_proj"],
    )

    enc_cos, enc_sin = _rope_tables(cfg, jnp.arange(enc_h.shape[1]))

    def enc_layer(h, lp):
        a = multi_head_attention(
            lp["attn"], cfg.attn_cfg(causal=False),
            rms_norm(h, lp["ln1"], cfg.norm_eps),
            rope_cos=enc_cos, rope_sin=enc_sin, kv_chunk=cfg.attn_kv_chunk,
        )
        h = h + a
        ff = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps), lp["mlp"]["w_gate"],
                    lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return h + ff, None

    enc_h, _ = jax.lax.scan(enc_layer, enc_h, params["enc_layers"])
    return rms_norm(enc_h, params["enc_norm"], cfg.norm_eps)


def _encdec_prefill(cfg: ModelConfig, params, batch, max_len: int):
    enc_h = _encode(cfg, params, batch["frontend_embeds"])
    h = _embed_tokens(cfg, params, batch["tokens"])
    B, S, _ = h.shape
    cos, sin = _rope_tables(cfg, jnp.arange(S))
    C = _cache_len(cfg, max_len)

    def dec_layer(h, lp):
        a, kc, vc = _attn_prefill(
            lp["self_attn"], cfg.attn_cfg(),
            rms_norm(h, lp["ln1"], cfg.norm_eps), cos, sin, C, cfg.attn_kv_chunk,
        )
        h = h + a
        # cross-attention + cache the encoder projections
        xk = jnp.einsum("btd,dhk->bthk", enc_h, lp["cross_attn"]["wk"])
        xv = jnp.einsum("btd,dhk->bthk", enc_h, lp["cross_attn"]["wv"])
        c = multi_head_attention(
            lp["cross_attn"], cfg.attn_cfg(causal=False),
            rms_norm(h, lp["ln2"], cfg.norm_eps),
            kv_source=enc_h, kv_chunk=cfg.attn_kv_chunk,
        )
        h = h + c
        ff = swiglu(rms_norm(h, lp["ln3"], cfg.norm_eps), lp["mlp"]["w_gate"],
                    lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return h + ff, {"k": kc, "v": vc, "xk": xk, "xv": xv}

    h, cache = jax.lax.scan(dec_layer, h, params["dec_layers"])
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["unembed"])
    return logits, cache, S


def _encdec_decode(cfg: ModelConfig, params, cache, pos, token):
    h = _embed_tokens(cfg, params, token[:, None])
    cos, sin = _rope_tables(cfg, pos[None])

    def dec_layer(h, inp):
        lp, st = inp
        a, kv = decode_attention(
            lp["self_attn"], cfg.attn_cfg(),
            rms_norm(h, lp["ln1"], cfg.norm_eps),
            {"k": st["k"], "v": st["v"]}, pos, rope_cos=cos, rope_sin=sin,
        )
        h = h + a
        # cross-attention against the precomputed encoder projections
        acfg = cfg.attn_cfg(causal=False)
        q = jnp.einsum("bsd,dhk->bshk", rms_norm(h, lp["ln2"], cfg.norm_eps),
                       lp["cross_attn"]["wq"])
        kk = _expand_kv(st["xk"], acfg.n_heads)
        vv = _expand_kv(st["xv"], acfg.n_heads)
        logits = jnp.einsum("bshk,bthk->bhst", q, kk) * (acfg.head_dim ** -0.5)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(h.dtype)
        c = jnp.einsum("bhst,bthk->bshk", probs, vv)
        h = h + jnp.einsum("bshk,hkd->bsd", c, lp["cross_attn"]["wo"])
        ff = swiglu(rms_norm(h, lp["ln3"], cfg.norm_eps), lp["mlp"]["w_gate"],
                    lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return h + ff, {"k": kv["k"], "v": kv["v"], "xk": st["xk"], "xv": st["xv"]}

    h, cache = jax.lax.scan(dec_layer, h, (params["dec_layers"], cache))
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], params["unembed"])
    return logits, cache


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
_PREFILL = {
    "dense": _dense_prefill,
    "moe": _dense_prefill,
    "rwkv6": _rwkv_prefill,
    "hybrid": _hybrid_prefill,
    "encdec": _encdec_prefill,
}
_DECODE = {
    "dense": _dense_decode,
    "moe": _dense_decode,
    "rwkv6": _rwkv_decode,
    "hybrid": _hybrid_decode,
    "encdec": _encdec_decode,
}


def prefill(cfg: ModelConfig, params, batch, *, max_len: int):
    """Process the full prompt; returns (last-token logits, cache, pos)."""
    return _PREFILL[cfg.family](cfg, params, batch, max_len)


def decode_step(cfg: ModelConfig, params, cache, pos, token):
    """One token for the whole batch; returns (logits [B,Vpad], new cache)."""
    return _DECODE[cfg.family](cfg, params, cache, pos, token)


def init_decode_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, enc_len: int = 0,
    dtype=jnp.bfloat16,
) -> Pytree:
    """Zero-initialized cache with the right (stacked) structure — used by
    the dry-run to build ShapeDtypeStructs and by serving to warm-start."""
    B, Hkv, Dh = batch, cfg.n_kv_heads, cfg.hd
    C = _cache_len(cfg, max_len)
    L = cfg.n_layers
    if cfg.family in ("dense", "moe"):
        return {
            "k": jnp.zeros((L, B, C, Hkv, Dh), dtype),
            "v": jnp.zeros((L, B, C, Hkv, Dh), dtype),
        }
    if cfg.family == "rwkv6":
        H = cfg.n_heads
        D = cfg.d_model
        return {
            "shift_tm": jnp.zeros((L, B, 1, D), dtype),
            "wkv": jnp.zeros((L, B, H, D // H, D // H), jnp.float32),
            "shift_cm": jnp.zeros((L, B, 1, D), dtype),
        }
    if cfg.family == "hybrid":
        n_super, n_tail = divmod(cfg.n_layers, 3)
        R = cfg.d_rnn or cfg.d_model
        W = cfg.local_window
        rec = lambda n: {  # noqa: E731
            "conv": jnp.zeros((n, B, 3, R), dtype),
            "h": jnp.zeros((n, B, R), jnp.float32),
        }
        cache = {
            "super": {
                "rec1": rec(n_super),
                "rec2": rec(n_super),
                "attn": {
                    "k": jnp.zeros((n_super, B, W, Hkv, Dh), dtype),
                    "v": jnp.zeros((n_super, B, W, Hkv, Dh), dtype),
                },
            }
        }
        if n_tail:
            cache["tail"] = rec(n_tail)
        return cache
    if cfg.family == "encdec":
        Ld = cfg.n_dec_layers or cfg.n_layers
        return {
            "k": jnp.zeros((Ld, B, C, Hkv, Dh), dtype),
            "v": jnp.zeros((Ld, B, C, Hkv, Dh), dtype),
            "xk": jnp.zeros((Ld, B, enc_len, Hkv, Dh), dtype),
            "xv": jnp.zeros((Ld, B, enc_len, Hkv, Dh), dtype),
        }
    raise ValueError(cfg.family)
