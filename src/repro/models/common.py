"""Shared model building blocks: parameter specs, norms, RoPE, MLPs, losses.

No flax — parameters are plain pytrees of jax.Arrays, and every parameter
carries a *logical* PartitionSpec built from the placeholder axis names
  'tp'    -> the tensor-parallel mesh axis ('model')
  'fsdp'  -> the fully-sharded-data-parallel axis ('data')
  'batch' -> the data-parallel activation axes (('pod','data') on the
             multi-pod mesh, ('data',) on a single pod)
which ``repro.distributed.meshes.resolve_spec`` maps to physical axes.
This keeps model code mesh-agnostic (1000-node posture: the same model file
serves any mesh topology).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]  # logical sharding per dim
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical)


def build_param_specs(tree: Pytree) -> Pytree:
    """Identity helper for readability at call sites."""
    return tree


def init_params(specs: Pytree, key: jax.Array, dtype=jnp.bfloat16) -> Pytree:
    """Materialize parameters from a ParamSpec tree."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
            std = spec.scale / np.sqrt(fan_in)
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(specs: Pytree, dtype=jnp.bfloat16) -> Pytree:
    """ShapeDtypeStruct tree — the dry-run path (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def logical_specs(specs: Pytree) -> Pytree:
    """Tree of logical-axis tuples, same structure as the params."""
    return jax.tree_util.tree_map(
        lambda s: s.logical,
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_frequencies(head_dim: int, max_pos: int, theta: float = 10000.0):
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    t = np.arange(max_pos, dtype=np.float32)
    freqs = np.outer(t, inv)  # [max_pos, half]
    return jnp.asarray(np.cos(freqs)), jnp.asarray(np.sin(freqs))


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, H, D]; cos/sin: [S, D/2] (or [1, D/2] for decode).
    Rotation runs in f32 (tables are f32) and casts back to x.dtype."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., :, None, :]  # broadcast over heads
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array):
    return jnp.einsum(
        "...f,fd->...d", jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_up)), w_down
    )


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean token CE in f32. logits [..., V]; labels int[...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
