from repro.models.lm import ModelConfig, forward, loss_fn, param_specs
from repro.models.common import abstract_params, init_params, logical_specs
from repro.models.decode import decode_step, init_decode_cache, prefill

__all__ = [
    "ModelConfig",
    "forward",
    "loss_fn",
    "param_specs",
    "abstract_params",
    "init_params",
    "logical_specs",
    "decode_step",
    "init_decode_cache",
    "prefill",
]
