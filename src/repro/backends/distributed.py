"""Distributed backend — the shard_map BSP executor behind the
``Backend`` protocol (device work in ``repro.solver.distributed``).

The k schedule cores are k devices on the mesh's ``model`` axis; the RHS
batch shards over ``data``. The jitted sharded solve is cached per padded
batch size, and that cache is SHARED across ``update_values`` clones —
the lowered graph is shape-only, so a live refactorization never
recompiles, it only swaps the value operands.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro import obs
from repro.backends.base import (
    Backend,
    BoundSolve,
    expected_entry_count,
    masked_value_gather,
)
from repro.backends.registry import register_backend


class DistributedBoundSolve(BoundSolve):
    backend = "distributed"

    def __init__(self, spec, mesh, args, val_src, diag_src, np_dtype,
                 n_entries, jitted=None, jit_lock=None):
        # args = (row_ids, col_idx, vals, diag, accum_mask) device arrays
        self._spec = spec  # solver.distributed.DistPlanSpec (batch unset)
        self._mesh = mesh
        self._args = args
        self._val_src = val_src
        self._diag_src = diag_src
        self._np_dtype = np_dtype
        # padded-batch -> jitted solve; shape-only, shared across value
        # refreshes so serve version swaps reuse every compiled variant.
        # The lock rides along with it: serve worker threads insert while
        # telemetry threads snapshot (describe()).
        self._jitted = {} if jitted is None else jitted
        self._jit_lock = threading.Lock() if jit_lock is None else jit_lock
        self.n = spec.n
        self.n_entries = n_entries

    def solve(self, b):
        import jax
        import jax.numpy as jnp

        from repro.solver.distributed import build_distributed_solver

        b2 = np.asarray(b)
        single = b2.ndim == 1
        b2 = b2[None, :] if single else np.ascontiguousarray(b2.T)
        B = b2.shape[0]
        # the batch shards over 'data': pad it to a multiple
        data_ax = self._mesh.shape["data"]
        Bp = -(-B // data_ax) * data_ax
        b2 = np.concatenate([b2, np.zeros((Bp - B, b2.shape[1]), b2.dtype)])
        b_pad = np.concatenate([b2, np.zeros((Bp, 1), b2.dtype)], axis=1)
        with self._jit_lock:
            fn = self._jitted.get(Bp)
        if fn is None:
            spec = dataclasses.replace(self._spec, batch=Bp)
            fn = jax.jit(build_distributed_solver(spec, self._mesh))
            with self._jit_lock:
                fn = self._jitted.setdefault(Bp, fn)
        with self._mesh:
            x = fn(*self._args, jnp.asarray(b_pad, self._np_dtype))
        # slice/transpose on device — pulling the sharded result through
        # np.asarray and re-uploading it would round-trip host memory per
        # batch; the caller materializes the returned array exactly once
        # (return type consistent with the scan/pallas backends)
        x = x[:, : self.n]
        return x[0] if single else x[:B].T

    def update_values(self, data: np.ndarray) -> "DistributedBoundSolve":
        import jax.numpy as jnp

        with obs.span(
            "backend.update_values", cat="backend", backend=self.backend
        ):
            data = jnp.asarray(
                self._check_data(data).astype(self._np_dtype)
            )
            row_ids, col_idx, vals, diag, accum = self._args
            vals, diag = masked_value_gather(
                data, self._val_src, vals, self._diag_src, diag
            )
        return DistributedBoundSolve(
            self._spec,
            self._mesh,
            (row_ids, col_idx, vals, diag, accum),
            self._val_src,
            self._diag_src,
            self._np_dtype,
            self.n_entries,
            jitted=self._jitted,  # shapes unchanged -> reuse compilations
            jit_lock=self._jit_lock,
        )

    def describe(self) -> dict:
        with self._jit_lock:  # solve() may be inserting concurrently
            compiled = sorted(self._jitted)
        return {
            "backend": self.backend,
            "n": self.n,
            "n_steps": self._spec.T,
            "k": self._spec.k,
            "W": self._spec.W,
            "n_supersteps": len(self._spec.step_bounds) - 1,
            "dtype": np.dtype(self._np_dtype).name,
            "mesh": dict(self._mesh.shape),
            "compiled_batch_sizes": compiled,
            "device_bytes": int(
                sum(a.size * a.dtype.itemsize
                    for a in self._args + (self._val_src, self._diag_src))
            ),
        }


def _pad_cores(plan, model_ax: int):
    """Pad the plan's core axis UP to the mesh's ``model`` axis size so
    narrower schedules (e.g. serial's k=1 chains) shard cleanly — the
    executor assigns exactly one schedule core per model-axis device, so
    k must end up equal to it. A plan with MORE cores than devices
    cannot be executed (each device's scan walks one chain) and is
    rejected with a clear error instead of failing at trace time.
    Padding lanes follow the plan's own protocol — row id n (scratch),
    self-gathers, val 0 / diag 1, source maps -1 — so they compute
    harmless writes to the scratch slot."""
    k, kp = plan.k, model_ax
    if k > model_ax:
        raise ValueError(
            f"distributed backend: plan has k={k} schedule cores but the "
            f"mesh 'model' axis has only {model_ax} devices — schedule "
            f"with k <= mesh.shape['model'] (one core per device)"
        )
    if kp == k:
        return plan
    T, pad = plan.n_steps, kp - k

    def padk(a, fill):
        block = np.full((T, pad, *a.shape[2:]), fill, dtype=a.dtype)
        return np.concatenate([a, block], axis=1)

    return dataclasses.replace(
        plan,
        k=kp,
        row_ids=padk(plan.row_ids, plan.n),
        col_idx=padk(plan.col_idx, plan.n),
        vals=padk(plan.vals, 0),
        diag=padk(plan.diag, 1),
        accum=padk(plan.accum, False),
        val_src=None if plan.val_src is None else padk(plan.val_src, -1),
        diag_src=None if plan.diag_src is None else padk(plan.diag_src, -1),
    )


@register_backend
class DistributedBackend(Backend):
    """BSP on a device mesh: one all-gather barrier per superstep."""

    name = "distributed"

    def requires(self):
        return ("mesh",)

    def bind(self, exec_plan, *, dtype=np.float32, steps_per_tile=8,
             interpret=None, mesh=None, slack=0) -> DistributedBoundSolve:
        with obs.span(
            "backend.bind",
            cat="backend",
            backend=self.name,
            n=exec_plan.n,
            slack=slack,
        ):
            return self._bind(
                exec_plan, dtype=dtype, mesh=mesh, slack=slack
            )

    def _bind(self, exec_plan, *, dtype, mesh, slack):
        import jax.numpy as jnp

        from repro.solver.distributed import dist_plan_spec

        if slack > 0:
            # the elastic certificate's fused superstep bounds (the
            # cross-device barrier schedule) are computed and reported by
            # ExecPlan.stats(), but this executor still unrolls one
            # all-gather per superstep — refuse rather than silently run
            # bulk-synchronous under an elastic request
            raise ValueError(
                "backend='distributed' does not support mode='elastic' "
                "(no 'elastic' capability); use the scan or pallas backend"
            )
        if mesh is None:
            raise ValueError("backend='distributed' requires a mesh")
        np_dtype = np.dtype(dtype)
        exec_plan = _pad_cores(exec_plan, mesh.shape["model"])
        spec = dist_plan_spec(exec_plan, batch=0, dtype=np_dtype)
        args = (
            jnp.asarray(exec_plan.row_ids, jnp.int32),
            jnp.asarray(exec_plan.col_idx, jnp.int32),
            jnp.asarray(exec_plan.vals, np_dtype),
            jnp.asarray(exec_plan.diag, np_dtype),
            jnp.asarray(exec_plan.accum.astype(np_dtype)),
        )
        assert exec_plan.val_src is not None and exec_plan.diag_src is not None
        return DistributedBoundSolve(
            spec,
            mesh,
            args,
            jnp.asarray(exec_plan.val_src, jnp.int32),
            jnp.asarray(exec_plan.diag_src, jnp.int32),
            np_dtype,
            expected_entry_count(exec_plan),
        )
